"""Pruning-graph invariants + quality ordering on the AOT (JAX) path.

These mirror the Rust test-suite invariants so the two implementations
are held to the same contract; exact cross-validation against Rust
happens in the Rust integration tests through the runtime.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import prune

jax.config.update("jax_platform_name", "cpu")


def setup(c, b, a, seed):
    """Correlated calibration data -> (w, h, xnorm_sq, x)."""
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    w = jax.random.normal(k1, (c, b))
    factors = jax.random.normal(k2, (max(b // 4, 2), a))
    loading = jax.random.normal(k3, (b, max(b // 4, 2)))
    x = loading @ factors + 0.3 * jax.random.normal(k4, (b, a))
    h = 2.0 * (x @ x.T) / a
    xnorm_sq = jnp.sum(jnp.square(x), axis=1)
    return w, h, xnorm_sq, x


def recon_loss(w_new, w, x):
    d = (w_new - w) @ x
    return float(jnp.sum(jnp.square(d)))


def sparsity(w):
    return float(jnp.mean((w == 0.0).astype(jnp.float32)))


def test_magnitude_exact_count():
    w, _, _, _ = setup(16, 32, 64, 0)
    w_new, mask = prune.magnitude_unstructured(w, jnp.int32(16 * 16))
    assert int(mask.sum()) == 16 * 16
    assert sparsity(w_new) == 0.5


def test_wanda_per_row_count():
    w, _, xn, _ = setup(12, 32, 64, 1)
    w_new, mask = prune.wanda_unstructured(w, xn, jnp.int32(16))
    per_row = np.asarray(mask.sum(axis=1))
    np.testing.assert_array_equal(per_row, 16)
    # kept weights unchanged
    kept = np.asarray(mask) == 0
    np.testing.assert_array_equal(np.asarray(w_new)[kept], np.asarray(w)[kept])


def test_wanda_nm_format():
    w, _, xn, _ = setup(8, 32, 64, 2)
    w_new, _ = prune.wanda_nm(w, xn, 2, 4)
    grp = np.asarray(w_new).reshape(8, 8, 4)
    zeros = (grp == 0).sum(axis=-1)
    assert (zeros == 2).all()


@pytest.mark.parametrize("block_size", [8, 16, 32])
def test_thanos_unstructured_sparsity_and_quality(block_size):
    w, h, xn, x = setup(16, 32, 96, 3)
    p = jnp.float32(0.5)
    w_new, mask = prune.thanos_unstructured(w, h, xn, p, block_size=block_size)
    got = sparsity(w_new)
    # sort-threshold ties can overshoot a hair; must be within 2%
    assert abs(got - 0.5) < 0.02, got
    # masked entries exactly zero
    assert np.all(np.asarray(w_new)[np.asarray(mask) > 0] == 0.0)
    # joint update beats mask-only at the same mask
    w_maskonly = jnp.where(mask > 0, 0.0, w)
    assert recon_loss(w_new, w, x) < recon_loss(w_maskonly, w, x)


def test_thanos_beats_wanda_jax():
    wins = 0
    for seed in range(4):
        w, h, xn, x = setup(16, 32, 96, 10 + seed)
        t, _ = prune.thanos_unstructured(w, h, xn, jnp.float32(0.5), block_size=16)
        k = jnp.int32(16)
        wa, _ = prune.wanda_unstructured(w, xn, k)
        if recon_loss(t, w, x) < recon_loss(wa, w, x):
            wins += 1
    assert wins >= 3, wins


def test_thanos_nm_format_and_outliers():
    w, h, xn, x = setup(10, 32, 96, 4)
    w_new, mask = prune.thanos_nm(w, h, xn, jnp.float32(0.2), 2, 4, block_size=16)
    wn = np.asarray(w_new)
    m = np.asarray(mask)
    # ceil(0.2*10)=2 outlier rows untouched
    untouched = [i for i in range(10) if np.array_equal(wn[i], np.asarray(w)[i])]
    assert len(untouched) == 2, untouched
    # pruned rows satisfy 2:4
    for i in range(10):
        if i in untouched:
            continue
        zeros = (wn[i].reshape(8, 4) == 0).sum(axis=-1)
        assert (zeros >= 2).all(), f"row {i}: {zeros}"
    assert np.all(wn[m > 0] == 0.0)


def test_thanos_structured_columns():
    w, h, xn, x = setup(12, 24, 72, 5)
    p, alpha = jnp.float32(0.25), jnp.float32(0.0)
    w_new, mask = prune.thanos_structured(w, h, xn, p, alpha)
    wn = np.asarray(w_new)
    # whole columns zero
    removed = [j for j in range(24) if (wn[:, j] == 0).all()]
    s = int(np.ceil(0.25 * 24))
    assert len(removed) == s, (removed, s)
    assert abs(sparsity(w_new) - s / 24) < 1e-6


def test_thanos_structured_alpha_outliers():
    w, h, xn, x = setup(12, 24, 72, 6)
    w_new, mask = prune.thanos_structured(w, h, xn, jnp.float32(0.25), jnp.float32(0.25))
    wn = np.asarray(w_new)
    untouched = [i for i in range(12) if np.array_equal(wn[i], np.asarray(w)[i])]
    assert len(untouched) == 3  # ceil(0.25*12)
    # pruned rows share a common removed-column set of size s
    s = int(np.ceil(0.25 * 24 / 0.75))
    pruned_rows = [i for i in range(12) if i not in untouched]
    removed = [j for j in range(24) if all(wn[i, j] == 0 for i in pruned_rows)]
    assert len(removed) == s


def test_thanos_structured_beats_column_masking():
    w, h, xn, x = setup(16, 24, 96, 7)
    w_new, mask = prune.thanos_structured(w, h, xn, jnp.float32(0.3), jnp.float32(0.0))
    w_maskonly = jnp.where(mask > 0, 0.0, w)
    assert recon_loss(w_new, w, x) < recon_loss(w_maskonly, w, x)


def test_hessian_accum_entry():
    w, h, xn, x = setup(4, 16, 32, 8)
    h0 = jnp.zeros((16, 16))
    xt = x.T  # [a, b]
    h1, xn1 = prune.hessian_accum(h0, xt)
    np.testing.assert_allclose(h1, 2.0 * x @ x.T, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(xn1, jnp.sum(x * x, axis=1), rtol=1e-5, atol=1e-4)
