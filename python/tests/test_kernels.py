"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (including non-128-multiples, exercising the
divisor-picking tile logic) and value scales; assert_allclose is the
acceptance gate.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rnd(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


dims = st.sampled_from([8, 16, 32, 64, 128, 192, 256])
small_dims = st.sampled_from([8, 16, 24, 32, 64])


@settings(max_examples=12, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 10_000))
def test_matmul_matches_ref(m, k, n, seed):
    x = rnd(seed, (m, k))
    y = rnd(seed + 1, (k, n))
    got = kernels.matmul(x, y)
    want = ref.matmul(x, y)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(c=small_dims, width=small_dims, rest=dims, seed=st.integers(0, 10_000))
def test_matmul_sub_matches_ref(c, width, rest, seed):
    if width > rest:
        width = rest
    a = rnd(seed, (c, rest))
    lam = rnd(seed + 1, (c, width))
    u = rnd(seed + 2, (width, rest))
    got = kernels.matmul_sub(a, lam, u)
    want = ref.matmul_sub(a, lam, u)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(b=small_dims, a=dims, seed=st.integers(0, 10_000))
def test_hessian_accum_matches_ref(b, a, seed):
    h0 = rnd(seed, (b, b), scale=0.5)
    h0 = h0 @ h0.T  # start from a PSD accumulator as in real use
    xt = rnd(seed + 1, (a, b))
    got = kernels.hessian_accum(h0, xt)
    want = ref.hessian_accum(h0, xt)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-3)


@settings(max_examples=10, deadline=None)
@given(c=small_dims, b=small_dims, seed=st.integers(0, 10_000))
def test_wanda_metric_matches_ref(c, b, seed):
    w = rnd(seed, (c, b))
    xn = jnp.abs(rnd(seed + 1, (b,))) + 1e-3
    got = kernels.wanda_metric(w, xn)
    want = ref.wanda_metric(w, xn)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_matmul_large_mxu_aligned():
    # the exact tile configuration the AOT graphs use
    x = rnd(1, (1024, 256))
    y = rnd(2, (256, 384))
    np.testing.assert_allclose(
        kernels.matmul(x, y), ref.matmul(x, y), rtol=2e-5, atol=5e-4
    )


def test_hessian_accum_symmetry():
    xt = rnd(3, (256, 64))
    h = kernels.hessian_accum(jnp.zeros((64, 64)), xt)
    np.testing.assert_allclose(h, h.T, rtol=0, atol=1e-5)
    # PSD: all eigenvalues >= 0 (up to fp noise)
    evals = np.linalg.eigvalsh(np.asarray(h, np.float64))
    assert evals.min() > -1e-3


def test_kernels_jit_stability():
    # kernels must be stable under jit re-tracing with new shapes
    for m in (16, 32):
        x = rnd(m, (m, 64))
        y = rnd(m + 1, (64, m))
        np.testing.assert_allclose(
            kernels.matmul(x, y), ref.matmul(x, y), rtol=2e-5, atol=2e-4
        )
