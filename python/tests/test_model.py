"""L2 model contract tests: shapes, training signal, capture
consistency, Pallas/jnp path equivalence."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = dict(vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64, seq_len=16)


def toy_tokens(nb=4, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (nb, CFG["seq_len"]), 0, CFG["vocab"])


def test_param_layout_contiguous_and_complete():
    rows, total = M.param_layout(CFG)
    off = 0
    for name, o, shape in rows:
        assert o == off, name
        off += int(np.prod(shape))
    assert off == total == M.flat_size(CFG)


def test_init_and_unflatten_shapes():
    flat = M.init_params(CFG, seed=1)
    assert flat.shape == (M.flat_size(CFG),)
    p = M.unflatten(CFG, flat)
    assert p["emb"].shape == (64, 32)
    assert p["blocks.1.w1"].shape == (64, 32)
    # norms init to one, weights not all zero
    np.testing.assert_array_equal(p["ln_f"], 1.0)
    assert float(jnp.abs(p["blocks.0.wq"]).sum()) > 0


def test_forward_shapes_and_nll():
    flat = M.init_params(CFG, seed=2)
    toks = toy_tokens()
    logits = M.forward_logits(CFG, flat, toks)
    assert logits.shape == (4, 16, 64)
    nll = M.nll_positions(CFG, flat, toks)
    assert nll.shape == (4, 15)
    # random init ≈ uniform: NLL near log(vocab)
    assert abs(float(nll.mean()) - np.log(64)) < 0.5


def test_causality():
    """Changing a future token must not change past predictions."""
    flat = M.init_params(CFG, seed=3)
    toks = toy_tokens(nb=1, seed=4)
    base = M.forward_logits(CFG, flat, toks)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % CFG["vocab"])
    pert = M.forward_logits(CFG, flat, toks2)
    np.testing.assert_allclose(base[0, :-1], pert[0, :-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(base[0, -1], pert[0, -1])


def test_train_step_reduces_loss():
    flat = M.init_params(CFG, seed=5)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    toks = toy_tokens(nb=8, seed=6)
    step_fn = jax.jit(
        lambda f, m_, v_, t, s: M.train_step(CFG, f, m_, v_, t, s, lr=3e-3)
    )
    losses = []
    for s in range(30):
        loss, flat, m, v = step_fn(flat, m, v, toks, jnp.int32(s))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses[::10]


def test_block_capture_consistent_with_forward():
    flat = M.init_params(CFG, seed=7)
    toks = toy_tokens(nb=2, seed=8)
    x = M.embed(CFG, flat, toks)
    p = M.unflatten(CFG, flat)
    rows, _ = M.param_layout(CFG)
    # block 0 flat slice
    b0 = [r for r in rows if r[0].startswith("blocks.0.")]
    off0 = b0[0][1]
    size0 = sum(int(np.prod(s)) for _, _, s in b0)
    flat_b0 = flat[off0 : off0 + size0]
    y, xa, xo, xf1, xf2 = M.block_capture(CFG, flat_b0, x)
    # full forward through one block must agree
    bp = {k.split(".")[-1]: v for k, v in p.items() if k.startswith("blocks.0.")}
    y_ref = M.block_forward(bp, x, CFG["n_heads"])
    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)
    assert xa.shape == (2 * 16, 32)
    assert xf2.shape == (2 * 16, 64)
    # captured w1 input reproduces the ff path: gelu(xf1 @ w1.T) == xf2
    np.testing.assert_allclose(
        M.gelu(xf1 @ bp["w1"].T), xf2, rtol=1e-5, atol=1e-5
    )


def test_pallas_linear_matches_jnp():
    cfg = dict(CFG, d_model=32, d_ff=64)
    flat = M.init_params(cfg, seed=9)
    toks = toy_tokens(nb=2, seed=10)
    a = M.forward_logits(cfg, flat, toks, use_pallas=False)
    b = M.forward_logits(cfg, flat, toks, use_pallas=True)
    np.testing.assert_allclose(a, b, rtol=5e-5, atol=5e-4)


def test_presets_sane():
    for name, cfg in M.PRESETS.items():
        assert cfg["d_model"] % cfg["n_heads"] == 0, name
        assert cfg["d_model"] % 128 == 0 and cfg["d_ff"] % 128 == 0, name
