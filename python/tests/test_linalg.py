"""Scan-based linear algebra vs LAPACK-backed jnp.linalg (the latter is
fine at test time; it is only banned inside AOT graphs)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import linalg_jax as la

jax.config.update("jax_platform_name", "cpu")


def spd(n, seed, damp=0.05):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, n + 4))
    return x @ x.T + damp * jnp.eye(n)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 48), seed=st.integers(0, 1000))
def test_cholesky_matches_lapack(n, seed):
    a = spd(n, seed)
    l = la.cholesky(a)
    l_ref = jnp.linalg.cholesky(a)
    np.testing.assert_allclose(l, l_ref, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 48), seed=st.integers(0, 1000))
def test_chol_solve_solves(n, seed):
    a = spd(n, seed)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,))
    x = la.chol_solve(la.cholesky(a), b)
    np.testing.assert_allclose(a @ x, b, rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 32), k=st.integers(1, 8), seed=st.integers(0, 1000))
def test_chol_solve_many(n, k, seed):
    a = spd(n, seed)
    bs = jax.random.normal(jax.random.PRNGKey(seed + 2), (n, k))
    xs = la.chol_solve_many(la.cholesky(a), bs)
    np.testing.assert_allclose(a @ xs, bs, rtol=2e-3, atol=2e-3)


def test_chol_inverse():
    a = spd(24, 7)
    inv = la.chol_inverse(a)
    np.testing.assert_allclose(a @ inv, jnp.eye(24), rtol=0, atol=5e-4)
    np.testing.assert_allclose(inv, inv.T, rtol=0, atol=0)  # exact symmetry


def test_suffix_inverse_identity():
    """(H[j:, j:])^{-1} == U[j:, j:].T @ U[j:, j:] — the factorization
    identity every Thanos block step relies on."""
    h = spd(20, 9)
    u = la.inverse_cholesky_upper(h)
    for j in (0, 3, 8, 15):
        direct = jnp.linalg.inv(h[j:, j:])
        via_u = u[j:, j:].T @ u[j:, j:]
        np.testing.assert_allclose(via_u, direct, rtol=2e-3, atol=2e-3)


def test_spd_solve_batched():
    mats = jnp.stack([spd(12, s) for s in range(5)])
    rhs = jax.random.normal(jax.random.PRNGKey(3), (5, 12))
    xs = la.spd_solve_batched(mats, rhs)
    for i in range(5):
        np.testing.assert_allclose(mats[i] @ xs[i], rhs[i], rtol=2e-3, atol=2e-3)


def test_damp_fixes_dead_channels():
    h = jnp.diag(jnp.array([4.0, 0.0, 1.0]))
    hd = la.damp(h, 0.01)
    assert hd[1, 1] == 1.0
    assert hd[0, 0] > 4.0
    # still symmetric, now PD
    l = la.cholesky(hd)
    assert bool(jnp.all(jnp.isfinite(l)))


def test_masked_system_principle():
    """The masked embedding solves the exact principal subsystem:
    compare against a gathered dense solve."""
    h = spd(10, 11)
    hinv = la.chol_inverse(h)
    mask = jnp.array([1, 0, 1, 1, 0, 0, 1, 0, 0, 1], dtype=jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(4), (10,))
    eye = jnp.eye(10)
    rhat = mask[:, None] * mask[None, :] * hinv + (1.0 - mask)[None, :] * eye
    lam = la.chol_solve(la.cholesky(rhat), mask * w)
    # gathered reference
    idx = np.where(np.asarray(mask) > 0)[0]
    sub = np.asarray(hinv)[np.ix_(idx, idx)]
    lam_ref = np.linalg.solve(sub, np.asarray(w)[idx])
    np.testing.assert_allclose(np.asarray(lam)[idx], lam_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(lam)[np.asarray(mask) == 0], 0.0, atol=1e-5)
