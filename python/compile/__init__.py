"""Build-time Python for the Thanos stack (Layer 1 + Layer 2).

Nothing in this package runs at request time: ``python -m compile.aot``
lowers the JAX model, the Pallas kernels and the pruning graphs to HLO
text under ``artifacts/``, after which the Rust binary is
self-contained.
"""
