"""Layer-2 JAX model: a decoder-only transformer LM over a flat
parameter vector.

All parameters live in ONE flat f32 vector so the Rust coordinator
manages exactly three buffers (params, adam-m, adam-v) and can splice
pruned weight matrices back in by manifest offsets — no pytree
marshalling across the FFI boundary. The layout table (name, offset,
shape) is emitted into ``artifacts/manifest.json`` by ``aot.py``.

Architecture (pre-norm, tied embeddings):

    x   = emb[tokens] + pos
    for each block:  x += Wo . attn(RMSNorm_1(x));  x += W2 . gelu(W1 . RMSNorm_2(x))
    logits = RMSNorm_f(x) @ emb.T

The prunable layers are exactly the six per-block projection matrices
(wq wk wv wo w1 w2) — the paper prunes linear layers only (§1.1).
Matmuls route through the Pallas kernel when ``use_pallas=True``
(numerics pinned against the jnp path in test_model.py); the default
AOT model uses jnp.dot for the forward substrate and reserves Pallas
for the pruning hot-spot graphs (DESIGN.md §Hardware-Adaptation).
"""

import math
from functools import partial

import jax
import jax.numpy as jnp

from . import kernels

# Mirrors rust/src/config/mod.rs — keep in sync (checked by the Rust
# loader against the manifest at startup).
PRESETS = {
    "tiny": dict(vocab=512, d_model=128, n_layers=2, n_heads=4, d_ff=512, seq_len=128),
    "small": dict(vocab=512, d_model=256, n_layers=4, n_heads=4, d_ff=1024, seq_len=128),
    "med": dict(vocab=512, d_model=384, n_layers=6, n_heads=6, d_ff=1536, seq_len=128),
}


# ---------------------------------------------------------------------------
# parameter layout
# ---------------------------------------------------------------------------

def param_specs(cfg):
    """Canonical (name, shape) list. Weight matrices are stored
    (out, in) = (c, b), matching the Rust `Mat` convention."""
    d, dff = cfg["d_model"], cfg["d_ff"]
    specs = [
        ("emb", (cfg["vocab"], d)),
        ("pos", (cfg["seq_len"], d)),
    ]
    for l in range(cfg["n_layers"]):
        specs += [
            (f"blocks.{l}.ln1", (d,)),
            (f"blocks.{l}.wq", (d, d)),
            (f"blocks.{l}.wk", (d, d)),
            (f"blocks.{l}.wv", (d, d)),
            (f"blocks.{l}.wo", (d, d)),
            (f"blocks.{l}.ln2", (d,)),
            (f"blocks.{l}.w1", (dff, d)),
            (f"blocks.{l}.w2", (d, dff)),
        ]
    specs.append(("ln_f", (d,)))
    return specs


def param_layout(cfg):
    """(name, offset, shape) rows + total size."""
    rows, off = [], 0
    for name, shape in param_specs(cfg):
        size = int(math.prod(shape))
        rows.append((name, off, shape))
        off += size
    return rows, off


def flat_size(cfg):
    return param_layout(cfg)[1]


def unflatten(cfg, flat):
    """Flat vector -> dict of named arrays (views via reshape)."""
    out = {}
    for name, off, shape in param_layout(cfg)[0]:
        size = int(math.prod(shape))
        out[name] = flat[off : off + size].reshape(shape)
    return out


def init_params(cfg, seed=0):
    """GPT-2-style init, returned as the flat vector."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            chunks.append(jnp.ones(shape, jnp.float32).ravel())
        else:
            std = 0.02
            if name.endswith(("wo", "w2")):  # residual-path scaling
                std = 0.02 / math.sqrt(2 * cfg["n_layers"])
            chunks.append(
                (jax.random.normal(sub, shape, jnp.float32) * std).ravel()
            )
    return jnp.concatenate(chunks)


# ---------------------------------------------------------------------------
# model pieces
# ---------------------------------------------------------------------------

def rmsnorm(x, gain, eps=1e-6):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def gelu(x):
    # tanh approximation: basic HLO ops only (erf can lower to a
    # custom-call on some backends)
    c = math.sqrt(2.0 / math.pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def linear(x, w, use_pallas=False):
    """``y = x @ w.T`` with ``w: (out, in)``; optionally via the Pallas
    matmul kernel (flattening leading dims to a 2-D tile-friendly GEMM).
    """
    if not use_pallas:
        return jnp.dot(x, w.T)
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    y2 = kernels.matmul(x2, w.T)
    return y2.reshape(lead + (w.shape[0],))


def attention(q, k, v, n_heads):
    """Multi-head causal self-attention over [nb, seq, d] projections."""
    nb, seq, d = q.shape
    hd = d // n_heads

    def split(t):
        return t.reshape(nb, seq, n_heads, hd).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((seq, seq), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vh)
    return out.transpose(0, 2, 1, 3).reshape(nb, seq, d)


def block_forward(bp, x, n_heads, use_pallas=False, capture=False):
    """One transformer block. ``bp`` is the dict of this block's params
    (keys ln1, wq, wk, wv, wo, ln2, w1, w2).

    With ``capture=True`` also returns the inputs of every prunable
    linear layer — the `X` matrices of the paper's generic pruning loop
    (Alg. 3 line 3).
    """
    xn = rmsnorm(x, bp["ln1"])
    q = linear(xn, bp["wq"], use_pallas)
    k = linear(xn, bp["wk"], use_pallas)
    v = linear(xn, bp["wv"], use_pallas)
    attn_out = attention(q, k, v, n_heads)
    o = linear(attn_out, bp["wo"], use_pallas)
    x = x + o
    xn2 = rmsnorm(x, bp["ln2"])
    h = gelu(linear(xn2, bp["w1"], use_pallas))
    ff = linear(h, bp["w2"], use_pallas)
    y = x + ff
    if not capture:
        return y
    captures = {
        "x_attn": xn,      # input of wq / wk / wv
        "x_o": attn_out,   # input of wo
        "x_ff1": xn2,      # input of w1
        "x_ff2": h,        # input of w2
    }
    return y, captures


def block_param_specs(cfg):
    """(name, shape) of one block in flat order (block-local layout)."""
    d, dff = cfg["d_model"], cfg["d_ff"]
    return [
        ("ln1", (d,)),
        ("wq", (d, d)),
        ("wk", (d, d)),
        ("wv", (d, d)),
        ("wo", (d, d)),
        ("ln2", (d,)),
        ("w1", (dff, d)),
        ("w2", (d, dff)),
    ]


def unflatten_block(cfg, flat_block):
    out, off = {}, 0
    for name, shape in block_param_specs(cfg):
        size = int(math.prod(shape))
        out[name] = flat_block[off : off + size].reshape(shape)
        off += size
    return out


def block_flat_size(cfg):
    return sum(int(math.prod(s)) for _, s in block_param_specs(cfg))


# ---------------------------------------------------------------------------
# full-model functions (the AOT entry points)
# ---------------------------------------------------------------------------

def embed(cfg, flat, tokens):
    """tokens [nb, seq] i32 -> x0 [nb, seq, d]."""
    p = unflatten(cfg, flat)
    return p["emb"][tokens] + p["pos"][None, : tokens.shape[1], :]


def forward_hidden(cfg, flat, tokens, use_pallas=False):
    p = unflatten(cfg, flat)
    x = embed(cfg, flat, tokens)
    for l in range(cfg["n_layers"]):
        bp = {k.split(".")[-1]: v for k, v in p.items() if k.startswith(f"blocks.{l}.")}
        x = block_forward(bp, x, cfg["n_heads"], use_pallas)
    return rmsnorm(x, p["ln_f"])


def forward_logits(cfg, flat, tokens, use_pallas=False):
    p = unflatten(cfg, flat)
    xf = forward_hidden(cfg, flat, tokens, use_pallas)
    return jnp.dot(xf, p["emb"].T)


def nll_positions(cfg, flat, tokens, use_pallas=False):
    """Per-position negative log-likelihood of the next token:
    output [nb, seq-1]; position t scores tokens[:, t+1]."""
    logits = forward_logits(cfg, flat, tokens, use_pallas)[:, :-1, :]
    logp = jax.nn.log_softmax(logits, axis=-1)
    targets = tokens[:, 1:]
    picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -picked


def mean_loss(cfg, flat, tokens, use_pallas=False):
    return jnp.mean(nll_positions(cfg, flat, tokens, use_pallas))


def block_capture(cfg, flat_block, x):
    """AOT entry: one block forward returning the block output and the
    flattened (tokens x features) inputs of every prunable layer."""
    bp = unflatten_block(cfg, flat_block)
    y, cap = block_forward(bp, x, cfg["n_heads"], capture=True)
    nb, seq, d = x.shape
    flat2 = lambda t: t.reshape(nb * seq, t.shape[-1])
    return (
        y,
        flat2(cap["x_attn"]),
        flat2(cap["x_o"]),
        flat2(cap["x_ff1"]),
        flat2(cap["x_ff2"]),
    )


def train_step(cfg, flat, m, v, tokens, step, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam step. ``step`` is the 0-based step index (i32 scalar).
    Returns (loss, flat', m', v')."""
    loss, g = jax.value_and_grad(lambda f: mean_loss(cfg, f, tokens))(flat)
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * g * g
    t = step.astype(jnp.float32) + 1.0
    mhat = m / (1.0 - b1**t)
    vhat = v / (1.0 - b2**t)
    flat = flat - lr * mhat / (jnp.sqrt(vhat) + eps)
    return loss, flat, m, v
