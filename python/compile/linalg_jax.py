"""Scan-based dense linear algebra in pure jnp/lax primitives.

``jnp.linalg.*`` is OFF-LIMITS inside AOT graphs: on CPU it lowers to
LAPACK custom-calls whose symbol names (``lapack_spotrf_ffi`` etc.,
jax >= 0.5 FFI registry) do not exist in the xla_extension 0.5.1
runtime that executes the artifacts. Everything here is built from
basic HLO ops (fori_loop, dynamic slicing, elementwise math) so the
lowered module is plain HLO that any PJRT backend runs.

Accuracy: pytest pins these against ``jnp.linalg`` / scipy at test time
(where LAPACK is fine because tests run under jax's own jaxlib).
"""

import jax
import jax.numpy as jnp
from jax import lax


def cholesky(a):
    """Lower-triangular L with ``a = L @ L.T`` (right-looking update).

    ``a`` must be symmetric positive definite; callers damp Hessians
    first (see :func:`damp`). O(n) sequential steps of O(n^2) vector
    work — identical complexity to LAPACK potrf, scan-friendly.
    """
    n = a.shape[-1]
    idx = jnp.arange(n)

    def body(j, carry):
        a_cur, l_acc = carry
        pivot = jnp.sqrt(a_cur[j, j])
        col = a_cur[:, j] / pivot
        col = jnp.where(idx >= j, col, 0.0)
        l_acc = l_acc.at[:, j].set(col)
        a_cur = a_cur - jnp.outer(col, col)
        return (a_cur, l_acc)

    _, l = lax.fori_loop(0, n, body, (a, jnp.zeros_like(a)))
    return l


def solve_lower(l, b):
    """Solve ``L y = b`` (forward substitution), ``b: [n]``."""
    n = l.shape[-1]

    def body(j, y):
        s = jnp.dot(l[j, :], y)
        return y.at[j].set((b[j] - s) / l[j, j])

    return lax.fori_loop(0, n, body, jnp.zeros_like(b))


def solve_lower_t(l, y):
    """Solve ``L.T x = y`` (backward substitution)."""
    n = l.shape[-1]

    def body(t, x):
        j = n - 1 - t
        s = jnp.dot(l[:, j], x)
        return x.at[j].set((y[j] - s) / l[j, j])

    return lax.fori_loop(0, n, body, jnp.zeros_like(y))


def chol_solve(l, b):
    """Solve ``A x = b`` given ``L = cholesky(A)``."""
    return solve_lower_t(l, solve_lower(l, b))


def chol_solve_many(l, bs):
    """Solve ``A X = B`` for ``B: [n, k]`` (k right-hand sides)."""
    return jax.vmap(lambda col: chol_solve(l, col), in_axes=1, out_axes=1)(bs)


def spd_solve_batched(mats, rhs):
    """Batched SPD solve: ``mats: [c, s, s]``, ``rhs: [c, s]`` —
    the Thanos per-row padded systems (paper §H.1)."""
    def one(m, r):
        return chol_solve(cholesky(m), r)

    return jax.vmap(one)(mats, rhs)


def lower_tri_inverse(l):
    """Inverse of a lower-triangular matrix: column ``j`` is the forward
    solve of ``L x = e_j``; the n solves are vmapped so XLA executes
    them as one batched scan (n steps of O(n^2) vectorized work)."""
    n = l.shape[-1]
    eye = jnp.eye(n, dtype=l.dtype)
    return jax.vmap(lambda e: solve_lower(l, e), in_axes=1, out_axes=1)(eye)


def inverse_cholesky_upper(a):
    """Upper U with ``A^{-1} = U.T @ U`` — WITHOUT forming the inverse.

    Reversal trick (§Perf-L2): with J the index-reversal and
    ``M = J A J = Lm Lm^T``, one has ``U = J Lm^{-1} J`` (upper) and
    ``U^T U = J M^{-1} J = A^{-1}``. One scan-cholesky + one batched
    triangular solve, vs cholesky + n^2-solve inverse + second cholesky
    for the naive chain. For any suffix ``j``,
    ``(A[j:, j:])^{-1} = U[j:, j:].T @ U[j:, j:]`` — one factorization
    serves every Thanos residual block (pinned in test_linalg.py).
    """
    m = a[::-1, ::-1]
    lm = cholesky(m)
    linv = lower_tri_inverse(lm)
    return linv[::-1, ::-1]


def chol_inverse(a):
    """Full inverse of an SPD matrix via the U factor (one matmul on
    top of ``inverse_cholesky_upper``; exactly symmetric by
    construction)."""
    u = inverse_cholesky_upper(a)
    return u.T @ u


def damp(h, percdamp=0.01):
    """SparseGPT-style damping: ``H + percdamp * mean(diag(H)) * I``,
    with zero diagonal entries (dead channels) replaced by 1."""
    n = h.shape[-1]
    d = jnp.diagonal(h)
    lam = percdamp * jnp.mean(d)
    d_new = jnp.where(d == 0.0, 1.0, d + lam)
    return h + jnp.diag(d_new - d)
