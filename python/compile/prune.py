"""Layer-2 pruning compute graphs — the AOT path of every Thanos
variant plus the mask-only baselines.

Everything here is a pure jittable function over static shapes with
**runtime** sparsity controls (p, k, alpha arrive as traced scalars via
the sort-threshold trick), so ONE artifact per layer shape serves every
sparsity point of every experiment. Only the Thanos block size B and
the n:m pattern are baked per artifact.

Two implementation tricks make the graphs static-shape friendly:

1. **Masked padded systems** (the paper's §H.1 padding, taken to its
   logical conclusion): instead of gathering each row's removal indices
   q into an s x s system, the system is embedded over the full block
   width: ``Rhat' = (m x m) * Hinv_bb + diag(1 - m)`` with rhs
   ``m * w``. Unmasked coordinates solve to exactly lambda = 0, masked
   coordinates solve the exact principal subsystem — no gathers, fully
   batched, PD by construction.

2. **Suffix-inverse factor** (the SparseGPT identity): with
   ``H^{-1} = U^T U`` (U upper), the residual-block inverse the paper
   recomputes per block (Alg. 1 line 17 + inversion) is
   ``(H[j:, j:])^{-1} = U[j:, j:]^T U[j:, j:]`` — one O(b^3)
   factorization per layer and two tile matmuls per block instead of a
   fresh O(rest^3) inversion per block (complexity drops from the
   paper's O(b^4/B) to O(b^3 + b^2 B); numerics identical, pinned by
   tests against the direct form).

SparseGPT itself is served by the Rust implementation (it is a
baseline, not the contribution; see DESIGN.md §System-inventory).
"""

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from . import kernels
from . import linalg_jax as la


# ---------------------------------------------------------------------------
# mask helpers (runtime counts via sort thresholds)
# ---------------------------------------------------------------------------

def _smallest_r_mask_flat(metric_flat, r):
    """Boolean mask of the r smallest entries (r is a traced i32).
    Ties at the threshold may slightly overshoot r — the documented
    deviation of the AOT path from the bit-exact Rust path."""
    n = metric_flat.shape[0]
    r = jnp.clip(r, 0, n)
    srt = jnp.sort(metric_flat)
    idx = jnp.clip(r - 1, 0, n - 1)
    thr = lax.dynamic_slice(srt, (idx,), (1,))[0]
    return (metric_flat <= thr) & (r > 0)


def _per_row_smallest(metric, k):
    """Per-row mask of the k smallest entries (k traced)."""
    c, b = metric.shape
    k = jnp.clip(k, 0, b)
    srt = jnp.sort(metric, axis=1)
    idx = jnp.clip(k - 1, 0, b - 1)
    thr = lax.dynamic_slice_in_dim(srt, idx, 1, axis=1)
    return (metric <= thr) & (k > 0)


def _nm_group_mask(metric, n, m):
    """n smallest per group of m consecutive entries (n, m static)."""
    c, b = metric.shape
    g = metric.reshape(c, b // m, m)
    order = jnp.argsort(g, axis=-1)
    rank = jnp.argsort(order, axis=-1)
    return (rank < n).reshape(c, b)


def _apply_mask(w, mask):
    return jnp.where(mask, 0.0, w)


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

def magnitude_unstructured(w, r):
    """Alg. 4: zero the r smallest |w| anywhere (r traced i32)."""
    mask = _smallest_r_mask_flat(jnp.abs(w).ravel(), r).reshape(w.shape)
    return _apply_mask(w, mask), mask.astype(jnp.float32)


def wanda_unstructured(w, xnorm_sq, k):
    """Alg. 6: per-row k smallest of |W|*||X_j|| (k traced i32)."""
    metric = kernels.wanda_metric(w, xnorm_sq)
    mask = _per_row_smallest(metric, k)
    return _apply_mask(w, mask), mask.astype(jnp.float32)


def wanda_nm(w, xnorm_sq, n, m):
    """n:m Wanda (n, m static)."""
    metric = kernels.wanda_metric(w, xnorm_sq)
    mask = _nm_group_mask(metric, n, m)
    return _apply_mask(w, mask), mask.astype(jnp.float32)


def magnitude_nm(w, n, m):
    """n:m magnitude: n smallest |w| per group of m."""
    mask = _nm_group_mask(jnp.abs(w), n, m)
    return _apply_mask(w, mask), mask.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Thanos
# ---------------------------------------------------------------------------

def _masked_padded_solve(hinv_bb, local_mask, w_block):
    """Per-row joint systems via the masked embedding (§H.1 trick).

    hinv_bb: [width, width] block of the residual inverse Hessian,
    local_mask: [c, width] bool, w_block: [c, width].
    Returns lambda: [c, width] with zeros at unmasked coordinates.
    """
    mf = local_mask.astype(w_block.dtype)
    width = hinv_bb.shape[0]
    eye = jnp.eye(width, dtype=w_block.dtype)
    # Rhat' = (m x m) . Hinv_bb  +  diag(1 - m)
    rhat = mf[:, :, None] * mf[:, None, :] * hinv_bb[None] + (1.0 - mf)[:, None, :] * eye[None]
    rhs = mf * w_block
    lam = la.spd_solve_batched(rhat, rhs)
    return lam * mf


def _suffix_factors(u, j1, width, b):
    """Residual-inverse pieces from the global factor U (static slices):
    returns (hinv_bb [width, width], hinv_rows [width, rest])."""
    usq = u[j1 : j1 + width, j1 : j1 + width]
    ublk = u[j1 : j1 + width, j1:]
    hinv_bb = jnp.dot(usq.T, usq)
    hinv_rows = kernels.matmul(usq.T, ublk) if width >= 8 else jnp.dot(usq.T, ublk)
    return hinv_bb, hinv_rows


def thanos_unstructured(w, h, xnorm_sq, p, block_size=128, percdamp=0.01):
    """Alg. 1: block-wise walk, global residual mask (eq. 11), joint
    per-row updates (eq. 10). p is a traced f32 scalar."""
    c, b = w.shape
    bsize = min(block_size, b)
    hd = la.damp(h, percdamp)
    hinv = la.chol_inverse(hd)
    u = la.cholesky(hinv).T  # H^{-1} = U^T U

    r_left = jnp.floor(p * (c * b)).astype(jnp.int32)
    mask_full = jnp.zeros((c, b), bool)

    for j1 in range(0, b, bsize):
        width = min(bsize, b - j1)
        rest = b - j1
        hinv_bb, hinv_rows = _suffix_factors(u, j1, width, b)

        wres = lax.slice_in_dim(w, j1, b, axis=1)
        metric = kernels.wanda_metric(wres, lax.slice_in_dim(xnorm_sq, j1, b))
        res_mask = _smallest_r_mask_flat(metric.ravel(), r_left).reshape(c, rest)
        local = res_mask[:, :width]
        r_left = r_left - jnp.sum(local).astype(jnp.int32)

        lam = _masked_padded_solve(hinv_bb, local, wres[:, :width])
        wres_new = kernels.matmul_sub(wres, lam, hinv_rows)
        # masked coordinates are zero in exact arithmetic; clamp exactly
        pad = jnp.zeros((c, rest - width), bool)
        local_wide = jnp.concatenate([local, pad], axis=1)
        wres_new = jnp.where(local_wide, 0.0, wres_new)
        w = lax.dynamic_update_slice(w, wres_new, (0, j1))
        mask_full = mask_full.at[:, j1 : j1 + width].set(local)

    return w, mask_full.astype(jnp.float32)


def _prune_row_mask(w, h, alpha):
    """Rows NOT in the top ceil(alpha*c) by loss h_i = W_i H W_i^T
    (eq. 14) — the rows structured/semi-structured pruning touches."""
    c = w.shape[0]
    hrow = jnp.einsum("ij,jk,ik->i", w, h, w)
    c_prune = c - jnp.ceil(alpha * c).astype(jnp.int32)
    srt = jnp.sort(hrow)
    idx = jnp.clip(c_prune - 1, 0, c - 1)
    thr = lax.dynamic_slice(srt, (idx,), (1,))[0]
    return (hrow <= thr) & (c_prune > 0)


def thanos_nm(w, h, xnorm_sq, alpha, n, m, block_size=128, percdamp=0.01):
    """Alg. 8: n:m masks per group, joint updates per block, outlier
    rows (fraction alpha, traced) skipped."""
    c, b = w.shape
    assert b % m == 0
    bsize = max(m, min(block_size, b))
    bsize -= bsize % m
    hd = la.damp(h, percdamp)
    hinv = la.chol_inverse(hd)
    u = la.cholesky(hinv).T

    prune_rows = _prune_row_mask(w, hd, alpha)
    mask_full = jnp.zeros((c, b), bool)

    for j1 in range(0, b, bsize):
        width = min(bsize, b - j1)
        rest = b - j1
        hinv_bb, hinv_rows = _suffix_factors(u, j1, width, b)
        wres = lax.slice_in_dim(w, j1, b, axis=1)
        metric = kernels.wanda_metric(
            wres[:, :width], lax.slice_in_dim(xnorm_sq, j1, j1 + width)
        )
        local = _nm_group_mask(metric, n, m) & prune_rows[:, None]

        lam = _masked_padded_solve(hinv_bb, local, wres[:, :width])
        wres_new = kernels.matmul_sub(wres, lam, hinv_rows)
        pad = jnp.zeros((c, rest - width), bool)
        local_wide = jnp.concatenate([local, pad], axis=1)
        wres_new = jnp.where(local_wide, 0.0, wres_new)
        w = lax.dynamic_update_slice(w, wres_new, (0, j1))
        mask_full = mask_full.at[:, j1 : j1 + width].set(local)

    return w, mask_full.astype(jnp.float32)


def thanos_structured(w, h, xnorm_sq, p, alpha, percdamp=0.01):
    """Alg. 2: structured column removal with outlier rows. No explicit
    permutations — the masked-system embedding makes them unnecessary
    (the permutation of §G.4.4 is an implementation device for gathers;
    the solved system is identical)."""
    c, b = w.shape
    hd = la.damp(h, percdamp)
    hinv = la.chol_inverse(hd)

    prune_rows = _prune_row_mask(w, hd, alpha)

    # column losses over pruned rows only (eq. 15)
    v = jnp.sum(jnp.square(w) * prune_rows[:, None].astype(w.dtype), axis=0) * xnorm_sq
    s = jnp.ceil(p * b / (1.0 - alpha)).astype(jnp.int32)
    s = jnp.clip(s, 0, b)
    srt = jnp.sort(v)
    idx = jnp.clip(s - 1, 0, b - 1)
    thr = lax.dynamic_slice(srt, (idx,), (1,))[0]
    col_mask = (v <= thr) & (s > 0)

    # joint closed-form update (eq. 13) via the masked embedding:
    # Rhat' = (m x m) * Hinv + diag(1-m); lambda_k = Rhat'^{-1} (m * w_k)
    mf = col_mask.astype(w.dtype)
    eye = jnp.eye(b, dtype=w.dtype)
    rhat = mf[:, None] * mf[None, :] * hinv + (1.0 - mf)[None, :] * eye
    l = la.cholesky(rhat)
    rhs = w * mf[None, :]
    lam = jax.vmap(lambda r: la.chol_solve(l, r))(rhs)  # [c, b]
    delta = kernels.matmul(lam, hinv)
    pr = prune_rows[:, None].astype(w.dtype)
    w_new = w - delta * pr
    full_mask = col_mask[None, :] & prune_rows[:, None]
    w_new = jnp.where(full_mask, 0.0, w_new)
    return w_new, full_mask.astype(jnp.float32)


# ---------------------------------------------------------------------------
# calibration statistics (AOT entry)
# ---------------------------------------------------------------------------

def hessian_accum(h, xt):
    """H += 2 Xt^T Xt (Pallas kernel); also returns the running
    row-norm-squared update for the Wanda metric."""
    return kernels.hessian_accum(h, xt), jnp.sum(jnp.square(xt), axis=0)
