"""Wanda/OBD saliency Pallas kernel: ``metric_ij = |W_ij| * sqrt(xnorm_sq_j)``.

A VPU (elementwise) kernel: one VMEM pass over the weight tile fused
with a broadcast of the per-column calibration norm. The norm vector is
carried as a ``[1, b]`` operand (TPU-friendly: trailing-2D layout).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _pick


def _metric_kernel(w_ref, n_ref, o_ref):
    o_ref[...] = jnp.abs(w_ref[...]) * jnp.sqrt(n_ref[...])


@functools.partial(jax.jit, static_argnames=("bc", "bb"))
def wanda_metric(w, xnorm_sq, bc: int = 128, bb: int = 128):
    """``|W| * ||X_j||_2`` with ``w: [c, b]``, ``xnorm_sq: [b]``."""
    c, b = w.shape
    assert xnorm_sq.shape == (b,)
    bc, bb = _pick(c, bc), _pick(b, bb)
    n2d = xnorm_sq.reshape(1, b)
    return pl.pallas_call(
        _metric_kernel,
        grid=(c // bc, b // bb),
        in_specs=[
            pl.BlockSpec((bc, bb), lambda i, j: (i, j)),
            pl.BlockSpec((1, bb), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bc, bb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((c, b), w.dtype),
        interpret=True,
    )(w, n2d)
