"""Pure-jnp correctness oracles for every Pallas kernel.

These are the CORE correctness signal of the L1 layer: pytest +
hypothesis assert ``assert_allclose(kernel(...), ref(...))`` over a
sweep of shapes and dtypes (``python/tests/test_kernels.py``).
"""

import jax.numpy as jnp


def matmul(x, y):
    """Oracle for :func:`kernels.matmul`."""
    return jnp.dot(x, y)


def matmul_sub(a, lam, u):
    """Oracle for :func:`kernels.matmul_sub` (Thanos update, eq. 10)."""
    return a - jnp.dot(lam, u)


def hessian_accum(h, xt):
    """Oracle for :func:`kernels.hessian_accum` (paper eq. 34)."""
    return h + 2.0 * jnp.dot(xt.T, xt)


def wanda_metric(w, xnorm_sq):
    """Oracle for :func:`kernels.wanda_metric` (paper eq. 5 / 11)."""
    return jnp.abs(w) * jnp.sqrt(xnorm_sq)[None, :]
