"""Tiled Pallas matmul kernels.

Design notes (TPU mental model, run under interpret=True here):

* Grid is ``(M/bm, N/bn, K/bk)`` with **K innermost** so each output
  tile stays resident in VMEM across the whole K loop (accumulator
  revisiting) — the Pallas analogue of a CUDA tile-and-accumulate loop.
* Default tiles are 128x128: MXU-aligned, and every matmul operand in
  this project (d_model / d_ff / vocab / token counts) is a multiple of
  128 by construction (see ``config.ModelConfig`` presets).
* VMEM footprint per step = bm*bk + bk*bn + bm*bn floats
  (3 * 128 * 128 * 4 B = 192 KiB << 16 MiB VMEM), leaving headroom for
  double buffering; MXU utilization estimate in EXPERIMENTS.md §Perf-L1.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


def _pick(dim: int, pref: int) -> int:
    """Largest tile <= pref that divides dim (dims here are powers of
    two times small factors, so this terminates at 1 in the worst case).
    """
    t = min(pref, dim)
    while dim % t:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, bm: int = 128, bn: int = 128, bk: int = 128):
    """``x @ y`` via the tiled Pallas kernel. ``x: [M, K], y: [K, N]``."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dim mismatch {x.shape} @ {y.shape}"
    bm, bn, bk = _pick(m, bm), _pick(n, bn), _pick(k, bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


def _mm_sub_kernel(a_ref, lam_ref, u_ref, o_ref):
    """o = a - lam @ u, fused: accumulate the product across the K grid
    axis, subtract from `a` on the final step (single VMEM pass over the
    output tile)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        lam_ref[...], u_ref[...], preferred_element_type=o_ref.dtype
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = a_ref[...] - o_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_sub(a, lam, u, bm: int = 128, bn: int = 128, bk: int = 128):
    """``a - lam @ u`` — the Thanos row-update application
    ``W <- W - Lambda . R`` (eq. 10) as one fused kernel.

    ``a: [c, rest], lam: [c, width], u: [width, rest]``.
    """
    m, n = a.shape
    m2, k = lam.shape
    k2, n2 = u.shape
    assert (m, n) == (m2, n2) or (m == m2 and n == n2), "shape mismatch"
    assert k == k2 and n == n2 and m == m2
    bm, bn, bk = _pick(m, bm), _pick(n, bn), _pick(k, bk)
    return pl.pallas_call(
        _mm_sub_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, lam, u)
