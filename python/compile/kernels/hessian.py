"""Hessian-accumulation Pallas kernel: ``H <- H + 2 * Xt^T Xt``.

The layer-reconstruction Hessian ``H = 2 * X X^T`` (paper eq. 34) is
accumulated chunk-by-chunk over calibration batches; the coordinator
streams activation chunks ``Xt: [a, b]`` (tokens x features, the layout
the forward capture produces) and keeps ``H: [b, b]`` resident.

Kernel shape: grid ``(b/bn, b/bn, a/bk)`` with the token axis innermost
(accumulator revisiting); each step contracts a ``[bk, bn] x [bk, bn]``
pair of tiles of the same operand — a Gram-matrix specialisation of the
matmul kernel that reads ``Xt`` tiles twice instead of materialising a
transpose in HBM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _pick


def _gram_kernel(h_ref, xt_ref, xs_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # contribution 2 * Xt[:, i-tile]^T @ Xt[:, j-tile]
    o_ref[...] += 2.0 * jnp.dot(
        xt_ref[...].T, xs_ref[...], preferred_element_type=o_ref.dtype
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] += h_ref[...]


@functools.partial(jax.jit, static_argnames=("bn", "bk"))
def hessian_accum(h, xt, bn: int = 128, bk: int = 128):
    """``h + 2 * xt.T @ xt`` with ``h: [b, b]``, ``xt: [a, b]``."""
    a, b = xt.shape
    assert h.shape == (b, b), f"H shape {h.shape} vs b={b}"
    bn, bk = _pick(b, bn), _pick(a, bk)
    return pl.pallas_call(
        _gram_kernel,
        grid=(b // bn, b // bn, a // bk),
        in_specs=[
            pl.BlockSpec((bn, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, b), h.dtype),
        interpret=True,
    )(h, xt, xt)
