"""Layer-1 Pallas kernels — the compute hot spots of the Thanos stack.

Every kernel is written for a TPU execution model (VMEM tiles shaped to
the 128x128 MXU, K-innermost accumulator revisiting) but lowered with
``interpret=True`` so the CPU PJRT runtime can execute the resulting
HLO (real-TPU lowering emits Mosaic custom-calls the CPU client cannot
run — see DESIGN.md section Hardware-Adaptation).

Correctness oracles for every kernel live in :mod:`.ref` and are pinned
by ``python/tests/test_kernels.py`` (hypothesis sweeps shapes/dtypes).
"""

from .matmul import matmul, matmul_sub
from .hessian import hessian_accum
from .metric import wanda_metric

__all__ = ["matmul", "matmul_sub", "hessian_accum", "wanda_metric"]
