"""AOT pipeline: lower every L2 graph (model + pruning) to HLO **text**
under ``artifacts/`` and write the manifest the Rust runtime loads.

HLO text — not ``lowered.compile().serialize()`` — is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids
which the xla_extension 0.5.1 runtime rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.

Usage (from the Makefile)::

    cd python && python -m compile.aot --outdir ../artifacts --models tiny,small

Python runs ONCE at build time; the Rust binary is self-contained
afterwards.
"""

import argparse
import json
import math
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import prune as P

jax.config.update("jax_platform_name", "cpu")

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


class Emitter:
    def __init__(self, outdir):
        self.outdir = outdir
        self.entries = {}

    def emit(self, name, fn, arg_specs, meta=None):
        """Lower fn(*arg_specs) and write `<name>.hlo.txt`."""
        if name in self.entries:
            return  # deduped across models sharing layer shapes
        t0 = time.time()
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.outdir, fname), "w") as f:
            f.write(text)
        args = [
            {"shape": list(s.shape), "dtype": "i32" if s.dtype == jnp.int32 else "f32"}
            for s in arg_specs
        ]
        self.entries[name] = {"file": fname, "args": args, **(meta or {})}
        print(f"  [{time.time() - t0:6.1f}s] {name}  ({len(text) // 1024} KiB)")


def emit_model(em: Emitter, name: str, cfg: dict, consts: dict):
    nbc, nbe, bs = consts["nb_calib"], consts["nb_eval"], consts["train_bs"]
    seq, d = cfg["seq_len"], cfg["d_model"]
    flat_n = M.flat_size(cfg)
    blk_n = M.block_flat_size(cfg)

    em.emit(
        f"embed_{name}",
        lambda flat, toks: (M.embed(cfg, flat, toks),),
        [spec((flat_n,)), spec((nbc, seq), I32)],
    )
    em.emit(
        f"block_capture_{name}",
        lambda fb, x: M.block_capture(cfg, fb, x),
        [spec((blk_n,)), spec((nbc, seq, d))],
    )
    em.emit(
        f"logprobs_{name}",
        lambda flat, toks: (M.nll_positions(cfg, flat, toks),),
        [spec((flat_n,)), spec((nbe, seq), I32)],
    )
    em.emit(
        f"train_step_{name}",
        lambda flat, m, v, toks, step, lr: M.train_step(
            cfg, flat, m, v, toks, step, lr=lr
        ),
        [
            spec((flat_n,)),
            spec((flat_n,)),
            spec((flat_n,)),
            spec((bs, seq), I32),
            spec((), I32),
            spec((), F32),
        ],
    )


def emit_pruning(em: Emitter, cfg: dict, consts: dict, block_size: int):
    d, dff, seq = cfg["d_model"], cfg["d_ff"], cfg["seq_len"]
    a = consts["nb_calib"] * seq
    shapes = [(d, d), (dff, d), (d, dff)]
    for b in sorted({d, dff}):
        em.emit(
            f"hessian_accum_{b}",
            lambda h, xt: P.hessian_accum(h, xt),
            [spec((b, b)), spec((a, b))],
            meta={"b": b, "a": a},
        )
    for c, b in shapes:
        sname = f"{c}x{b}"
        meta = {"c": c, "b": b}
        em.emit(
            f"prune_magnitude_{sname}",
            lambda w, r: P.magnitude_unstructured(w, r),
            [spec((c, b)), spec((), I32)],
            meta,
        )
        em.emit(
            f"prune_wanda_{sname}",
            lambda w, xn, k: P.wanda_unstructured(w, xn, k),
            [spec((c, b)), spec((b,)), spec((), I32)],
            meta,
        )
        for n, m in ((2, 4), (4, 8)):
            em.emit(
                f"prune_magnitude_nm_{sname}_{n}_{m}",
                (lambda n_, m_: lambda w: P.magnitude_nm(w, n_, m_))(n, m),
                [spec((c, b))],
                {**meta, "n": n, "m": m},
            )
            em.emit(
                f"prune_wanda_nm_{sname}_{n}_{m}",
                (lambda n_, m_: lambda w, xn: P.wanda_nm(w, xn, n_, m_))(n, m),
                [spec((c, b)), spec((b,))],
                {**meta, "n": n, "m": m},
            )
            em.emit(
                f"prune_thanos_nm_{sname}_{n}_{m}_B{block_size}",
                (
                    lambda n_, m_: lambda w, h, xn, alpha: P.thanos_nm(
                        w, h, xn, alpha, n_, m_, block_size=block_size
                    )
                )(n, m),
                [spec((c, b)), spec((b, b)), spec((b,)), spec((), F32)],
                {**meta, "n": n, "m": m, "block_size": block_size},
            )
        em.emit(
            f"prune_thanos_unstr_{sname}_B{block_size}",
            lambda w, h, xn, p: P.thanos_unstructured(
                w, h, xn, p, block_size=block_size
            ),
            [spec((c, b)), spec((b, b)), spec((b,)), spec((), F32)],
            {**meta, "block_size": block_size},
        )
        em.emit(
            f"prune_thanos_struct_{sname}",
            lambda w, h, xn, p, alpha: P.thanos_structured(w, h, xn, p, alpha),
            [spec((c, b)), spec((b, b)), spec((b,)), spec((), F32), spec((), F32)],
            meta,
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--models", default="tiny,small")
    ap.add_argument("--nb-calib", type=int, default=8)
    ap.add_argument("--nb-eval", type=int, default=8)
    ap.add_argument("--train-bs", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=128)
    # legacy single-file interface kept for Makefile compatibility
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    outdir = args.outdir if args.out is None else os.path.dirname(args.out) or "."
    os.makedirs(outdir, exist_ok=True)
    em = Emitter(outdir)
    consts = {
        "nb_calib": args.nb_calib,
        "nb_eval": args.nb_eval,
        "train_bs": args.train_bs,
    }

    manifest = {"constants": consts, "models": {}, "executables": None}
    for name in args.models.split(","):
        name = name.strip()
        cfg = M.PRESETS[name]
        print(f"== model {name}: {cfg}")
        emit_model(em, name, cfg, consts)
        emit_pruning(em, cfg, consts, args.block_size)
        rows, flat_n = M.param_layout(cfg)
        manifest["models"][name] = {
            "config": cfg,
            "flat_size": flat_n,
            "block_flat_size": M.block_flat_size(cfg),
            "param_layout": [
                {"name": n, "offset": o, "shape": list(s)} for n, o, s in rows
            ],
        }
    manifest["executables"] = em.entries
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    # marker file for `make` freshness
    with open(os.path.join(outdir, ".stamp"), "w") as f:
        f.write(str(time.time()))
    print(f"wrote {len(em.entries)} executables + manifest to {outdir}")


if __name__ == "__main__":
    main()
