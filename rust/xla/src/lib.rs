//! Offline stub of the `xla` (xla-rs / PJRT) bindings.
//!
//! The container this repo builds in has no XLA/PJRT shared libraries,
//! so the real bindings cannot link. This crate re-creates exactly the
//! API surface the `thanos` crate uses — [`Literal`] marshalling is
//! fully functional (it is plain host memory), while client creation,
//! HLO parsing and executable compilation return a descriptive
//! [`Error`]. Every AOT code path in `thanos` is already gated on the
//! presence of `artifacts/manifest.json` (written by `make artifacts`),
//! so with the stub the pure-Rust pipeline, the test-suite and the
//! benches all build and run; only actual HLO execution is unavailable.
//!
//! Swapping the real bindings back in is a one-line change in
//! `rust/Cargo.toml` — no `thanos` source touches are needed.

use std::fmt;

/// Error type mirroring `xla::Error`: convertible into `anyhow::Error`
/// through the std `Error` impl.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable in the offline xla stub (no PJRT runtime in this build; \
         swap in the real `xla` bindings to execute AOT artifacts)"
    ))
}

/// Element storage of a [`Literal`].
#[derive(Debug, Clone)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side literal: dtype-tagged buffer plus dimensions. Fully
/// functional (it is how `thanos` marshals data in and out of
/// executables, and tests construct literals without a runtime).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Types storable in a [`Literal`].
pub trait NativeType: Copy {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(l: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(l: &Literal) -> Result<Vec<Self>> {
        match &l.data {
            Data::F32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn unwrap(l: &Literal) -> Result<Vec<Self>> {
        match &l.data {
            Data::I32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not i32".into())),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: Vec::new(), data: T::wrap(vec![v]) }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape to {dims:?} ({n} elements) from {} elements",
                self.element_count()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(t) => t.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Extract the host buffer.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self)
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(t) => Ok(t),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }

    /// Build a tuple literal (test/bench helper; the real bindings
    /// return tuples from executions).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { dims: vec![elems.len() as i64], data: Data::Tuple(elems) }
    }
}

/// Parsed HLO module. Construction always fails in the stub.
#[derive(Debug)]
#[non_exhaustive]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parsing HLO text ({path})")))
    }
}

/// Computation wrapper over a parsed module.
#[derive(Debug)]
#[non_exhaustive]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by executions; never constructed in the stub.
#[derive(Debug)]
pub struct PjRtBuffer(std::convert::Infallible);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.0 {}
    }
}

/// Compiled executable; never constructed in the stub.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(std::convert::Infallible);

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.0 {}
    }
}

/// PJRT client handle. Creation succeeds (so `Runtime::load` can parse
/// manifests and report a precise error only when an executable is
/// actually compiled); `compile` fails with the stub notice.
#[derive(Debug)]
#[non_exhaustive]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling an XLA computation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.element_count(), 4);
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn literal_scalar_and_tuple() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.element_count(), 1);
        let t = Literal::tuple(vec![s.clone(), Literal::scalar(1.5f32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<i32>().unwrap(), vec![7]);
        assert!(s.to_tuple().is_err());
    }

    #[test]
    fn runtime_surface_errors_cleanly() {
        assert!(HloModuleProto::from_text_file("missing.hlo.txt").is_err());
        let client = PjRtClient::cpu().unwrap();
        let proto_err = HloModuleProto::from_text_file("x").unwrap_err();
        assert!(proto_err.to_string().contains("offline xla stub"));
        // compile fails with the stub notice
        // (XlaComputation can only be built from a proto, which cannot
        // exist here, so exercise the error text via from_text_file)
        let _ = client;
    }
}
