//! Fixture tests for rules D1–D7, allowlist behaviour, and — the one
//! that matters — a scan of the real tree against the real checked-in
//! `audit.toml`, asserting it is clean. Every expected count below was
//! pinned against the fixture by hand; a rule change that shifts any of
//! them must update the fixture and the justification together.

use thanos_audit::{allowlist, analyze_source, Finding, RuleConfig};

fn fixture(name: &str) -> String {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("fixtures")
        .join(name);
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

fn analyze(name: &str, virtual_path: &str, d4_files: &[&str]) -> Vec<Finding> {
    let cfg = RuleConfig {
        d4_files: d4_files.iter().map(|s| s.to_string()).collect(),
    };
    analyze_source(virtual_path, &fixture(name), &cfg)
}

fn rules(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------- D1

#[test]
fn d1_flags_sync_primitives_inside_submission_closures() {
    let f = analyze("d1_pos.rs", "rust/src/pruning/fake.rs", &[]);
    assert_eq!(rules(&f), ["D1", "D1"], "{f:#?}");
    assert!(f[0].text.contains("lock"), "{:?}", f[0]);
    assert!(f[1].text.contains("fetch_add"), "{:?}", f[1]);
}

#[test]
fn d1_accepts_the_per_band_slot_shape() {
    let f = analyze("d1_neg.rs", "rust/src/pruning/fake.rs", &[]);
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn d1_does_not_apply_inside_engine_itself() {
    // engine/ implements the primitives; the rule scopes to its users.
    let f = analyze("d1_pos.rs", "rust/src/engine/fake.rs", &[]);
    assert!(f.is_empty(), "{f:#?}");
}

// ---------------------------------------------------------------- D2

#[test]
fn d2_flags_hash_containers_but_not_in_tests() {
    let f = analyze("d2_pos.rs", "rust/src/sparse/fake.rs", &[]);
    // the `use` plus two call-site mentions; the cfg(test) HashSet is
    // masked out entirely.
    assert_eq!(rules(&f), ["D2", "D2", "D2"], "{f:#?}");
    assert!(f.iter().all(|x| x.text.contains("HashMap")), "{f:#?}");
}

#[test]
fn d2_accepts_btree_containers() {
    let f = analyze("d2_neg.rs", "rust/src/sparse/fake.rs", &[]);
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn d2_ignores_non_compute_modules() {
    let f = analyze("d2_pos.rs", "rust/src/model/fake.rs", &[]);
    assert!(f.is_empty(), "{f:#?}");
}

// ---------------------------------------------------------------- D3

#[test]
fn d3_flags_fma_and_narrowing_outside_kernel() {
    let f = analyze("d3_pos.rs", "rust/src/linalg/fake.rs", &[]);
    // mul_add, `d as f32`, and the `(…) as f32` on the widened sum;
    // the `a as f64` widening inside it is never flagged.
    assert_eq!(rules(&f), ["D3", "D3", "D3"], "{f:#?}");
    assert!(f[0].text.contains("mul_add"), "{:?}", f[0]);
}

#[test]
fn d3_kernel_is_the_designated_rounding_point() {
    let f = analyze("d3_pos.rs", "rust/src/linalg/kernel.rs", &[]);
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn d3_accepts_widening_only_arithmetic() {
    let f = analyze("d3_neg.rs", "rust/src/linalg/fake.rs", &[]);
    assert!(f.is_empty(), "{f:#?}");
}

// ---------------------------------------------------------------- D4

#[test]
fn d4_flags_unsafe_without_safety_comment() {
    let f = analyze("d4_pos.rs", "rust/src/engine/mod.rs", &["rust/src/engine/mod.rs"]);
    assert_eq!(rules(&f), ["D4"], "{f:#?}");
    assert!(f[0].msg.contains("SAFETY"), "{:?}", f[0]);
}

#[test]
fn d4_flags_unsafe_outside_the_file_allowlist() {
    let f = analyze("d4_pos.rs", "rust/src/model/mod.rs", &["rust/src/engine/mod.rs"]);
    assert_eq!(rules(&f), ["D4"], "{f:#?}");
    assert!(f[0].msg.contains("allowlist"), "{:?}", f[0]);
}

#[test]
fn d4_accepts_commented_unsafe_in_allowed_files() {
    let f = analyze("d4_neg.rs", "rust/src/engine/mod.rs", &["rust/src/engine/mod.rs"]);
    assert!(f.is_empty(), "{f:#?}");
}

// ---------------------------------------------------------------- D5

#[test]
fn d5_flags_thread_spawning_outside_engine() {
    let f = analyze("d5_pos.rs", "rust/src/pruning/fake.rs", &[]);
    assert_eq!(rules(&f), ["D5", "D5"], "{f:#?}");
}

#[test]
fn d5_engine_is_allowed_to_spawn() {
    let f = analyze("d5_pos.rs", "rust/src/engine/fake.rs", &[]);
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn d5_accepts_parallelism_queries() {
    let f = analyze("d5_neg.rs", "rust/src/pruning/fake.rs", &[]);
    assert!(f.is_empty(), "{f:#?}");
}

// ---------------------------------------------------------------- D6

#[test]
fn d6_flags_wall_clock_and_ambient_rng() {
    let f = analyze("d6_pos.rs", "rust/src/linalg/fake.rs", &[]);
    assert_eq!(rules(&f), ["D6", "D6", "D6"], "{f:#?}");
    assert!(f[0].text.contains("Instant"), "{:?}", f[0]);
    assert!(f[2].text.contains("rand::"), "{:?}", f[2]);
}

#[test]
fn d6_accepts_seeded_rng() {
    let f = analyze("d6_neg.rs", "rust/src/linalg/fake.rs", &[]);
    assert!(f.is_empty(), "{f:#?}");
}

// ---------------------------------------------------------------- D7

#[test]
fn d7_flags_raw_write_sites_outside_robust() {
    let f = analyze("d7_pos.rs", "rust/src/model/fake.rs", &[]);
    assert_eq!(rules(&f), ["D7", "D7", "D7"], "{f:#?}");
    assert!(f[0].text.contains("fs::write"), "{:?}", f[0]);
    assert!(f[1].text.contains("File::create"), "{:?}", f[1]);
    assert!(f[2].text.contains("OpenOptions"), "{:?}", f[2]);
}

#[test]
fn d7_robust_implements_the_machinery_and_is_exempt() {
    let f = analyze("d7_pos.rs", "rust/src/robust/fake.rs", &[]);
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn d7_accepts_atomic_writers_and_reads() {
    let f = analyze("d7_neg.rs", "rust/src/model/fake.rs", &[]);
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn d7_chunk_container_write_machinery_is_exempt_inside_robust_stream() {
    // The .thsc ChunkWriter commit path (create, append, marker write)
    // lives at rust/src/robust/stream.rs — the designated write layer.
    let f = analyze("d7_stream_pos.rs", "rust/src/robust/stream.rs", &[]);
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn d7_chunk_container_writes_are_flagged_outside_robust() {
    // The same container machinery copied anywhere else must route
    // through robust::atomic instead: three findings, source order.
    let f = analyze("d7_stream_pos.rs", "rust/src/model/stream.rs", &[]);
    assert_eq!(rules(&f), ["D7", "D7", "D7"], "{f:#?}");
    assert!(f[0].text.contains("File::create"), "{:?}", f[0]);
    assert!(f[1].text.contains("fs::write"), "{:?}", f[1]);
    assert!(f[2].text.contains("OpenOptions"), "{:?}", f[2]);
}

// ------------------------------------------------------- allowlist

#[test]
fn allowlist_suppresses_exact_counts_and_reports_stale() {
    let toml = r#"
[d4]
files = []

[[allow]]
rule = "D6"
file = "rust/src/linalg/fake.rs"
contains = "Instant::now"
count = 1
reason = "fixture: timing is observability here"
"#;
    let allow = allowlist::parse(toml).unwrap();
    let f = analyze("d6_pos.rs", "rust/src/linalg/fake.rs", &[]);
    let applied = allow.apply(f);
    assert_eq!(applied.suppressed, 1);
    assert_eq!(applied.unallowed.len(), 2, "{:#?}", applied.unallowed);
    assert!(applied.stale.is_empty(), "{:?}", applied.stale);
}

#[test]
fn allowlist_entry_matching_nothing_is_stale() {
    let toml = r#"
[[allow]]
rule = "D6"
file = "rust/src/linalg/fake.rs"
contains = "no_such_call"
reason = "fixture: deliberately stale"
"#;
    let allow = allowlist::parse(toml).unwrap();
    let f = analyze("d6_neg.rs", "rust/src/linalg/fake.rs", &[]);
    let applied = allow.apply(f);
    assert_eq!(applied.stale.len(), 1, "{:?}", applied.stale);
    assert!(applied.stale[0].contains("no_such_call"), "{:?}", applied.stale);
}

#[test]
fn allowlist_count_mismatch_is_stale() {
    let toml = r#"
[[allow]]
rule = "D6"
file = "rust/src/linalg/fake.rs"
contains = "::now"
count = 1
reason = "fixture: pinned too tightly on purpose"
"#;
    let allow = allowlist::parse(toml).unwrap();
    // d6_pos has two `::now` call sites → count = 1 is a mismatch.
    let f = analyze("d6_pos.rs", "rust/src/linalg/fake.rs", &[]);
    let applied = allow.apply(f);
    assert_eq!(applied.suppressed, 2);
    assert_eq!(applied.stale.len(), 1, "{:?}", applied.stale);
}

// ------------------------------------------------- the real gate

/// The whole point: the shipped tree, scanned with the shipped
/// `audit.toml`, has zero unallowlisted findings and zero stale
/// entries. This runs under plain `cargo test`, so tier-1 CI carries
/// the determinism-contract gate even without the CLI invocation.
#[test]
fn real_tree_is_clean_under_the_checked_in_allowlist() {
    let root = thanos_audit::find_root(std::path::Path::new(env!("CARGO_MANIFEST_DIR")));
    let toml_path = root.join("audit.toml");
    let toml_text = std::fs::read_to_string(&toml_path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", toml_path.display()));
    let allow = allowlist::parse(&toml_text).unwrap();
    let cfg = RuleConfig {
        d4_files: allow.d4_files.clone(),
    };
    let (n_files, findings) = thanos_audit::scan_tree(&root, &cfg).unwrap();
    assert!(n_files >= 10, "expected the full tree, scanned only {n_files} files");
    let applied = allow.apply(findings);
    let rendered: Vec<String> = applied.unallowed.iter().map(Finding::render).collect();
    assert!(
        rendered.is_empty(),
        "unallowlisted findings in the tree:\n{}",
        rendered.join("\n")
    );
    assert!(
        applied.stale.is_empty(),
        "stale audit.toml entries:\n{}",
        applied.stale.join("\n")
    );
    assert!(applied.suppressed > 0, "allowlist should be exercising real exceptions");
}
