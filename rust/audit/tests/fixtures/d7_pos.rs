// D7 positive: raw file-write sites outside robust/. Expected
// findings: 3 (fs::write, File::create, OpenOptions); the cfg(test)
// scratch write is exempt.
use std::fs::File;

fn save_report(path: &str, text: &str) -> std::io::Result<()> {
    std::fs::write(path, text)?;
    let f = File::create(path)?;
    drop(f);
    let o = std::fs::OpenOptions::new().write(true).truncate(true).open(path)?;
    drop(o);
    Ok(())
}

#[cfg(test)]
mod tests {
    fn scratch_files_are_fine() {
        std::fs::write("/tmp/scratch", b"x").unwrap();
    }
}
