// D7 negative: reads are unrestricted, and writes that route through
// the robust atomic writer are the sanctioned shape. Expected
// findings: 0.
use std::io::Write;

fn save_report(path: &std::path::Path, text: &str) -> anyhow::Result<()> {
    let previous = std::fs::read(path)?;
    crate::robust::write_atomic(path, text.as_bytes())?;
    let mut f = crate::robust::AtomicFile::create(path)?;
    f.write_all(&previous)?;
    f.commit()?;
    Ok(())
}
