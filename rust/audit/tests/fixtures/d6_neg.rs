// D6 negative: seeded, explicit randomness and no timing — the only
// entropy a compute path may consume is a caller-provided seed.
fn f(seed: u64) -> f64 {
    let mut rng = crate::rng::Rng::new(seed);
    rng.uniform()
}
