// D3 negative: f64 accumulation with the rounding left to the caller's
// designated point; widening casts are always fine.
fn f(a: f32, b: f32, c: f64) -> f64 {
    c + (a as f64) * (b as f64)
}
