// D5 positive: raw thread spawning outside engine/. Expected: 2.
fn f() {
    std::thread::spawn(|| {});
    std::thread::scope(|s| {
        let _ = s;
    });
}
