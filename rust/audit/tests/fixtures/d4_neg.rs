// D4 negative: allowlisted file + a SAFETY comment within 4 lines.
fn read(p: *const u32, q: *const u32) -> u32 {
    // SAFETY: caller guarantees both pointers are valid and aligned
    // (they come from a live, bounds-checked slice).
    unsafe { *p + *q }
}
