// D1 positive: shared-state sync primitives inside engine-submission
// closures. Expected findings: 2 (`lock`, `fetch_add`).
fn bad(eng: &Engine, out: &mut [f32], total: &std::sync::Mutex<f32>, n: &AtomicUsize) {
    eng.run(4, |i| {
        *total.lock().unwrap() += out[i];
        n.fetch_add(1, Ordering::Relaxed);
    });
}
