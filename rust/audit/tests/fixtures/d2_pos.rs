// D2 positive: hash containers in a compute module. Expected findings:
// 3 (the `use` plus two mentions); the cfg(test) HashSet is exempt.
use std::collections::HashMap;

fn counts() -> HashMap<u32, f32> {
    HashMap::new()
}

#[cfg(test)]
mod tests {
    use std::collections::HashSet;

    fn fine_in_tests() {
        let _ = HashSet::<u32>::new();
    }
}
