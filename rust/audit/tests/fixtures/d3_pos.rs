// D3 positive outside kernel.rs: one FMA, one narrowing cast. Expected
// findings: 2 (the widening `as f64` is never flagged). The same file
// analyzed under the path rust/src/linalg/kernel.rs is clean — the
// kernel owns the designated rounding points.
fn f(a: f32, b: f32, c: f32, d: f64) -> f32 {
    let x = a.mul_add(b, c);
    let y = d as f32;
    let z = (a as f64 + d) as f32;
    x + y + z
}
