// D1 negative: the blessed shape — disjoint per-band slots, reduced in
// ascending order on the submitter after the job completes.
fn good(eng: &Engine, rows: usize) -> f64 {
    let mut slots = vec![0.0f64; rows];
    eng.for_each_band(&mut slots, 1, |i, slot| {
        slot[0] = work(i);
    });
    slots.iter().sum()
}
