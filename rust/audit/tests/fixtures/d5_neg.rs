// D5 negative: querying parallelism is fine anywhere; spawning is what
// the rule forbids (and engine/ itself is exempt — it IS the pool).
fn f() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}
