// D2 negative: order-stable containers are the blessed replacements.
use std::collections::BTreeMap;

fn counts(keys: &[u32]) -> BTreeMap<u32, usize> {
    let mut m = BTreeMap::new();
    for &k in keys {
        *m.entry(k).or_insert(0) += 1;
    }
    m
}
