// D4 positive: an `unsafe` block with no safety comment in reach.
// Expected: 1 finding when the file is on the [d4] list (missing
// comment), and 1 finding when it is not (file not allowed at all).
fn read(p: *const u32) -> u32 {
    unsafe { *p }
}
