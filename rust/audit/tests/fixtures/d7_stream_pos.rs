// D7 streaming positive: chunk-container write machinery shaped like
// robust/stream.rs's ChunkWriter commit path — a raw create/append/
// marker-write sequence. Under rust/src/robust/ this is the exempt
// implementation layer; anywhere else it is 3 findings in source
// order (File::create, fs::write, OpenOptions). The cfg(test) spill
// cleanup write stays exempt either way.
use std::io::Write;

fn commit_container(path: &std::path::Path, payload: &[u8], table: &[u8]) -> std::io::Result<()> {
    let tmp = path.with_extension("tmp");
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(payload)?;
    f.write_all(table)?;
    f.sync_all()?;
    std::fs::rename(&tmp, path)?;
    std::fs::write(path.with_extension("crc"), format!("{}", payload.len()))?;
    let mut tail = std::fs::OpenOptions::new().append(true).open(path)?;
    tail.write_all(b"THSC")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    fn spill_scratch_is_fine() {
        std::fs::write("/tmp/spill.thsc", b"THSC").unwrap();
    }
}
