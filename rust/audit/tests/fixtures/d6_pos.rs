// D6 positive: wall-clock and ambient RNG in a compute path. Expected
// findings: 3 (Instant, SystemTime, rand::).
fn f() -> f64 {
    let t0 = std::time::Instant::now();
    let _ = std::time::SystemTime::now();
    let r: f64 = rand::random();
    t0.elapsed().as_secs_f64() + r
}
