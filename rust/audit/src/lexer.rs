//! A small purpose-built Rust lexer.
//!
//! `syn` (the obvious choice) is a registry dependency the offline
//! vendor set does not carry, and the determinism rules (DESIGN.md
//! §Determinism-contract) only need token-level structure: identifiers,
//! punctuation, literals and comments with exact line numbers, plus
//! enough bracket matching to delimit `#[cfg(test)]` items and call
//! argument spans. So the lexer is written from scratch, like the
//! crate's linear algebra.
//!
//! It understands the token shapes that would otherwise break a naive
//! scanner: nested block comments, string escapes including the
//! backslash-newline line continuation, raw strings (`r"…"`,
//! `r#"…"#`, `br"…"`), byte strings, char literals vs lifetimes, and
//! float literals (`1.5e-3` does not end at the dot). Everything else
//! is a single-character punctuation token.

/// Token class. `Comment` tokens are kept (rule D4 reads `// SAFETY:`
/// markers); rules that only care about code filter them out.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Punct,
    Lit,
    Comment,
}

/// One lexed token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

fn is_id_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_id_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into tokens. Never fails: unrecognized bytes become
/// punctuation tokens, unterminated literals run to end-of-file.
pub fn lex(src: &str) -> Vec<Token> {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    let text = |a: usize, b: usize| -> String { s[a..b].iter().collect() };
    while i < n {
        let c = s[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment
        if c == '/' && i + 1 < n && s[i + 1] == '/' {
            let mut j = i;
            while j < n && s[j] != '\n' {
                j += 1;
            }
            toks.push(Token { kind: Kind::Comment, text: text(i, j), line });
            i = j;
            continue;
        }
        // block comment (nesting)
        if c == '/' && i + 1 < n && s[i + 1] == '*' {
            let start = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if s[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if s[j] == '/' && j + 1 < n && s[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if s[j] == '*' && j + 1 < n && s[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            toks.push(Token { kind: Kind::Comment, text: text(i, j), line: start });
            i = j;
            continue;
        }
        // raw (byte) strings: r"…", r#"…"#, br"…", br#"…"#
        if c == 'r' || c == 'b' {
            let mut k = i;
            let mut pref = 0usize;
            while k < n && (s[k] == 'r' || s[k] == 'b') && pref < 2 {
                pref += 1;
                k += 1;
            }
            let has_r = s[i..k].contains(&'r');
            if has_r && k < n && (s[k] == '#' || s[k] == '"') {
                let mut hashes = 0usize;
                while k < n && s[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && s[k] == '"' {
                    let start = line;
                    let mut j = k + 1;
                    'scan: while j < n {
                        if s[j] == '\n' {
                            line += 1;
                        } else if s[j] == '"' {
                            let mut h = 0usize;
                            while h < hashes && j + 1 + h < n && s[j + 1 + h] == '#' {
                                h += 1;
                            }
                            if h == hashes {
                                j += 1 + hashes;
                                break 'scan;
                            }
                        }
                        j += 1;
                    }
                    toks.push(Token { kind: Kind::Lit, text: text(i, j), line: start });
                    i = j;
                    continue;
                }
                // `r#ident` raw identifiers fall through to ident lexing
            }
        }
        // plain (byte) strings
        if c == '"' || (c == 'b' && i + 1 < n && s[i + 1] == '"') {
            let start = line;
            let mut j = i + if c == 'b' { 2 } else { 1 };
            while j < n {
                if s[j] == '\\' {
                    // escapes, including the backslash-newline
                    // continuation (which must still count the line)
                    if j + 1 < n && s[j + 1] == '\n' {
                        line += 1;
                    }
                    j += 2;
                    continue;
                }
                if s[j] == '\n' {
                    line += 1;
                }
                if s[j] == '"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            toks.push(Token { kind: Kind::Lit, text: text(i, j), line: start });
            i = j;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            let mut j = i + 1;
            if j < n && is_id_start(s[j]) {
                let mut k = j;
                while k < n && is_id_cont(s[k]) {
                    k += 1;
                }
                if k == j + 1 && k < n && s[k] == '\'' {
                    // 'x' — a one-character char literal
                    toks.push(Token { kind: Kind::Lit, text: text(i, k + 1), line });
                    i = k + 1;
                } else {
                    // 'ident — a lifetime
                    toks.push(Token { kind: Kind::Lit, text: text(i, k), line });
                    i = k;
                }
                continue;
            }
            if j < n && s[j] == '\\' {
                j += 2;
                while j < n && s[j] != '\'' {
                    j += 1;
                }
                j += 1;
            } else {
                j += 1;
                if j < n && s[j] == '\'' {
                    j += 1;
                }
            }
            let j = j.min(n);
            toks.push(Token { kind: Kind::Lit, text: text(i, j), line });
            i = j;
            continue;
        }
        if is_id_start(c) {
            let mut j = i;
            while j < n && is_id_cont(s[j]) {
                j += 1;
            }
            toks.push(Token { kind: Kind::Ident, text: text(i, j), line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && is_id_cont(s[j]) {
                j += 1;
            }
            if j < n && s[j] == '.' && j + 1 < n && s[j + 1].is_ascii_digit() {
                j += 1;
                while j < n && is_id_cont(s[j]) {
                    j += 1;
                }
            }
            toks.push(Token { kind: Kind::Lit, text: text(i, j), line });
            i = j;
            continue;
        }
        toks.push(Token { kind: Kind::Punct, text: c.to_string(), line });
        i += 1;
    }
    toks
}

/// Mark tokens belonging to `#[cfg(test)]` items (and the attribute
/// itself) as masked. Returns one bool per token: `true` = keep.
///
/// The determinism contract governs production compute paths; test
/// modules legitimately use timing, hash containers and ad-hoc
/// reductions, so every rule runs on the unmasked stream only.
pub fn mask_test_code(toks: &[Token]) -> Vec<bool> {
    let mut keep = vec![true; toks.len()];
    // indices of non-comment tokens (attributes and items are matched
    // on code tokens; interleaved comments are masked by range)
    let idxs: Vec<usize> = (0..toks.len())
        .filter(|&i| toks[i].kind != Kind::Comment)
        .collect();
    let m = idxs.len();
    let tk = |p: usize| -> (&Kind, &str) { (&toks[idxs[p]].kind, toks[idxs[p]].text.as_str()) };
    let is_p = |p: usize, ch: &str| -> bool {
        let (k, t) = tk(p);
        *k == Kind::Punct && t == ch
    };
    let mut p = 0usize;
    while p < m {
        if is_p(p, "#") && p + 1 < m && is_p(p + 1, "[") {
            // scan the attribute for `cfg` … `test`
            let mut q = p + 2;
            let mut depth = 1usize;
            let mut saw_cfg = false;
            let mut is_test = false;
            while q < m && depth > 0 {
                let (k, t) = tk(q);
                if *k == Kind::Punct && t == "[" {
                    depth += 1;
                } else if *k == Kind::Punct && t == "]" {
                    depth -= 1;
                } else if *k == Kind::Ident && t == "cfg" {
                    saw_cfg = true;
                } else if *k == Kind::Ident && t == "test" && saw_cfg {
                    is_test = true;
                }
                q += 1;
            }
            if is_test {
                // skip any further attributes on the same item
                while q + 1 < m && is_p(q, "#") && is_p(q + 1, "[") {
                    q += 2;
                    let mut d = 1usize;
                    while q < m && d > 0 {
                        if is_p(q, "[") {
                            d += 1;
                        } else if is_p(q, "]") {
                            d -= 1;
                        }
                        q += 1;
                    }
                }
                // mask through the end of the item: the matching `}` of
                // its first top-level brace, or a top-level `;`
                let start = p;
                let mut d = 0isize;
                while q < m {
                    let (k, t) = tk(q);
                    if *k == Kind::Punct && (t == "(" || t == "[") {
                        d += 1;
                    } else if *k == Kind::Punct && (t == ")" || t == "]") {
                        d -= 1;
                    } else if *k == Kind::Punct && t == "{" && d == 0 {
                        let mut bd = 1usize;
                        q += 1;
                        while q < m && bd > 0 {
                            if is_p(q, "{") {
                                bd += 1;
                            } else if is_p(q, "}") {
                                bd -= 1;
                            }
                            q += 1;
                        }
                        break;
                    } else if *k == Kind::Punct && t == ";" && d == 0 {
                        q += 1;
                        break;
                    }
                    q += 1;
                }
                // mask the token range, comments included
                let lo = idxs[start];
                let hi = if q < m { idxs[q] } else { toks.len() };
                for slot in keep.iter_mut().take(hi).skip(lo) {
                    *slot = false;
                }
                p = q;
                continue;
            }
        }
        p += 1;
    }
    keep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<(String, u32)> {
        lex(src)
            .into_iter()
            .filter(|t| t.kind == Kind::Ident)
            .map(|t| (t.text, t.line))
            .collect()
    }

    #[test]
    fn lines_survive_comments_strings_and_continuations() {
        let src = "/* a\nb */ one\n\"x\\\ny\" two\nr#\"raw\nstill\"# three\n";
        let ids = idents(src);
        assert_eq!(
            ids,
            vec![
                ("one".to_string(), 2),
                ("two".to_string(), 4),
                ("three".to_string(), 6)
            ]
        );
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let toks = lex("fn f<'a>(x: &'a str) { let c = 'x'; }");
        let lits: Vec<String> = toks
            .iter()
            .filter(|t| t.kind == Kind::Lit)
            .map(|t| t.text.clone())
            .collect();
        assert_eq!(lits, vec!["'a", "'a", "'x'"]);
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let ids = idents("/* x /* y */ z */ after");
        assert_eq!(ids, vec![("after".to_string(), 1)]);
    }

    #[test]
    fn float_literals_do_not_split_at_the_dot() {
        let toks = lex("let x = 1.5e-3 + 0.0;");
        let lits: Vec<String> = toks
            .iter()
            .filter(|t| t.kind == Kind::Lit)
            .map(|t| t.text.clone())
            .collect();
        // `e-3` exponent sign splits (harmless for the rules): the key
        // property is that `1.5` and `0.0` stay single tokens
        assert!(lits.contains(&"1.5e".to_string()) || lits.contains(&"1.5e-3".to_string()));
        assert!(lits.contains(&"0.0".to_string()));
    }

    #[test]
    fn cfg_test_mod_is_masked_entirely() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn dead() { HashMap::new(); }\n}\nfn live2() {}\n";
        let toks = lex(src);
        let keep = mask_test_code(&toks);
        let kept: Vec<&str> = toks
            .iter()
            .zip(&keep)
            .filter(|(t, &k)| k && t.kind == Kind::Ident)
            .map(|(t, _)| t.text.as_str())
            .collect();
        assert!(kept.contains(&"live"));
        assert!(kept.contains(&"live2"));
        assert!(!kept.contains(&"dead"));
        assert!(!kept.contains(&"HashMap"));
    }

    #[test]
    fn cfg_test_use_item_is_masked_to_the_semicolon() {
        let src = "#[cfg(test)]\nuse std::collections::HashSet;\nfn live() {}\n";
        let toks = lex(src);
        let keep = mask_test_code(&toks);
        let kept: Vec<&str> = toks
            .iter()
            .zip(&keep)
            .filter(|(t, &k)| k && t.kind == Kind::Ident)
            .map(|(t, _)| t.text.as_str())
            .collect();
        assert!(!kept.contains(&"HashSet"));
        assert!(kept.contains(&"live"));
    }

    #[test]
    fn cfg_all_test_counts_as_test() {
        let src = "#[cfg(all(test, feature = \"x\"))]\nmod t { fn dead() {} }\nfn live() {}\n";
        let toks = lex(src);
        let keep = mask_test_code(&toks);
        let kept: Vec<&str> = toks
            .iter()
            .zip(&keep)
            .filter(|(t, &k)| k && t.kind == Kind::Ident)
            .map(|(t, _)| t.text.as_str())
            .collect();
        assert!(!kept.contains(&"dead"));
        assert!(kept.contains(&"live"));
    }
}
