//! The checked-in allowlist (`audit.toml` at the repo root).
//!
//! The file carries the *justified* exceptions to the determinism
//! rules, each with a reason, and the list of files allowed to contain
//! `unsafe` at all (rule D4). The parser is a deliberately small TOML
//! subset (no registry TOML crate in the offline vendor set): comments,
//! `[section]` / `[[array-of-tables]]` headers, `key = "string"`,
//! `key = integer` and `key = ["a", "b"]` on one line — exactly the
//! shapes `audit.toml` uses, rejected loudly otherwise.
//!
//! Matching is content-based (`contains` against the finding's trimmed
//! line text) rather than line-number-based, so entries survive
//! unrelated edits; the `count` field pins the expected number of
//! matches so silently *growing* a rounding point past its audit is
//! still caught. Every entry must keep matching (stale entries fail
//! the audit) — the allowlist can only shrink by editing it.

use crate::rules::Finding;

/// One `[[allow]]` entry.
#[derive(Clone, Debug, Default)]
pub struct AllowEntry {
    /// rule id the entry suppresses (`"D3"`, …)
    pub rule: String,
    /// exact repo-relative file the findings live in
    pub file: String,
    /// substring of the finding's trimmed source line
    pub contains: String,
    /// expected number of matched findings (entry is stale otherwise);
    /// `None` means "at least one"
    pub count: Option<usize>,
    /// why the exception is sound — required, it is the documentation
    pub reason: String,
}

/// Parsed `audit.toml`.
#[derive(Clone, Debug, Default)]
pub struct Allowlist {
    /// `[d4] files = [...]` — files allowed to contain `unsafe`
    pub d4_files: Vec<String>,
    /// `[[allow]]` entries
    pub entries: Vec<AllowEntry>,
}

/// Result of applying the allowlist to a finding set.
#[derive(Clone, Debug, Default)]
pub struct Applied {
    /// findings no entry matched — these fail the audit
    pub unallowed: Vec<Finding>,
    /// number of findings suppressed by entries
    pub suppressed: usize,
    /// human-readable descriptions of stale entries — these fail too
    pub stale: Vec<String>,
}

fn unquote(v: &str, where_: &str) -> Result<String, String> {
    let v = v.trim();
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        Ok(v[1..v.len() - 1].to_string())
    } else {
        Err(format!("audit.toml: expected a quoted string in {where_}, got `{v}`"))
    }
}

/// Strip a `#` comment that is not inside a quoted string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse `audit.toml` text.
pub fn parse(text: &str) -> Result<Allowlist, String> {
    let mut out = Allowlist::default();
    // section: 0 = none/top, 1 = [d4], 2 = current [[allow]] entry
    let mut section = 0u8;
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if line == "[[allow]]" {
            out.entries.push(AllowEntry::default());
            section = 2;
            continue;
        }
        if line == "[d4]" {
            section = 1;
            continue;
        }
        if line.starts_with('[') {
            return Err(format!("audit.toml:{lineno}: unknown section `{line}`"));
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("audit.toml:{lineno}: expected `key = value`, got `{line}`"))?;
        let key = key.trim();
        let value = value.trim();
        match (section, key) {
            (1, "files") => {
                let inner = value
                    .strip_prefix('[')
                    .and_then(|v| v.strip_suffix(']'))
                    .ok_or_else(|| {
                        format!("audit.toml:{lineno}: [d4] files must be a one-line array")
                    })?;
                for part in inner.split(',') {
                    let part = part.trim();
                    if part.is_empty() {
                        continue;
                    }
                    out.d4_files.push(unquote(part, "[d4] files")?);
                }
            }
            (2, _) => {
                let entry = out
                    .entries
                    .last_mut()
                    .expect("section 2 implies at least one entry");
                match key {
                    "rule" => entry.rule = unquote(value, "allow.rule")?,
                    "file" => entry.file = unquote(value, "allow.file")?,
                    "contains" => entry.contains = unquote(value, "allow.contains")?,
                    "reason" => entry.reason = unquote(value, "allow.reason")?,
                    "count" => {
                        let c: usize = value.parse().map_err(|_| {
                            format!("audit.toml:{lineno}: count must be an integer, got `{value}`")
                        })?;
                        entry.count = Some(c);
                    }
                    _ => {
                        return Err(format!(
                            "audit.toml:{lineno}: unknown [[allow]] key `{key}`"
                        ));
                    }
                }
            }
            _ => {
                return Err(format!(
                    "audit.toml:{lineno}: key `{key}` outside a known section"
                ));
            }
        }
    }
    for (i, e) in out.entries.iter().enumerate() {
        if e.rule.is_empty() || e.file.is_empty() || e.contains.is_empty() {
            return Err(format!(
                "audit.toml: [[allow]] entry #{} needs rule, file and contains",
                i + 1
            ));
        }
        if e.reason.is_empty() {
            return Err(format!(
                "audit.toml: [[allow]] entry #{} ({} {}): a reason is required",
                i + 1,
                e.rule,
                e.file
            ));
        }
    }
    Ok(out)
}

impl AllowEntry {
    fn matches(&self, f: &Finding) -> bool {
        self.rule == f.rule && self.file == f.file && f.text.contains(&self.contains)
    }

    fn describe(&self) -> String {
        format!("[[allow]] {} {} contains=\"{}\"", self.rule, self.file, self.contains)
    }
}

impl Allowlist {
    /// Split `findings` into suppressed and unallowed, and detect stale
    /// entries (zero matches, or a match count different from `count`).
    pub fn apply(&self, findings: Vec<Finding>) -> Applied {
        let mut matched = vec![0usize; self.entries.len()];
        let mut applied = Applied::default();
        for f in findings {
            let mut hit = false;
            for (ei, e) in self.entries.iter().enumerate() {
                if e.matches(&f) {
                    matched[ei] += 1;
                    hit = true;
                }
            }
            if hit {
                applied.suppressed += 1;
            } else {
                applied.unallowed.push(f);
            }
        }
        for (e, &got) in self.entries.iter().zip(&matched) {
            let stale = match e.count {
                Some(want) => got != want,
                None => got == 0,
            };
            if stale {
                let want = e.count.map_or("≥1".to_string(), |c| c.to_string());
                applied.stale.push(format!(
                    "{} matched {got} finding(s), expected {want} — update or remove it",
                    e.describe()
                ));
            }
        }
        applied
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# exceptions with reasons
[d4]
files = ["rust/src/engine/mod.rs"]

[[allow]]
rule = "D3"
file = "rust/src/pruning/thanos.rs"
contains = "delta[jj] as f32"
count = 1
reason = "seed-arithmetic rounding point"
"#;

    #[test]
    fn parses_sections_entries_and_arrays() {
        let a = parse(SAMPLE).unwrap();
        assert_eq!(a.d4_files, vec!["rust/src/engine/mod.rs"]);
        assert_eq!(a.entries.len(), 1);
        let e = &a.entries[0];
        assert_eq!(e.rule, "D3");
        assert_eq!(e.count, Some(1));
        assert_eq!(e.reason, "seed-arithmetic rounding point");
    }

    #[test]
    fn missing_reason_is_rejected() {
        let bad = "[[allow]]\nrule = \"D3\"\nfile = \"x.rs\"\ncontains = \"y\"\n";
        let err = parse(bad).unwrap_err();
        assert!(err.contains("reason"), "{err}");
    }

    #[test]
    fn unknown_keys_and_sections_are_rejected() {
        assert!(parse("[mystery]\n").is_err());
        assert!(parse("[[allow]]\nrule = \"D3\"\nbogus = \"x\"\n").is_err());
    }

    fn finding(rule: &'static str, file: &str, text: &str) -> Finding {
        Finding {
            rule,
            file: file.to_string(),
            line: 1,
            msg: String::new(),
            text: text.to_string(),
        }
    }

    #[test]
    fn apply_suppresses_matches_and_reports_stale() {
        let a = parse(SAMPLE).unwrap();
        let hit = finding("D3", "rust/src/pruning/thanos.rs", "row[jj] -= delta[jj] as f32;");
        let miss = finding("D3", "rust/src/pruning/thanos.rs", "other as f32");
        let r = a.apply(vec![hit.clone(), miss]);
        assert_eq!(r.suppressed, 1);
        assert_eq!(r.unallowed.len(), 1);
        assert!(r.stale.is_empty(), "{:?}", r.stale);
        // same entry with nothing to match → stale
        let r2 = a.apply(Vec::new());
        assert_eq!(r2.stale.len(), 1);
        // count mismatch (two matches for count = 1) → stale
        let r3 = a.apply(vec![hit.clone(), hit]);
        assert_eq!(r3.suppressed, 2);
        assert_eq!(r3.stale.len(), 1);
    }
}
