//! CLI: `cargo run -p thanos-audit [-- --root <repo-root>]`
//!
//! Scans `rust/src` against the checked-in `audit.toml` and prints one
//! `file:line · rule · explanation` row per finding. Exit codes:
//! `0` clean, `1` unallowlisted findings, `2` stale allowlist entries
//! or configuration errors — all nonzero so CI and pre-push hooks can
//! gate on it directly.

use std::path::PathBuf;
use std::process::ExitCode;

use thanos_audit::{scan_tree, Allowlist, RuleConfig};

fn run() -> Result<u8, String> {
    let mut args = std::env::args().skip(1);
    let mut root: Option<PathBuf> = None;
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => {
                let v = args.next().ok_or_else(|| "--root needs a path".to_string())?;
                root = Some(PathBuf::from(v));
            }
            "--help" | "-h" => {
                println!("usage: thanos-audit [--root <repo-root>]");
                return Ok(0);
            }
            other => return Err(format!("unknown argument `{other}`")),
        }
    }
    let root = root.unwrap_or_else(|| {
        let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        thanos_audit::find_root(&cwd)
    });
    let toml_path = root.join("audit.toml");
    let toml_text = std::fs::read_to_string(&toml_path)
        .map_err(|e| format!("cannot read {}: {e}", toml_path.display()))?;
    let allow: Allowlist = thanos_audit::allowlist::parse(&toml_text)?;
    let cfg = RuleConfig { d4_files: allow.d4_files.clone() };
    let (n_files, findings) =
        scan_tree(&root, &cfg).map_err(|e| format!("scanning {}: {e}", root.display()))?;
    let applied = allow.apply(findings);
    for f in &applied.unallowed {
        println!("{}", f.render());
        println!("    {}", f.text);
    }
    for s in &applied.stale {
        println!("stale allowlist entry: {s}");
    }
    let clean = applied.unallowed.is_empty() && applied.stale.is_empty();
    println!(
        "thanos-audit: {n_files} files scanned, {} finding(s) suppressed by audit.toml, \
         {} unallowlisted, {} stale {}",
        applied.suppressed,
        applied.unallowed.len(),
        applied.stale.len(),
        if clean { "— clean" } else { "— FAIL" },
    );
    if !applied.unallowed.is_empty() {
        Ok(1)
    } else if !applied.stale.is_empty() {
        Ok(2)
    } else {
        Ok(0)
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(code) => ExitCode::from(code),
        Err(e) => {
            eprintln!("thanos-audit: {e}");
            ExitCode::from(2)
        }
    }
}
