//! The determinism-contract rules D1–D6 and the crash-safety rule D7
//! (DESIGN.md §Determinism-contract, §Robustness).
//!
//! Every rule is a token-level pass over one source file, scoped by the
//! file's repo-relative path. Findings carry the source line text so
//! the allowlist can match on content (stable under line drift) and so
//! reports are explainable without opening the file.

use crate::lexer::{self, Kind};

/// Module prefixes whose code is "compute": the paths the
/// serial==parallel bitwise contract and the seed-arithmetic contract
/// govern. Everything else (config, IO, metrics, CLI, eval) may use
/// timing, hashing and ad-hoc iteration freely. `trace/` is scanned
/// because it is the crate's single wall-clock authority: every timer
/// in the compute paths reads through `trace::clock`, so D6 pins the
/// one `Instant::now` site there instead of a scatter of exceptions.
/// `robust/` is scanned for the same reason trace/ is: it is the
/// crate's single file-write authority (rule D7), and the fault-replay
/// story only holds if the module itself stays D1–D6 deterministic.
pub const COMPUTE_PREFIXES: [&str; 6] = [
    "rust/src/linalg",
    "rust/src/pruning",
    "rust/src/sparse",
    "rust/src/engine",
    "rust/src/trace",
    "rust/src/robust",
];

/// One rule violation at a source location.
#[derive(Clone, Debug)]
pub struct Finding {
    /// rule id: `"D1"` … `"D7"`
    pub rule: &'static str,
    /// repo-relative path with forward slashes
    pub file: String,
    /// 1-based line
    pub line: u32,
    /// human explanation of the contract the site breaks
    pub msg: String,
    /// trimmed source line text (allowlist matching + reports)
    pub text: String,
}

impl Finding {
    /// `file:line · rule · explanation` — the report line format.
    pub fn render(&self) -> String {
        format!("{}:{} · {} · {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Static rule configuration (the D4 file allowlist comes from
/// `audit.toml`, not from code).
#[derive(Clone, Debug, Default)]
pub struct RuleConfig {
    /// Files allowed to contain `unsafe` at all (rule D4). Every
    /// occurrence still needs a `// SAFETY:` comment.
    pub d4_files: Vec<String>,
}

/// Sync primitives banned inside engine-submission closures (rule D1).
const D1_BANNED: [&str; 8] = [
    "Mutex",
    "RwLock",
    "lock",
    "fetch_add",
    "fetch_sub",
    "fetch_max",
    "fetch_min",
    "compare_exchange",
];

/// `std::thread` constructors banned outside `engine/` (rule D5).
const D5_BANNED: [&str; 3] = ["spawn", "scope", "Builder"];

/// Path heads that mean wall-clock / ambient entropy (rule D6) when
/// followed by `::`.
const D6_PATH: [&str; 3] = ["Instant", "SystemTime", "rand"];

/// Bare calls that mean ambient entropy (rule D6).
const D6_BARE: [&str; 2] = ["thread_rng", "from_entropy"];

fn is_compute(path: &str) -> bool {
    COMPUTE_PREFIXES.iter().any(|p| path.starts_with(p))
}

/// Analyze one source file. `path` is the repo-relative path (forward
/// slashes) deciding which rules apply; `#[cfg(test)]` items are
/// excluded before any rule runs.
pub fn analyze_source(path: &str, src: &str, cfg: &RuleConfig) -> Vec<Finding> {
    let lines: Vec<&str> = src.split('\n').collect();
    let toks = lexer::lex(src);
    let keep = lexer::mask_test_code(&toks);
    let code: Vec<(Kind, &str, u32)> = toks
        .iter()
        .zip(&keep)
        .filter(|(t, &k)| k && t.kind != Kind::Comment)
        .map(|(t, _)| (t.kind, t.text.as_str(), t.line))
        .collect();
    let n = code.len();
    let compute = is_compute(path);
    let in_engine = path.starts_with("rust/src/engine");
    let is_kernel = path == "rust/src/linalg/kernel.rs";
    let mut out: Vec<Finding> = Vec::new();
    let line_text = |ln: u32| -> String {
        lines
            .get(ln as usize - 1)
            .map(|l| l.trim().to_string())
            .unwrap_or_default()
    };
    let is_punct = |i: usize, ch: &str| -> bool { code[i].0 == Kind::Punct && code[i].1 == ch };
    let is_path_sep = |i: usize| -> bool {
        i + 1 < n && is_punct(i, ":") && is_punct(i + 1, ":")
    };

    // D1 — ordered reductions: no shared-state sync primitives inside
    // closures submitted to the engine. Cross-thread float accumulation
    // must land in disjoint per-band slots reduced in ascending order
    // on the submitter (`gemm::recon_loss` is the exemplar). The
    // engine module itself implements the machinery and is exempt.
    if compute && !in_engine {
        let mut p = 0usize;
        while p < n {
            let (k, t, _) = code[p];
            let submit =
                k == Kind::Ident && matches!(t, "run" | "for_each_band" | "for_each_band2");
            if submit && p > 0 && is_punct(p - 1, ".") && p + 1 < n && is_punct(p + 1, "(") {
                let mut q = p + 2;
                let mut depth = 1usize;
                while q < n && depth > 0 {
                    let (kk, tt, ll) = code[q];
                    if kk == Kind::Punct && tt == "(" {
                        depth += 1;
                    } else if kk == Kind::Punct && tt == ")" {
                        depth -= 1;
                    } else if kk == Kind::Ident
                        && (D1_BANNED.contains(&tt) || tt.starts_with("Atomic"))
                    {
                        out.push(Finding {
                            rule: "D1",
                            file: path.to_string(),
                            line: ll,
                            msg: format!(
                                "`{tt}` inside an engine-submission closure: cross-thread \
                                 accumulation must land in disjoint slot vectors reduced in \
                                 ascending band order on the submitter (see gemm::recon_loss)"
                            ),
                            text: line_text(ll),
                        });
                    }
                    q += 1;
                }
                p = q;
                continue;
            }
            p += 1;
        }
    }

    // D2 — order-stable containers only in compute modules: HashMap /
    // HashSet iteration order varies run-to-run (RandomState), which is
    // exactly the nondeterminism class the bitwise contract forbids.
    if compute {
        for &(k, t, ln) in &code {
            if k == Kind::Ident && (t == "HashMap" || t == "HashSet") {
                out.push(Finding {
                    rule: "D2",
                    file: path.to_string(),
                    line: ln,
                    msg: format!(
                        "`{t}` in a compute module: iteration order is seed-dependent; use a \
                         sorted Vec or BTreeMap/BTreeSet (order-stable) instead"
                    ),
                    text: line_text(ln),
                });
            }
        }
    }

    // D3 — rounding points are fixed: FMA contraction and f64→f32
    // narrowing change accumulation chains, so they are confined to
    // linalg/kernel.rs (the kmix/kf32/kf64 cores own the designated
    // rounding points); deliberate seed-arithmetic rounding elsewhere
    // must be allowlisted with a reason.
    if compute && !is_kernel {
        for i in 0..n {
            let (k, t, ln) = code[i];
            if k != Kind::Ident {
                continue;
            }
            if t == "mul_add" {
                out.push(Finding {
                    rule: "D3",
                    file: path.to_string(),
                    line: ln,
                    msg: "`mul_add` outside linalg/kernel.rs: FMA contraction changes the \
                          rounding chain; route through the kernel fmadd helpers"
                        .to_string(),
                    text: line_text(ln),
                });
            }
            if t == "as" && i + 1 < n && code[i + 1].0 == Kind::Ident && code[i + 1].1 == "f32" {
                out.push(Finding {
                    rule: "D3",
                    file: path.to_string(),
                    line: ln,
                    msg: "`as f32` narrowing outside linalg/kernel.rs: rounding points are \
                          fixed by the seed-arithmetic contract; allowlist deliberate ones \
                          in audit.toml"
                        .to_string(),
                    text: line_text(ln),
                });
            }
        }
    }

    // D4 — `unsafe` only in allowlisted files, and every occurrence
    // carries a `// SAFETY:` comment within the 4 preceding lines.
    for &(k, t, ln) in &code {
        if k == Kind::Ident && t == "unsafe" {
            if !cfg.d4_files.iter().any(|f| f.as_str() == path) {
                out.push(Finding {
                    rule: "D4",
                    file: path.to_string(),
                    line: ln,
                    msg: "`unsafe` outside the audited file list (audit.toml [d4] files)"
                        .to_string(),
                    text: line_text(ln),
                });
            } else {
                // window = the finding's own line plus the 4 above
                let hi = (ln as usize).min(lines.len());
                let lo = (ln as usize).saturating_sub(5).min(hi);
                let documented = lines[lo..hi].iter().any(|l| l.contains("SAFETY:"));
                if !documented {
                    out.push(Finding {
                        rule: "D4",
                        file: path.to_string(),
                        line: ln,
                        msg: "`unsafe` without a `// SAFETY:` comment within the 4 preceding \
                              lines stating the invariant"
                            .to_string(),
                        text: line_text(ln),
                    });
                }
            }
        }
    }

    // D5 — no direct thread spawning outside engine/: every parallel
    // path shares the PruneEngine pool (thread budget + determinism).
    if !in_engine {
        for i in 0..n {
            let (k, t, ln) = code[i];
            if k == Kind::Ident
                && t == "thread"
                && i + 3 < n
                && is_path_sep(i + 1)
                && code[i + 3].0 == Kind::Ident
                && D5_BANNED.contains(&code[i + 3].1)
            {
                out.push(Finding {
                    rule: "D5",
                    file: path.to_string(),
                    line: ln,
                    msg: format!(
                        "`thread::{}` outside engine/: all parallelism routes through the \
                         PruneEngine pool",
                        code[i + 3].1
                    ),
                    text: line_text(ln),
                });
            }
        }
    }

    // D6 — no wall-clock or ambient RNG in compute paths: timing and
    // entropy are observability concerns (metrics/benches), never
    // inputs to seed-faithful kernels.
    if compute {
        for i in 0..n {
            let (k, t, ln) = code[i];
            if k != Kind::Ident {
                continue;
            }
            if D6_BARE.contains(&t) {
                out.push(Finding {
                    rule: "D6",
                    file: path.to_string(),
                    line: ln,
                    msg: format!("ambient RNG `{t}` in a compute path"),
                    text: line_text(ln),
                });
            }
            if D6_PATH.contains(&t) && i + 2 < n && is_path_sep(i + 1) {
                let what = if t == "rand" { "ambient RNG" } else { "wall-clock" };
                out.push(Finding {
                    rule: "D6",
                    file: path.to_string(),
                    line: ln,
                    msg: format!(
                        "{what} `{t}::` in a compute path: timing and entropy stay out of \
                         seed-faithful kernels (observability lives in metrics/benches)"
                    ),
                    text: line_text(ln),
                });
            }
        }
    }

    // D7 — production file writes go through `robust::atomic`: a raw
    // `fs::write` / `File::create` / `OpenOptions` site can leave a
    // torn file behind on crash, and bypasses both the checksum framing
    // and the fault-injection points. Reads (`fs::read`, `File::open`)
    // are unrestricted. `robust/` implements the machinery and is the
    // single exempt tree; test code is masked like everywhere else.
    if !path.starts_with("rust/src/robust") {
        for i in 0..n {
            let (k, t, ln) = code[i];
            if k != Kind::Ident {
                continue;
            }
            let follows = |want: &str| -> bool {
                i + 3 < n
                    && is_path_sep(i + 1)
                    && code[i + 3].0 == Kind::Ident
                    && code[i + 3].1 == want
            };
            let what = if t == "fs" && follows("write") {
                Some("fs::write")
            } else if t == "File" && follows("create") {
                Some("File::create")
            } else if t == "OpenOptions" {
                Some("OpenOptions")
            } else {
                None
            };
            if let Some(what) = what {
                out.push(Finding {
                    rule: "D7",
                    file: path.to_string(),
                    line: ln,
                    msg: format!(
                        "raw `{what}` outside robust/: production writes route through \
                         `robust::atomic` (temp file + fsync + rename) so a crash never \
                         publishes a torn file and fault injection covers the site"
                    ),
                    text: line_text(ln),
                });
            }
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_with(files: &[&str]) -> RuleConfig {
        RuleConfig { d4_files: files.iter().map(|s| s.to_string()).collect() }
    }

    #[test]
    fn non_compute_paths_skip_compute_rules() {
        let src = "use std::collections::HashMap;\nfn f() { let t = Instant::now(); }\n";
        let f = analyze_source("rust/src/metrics.rs", src, &RuleConfig::default());
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn d5_applies_outside_compute_modules_too() {
        let src = "fn f() { std::thread::spawn(|| {}); }\n";
        let f = analyze_source("rust/src/metrics.rs", src, &RuleConfig::default());
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "D5");
    }

    #[test]
    fn render_format_is_file_line_rule() {
        let src = "fn f() { let t = std::time::Instant::now(); }\n";
        let f = analyze_source("rust/src/linalg/x.rs", src, &RuleConfig::default());
        assert_eq!(f.len(), 1);
        let r = f[0].render();
        assert!(r.starts_with("rust/src/linalg/x.rs:1 · D6 · "), "{r}");
    }

    #[test]
    fn d4_requires_both_file_listing_and_comment() {
        let with_comment = "fn f() {\n    // SAFETY: disjoint bands\n    unsafe { g() }\n}\n";
        let bare = "fn f() {\n    unsafe { g() }\n}\n";
        let listed = cfg_with(&["rust/src/engine/mod.rs"]);
        // listed + commented → clean
        assert!(analyze_source("rust/src/engine/mod.rs", with_comment, &listed).is_empty());
        // listed, no comment → 1 finding
        assert_eq!(analyze_source("rust/src/engine/mod.rs", bare, &listed).len(), 1);
        // unlisted, commented → 1 finding
        assert_eq!(
            analyze_source("rust/src/model/mod.rs", with_comment, &listed).len(),
            1
        );
    }
}
