//! `thanos-audit` — the determinism-contract static analyzer.
//!
//! The whole performance story of the `thanos` crate rests on one
//! contract: serial == parallel **bitwise**, and arithmetic faithful to
//! the seed chains (DESIGN.md §Perf-L3/L4/L5). Runtime bit-identity
//! tests sample a handful of shapes; this crate checks the contract at
//! the *source* level, as named, explainable rules over the full
//! `rust/src` tree:
//!
//! | rule | contract |
//! |---|---|
//! | D1 | no shared-state sync primitives inside engine-submission closures — cross-thread accumulation goes through per-band slots reduced in ascending order |
//! | D2 | no `HashMap`/`HashSet` in compute modules — order-stable containers only |
//! | D3 | FMA (`mul_add`) and `as f32` narrowing only at the designated rounding points in `linalg/kernel.rs`; deliberate exceptions allowlisted |
//! | D4 | `unsafe` only in allowlisted files, each occurrence with a `// SAFETY:` comment |
//! | D5 | no `std::thread::{spawn,scope,Builder}` outside `engine/` |
//! | D6 | no wall-clock or ambient RNG in compute paths |
//! | D7 | no raw `fs::write` / `File::create` / `OpenOptions` outside `robust/` — production writes go through the atomic fsync-rename writer |
//!
//! `cargo run -p thanos-audit` scans the tree against the checked-in
//! `audit.toml` and exits nonzero on any unallowlisted finding or stale
//! allowlist entry. The test suite (`tests/rules.rs`) pins every rule
//! with positive/negative fixtures *and* asserts the real tree is
//! clean, so `cargo test` carries the gate too.

#![deny(unsafe_code)]

pub mod allowlist;
pub mod lexer;
pub mod rules;

pub use allowlist::{Allowlist, Applied};
pub use rules::{analyze_source, Finding, RuleConfig};

use std::path::{Path, PathBuf};

/// Collect every `.rs` file under `root/rust/src`, sorted by path so
/// reports (and finding order) are stable across filesystems.
pub fn source_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.join("rust").join("src")];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Repo-relative path with forward slashes (rule scoping + reports).
pub fn rel_path(root: &Path, file: &Path) -> String {
    let rel = file.strip_prefix(root).unwrap_or(file);
    let parts: Vec<String> = rel
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect();
    parts.join("/")
}

/// Scan the tree under `root` with the given D4 file list. Returns
/// `(files_scanned, findings)`.
pub fn scan_tree(root: &Path, cfg: &RuleConfig) -> std::io::Result<(usize, Vec<Finding>)> {
    let files = source_files(root)?;
    let mut findings = Vec::new();
    for file in &files {
        let src = std::fs::read_to_string(file)?;
        let rel = rel_path(root, file);
        findings.extend(analyze_source(&rel, &src, cfg));
    }
    Ok((files.len(), findings))
}

/// Locate the repo root: the nearest ancestor of `start` containing
/// `audit.toml`, falling back to the workspace root this crate was
/// compiled in (two levels above its manifest).
pub fn find_root(start: &Path) -> PathBuf {
    let mut cur = Some(start);
    while let Some(dir) = cur {
        if dir.join("audit.toml").is_file() {
            return dir.to_path_buf();
        }
        cur = dir.parent();
    }
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("..")
        .to_path_buf()
}
