//! Evaluation harness: held-out perplexity (the WikiText-2 analogue)
//! and the seven synthetic zero-shot tasks (LM option scoring, the
//! EleutherAI-harness readout), plus the compression report — *measured*
//! bytes and kernel timings from the [`crate::sparse`] formats, with
//! the modeled GPU n:m figure retained as a labeled secondary line.

use crate::data::{Grammar, Sequences, Task, TaskInstance, Token, ALL_TASKS};
use crate::model::ModelState;
use crate::runtime::{lit_f32, lit_i32, to_vec_f32, Runtime};
use anyhow::{ensure, Result};

/// Run the `logprobs_<model>` executable on one batch of `nb_eval`
/// sequences; returns per-position NLL `[nb, seq-1]` row-major.
fn nll_batch(rt: &Runtime, state: &ModelState, tokens: &[i32]) -> Result<Vec<f32>> {
    let nb = rt.manifest.nb_eval;
    let seq = state.config.seq_len;
    ensure!(tokens.len() == nb * seq, "eval batch shape");
    let out = rt.exec(
        &format!("logprobs_{}", state.config.name),
        &[
            lit_f32(&state.flat, &[state.flat.len()])?,
            lit_i32(tokens, &[nb, seq])?,
        ],
    )?;
    to_vec_f32(&out[0])
}

/// Perplexity over an eval split: `exp(mean NLL)` across all positions
/// of all sequences (sequences are chunked into `nb_eval` batches; a
/// final partial batch is padded with repeats and the padding rows are
/// excluded from the mean).
pub fn perplexity(rt: &Runtime, state: &ModelState, seqs: &Sequences) -> Result<f64> {
    let nb = rt.manifest.nb_eval;
    let seq = state.config.seq_len;
    ensure!(seqs.seq_len == seq, "eval seq_len mismatch");
    let n = seqs.n_seqs();
    ensure!(n > 0, "empty eval split");
    let mut total = 0.0f64;
    let mut count = 0usize;
    let mut batch: Vec<i32> = Vec::with_capacity(nb * seq);
    let mut start = 0;
    while start < n {
        batch.clear();
        let real = nb.min(n - start);
        for i in 0..nb {
            let idx = if i < real { start + i } else { start + real - 1 };
            batch.extend(seqs.seq(idx).iter().map(|&t| t as i32));
        }
        let nll = nll_batch(rt, state, &batch)?;
        for row in 0..real {
            for p in 0..seq - 1 {
                total += nll[row * (seq - 1) + p] as f64;
            }
            count += seq - 1;
        }
        start += real;
    }
    Ok((total / count as f64).exp())
}

/// Accuracy of one zero-shot task: each option is scored by the summed
/// log-likelihood of its tokens given the context; the argmax option is
/// the model's answer.
pub fn task_accuracy(
    rt: &Runtime,
    state: &ModelState,
    instances: &[TaskInstance],
) -> Result<f64> {
    let nb = rt.manifest.nb_eval;
    let seq = state.config.seq_len;
    // build one scored row per (instance, option)
    struct Row {
        inst: usize,
        opt: usize,
        /// nll positions [lo, hi) to sum (position p predicts token p+1)
        lo: usize,
        hi: usize,
    }
    let mut rows = Vec::new();
    let mut toks: Vec<i32> = Vec::new();
    for (ii, inst) in instances.iter().enumerate() {
        let cl = inst.context.len();
        for (oi, opt) in inst.options.iter().enumerate() {
            let ol = opt.len();
            ensure!(cl + ol <= seq, "task sequence too long for model");
            let mut row: Vec<i32> = Vec::with_capacity(seq);
            row.extend(inst.context.iter().map(|&t| t as i32));
            row.extend(opt.iter().map(|&t| t as i32));
            row.resize(seq, 0);
            toks.extend(row);
            rows.push(Row { inst: ii, opt: oi, lo: cl - 1, hi: cl + ol - 1 });
        }
    }
    // pad the row count to a multiple of nb by repeating the last row
    let real_rows = rows.len();
    while (toks.len() / seq) % nb != 0 {
        let last = toks[toks.len() - seq..].to_vec();
        toks.extend(last);
    }
    // score rows in batches
    let mut scores = vec![0.0f64; real_rows];
    let nrows = toks.len() / seq;
    for b0 in (0..nrows).step_by(nb) {
        let batch = &toks[b0 * seq..(b0 + nb) * seq];
        let nll = nll_batch(rt, state, batch)?;
        for r in 0..nb {
            let global = b0 + r;
            if global >= real_rows {
                break;
            }
            let row = &rows[global];
            let mut s = 0.0f64;
            for p in row.lo..row.hi {
                s -= nll[r * (seq - 1) + p] as f64;
            }
            scores[global] = s;
        }
    }
    // pick argmax per instance
    let mut best: Vec<(f64, usize)> = vec![(f64::NEG_INFINITY, 0); instances.len()];
    for (ridx, row) in rows.iter().enumerate() {
        if scores[ridx] > best[row.inst].0 {
            best[row.inst] = (scores[ridx], row.opt);
        }
    }
    let correct = instances
        .iter()
        .zip(&best)
        .filter(|(inst, (_, opt))| *opt == inst.answer)
        .count();
    Ok(correct as f64 / instances.len() as f64)
}

/// Per-task + average accuracy over all seven tasks (the Table 3 /
/// Appendix D readout).
pub fn zero_shot_suite(
    rt: &Runtime,
    state: &ModelState,
    grammar: &Grammar,
    n_instances: usize,
    seed: u64,
) -> Result<Vec<(Task, f64)>> {
    let mut out = Vec::new();
    for task in ALL_TASKS {
        let instances = task.build(grammar, n_instances, seed);
        let acc = task_accuracy(rt, state, &instances)?;
        out.push((task, acc));
    }
    Ok(out)
}

pub fn zero_shot_average(results: &[(Task, f64)]) -> f64 {
    results.iter().map(|(_, a)| a).sum::<f64>() / results.len() as f64
}

/// Format a Table-3-style row.
pub fn format_zero_shot(results: &[(Task, f64)]) -> String {
    let mut s = String::new();
    for (t, a) in results {
        s.push_str(&format!("  {:<16} {:6.2}%\n", t.name(), a * 100.0));
    }
    s.push_str(&format!(
        "  {:<16} {:6.2}%\n",
        "Average",
        zero_shot_average(results) * 100.0
    ));
    s
}

/// n:m compression/speedup report from the *accounting formulas* (the
/// hardware speedup line is modeled — DESIGN.md §Substitutions).
/// Superseded by [`compression_report`], which packs the actual layers
/// and measures the CPU kernels; retained for the f16 what-if readout.
pub fn nm_report(state: &ModelState, n: usize, m: usize) -> String {
    use crate::pruning::nm;
    let mut dense = 0usize;
    let mut comp = 0usize;
    for l in 0..state.config.n_layers {
        for name in state.prunable_layers(l) {
            let e = state.entry(&name).unwrap();
            let (c, b) = (e.shape[0], e.shape[1]);
            dense += nm::dense_bytes(c, b, 2);
            comp += nm::compressed_bytes(c, b, n, m, 2);
        }
    }
    format!(
        "  {n}:{m} weights: {:.1} MiB -> {:.1} MiB ({:.1}% of dense, f16)\n  modeled sparse-MMA speedup: {:.2}x\n",
        dense as f64 / (1 << 20) as f64,
        comp as f64 / (1 << 20) as f64,
        100.0 * comp as f64 / dense as f64,
        nm::modeled_speedup(n, m),
    )
}

/// Token type re-export convenience for binaries.
pub fn tokens_to_i32(ts: &[Token]) -> Vec<i32> {
    ts.iter().map(|&t| t as i32).collect()
}

/// Measured CPU matmul speedup of a pruned layer vs its dense original
/// (the zero-skipping GEMM in `linalg::gemm` exploits unstructured
/// sparsity on CPU — a software analogue of the n:m hardware path; the
/// hardware number itself is modeled in [`crate::pruning::nm`]).
pub fn measured_sparse_speedup(
    w_dense: &crate::linalg::Mat,
    w_sparse: &crate::linalg::Mat,
    batch: usize,
) -> (f64, f64) {
    use crate::linalg::gemm::matmul_into;
    use crate::linalg::Mat;
    let mut r = crate::rng::Rng::new(0x5EED);
    let x = Mat::from_fn(w_dense.cols, batch, |_, _| r.normal_f32(0.0, 1.0));
    let mut out = Mat::zeros(w_dense.rows, batch);
    let time = |w: &Mat, out: &mut Mat| {
        // warm-up + best-of-3 (noise robustness)
        matmul_into(w, &x, out);
        (0..3)
            .map(|_| {
                let t = crate::trace::clock::now_nanos();
                matmul_into(w, &x, out);
                crate::trace::clock::secs_since(t)
            })
            .fold(f64::INFINITY, f64::min)
    };
    let dense_s = time(w_dense, &mut out);
    let sparse_s = time(w_sparse, &mut out);
    (dense_s, sparse_s)
}

/// Measured CPU time of the dense GEMM vs a compressed-format kernel on
/// the same layer and inputs: `(dense_secs, sparse_secs)`, best-of-3
/// (the same [`crate::sparse::bench::best_of`] harness the bench uses).
pub fn measured_format_speedup(
    w_dense: &crate::linalg::Mat,
    tensor: &crate::sparse::SparseTensor,
    batch: usize,
) -> (f64, f64) {
    use crate::linalg::gemm::matmul_into;
    use crate::linalg::Mat;
    use crate::sparse::bench::best_of;
    let mut r = crate::rng::Rng::new(0x5EED);
    let x = Mat::from_fn(w_dense.cols, batch, |_, _| r.normal_f32(0.0, 1.0));
    let mut out = Mat::zeros(w_dense.rows, batch);
    let dense_s = best_of(3, || matmul_into(w_dense, &x, &mut out));
    let sparse_s = best_of(3, || tensor.matmul_into(&x, &mut out));
    (dense_s, sparse_s)
}

/// Measured compression report over a packed model: per-layer format +
/// actual bytes, totals, a measured dense-vs-sparse kernel timing on
/// the largest compressed layer (matvec and batch 32), and — when the
/// model holds n:m layers — the modeled GPU sparse-MMA line, clearly
/// labeled as modeled (DESIGN.md §Sparse, §Substitutions).
pub fn compression_report(
    state: &ModelState,
    sm: &crate::sparse::SparseModel,
) -> Result<String> {
    use std::fmt::Write as _;
    let mut out = String::new();
    for l in &sm.layers {
        let dense = l.tensor.rows() * l.tensor.cols() * 4;
        let _ = writeln!(
            out,
            "  {:<16} {:>5}x{:<5} {:<14} {:>9} B -> {:>9} B ({:>5.1}%)",
            l.name,
            l.tensor.rows(),
            l.tensor.cols(),
            l.tensor.label(),
            dense,
            l.tensor.bytes(),
            100.0 * l.tensor.bytes() as f64 / dense as f64,
        );
    }
    let _ = writeln!(out, "  {}", sm.summary());
    if let Some(largest) = sm
        .layers
        .iter()
        .max_by_key(|l| l.tensor.rows() * l.tensor.cols())
    {
        let w = state.get_mat(&largest.name)?;
        for batch in [1usize, 32] {
            let (d, s) = measured_format_speedup(&w, &largest.tensor, batch);
            let _ = writeln!(
                out,
                "  measured CPU {} on {} (batch {batch}): dense {:.3}ms -> sparse {:.3}ms ({:.2}x)",
                largest.tensor.label(),
                largest.name,
                d * 1e3,
                s * 1e3,
                d / s.max(1e-12),
            );
        }
    }
    if let Some(crate::sparse::SparseTensor::Nm(t)) = sm
        .layers
        .iter()
        .map(|l| &l.tensor)
        .find(|t| matches!(t, crate::sparse::SparseTensor::Nm(_)))
    {
        let _ = writeln!(
            out,
            "  modeled GPU sparse-MMA speedup for {}:{} (secondary figure, not measured): {:.2}x",
            t.n,
            t.m,
            crate::pruning::nm::modeled_speedup(t.n, t.m),
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn sparse_matmul_not_slower() {
        let mut r = crate::rng::Rng::new(1);
        let dense = Mat::from_fn(256, 256, |_, _| r.normal_f32(0.0, 1.0));
        let mut sparse = dense.clone();
        for (k, v) in sparse.data.iter_mut().enumerate() {
            if k % 2 == 0 {
                *v = 0.0;
            }
        }
        let (d, s) = measured_sparse_speedup(&dense, &sparse, 256);
        assert!(s <= d * 1.3, "sparse {s} vs dense {d}");
    }

    #[test]
    fn tokens_to_i32_roundtrip() {
        assert_eq!(tokens_to_i32(&[1u16, 500]), vec![1, 500]);
    }
}
