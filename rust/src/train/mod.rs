//! Training driver: runs the AOT Adam train-step executable over the
//! synthetic corpus and logs the loss curve.
//!
//! The whole optimizer lives inside the HLO graph (L2); Rust owns the
//! three flat state buffers (params, m, v), samples batches, and loops.

use crate::data::{Corpus, Token};
use crate::model::ModelState;
use crate::rng::Rng;
use crate::runtime::{lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32, to_vec_f32, Runtime};
use anyhow::{Context, Result};

/// Adam trainer over the `train_step_<model>` executable.
pub struct Trainer<'a> {
    rt: &'a Runtime,
    pub state: ModelState,
    m: Vec<f32>,
    v: Vec<f32>,
    pub step: usize,
    pub lr: f32,
    exe_name: String,
    bs: usize,
    seq: usize,
}

/// One point of the loss log.
#[derive(Clone, Copy, Debug)]
pub struct LossPoint {
    pub step: usize,
    pub loss: f32,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime, state: ModelState, lr: f32) -> Result<Trainer<'a>> {
        let exe_name = format!("train_step_{}", state.config.name);
        if !rt.has_exe(&exe_name) {
            anyhow::bail!("missing executable {exe_name} — rebuild artifacts");
        }
        let n = state.flat.len();
        Ok(Trainer {
            rt,
            m: vec![0.0; n],
            v: vec![0.0; n],
            step: 0,
            lr,
            exe_name,
            bs: rt.manifest.train_bs,
            seq: state.config.seq_len,
            state,
        })
    }

    /// One optimizer step on the given batch (`bs*seq` tokens).
    pub fn step_on(&mut self, tokens: &[Token]) -> Result<f32> {
        assert_eq!(tokens.len(), self.bs * self.seq, "batch shape");
        let toks_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let inputs = [
            lit_f32(&self.state.flat, &[self.state.flat.len()])?,
            lit_f32(&self.m, &[self.m.len()])?,
            lit_f32(&self.v, &[self.v.len()])?,
            lit_i32(&toks_i32, &[self.bs, self.seq])?,
            lit_scalar_i32(self.step as i32),
            lit_scalar_f32(self.lr),
        ];
        let out = self.rt.exec(&self.exe_name, &inputs)?;
        let loss = to_vec_f32(&out[0])?[0];
        self.state.flat = to_vec_f32(&out[1])?;
        self.m = to_vec_f32(&out[2])?;
        self.v = to_vec_f32(&out[3])?;
        self.step += 1;
        Ok(loss)
    }

    /// Train for `steps` steps on random corpus batches; returns the
    /// loss curve (every step) for EXPERIMENTS.md.
    pub fn train(&mut self, corpus: &Corpus, steps: usize, seed: u64) -> Result<Vec<LossPoint>> {
        let mut rng = Rng::new(seed);
        let nseqs = corpus.train.n_seqs();
        anyhow::ensure!(nseqs >= self.bs, "corpus too small for batch size");
        let mut log = Vec::with_capacity(steps);
        let mut batch: Vec<Token> = Vec::with_capacity(self.bs * self.seq);
        for _ in 0..steps {
            batch.clear();
            for _ in 0..self.bs {
                let s = rng.below(nseqs);
                batch.extend_from_slice(corpus.train.seq(s));
            }
            let loss = self
                .step_on(&batch)
                .with_context(|| format!("train step {}", self.step))?;
            log.push(LossPoint { step: self.step, loss });
        }
        Ok(log)
    }
}

/// Pretty-print a loss curve, subsampled.
pub fn format_loss_curve(log: &[LossPoint], every: usize) -> String {
    let mut out = String::new();
    for p in log.iter().step_by(every.max(1)) {
        out.push_str(&format!("  step {:>5}  loss {:.4}\n", p.step, p.loss));
    }
    if let Some(last) = log.last() {
        out.push_str(&format!("  final {:>5}  loss {:.4}\n", last.step, last.loss));
    }
    out
}
