//! Minimal JSON parser/writer (no serde in the offline vendor set).
//!
//! Used for: the artifact `manifest.json` written by `python/compile/aot.py`,
//! run configuration files, and machine-readable experiment reports
//! emitted by the benches. Supports the full JSON grammar except for
//! `\u` surrogate pairs outside the BMP (not needed for manifests).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as f64 (manifest fields fit exactly).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let s = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&s).with_context(|| format!("parsing {}", path.display()))
    }

    // -- typed accessors ------------------------------------------------

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&Vec<Json>> {
        match self {
            Json::Arr(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    /// Object field lookup with a helpful error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .with_context(|| format!("missing field '{key}'"))
    }

    /// Optional field lookup.
    pub fn get_opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    // -- writer ---------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

pub fn arr_usize(v: &[usize]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .context("unexpected end of input")
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected '{}' at offset {}, found '{}'",
                c as char,
                self.i,
                self.b[self.i] as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character '{}' at offset {}", c as char, self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at offset {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']' at offset {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).context("invalid \\u escape")?);
                        }
                        c => bail!("invalid escape '\\{}'", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multi-byte UTF-8: re-decode from the byte stream
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        bail!("truncated UTF-8 sequence");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..end])?);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number '{s}'"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, -2.5e3], "c": {"d": "x\ny"}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("b").unwrap().as_arr().unwrap()[2],
            Json::Num(-2500.0)
        );
        assert_eq!(
            v.get("c").unwrap().get("d").unwrap().as_str().unwrap(),
            "x\ny"
        );
        // writer → parser roundtrip
        let again = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, again);
        let again = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("07x").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("[1] extra").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café → ok""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café → ok");
        let v = Json::parse("\"π≈3.14159\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "π≈3.14159");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("[]").unwrap().to_string_compact(), "[]");
    }

    #[test]
    fn numbers_render_as_ints_when_integral() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(-3.0).to_string_compact(), "-3");
        let s = Json::Num(0.125).to_string_compact();
        assert_eq!(s.parse::<f64>().unwrap(), 0.125);
    }

    #[test]
    fn nested_deep_roundtrip() {
        let v = obj(vec![
            ("shapes", Json::Arr(vec![arr_usize(&[2, 3]), arr_usize(&[4])])),
            ("vals", arr_f64(&[1.5, -0.25])),
            ("name", Json::Str("prune_wanda_256x256".into())),
            ("ok", Json::Bool(true)),
        ]);
        let round = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, round);
    }
}
