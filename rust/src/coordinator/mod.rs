//! The compression-pipeline coordinator — the paper's generic
//! block-by-block pruning loop (Alg. 3) as the L3 system.
//!
//! For every transformer block:
//!
//! 1. **capture pass** — run the `block_capture` executable on every
//!    calibration chunk; accumulate per-layer-input calibration
//!    statistics (Hessian `2·XXᵀ` + row norms), either through the AOT
//!    `hessian_accum` kernel (Pallas L1) or through the threaded Rust
//!    path (exact f64), per the selected [`Backend`].
//! 2. **prune** — each of the six linear layers is pruned to the
//!    requested pattern by the selected method, via AOT executables or
//!    the pure-Rust library.
//! 3. **re-forward** — the (now pruned) block is run again to produce
//!    the inputs of the next block, exactly as Alg. 3 lines 3–7.
//!
//! The coordinator owns no Python: every compute step is a compiled
//! HLO executable or native Rust.

use crate::data::Sequences;
use crate::linalg::Mat;
use crate::model::ModelState;
use crate::pruning::{self, CalibStats, Method, Pattern, PruneOpts};
use crate::runtime::{
    lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32, mat_lit, to_mat, to_vec_f32, Runtime,
};
use crate::trace::{self, clock};
use anyhow::{ensure, Context, Result};

/// Which engine performs calibration statistics + pruning math.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// AOT path: Pallas/JAX HLO executables (falls back to Rust for
    /// method/pattern combos with no artifact, e.g. SparseGPT).
    Aot,
    /// Pure-Rust reference path (f64 Hessians).
    Rust,
}

/// A pruning request for the whole model.
#[derive(Clone, Debug)]
pub struct PruneSpec {
    pub method: Method,
    pub pattern: Pattern,
    pub opts: PruneOpts,
    pub backend: Backend,
}

/// Per-layer outcome.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub c: usize,
    pub b: usize,
    pub sparsity: f64,
    pub secs: f64,
    /// true if this layer ran on the AOT executables
    pub aot: bool,
}

/// Whole-model outcome.
#[derive(Clone, Debug, Default)]
pub struct PruneReport {
    pub layers: Vec<LayerReport>,
    pub capture_secs: f64,
    pub hessian_secs: f64,
    /// wall time of the pruning stage (layers overlap under the
    /// layer-parallel engine path; per-layer times are in [`LayerReport`])
    pub prune_secs: f64,
    /// wall time re-forwarding calibration activations through each
    /// pruned block (its own stage — previously misfiled under capture)
    pub reforward_secs: f64,
    pub total_secs: f64,
    /// traced per-stage breakdown of this run (span name → count /
    /// summed seconds); empty unless tracing was enabled
    /// (`--trace` / `THANOS_TRACE`, see [`crate::trace`])
    pub stages: Vec<trace::StageLine>,
    /// [`crate::engine`] activity during this run (queue/occupancy)
    pub engine: crate::engine::EngineStats,
    /// the pattern this run pruned to — lets [`Self::sparse_model`]
    /// pick the matching compressed format per layer
    pub pattern: Option<Pattern>,
}

impl PruneReport {
    pub fn overall_sparsity(&self) -> f64 {
        let total: f64 = self.layers.iter().map(|l| (l.c * l.b) as f64).sum();
        let zeros: f64 = self
            .layers
            .iter()
            .map(|l| l.sparsity * (l.c * l.b) as f64)
            .sum();
        zeros / total
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "pruned {} layers to {:.1}% sparsity in {:.1}s (capture {:.1}s, hessian {:.1}s, \
             prune {:.1}s, re-forward {:.1}s) | engine: {} threads, {} jobs ({} inline), \
             {} tasks, queue peak {}, {:.0}% occupancy",
            self.layers.len(),
            self.overall_sparsity() * 100.0,
            self.total_secs,
            self.capture_secs,
            self.hessian_secs,
            self.prune_secs,
            self.reforward_secs,
            self.engine.threads,
            self.engine.jobs_submitted,
            self.engine.jobs_inline,
            self.engine.tasks_executed,
            self.engine.queue_peak,
            self.engine.occupancy(self.total_secs) * 100.0,
        );
        if !self.stages.is_empty() {
            s.push_str("\n  traced stages (summed span time; workers overlap):");
            for line in &self.stages {
                s.push_str(&format!(
                    "\n    {:<24} x{:<7} {:.3}s",
                    line.name, line.count, line.secs
                ));
            }
        }
        s
    }

    /// Emit the compressed form of the pruned model: every prunable
    /// layer packed in the format matching this run's pattern
    /// (n:m → `NmPacked`, unstructured → `Csr`, structured →
    /// `DenseCompact`). Feed the result to
    /// [`crate::model::ModelState::save_compressed`] (which round-trip
    /// verifies bitwise before writing) for a checkpoint-v2 file, or
    /// serve it through [`crate::sparse::kernels`].
    pub fn sparse_model(&self, state: &ModelState) -> Result<crate::sparse::SparseModel> {
        let pattern = self
            .pattern
            .context("PruneReport has no pattern (default-constructed report)")?;
        crate::sparse::SparseModel::compress_state(state, &pattern)
    }
}

/// Calibration statistics accumulator for one layer-input site.
enum Accum {
    Rust(CalibStats),
    Aot {
        /// running Hessian sum, row-major b×b (f32 on the AOT path)
        h: Vec<f32>,
        xnorm_sq: Vec<f32>,
        b: usize,
    },
}

impl Accum {
    fn new(backend: Backend, b: usize) -> Accum {
        match backend {
            Backend::Rust => Accum::Rust(CalibStats::new(b)),
            Backend::Aot => Accum::Aot { h: vec![0.0; b * b], xnorm_sq: vec![0.0; b], b },
        }
    }

    /// Rust-backend accumulation: no runtime needed, so calibration
    /// sites can accumulate concurrently on the engine pool.
    fn add_chunk_rust(&mut self, xt: &[f32], a: usize) -> Result<()> {
        match self {
            Accum::Rust(stats) => {
                let b = stats.b();
                ensure!(xt.len() == a * b);
                // CalibStats expects X as [b, a] (features × tokens)
                let xmat = Mat::from_vec(a, b, xt.to_vec()).transpose();
                stats.accumulate(&xmat);
                Ok(())
            }
            Accum::Aot { .. } => unreachable!("add_chunk_rust on an AOT accumulator"),
        }
    }

    /// Feed one captured chunk `xt`: row-major `[a, b]` (tokens × features).
    fn add_chunk(&mut self, rt: &Runtime, xt: &[f32], a: usize) -> Result<()> {
        match self {
            Accum::Rust(_) => self.add_chunk_rust(xt, a),
            Accum::Aot { h, xnorm_sq, b } => {
                let name = format!("hessian_accum_{b}");
                let out = rt.exec(
                    &name,
                    &[lit_f32(h, &[*b, *b])?, lit_f32(xt, &[a, *b])?],
                )?;
                *h = to_vec_f32(&out[0])?;
                let chunk = to_vec_f32(&out[1])?;
                for (acc, v) in xnorm_sq.iter_mut().zip(chunk) {
                    *acc += v;
                }
                Ok(())
            }
        }
    }
}

/// The coordinator itself.
pub struct Coordinator<'a> {
    pub rt: &'a Runtime,
}

impl<'a> Coordinator<'a> {
    pub fn new(rt: &'a Runtime) -> Coordinator<'a> {
        Coordinator { rt }
    }

    /// Prune every linear layer of `state` per `spec`, using `calib`
    /// sequences as the calibration set (paper: 128 C4 sequences).
    pub fn prune_model(
        &self,
        state: &mut ModelState,
        calib: &Sequences,
        spec: &PruneSpec,
    ) -> Result<PruneReport> {
        let t_total = clock::now_nanos();
        let stages0 = trace::stage_totals();
        let engine_stats0 = crate::engine::global().stats();
        let cfg = state.config.clone();
        let rt = self.rt;
        let nbc = rt.manifest.nb_calib;
        let seq = cfg.seq_len;
        ensure!(calib.seq_len == seq, "calibration seq_len mismatch");
        let n_chunks = (calib.n_seqs() / nbc).max(1);
        ensure!(calib.n_seqs() >= nbc, "need at least {nbc} calibration sequences");
        let a = nbc * seq; // tokens per chunk
        let d = cfg.d_model;

        let mut report = PruneReport { pattern: Some(spec.pattern), ..Default::default() };

        // embed calibration chunks → x literals
        let (xs_res, cap_secs) = trace::timed("coordinator.capture", || -> Result<Vec<_>> {
            let flat_lit = lit_f32(&state.flat, &[state.flat.len()])?;
            let mut xs: Vec<xla::Literal> = Vec::with_capacity(n_chunks);
            for ch in 0..n_chunks {
                let mut toks: Vec<i32> = Vec::with_capacity(a);
                for s in 0..nbc {
                    toks.extend(calib.seq(ch * nbc + s).iter().map(|&t| t as i32));
                }
                let out = rt.exec(
                    &format!("embed_{}", cfg.name),
                    &[flat_lit.clone(), lit_i32(&toks, &[nbc, seq])?],
                )?;
                xs.push(out.into_iter().next().unwrap());
            }
            Ok(xs)
        });
        report.capture_secs += cap_secs;
        let mut xs = xs_res?;

        // layer name → capture-output index (1-based in the exe outputs)
        // outputs: (y, x_attn, x_o, x_ff1, x_ff2)
        let site_of = |layer: &str| match layer {
            "wq" | "wk" | "wv" => 0usize,
            "wo" => 1,
            "w1" => 2,
            "w2" => 3,
            _ => unreachable!(),
        };
        let site_b = |site: usize| if site == 3 { cfg.d_ff } else { d };

        for l in 0..cfg.n_layers {
            // -- capture pass ---------------------------------------------
            let (captures_res, cap_secs) =
                trace::timed("coordinator.capture", || -> Result<Vec<_>> {
                    let block_lit = lit_f32(state.block_slice(l)?, &[state.block_flat_size])?;
                    let mut captures: Vec<Vec<xla::Literal>> = Vec::with_capacity(n_chunks);
                    for x in &xs {
                        let out = rt.exec(
                            &format!("block_capture_{}", cfg.name),
                            &[block_lit.clone(), x.clone()],
                        )?;
                        captures.push(out);
                    }
                    Ok(captures)
                });
            report.capture_secs += cap_secs;
            let captures = captures_res?;

            // -- calibration statistics per site --------------------------
            let (accums_res, h_secs) = trace::timed("coordinator.hessian", || -> Result<Vec<_>> {
                let mut accums: Vec<Accum> = (0..4)
                    .map(|s| Accum::new(spec.backend, site_b(s)))
                    .collect();
                match spec.backend {
                    Backend::Rust => {
                        // decode the capture outputs to plain buffers up
                        // front (the literal layer stays on this thread),
                        // then fan the four independent per-site Hessian
                        // accumulations out on the engine (chunk order
                        // within a site is fixed, so sums are bit-identical
                        // for any thread count)
                        let mut site_chunks: Vec<Vec<Vec<f32>>> =
                            (0..4).map(|_| Vec::with_capacity(captures.len())).collect();
                        for cap in &captures {
                            for (site, chunks) in site_chunks.iter_mut().enumerate() {
                                chunks.push(to_vec_f32(&cap[1 + site])?);
                            }
                        }
                        let errors: std::sync::Mutex<Vec<anyhow::Error>> =
                            std::sync::Mutex::new(Vec::new());
                        crate::engine::global().for_each_band(&mut accums, 1, |site, slot| {
                            for xt in &site_chunks[site] {
                                if let Err(e) = slot[0].add_chunk_rust(xt, a) {
                                    errors.lock().unwrap().push(e);
                                    break;
                                }
                            }
                        });
                        if let Some(e) = errors.into_inner().unwrap().into_iter().next() {
                            return Err(e.context("accumulating calibration statistics"));
                        }
                    }
                    Backend::Aot => {
                        // strictly sequential (needs the runtime): decode
                        // one chunk at a time so peak memory stays at one
                        // decoded chunk, as before
                        for cap in &captures {
                            for (site, accum) in accums.iter_mut().enumerate() {
                                let xt = to_vec_f32(&cap[1 + site])?;
                                accum.add_chunk(rt, &xt, a)?;
                            }
                        }
                    }
                }
                Ok(accums)
            });
            report.hessian_secs += h_secs;
            let accums = accums_res?;

            // -- prune the six layers --------------------------------------
            let lnames = ["wq", "wk", "wv", "wo", "w1", "w2"];
            let (prune_res, p_secs) = trace::timed("coordinator.prune", || -> Result<()> {
                if spec.backend == Backend::Rust {
                    // layer-parallel: the six layers of a block are
                    // independent given the per-site statistics, so they are
                    // captured once and pruned concurrently on the engine
                    // (layer tasks × row-parallel inner kernels share the
                    // same pool — no oversubscription)
                    let ws: Vec<(String, Mat, usize)> = lnames
                        .iter()
                        .map(|lname| {
                            let full = format!("blocks.{l}.{lname}");
                            let w = state.get_mat(&full)?;
                            Ok((full, w, site_of(lname)))
                        })
                        .collect::<Result<_>>()?;
                    let layer_inputs: Vec<(&Mat, &CalibStats)> = ws
                        .iter()
                        .map(|(_, w, site)| match &accums[*site] {
                            Accum::Rust(stats) => (w, stats),
                            Accum::Aot { .. } => unreachable!("Rust backend built Rust accums"),
                        })
                        .collect();
                    let results =
                        pruning::prune_many(&layer_inputs, spec.method, spec.pattern, &spec.opts);
                    for ((full, w, _site), res) in ws.iter().zip(results) {
                        let (pruned, secs) = res.with_context(|| full.clone())?;
                        report.layers.push(LayerReport {
                            name: full.clone(),
                            c: w.rows,
                            b: w.cols,
                            sparsity: pruned.w.sparsity(),
                            secs,
                            aot: false,
                        });
                        state.set_mat(full, &pruned.w)?;
                    }
                } else {
                    for lname in lnames {
                        let full = format!("blocks.{l}.{lname}");
                        let w = state.get_mat(&full)?;
                        let site = site_of(lname);
                        let t_layer = clock::now_nanos();
                        let (w_new, used_aot) = self
                            .prune_layer(&w, &accums[site], spec)
                            .with_context(|| full.clone())?;
                        report.layers.push(LayerReport {
                            name: full.clone(),
                            c: w.rows,
                            b: w.cols,
                            sparsity: w_new.sparsity(),
                            secs: clock::secs_since(t_layer),
                            aot: used_aot,
                        });
                        state.set_mat(&full, &w_new)?;
                    }
                }
                Ok(())
            });
            report.prune_secs += p_secs;
            prune_res?;

            // -- re-forward through the pruned block -----------------------
            let (rf_res, rf_secs) = trace::timed("coordinator.reforward", || -> Result<()> {
                let block_lit = lit_f32(state.block_slice(l)?, &[state.block_flat_size])?;
                for x in xs.iter_mut() {
                    let out = rt.exec(
                        &format!("block_capture_{}", cfg.name),
                        &[block_lit.clone(), x.clone()],
                    )?;
                    *x = out.into_iter().next().unwrap();
                }
                Ok(())
            });
            report.reforward_secs += rf_secs;
            rf_res?;
        }

        report.total_secs = clock::secs_since(t_total);
        report.engine = crate::engine::global().stats().delta_since(&engine_stats0);
        report.stages = trace::stage_delta(&stages0);
        rt.metrics
            .record_engine("engine.prune_model", &report.engine, report.total_secs);
        Ok(report)
    }

    /// Prune a single layer with the requested backend; returns the new
    /// weights and whether the AOT path was used.
    fn prune_layer(&self, w: &Mat, accum: &Accum, spec: &PruneSpec) -> Result<(Mat, bool)> {
        match accum {
            Accum::Rust(stats) => {
                let pruned = pruning::prune(spec.method, w, stats, spec.pattern, &spec.opts)?;
                Ok((pruned.w, false))
            }
            Accum::Aot { h, xnorm_sq, b } => {
                match self.prune_layer_aot(w, h, xnorm_sq, *b, spec) {
                    Ok(Some(m)) => Ok((m, true)),
                    Ok(None) => {
                        // no artifact for this combo (e.g. SparseGPT):
                        // rebuild Rust stats from the f32 accumulators
                        let stats = stats_from_f32(h, xnorm_sq, *b);
                        let pruned =
                            pruning::prune(spec.method, w, &stats, spec.pattern, &spec.opts)?;
                        Ok((pruned.w, false))
                    }
                    Err(e) => Err(e),
                }
            }
        }
    }

    /// AOT dispatch; Ok(None) = no executable for this combination.
    fn prune_layer_aot(
        &self,
        w: &Mat,
        h: &[f32],
        xnorm_sq: &[f32],
        b: usize,
        spec: &PruneSpec,
    ) -> Result<Option<Mat>> {
        let rt = self.rt;
        let (c, bb) = (w.rows, w.cols);
        ensure!(bb == b, "stats/layer dim mismatch");
        let sname = format!("{c}x{b}");
        let w_lit = mat_lit(w)?;
        let out = match (spec.method, spec.pattern) {
            (Method::Magnitude, Pattern::Unstructured { p }) => {
                let r = (p * (c * b) as f64).floor() as i32;
                rt.exec(&format!("prune_magnitude_{sname}"), &[w_lit, lit_scalar_i32(r)])?
            }
            (Method::Magnitude, Pattern::SemiStructured { n, m, .. }) => {
                let name = format!("prune_magnitude_nm_{sname}_{n}_{m}");
                if !rt.has_exe(&name) {
                    return Ok(None);
                }
                rt.exec(&name, &[w_lit])?
            }
            (Method::Wanda, Pattern::Unstructured { p }) => {
                let k = (p * b as f64).floor() as i32;
                rt.exec(
                    &format!("prune_wanda_{sname}"),
                    &[w_lit, lit_f32(xnorm_sq, &[b])?, lit_scalar_i32(k)],
                )?
            }
            (Method::Wanda, Pattern::SemiStructured { n, m, .. }) => {
                let name = format!("prune_wanda_nm_{sname}_{n}_{m}");
                if !rt.has_exe(&name) {
                    return Ok(None);
                }
                rt.exec(&name, &[w_lit, lit_f32(xnorm_sq, &[b])?])?
            }
            (Method::Thanos, Pattern::Unstructured { p }) => {
                let name = self.find_exe(&format!("prune_thanos_unstr_{sname}_B"))?;
                rt.exec(
                    &name,
                    &[
                        w_lit,
                        lit_f32(h, &[b, b])?,
                        lit_f32(xnorm_sq, &[b])?,
                        lit_scalar_f32(p as f32),
                    ],
                )?
            }
            (Method::Thanos, Pattern::SemiStructured { n, m, alpha }) => {
                let name = self.find_exe(&format!("prune_thanos_nm_{sname}_{n}_{m}_B"))?;
                rt.exec(
                    &name,
                    &[
                        w_lit,
                        lit_f32(h, &[b, b])?,
                        lit_f32(xnorm_sq, &[b])?,
                        lit_scalar_f32(alpha as f32),
                    ],
                )?
            }
            (Method::Thanos, Pattern::Structured { p, alpha }) => rt.exec(
                &format!("prune_thanos_struct_{sname}"),
                &[
                    w_lit,
                    lit_f32(h, &[b, b])?,
                    lit_f32(xnorm_sq, &[b])?,
                    lit_scalar_f32(p as f32),
                    lit_scalar_f32(alpha as f32),
                ],
            )?,
            // SparseGPT and the structured baselines run on the Rust path
            _ => return Ok(None),
        };
        Ok(Some(to_mat(&out[0], c, b)?))
    }

    fn find_exe(&self, prefix: &str) -> Result<String> {
        self.rt
            .manifest
            .executables
            .keys()
            .find(|k| k.starts_with(prefix))
            .cloned()
            .with_context(|| format!("no executable matching '{prefix}*' in manifest"))
    }
}

/// Convert the AOT f32 accumulators into Rust [`CalibStats`] (used when
/// an AOT-backend run needs a Rust-only method like SparseGPT).
fn stats_from_f32(h: &[f32], xnorm_sq: &[f32], b: usize) -> CalibStats {
    let mut stats = CalibStats::new(b);
    for (dst, &v) in stats.h_sum.data.iter_mut().zip(h) {
        *dst = v as f64;
    }
    for (dst, &v) in stats.xnorm_sq.iter_mut().zip(xnorm_sq) {
        *dst = v as f64;
    }
    // n_cols only matters for averaging; the methods are scale-invariant
    stats.n_cols = 1;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_conversion_preserves_values() {
        let h = vec![1.0f32, 2.0, 2.0, 5.0];
        let xn = vec![3.0f32, 4.0];
        let s = stats_from_f32(&h, &xn, 2);
        assert_eq!(s.h_sum.at(1, 1), 5.0);
        assert_eq!(s.xnorm_sq, vec![3.0, 4.0]);
    }

    #[test]
    fn sparse_model_requires_pattern() {
        let cfg = crate::config::ModelConfig {
            name: "t".into(),
            vocab: 4,
            d_model: 2,
            n_layers: 0,
            n_heads: 1,
            d_ff: 4,
            seq_len: 2,
        };
        let state = ModelState { config: cfg, layout: vec![], block_flat_size: 0, flat: vec![] };
        assert!(PruneReport::default().sparse_model(&state).is_err());
        let r = PruneReport {
            pattern: Some(Pattern::Unstructured { p: 0.5 }),
            ..Default::default()
        };
        assert!(r.sparse_model(&state).unwrap().layers.is_empty());
    }

    #[test]
    fn report_aggregation() {
        let mut r = PruneReport::default();
        r.layers.push(LayerReport {
            name: "a".into(),
            c: 2,
            b: 2,
            sparsity: 0.5,
            secs: 0.1,
            aot: true,
        });
        r.layers.push(LayerReport {
            name: "b".into(),
            c: 2,
            b: 2,
            sparsity: 1.0,
            secs: 0.1,
            aot: false,
        });
        assert!((r.overall_sparsity() - 0.75).abs() < 1e-12);
        assert!(r.summary().contains("2 layers"));
        // re-forward is its own summary stage (not folded into capture)
        assert!(r.summary().contains("re-forward"));
        // traced stage lines appear only when a run recorded spans
        assert!(!r.summary().contains("traced stages"));
        r.stages.push(trace::StageLine { name: "walk.solve", count: 3, secs: 0.5 });
        let s = r.summary();
        assert!(s.contains("traced stages") && s.contains("walk.solve"));
    }
}
