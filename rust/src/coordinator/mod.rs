//! The compression-pipeline coordinator — the paper's generic
//! block-by-block pruning loop (Alg. 3) as the L3 system.
//!
//! For every transformer block:
//!
//! 1. **capture pass** — run the `block_capture` executable on every
//!    calibration chunk; accumulate per-layer-input calibration
//!    statistics (Hessian `2·XXᵀ` + row norms), either through the AOT
//!    `hessian_accum` kernel (Pallas L1) or through the threaded Rust
//!    path (exact f64), per the selected [`Backend`].
//! 2. **prune** — each of the six linear layers is pruned to the
//!    requested pattern by the selected method, via AOT executables or
//!    the pure-Rust library.
//! 3. **re-forward** — the (now pruned) block is run again to produce
//!    the inputs of the next block, exactly as Alg. 3 lines 3–7.
//!
//! The coordinator owns no Python: every compute step is a compiled
//! HLO executable or native Rust.
//!
//! The Rust-backend walk is factored behind the [`BlockPipeline`] trait
//! and driven by [`run_pruning`], which optionally journals progress
//! (one fsynced record per completed layer, one per saved block) so an
//! interrupted run can `--resume`, skip the completed blocks, and — by
//! the determinism contract — finish with a checkpoint **bitwise
//! identical** to an uninterrupted run (DESIGN.md §Robustness).

use crate::data::Sequences;
use crate::engine::pipeline::{run_pipeline, PipelineOpts};
use crate::jsonutil::{obj, Json};
use crate::linalg::Mat;
use crate::model::ModelState;
use crate::pruning::{self, CalibStats, Method, Pattern, PruneOpts, Pruned};
use crate::robust::{crc64, crc64_f32s, ChunkReader, ChunkWriter, Journal, MemoryGovernor};
use crate::runtime::{
    lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32, mat_lit, to_mat, to_vec_f32, Runtime,
};
use crate::trace::{self, clock};
use anyhow::{bail, ensure, Context, Result};
use std::path::{Path, PathBuf};

/// Which engine performs calibration statistics + pruning math.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// AOT path: Pallas/JAX HLO executables (falls back to Rust for
    /// method/pattern combos with no artifact, e.g. SparseGPT).
    Aot,
    /// Pure-Rust reference path (f64 Hessians).
    Rust,
}

/// A pruning request for the whole model.
#[derive(Clone, Debug)]
pub struct PruneSpec {
    pub method: Method,
    pub pattern: Pattern,
    pub opts: PruneOpts,
    pub backend: Backend,
}

/// Per-layer outcome.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub c: usize,
    pub b: usize,
    pub sparsity: f64,
    pub secs: f64,
    /// true if this layer ran on the AOT executables
    pub aot: bool,
}

/// Whole-model outcome.
#[derive(Clone, Debug, Default)]
pub struct PruneReport {
    pub layers: Vec<LayerReport>,
    pub capture_secs: f64,
    pub hessian_secs: f64,
    /// wall time of the pruning stage (layers overlap under the
    /// layer-parallel engine path; per-layer times are in [`LayerReport`])
    pub prune_secs: f64,
    /// wall time re-forwarding calibration activations through each
    /// pruned block (its own stage — previously misfiled under capture)
    pub reforward_secs: f64,
    pub total_secs: f64,
    /// traced per-stage breakdown of this run (span name → count /
    /// summed seconds); empty unless tracing was enabled
    /// (`--trace` / `THANOS_TRACE`, see [`crate::trace`])
    pub stages: Vec<trace::StageLine>,
    /// [`crate::engine`] activity during this run (queue/occupancy)
    pub engine: crate::engine::EngineStats,
    /// the pattern this run pruned to — lets [`Self::sparse_model`]
    /// pick the matching compressed format per layer
    pub pattern: Option<Pattern>,
    /// layers skipped because a `--resume` journal already recorded them
    pub resumed_layers: u64,
    /// transient-IO retries taken by the robust write paths during this run
    pub retries: u64,
    /// faults injected by an active `THANOS_FAULTS` schedule during this run
    pub faults_injected: u64,
}

impl PruneReport {
    pub fn overall_sparsity(&self) -> f64 {
        let total: f64 = self.layers.iter().map(|l| (l.c * l.b) as f64).sum();
        let zeros: f64 = self
            .layers
            .iter()
            .map(|l| l.sparsity * (l.c * l.b) as f64)
            .sum();
        zeros / total
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "pruned {} layers to {:.1}% sparsity in {:.1}s (capture {:.1}s, hessian {:.1}s, \
             prune {:.1}s, re-forward {:.1}s) | engine: {} threads, {} jobs ({} inline), \
             {} tasks, queue peak {}, {:.0}% occupancy",
            self.layers.len(),
            self.overall_sparsity() * 100.0,
            self.total_secs,
            self.capture_secs,
            self.hessian_secs,
            self.prune_secs,
            self.reforward_secs,
            self.engine.threads,
            self.engine.jobs_submitted,
            self.engine.jobs_inline,
            self.engine.tasks_executed,
            self.engine.queue_peak,
            self.engine.occupancy(self.total_secs) * 100.0,
        );
        if self.resumed_layers > 0 || self.retries > 0 || self.faults_injected > 0 {
            s.push_str(&format!(
                "\n  robust: {} resumed layer(s), {} IO retry(ies), {} injected fault(s)",
                self.resumed_layers, self.retries, self.faults_injected
            ));
        }
        if !self.stages.is_empty() {
            s.push_str("\n  traced stages (summed span time; workers overlap):");
            for line in &self.stages {
                s.push_str(&format!(
                    "\n    {:<24} x{:<7} {:.3}s",
                    line.name, line.count, line.secs
                ));
            }
        }
        s
    }

    /// Emit the compressed form of the pruned model: every prunable
    /// layer packed in the format matching this run's pattern
    /// (n:m → `NmPacked`, unstructured → `Csr`, structured →
    /// `DenseCompact`). Feed the result to
    /// [`crate::model::ModelState::save_compressed`] (which round-trip
    /// verifies bitwise before writing) for a checkpoint-v2 file, or
    /// serve it through [`crate::sparse::kernels`].
    pub fn sparse_model(&self, state: &ModelState) -> Result<crate::sparse::SparseModel> {
        let pattern = self
            .pattern
            .context("PruneReport has no pattern (default-constructed report)")?;
        crate::sparse::SparseModel::compress_state(state, &pattern)
    }
}

/// Calibration statistics accumulator for one layer-input site.
enum Accum {
    Rust(CalibStats),
    Aot {
        /// running Hessian sum, row-major b×b (f32 on the AOT path)
        h: Vec<f32>,
        xnorm_sq: Vec<f32>,
        b: usize,
    },
}

impl Accum {
    fn new(backend: Backend, b: usize) -> Accum {
        match backend {
            Backend::Rust => Accum::Rust(CalibStats::new(b)),
            Backend::Aot => Accum::Aot { h: vec![0.0; b * b], xnorm_sq: vec![0.0; b], b },
        }
    }

    /// Rust-backend accumulation: no runtime needed, so calibration
    /// sites can accumulate concurrently on the engine pool.
    fn add_chunk_rust(&mut self, xt: &[f32], a: usize) -> Result<()> {
        match self {
            Accum::Rust(stats) => stats.accumulate_chunk_xt(xt, a),
            Accum::Aot { .. } => unreachable!("add_chunk_rust on an AOT accumulator"),
        }
    }

    /// Feed one captured chunk `xt`: row-major `[a, b]` (tokens × features).
    fn add_chunk(&mut self, rt: &Runtime, xt: &[f32], a: usize) -> Result<()> {
        match self {
            Accum::Rust(_) => self.add_chunk_rust(xt, a),
            Accum::Aot { h, xnorm_sq, b } => {
                let name = format!("hessian_accum_{b}");
                let out = rt.exec(
                    &name,
                    &[lit_f32(h, &[*b, *b])?, lit_f32(xt, &[a, *b])?],
                )?;
                *h = to_vec_f32(&out[0])?;
                let chunk = to_vec_f32(&out[1])?;
                for (acc, v) in xnorm_sq.iter_mut().zip(chunk) {
                    *acc += v;
                }
                Ok(())
            }
        }
    }
}

/// Capture-output site index feeding prunable layer `lname` (within the
/// 4-site statistics vector: attn-in, wo-in, w1-in, w2-in).
pub fn site_of_layer(lname: &str) -> usize {
    match lname {
        "wq" | "wk" | "wv" => 0,
        "wo" => 1,
        "w1" => 2,
        "w2" => 3,
        other => unreachable!("'{other}' is not a prunable layer"),
    }
}

/// The forward-pass half of the block-sequential walk (Alg. 3 lines
/// 3–7), abstracted so [`run_pruning`] can drive either the real AOT
/// runtime ([`RuntimePipeline`]) or a synthetic pipeline in tests.
///
/// A pipeline is stateful: it owns the calibration activations. `begin`
/// initializes them from `state` (the embedding pass) and `reforward(l)`
/// advances them through block `l`'s **current** weights — so replaying
/// `begin` + `reforward(0..k)` after a resume restore reproduces the
/// activations of an uninterrupted run bit-for-bit.
pub trait BlockPipeline {
    /// Number of transformer blocks to walk.
    fn n_blocks(&self) -> usize;
    /// Initialize the calibration activations from `state`. Called once
    /// per [`run_pruning`] call, after any resume restore.
    fn begin(&mut self, state: &ModelState) -> Result<()>;
    /// Run block `l` forward and return the per-site calibration
    /// statistics (site order: attn-in, wo-in, w1-in, w2-in).
    fn capture(&mut self, state: &ModelState, l: usize) -> Result<Vec<CalibStats>>;
    /// Re-run block `l` (now pruned), replacing the activations with its
    /// outputs — the inputs of block `l + 1`.
    fn reforward(&mut self, state: &ModelState, l: usize) -> Result<()>;
    /// Drain the (capture, hessian, reforward) stage seconds accumulated
    /// since the previous call.
    fn take_stage_secs(&mut self) -> (f64, f64, f64);
}

/// Journaling/resume options for [`run_pruning`].
#[derive(Clone, Debug, Default)]
pub struct RobustOpts {
    /// Append one fsynced record per completed layer/block to this file.
    pub journal: Option<PathBuf>,
    /// Replay the journal, skip completed blocks, continue from there.
    pub resume: bool,
    /// Byte budget for in-flight calibration activations
    /// (`--mem-budget`). `None` keeps the all-in-RAM behavior; `Some`
    /// routes the Rust backend through the bounded-memory
    /// [`StreamingPipeline`] (bitwise-identical output by construction).
    pub mem_budget: Option<u64>,
}

/// The progress checkpoint that rides beside a journal file.
pub fn progress_ckpt_path(journal: &Path) -> PathBuf {
    PathBuf::from(format!("{}.ckpt", journal.display()))
}

fn parse_hex(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).with_context(|| format!("bad hex digest '{s}'"))
}

/// Everything [`run_pruning`] pins about a run so a journal can refuse
/// to resume a different one.
fn run_descriptor(spec: &PruneSpec, n_blocks: usize, state: &ModelState) -> String {
    format!(
        "{:?}|{}|{:?}|{n_blocks}|{}",
        spec.method,
        spec.pattern.label(),
        spec.opts,
        state.config.to_json().to_string_compact()
    )
}

fn run_record(desc: &str, n_blocks: usize, spec: &PruneSpec) -> String {
    obj(vec![
        ("kind", Json::Str("run".into())),
        ("desc_crc", Json::Str(format!("{:016x}", crc64(desc.as_bytes())))),
        ("n_blocks", Json::Num(n_blocks as f64)),
        ("method", Json::Str(format!("{:?}", spec.method))),
        ("pattern", Json::Str(spec.pattern.label())),
    ])
    .to_string_compact()
}

fn layer_record(block: usize, lr: &LayerReport, pruned: &Pruned) -> String {
    let mask_bytes: Vec<u8> = pruned.mask.iter().map(|&m| m as u8).collect();
    obj(vec![
        ("kind", Json::Str("layer".into())),
        ("block", Json::Num(block as f64)),
        ("name", Json::Str(lr.name.clone())),
        ("c", Json::Num(lr.c as f64)),
        ("b", Json::Num(lr.b as f64)),
        ("sparsity", Json::Num(lr.sparsity)),
        ("secs", Json::Num(lr.secs)),
        // u64 digests do not fit a JSON f64 losslessly → hex strings
        ("weight_crc", Json::Str(format!("{:016x}", crc64_f32s(&pruned.w.data)))),
        ("mask_crc", Json::Str(format!("{:016x}", crc64(&mask_bytes)))),
    ])
    .to_string_compact()
}

fn block_record(block: usize, ckpt_len: u64, ckpt_crc: u64) -> String {
    obj(vec![
        ("kind", Json::Str("block".into())),
        ("block", Json::Num(block as f64)),
        ("ckpt_len", Json::Num(ckpt_len as f64)),
        ("ckpt_crc", Json::Str(format!("{ckpt_crc:016x}"))),
    ])
    .to_string_compact()
}

/// A resumed layer as replayed from the journal.
struct ResumedLayer {
    report: LayerReport,
    weight_crc: u64,
}

/// What a journal replay yields when at least one block completed.
struct ResumePoint {
    next_block: usize,
    ckpt_len: u64,
    ckpt_crc: u64,
    layers: Vec<ResumedLayer>,
    /// journal byte length through the last block record — the tail
    /// beyond it (layers of an incomplete block) is truncated away
    keep_len: u64,
}

fn journal_frame_len(payload: &str) -> u64 {
    12 + payload.len() as u64
}

/// Replay journal records: validate the run header against `desc` and
/// find the last completed block. `Ok(None)` = no block completed (or
/// an empty journal) — start fresh.
fn parse_resume(records: &[String], desc: &str) -> Result<Option<ResumePoint>> {
    let Some(head_rec) = records.first() else {
        return Ok(None);
    };
    let head = Json::parse(head_rec).context("journal run header")?;
    ensure!(
        head.get("kind")?.as_str()? == "run",
        "journal does not start with a run header"
    );
    let desc_crc = parse_hex(head.get("desc_crc")?.as_str()?)?;
    ensure!(
        desc_crc == crc64(desc.as_bytes()),
        "journal belongs to a different run (method, pattern, options or model config \
         changed); delete it or drop --resume"
    );
    let mut scanned_len = journal_frame_len(head_rec);
    let mut pending: Vec<ResumedLayer> = Vec::new();
    let mut kept: Vec<ResumedLayer> = Vec::new();
    let mut point: Option<ResumePoint> = None;
    for rec in &records[1..] {
        let j = Json::parse(rec)?;
        scanned_len += journal_frame_len(rec);
        match j.get("kind")?.as_str()? {
            "layer" => {
                let report = LayerReport {
                    name: j.get("name")?.as_str()?.to_string(),
                    c: j.get("c")?.as_usize()?,
                    b: j.get("b")?.as_usize()?,
                    sparsity: j.get("sparsity")?.as_f64()?,
                    secs: j.get("secs")?.as_f64()?,
                    aot: false,
                };
                pending.push(ResumedLayer {
                    report,
                    weight_crc: parse_hex(j.get("weight_crc")?.as_str()?)?,
                });
            }
            "block" => {
                kept.append(&mut pending);
                point = Some(ResumePoint {
                    next_block: j.get("block")?.as_usize()? + 1,
                    ckpt_len: j.get("ckpt_len")?.as_usize()? as u64,
                    ckpt_crc: parse_hex(j.get("ckpt_crc")?.as_str()?)?,
                    layers: Vec::new(),
                    keep_len: scanned_len,
                });
            }
            k => bail!("unknown journal record kind '{k}'"),
        }
    }
    Ok(point.map(|mut p| {
        p.layers = kept;
        p
    }))
}

/// The block-sequential pruning walk over any [`BlockPipeline`], with
/// optional journaling + resume.
///
/// With a journal: after each completed layer an fsynced layer record
/// (weight + mask digests) is appended; after each completed block the
/// whole state is saved atomically to the progress checkpoint and an
/// fsynced block record (checkpoint length + CRC) follows. Progress is
/// therefore **block-granular**: a kill at any point leaves either a
/// fully-pruned-and-recorded block or one that resume re-prunes from
/// scratch (mid-block resume cannot be bitwise-faithful because capture
/// reads the pre-prune block weights).
///
/// A panicking/failing layer does not abort its block's batch: the
/// surviving layers are applied and journaled, then the run stops at
/// that block with an error naming every failed layer — a subsequent
/// `--resume` re-prunes exactly that block.
pub fn run_pruning(
    state: &mut ModelState,
    pipe: &mut dyn BlockPipeline,
    spec: &PruneSpec,
    robust: &RobustOpts,
) -> Result<PruneReport> {
    ensure!(
        robust.journal.is_some() || !robust.resume,
        "resume requires a journal path"
    );
    let t_total = clock::now_nanos();
    let stages0 = trace::stage_totals();
    let engine0 = crate::engine::global().stats();
    let faults0 = crate::robust::faults::stats();
    let n_blocks = pipe.n_blocks();
    let mut report = PruneReport { pattern: Some(spec.pattern), ..Default::default() };

    let desc = run_descriptor(spec, n_blocks, state);
    let ckpt_path = robust.journal.as_deref().map(progress_ckpt_path);
    let mut journal: Option<Journal> = None;
    let mut start_block = 0usize;
    if let Some(jpath) = robust.journal.as_deref() {
        let mut resume_point = None;
        if robust.resume && jpath.exists() {
            let (j, records) = Journal::open_resume(jpath)?;
            resume_point = parse_resume(&records, &desc)?.map(|p| (j, p));
        }
        journal = Some(match resume_point {
            Some((mut j, p)) => {
                let cp = ckpt_path.as_ref().expect("journal implies ckpt path");
                let bytes = std::fs::read(cp).with_context(|| {
                    format!("reading progress checkpoint {}", cp.display())
                })?;
                ensure!(
                    bytes.len() as u64 == p.ckpt_len && crc64(&bytes) == p.ckpt_crc,
                    "progress checkpoint {} does not match the journal's block record",
                    cp.display()
                );
                let (loaded, _) = ModelState::from_bytes(&bytes)
                    .with_context(|| format!("loading progress checkpoint {}", cp.display()))?;
                *state = loaded;
                for lr in &p.layers {
                    let w = state.get_mat(&lr.report.name)?;
                    ensure!(
                        crc64_f32s(&w.data) == lr.weight_crc,
                        "resumed layer '{}' does not match its journaled weight digest",
                        lr.report.name
                    );
                    report.layers.push(lr.report.clone());
                }
                report.resumed_layers = report.layers.len() as u64;
                start_block = p.next_block;
                j.truncate_to(p.keep_len)?;
                j
            }
            None => {
                let mut j = Journal::create(jpath)?;
                j.append(&run_record(&desc, n_blocks, spec))?;
                j
            }
        });
    }

    pipe.begin(state)?;
    for l in 0..start_block {
        pipe.reforward(state, l)?;
    }

    let lnames = ["wq", "wk", "wv", "wo", "w1", "w2"];
    let mut failed: Vec<String> = Vec::new();
    for l in start_block..n_blocks {
        let stats = pipe.capture(state, l)?;
        ensure!(
            stats.len() == 4,
            "pipeline returned {} stat sites (expected 4)",
            stats.len()
        );
        let ws: Vec<(String, Mat, usize)> = lnames
            .iter()
            .map(|lname| {
                let full = format!("blocks.{l}.{lname}");
                let w = state.get_mat(&full)?;
                Ok((full, w, site_of_layer(lname)))
            })
            .collect::<Result<_>>()?;
        let layer_inputs: Vec<(&Mat, &CalibStats)> =
            ws.iter().map(|(_, w, site)| (w, &stats[*site])).collect();
        let (results, p_secs) = trace::timed("coordinator.prune", || {
            pruning::prune_many(&layer_inputs, spec.method, spec.pattern, &spec.opts)
        });
        report.prune_secs += p_secs;
        let mut block_ok = true;
        for ((full, w, _site), res) in ws.iter().zip(results) {
            match res {
                Ok((pruned, secs)) => {
                    state.set_mat(full, &pruned.w)?;
                    let lr = LayerReport {
                        name: full.clone(),
                        c: w.rows,
                        b: w.cols,
                        sparsity: pruned.w.sparsity(),
                        secs,
                        aot: false,
                    };
                    if let Some(j) = journal.as_mut() {
                        j.append(&layer_record(l, &lr, &pruned))?;
                    }
                    report.layers.push(lr);
                }
                Err(e) => {
                    block_ok = false;
                    failed.push(format!("{full}: {e:#}"));
                }
            }
        }
        if !block_ok {
            // Survivors were applied and journaled, but no block record
            // exists: a resume re-prunes this block from scratch.
            break;
        }
        pipe.reforward(state, l)?;
        if let (Some(j), Some(cp)) = (journal.as_mut(), ckpt_path.as_ref()) {
            let (saved, _) = trace::timed("robust.progress_ckpt", || -> Result<(u64, u64)> {
                state.save(cp)?;
                let bytes = std::fs::read(cp)?;
                Ok((bytes.len() as u64, crc64(&bytes)))
            });
            let (len, crc) = saved?;
            j.append(&block_record(l, len, crc))?;
        }
    }

    let (cap, hes, rf) = pipe.take_stage_secs();
    report.capture_secs += cap;
    report.hessian_secs += hes;
    report.reforward_secs += rf;
    let fstats = crate::robust::faults::stats();
    report.retries = fstats.retries.saturating_sub(faults0.retries);
    report.faults_injected = fstats.injected.saturating_sub(faults0.injected);
    report.total_secs = clock::secs_since(t_total);
    report.engine = crate::engine::global().stats().delta_since(&engine0);
    report.stages = trace::stage_delta(&stages0);
    if !failed.is_empty() {
        bail!(
            "{} layer(s) failed to prune; surviving layers were applied{}: {}",
            failed.len(),
            if robust.journal.is_some() { " and journaled" } else { "" },
            failed.join("; ")
        );
    }
    Ok(report)
}

/// [`BlockPipeline`] over the AOT runtime executables — the embed /
/// block-capture / re-forward passes of the original `prune_model`
/// loop, with the Rust-side Hessian fan-out (per-slot errors, fixed
/// chunk order per site, so sums are bit-identical for any thread
/// count).
pub struct RuntimePipeline<'a> {
    rt: &'a Runtime,
    cfg: crate::config::ModelConfig,
    nbc: usize,
    a: usize,
    tok_chunks: Vec<Vec<i32>>,
    xs: Vec<xla::Literal>,
    capture_secs: f64,
    hessian_secs: f64,
    reforward_secs: f64,
}

impl<'a> RuntimePipeline<'a> {
    pub fn new(rt: &'a Runtime, state: &ModelState, calib: &Sequences) -> Result<Self> {
        let cfg = state.config.clone();
        let nbc = rt.manifest.nb_calib;
        let seq = cfg.seq_len;
        ensure!(calib.seq_len == seq, "calibration seq_len mismatch");
        ensure!(calib.n_seqs() >= nbc, "need at least {nbc} calibration sequences");
        let n_chunks = (calib.n_seqs() / nbc).max(1);
        let a = nbc * seq; // tokens per chunk
        let mut tok_chunks = Vec::with_capacity(n_chunks);
        for ch in 0..n_chunks {
            let mut toks: Vec<i32> = Vec::with_capacity(a);
            for s in 0..nbc {
                toks.extend(calib.seq(ch * nbc + s).iter().map(|&t| t as i32));
            }
            tok_chunks.push(toks);
        }
        Ok(Self {
            rt,
            cfg,
            nbc,
            a,
            tok_chunks,
            xs: Vec::new(),
            capture_secs: 0.0,
            hessian_secs: 0.0,
            reforward_secs: 0.0,
        })
    }
}

impl BlockPipeline for RuntimePipeline<'_> {
    fn n_blocks(&self) -> usize {
        self.cfg.n_layers
    }

    fn begin(&mut self, state: &ModelState) -> Result<()> {
        let (res, secs) = trace::timed("coordinator.capture", || -> Result<Vec<xla::Literal>> {
            let flat_lit = lit_f32(&state.flat, &[state.flat.len()])?;
            let mut xs = Vec::with_capacity(self.tok_chunks.len());
            for toks in &self.tok_chunks {
                let out = self.rt.exec(
                    &format!("embed_{}", self.cfg.name),
                    &[flat_lit.clone(), lit_i32(toks, &[self.nbc, self.cfg.seq_len])?],
                )?;
                xs.push(out.into_iter().next().unwrap());
            }
            Ok(xs)
        });
        self.capture_secs += secs;
        self.xs = res?;
        Ok(())
    }

    fn capture(&mut self, state: &ModelState, l: usize) -> Result<Vec<CalibStats>> {
        let (caps_res, secs) =
            trace::timed("coordinator.capture", || -> Result<Vec<Vec<xla::Literal>>> {
                let block_lit = lit_f32(state.block_slice(l)?, &[state.block_flat_size])?;
                let mut captures = Vec::with_capacity(self.xs.len());
                for x in &self.xs {
                    captures.push(self.rt.exec(
                        &format!("block_capture_{}", self.cfg.name),
                        &[block_lit.clone(), x.clone()],
                    )?);
                }
                Ok(captures)
            });
        self.capture_secs += secs;
        let captures = caps_res?;

        let (d, d_ff, a) = (self.cfg.d_model, self.cfg.d_ff, self.a);
        let (stats_res, h_secs) =
            trace::timed("coordinator.hessian", || -> Result<Vec<CalibStats>> {
                // decode the capture outputs to plain buffers up front
                // (the literal layer stays on this thread), then fan the
                // four independent per-site accumulations out on the
                // engine; errors land in schedule-independent per-slot
                // options, chunk order within a site is fixed, so sums
                // are bit-identical for any thread count
                let mut site_chunks: Vec<Vec<Vec<f32>>> =
                    (0..4).map(|_| Vec::with_capacity(captures.len())).collect();
                for cap in &captures {
                    for (site, chunks) in site_chunks.iter_mut().enumerate() {
                        chunks.push(to_vec_f32(&cap[1 + site])?);
                    }
                }
                let mut slots: Vec<(CalibStats, Option<anyhow::Error>)> = (0..4)
                    .map(|s| (CalibStats::new(if s == 3 { d_ff } else { d }), None))
                    .collect();
                crate::engine::global().for_each_band(&mut slots, 1, |site, slot| {
                    let (stats, err) = &mut slot[0];
                    for xt in &site_chunks[site] {
                        // accumulate_chunk_xt transposes the captured
                        // [a, b] layout to the [b, a] CalibStats expects
                        if let Err(e) = stats.accumulate_chunk_xt(xt, a) {
                            *err = Some(e);
                            break;
                        }
                    }
                });
                let mut out = Vec::with_capacity(4);
                for (site, (stats, err)) in slots.into_iter().enumerate() {
                    if let Some(e) = err {
                        return Err(e.context(format!(
                            "accumulating calibration statistics for site {site}"
                        )));
                    }
                    out.push(stats);
                }
                Ok(out)
            });
        self.hessian_secs += h_secs;
        stats_res
    }

    fn reforward(&mut self, state: &ModelState, l: usize) -> Result<()> {
        let (res, secs) = trace::timed("coordinator.reforward", || -> Result<()> {
            let block_lit = lit_f32(state.block_slice(l)?, &[state.block_flat_size])?;
            for x in self.xs.iter_mut() {
                let out = self.rt.exec(
                    &format!("block_capture_{}", self.cfg.name),
                    &[block_lit.clone(), x.clone()],
                )?;
                *x = out.into_iter().next().unwrap();
            }
            Ok(())
        });
        self.reforward_secs += secs;
        res
    }

    fn take_stage_secs(&mut self) -> (f64, f64, f64) {
        let out = (self.capture_secs, self.hessian_secs, self.reforward_secs);
        self.capture_secs = 0.0;
        self.hessian_secs = 0.0;
        self.reforward_secs = 0.0;
        out
    }
}

// ---------------------------------------------------------------------------
// Streaming pipeline — the bounded-memory Alg. 3 walk (DESIGN.md
// §Streaming)
// ---------------------------------------------------------------------------

/// One calibration chunk forwarded through one block: the block output
/// (the next block's input, flat `[a × d_model]`) plus the four
/// capture-site activations, each row-major `[a, b_site]` (site order:
/// attn-in, wo-in, w1-in, w2-in).
pub struct ChunkForward {
    pub y: Vec<f32>,
    pub sites: [Vec<f32>; 4],
}

/// The per-chunk compute the streaming pipeline drives: embedding one
/// calibration chunk and forwarding one chunk through one block's
/// current weights. [`RuntimeChunkOps`] wraps the AOT executables;
/// tests and the `prune_stream` bench drive synthetic implementations
/// so the streaming machinery (spill, governor, pipeline, faults) is
/// exercised without a compiled HLO.
pub trait ChunkOps {
    fn n_blocks(&self) -> usize;
    fn n_chunks(&self) -> usize;
    /// Token rows per chunk (`a` — the row count of every activation).
    fn tokens_per_chunk(&self) -> usize;
    /// Feature dims of the 4 capture sites (attn-in, wo-in, w1-in, w2-in).
    fn site_dims(&self) -> [usize; 4];
    /// Embed calibration chunk `ch` → x₀, flat `[a × d_model]` f32.
    fn embed(&mut self, state: &ModelState, ch: usize) -> Result<Vec<f32>>;
    /// Forward one chunk through block `l`'s current weights.
    fn forward(&mut self, state: &ModelState, l: usize, x: &[f32]) -> Result<ChunkForward>;
}

/// Options for [`StreamingPipeline`].
#[derive(Clone, Debug)]
pub struct StreamOpts {
    /// Byte budget for in-flight activation chunks (`--mem-budget`).
    /// `None` = every chunk stays resident (the bitwise reference mode).
    pub mem_budget: Option<u64>,
    /// Spill-container path (`.thsc`) used when a budget is set.
    pub spill: PathBuf,
    /// Two-stage pipeline tuning (queue watchdog, heartbeat pacing).
    pub pipeline: PipelineOpts,
}

impl StreamOpts {
    pub fn new(mem_budget: Option<u64>, spill: PathBuf) -> StreamOpts {
        StreamOpts {
            mem_budget,
            spill,
            pipeline: PipelineOpts {
                prefetch_stage: "stream.prefetch",
                compute_stage: "pipeline.stage",
                ..PipelineOpts::default()
            },
        }
    }
}

/// Where the streamed pipeline spills activation chunks: beside the
/// journal when one is set (so an interrupted run and its resume use
/// the same container path), else a per-process temp file.
pub fn spill_path(robust: &RobustOpts) -> PathBuf {
    match robust.journal.as_deref() {
        Some(j) => PathBuf::from(format!("{}.spill.thsc", j.display())),
        None => {
            std::env::temp_dir().join(format!("thanos-spill-{}.thsc", std::process::id()))
        }
    }
}

/// [`BlockPipeline`] with bounded activation memory.
///
/// Two modes, selected by `StreamOpts::mem_budget`:
///
/// * **in-RAM** (`None`) — chunks stay resident in `xs`, the walk is a
///   plain serial loop: the bitwise reference behavior.
/// * **streamed** (`Some(budget)`) — `begin` spills the embedded chunks
///   into a CRC-framed container ([`ChunkWriter`]); `capture` and
///   `reforward` stream them back through the two-stage
///   [`run_pipeline`]: a prefetch stage (verified chunk reads, gated by
///   the [`MemoryGovernor`] byte budget via the queue capacity) feeding
///   the compute stage (block forward + Hessian accumulation).
///   `reforward` rewrites the spill atomically while reading the old
///   generation through a held descriptor — a kill mid-swap leaves the
///   old container intact for `--resume`.
///
/// Both modes accumulate the four sites strictly chunk-ascending
/// through [`CalibStats::accumulate_chunk_xt`], and the pipeline's
/// consumer applies items strictly in index order, so in-RAM, streamed,
/// serial and overlapped runs all produce bit-identical f64 sums — and
/// therefore bit-identical pruned weights.
pub struct StreamingPipeline<O: ChunkOps> {
    ops: O,
    opts: StreamOpts,
    governor: MemoryGovernor,
    /// resident chunks (in-RAM mode only)
    xs: Vec<Vec<f32>>,
    /// true once a spill container has been committed (streamed mode)
    spilled: bool,
    capture_secs: f64,
    hessian_secs: f64,
    reforward_secs: f64,
}

impl<O: ChunkOps> StreamingPipeline<O> {
    pub fn new(ops: O, opts: StreamOpts) -> StreamingPipeline<O> {
        let governor = MemoryGovernor::new(opts.mem_budget);
        StreamingPipeline {
            ops,
            opts,
            governor,
            xs: Vec::new(),
            spilled: false,
            capture_secs: 0.0,
            hessian_secs: 0.0,
            reforward_secs: 0.0,
        }
    }

    /// The governor (budget accounting: peak bytes, admissions).
    pub fn governor(&self) -> &MemoryGovernor {
        &self.governor
    }

    fn streamed(&self) -> bool {
        self.opts.mem_budget.is_some()
    }

    /// Bytes of one activation chunk at the block boundary (`[a, d_model]`
    /// f32) — the unit the governor budgets in.
    fn chunk_bytes(&self) -> u64 {
        (self.ops.tokens_per_chunk() as u64) * (self.ops.site_dims()[0] as u64) * 4
    }

    fn pipe_opts(&self) -> PipelineOpts {
        PipelineOpts {
            capacity: self.governor.capacity(self.chunk_bytes()),
            ..self.opts.pipeline
        }
    }
}

/// Fold one forwarded chunk into the four per-site accumulators —
/// strictly chunk-ascending at every call site, which is what makes
/// serial, overlapped, in-RAM and streamed runs bit-identical.
fn accumulate_sites(
    stats: &mut [CalibStats],
    fwd: &ChunkForward,
    a: usize,
    hessian_secs: &mut f64,
) -> Result<()> {
    let t = clock::now_nanos();
    let _span = trace::span("hessian.accum");
    for (site, xt) in fwd.sites.iter().enumerate() {
        stats[site]
            .accumulate_chunk_xt(xt, a)
            .with_context(|| format!("accumulating calibration statistics for site {site}"))?;
    }
    *hessian_secs += clock::secs_since(t);
    Ok(())
}

/// Probe a pipeline fault site, absorbing transient (`err`) actions
/// through the shared retry ladder.
fn probe(site: &'static str) -> std::io::Result<()> {
    crate::robust::faults::with_retry(&crate::robust::RetryPolicy::default(), || {
        crate::robust::faults::point(site)
    })
}

impl<O: ChunkOps> BlockPipeline for StreamingPipeline<O> {
    fn n_blocks(&self) -> usize {
        self.ops.n_blocks()
    }

    fn begin(&mut self, state: &ModelState) -> Result<()> {
        let (res, secs) = trace::timed("coordinator.capture", || -> Result<()> {
            let n = self.ops.n_chunks();
            if !self.streamed() {
                self.xs.clear();
                for ch in 0..n {
                    let x = self.ops.embed(state, ch)?;
                    self.xs.push(x);
                }
                return Ok(());
            }
            // Streamed: one embedded chunk resident at a time, spilled
            // straight into the (atomically committed) container.
            let mut w = ChunkWriter::create(&self.opts.spill)?;
            for ch in 0..n {
                let x = self.ops.embed(state, ch)?;
                w.write_chunk_f32s(&x)?;
            }
            w.finish()?;
            self.spilled = true;
            Ok(())
        });
        self.capture_secs += secs;
        res
    }

    fn capture(&mut self, state: &ModelState, l: usize) -> Result<Vec<CalibStats>> {
        let t0 = clock::now_nanos();
        let n = self.ops.n_chunks();
        let a = self.ops.tokens_per_chunk();
        let mut stats: Vec<CalibStats> =
            self.ops.site_dims().iter().map(|&b| CalibStats::new(b)).collect();
        let mut hes = 0.0f64;
        if !self.streamed() {
            for ch in 0..n {
                let fwd = self.ops.forward(state, l, &self.xs[ch])?;
                accumulate_sites(&mut stats, &fwd, a, &mut hes)?;
            }
        } else {
            let popts = self.pipe_opts();
            let per_chunk = self.chunk_bytes();
            let mut reader = ChunkReader::open(&self.opts.spill)?;
            let ops = &mut self.ops;
            let governor = &self.governor;
            run_pipeline(
                n,
                &popts,
                |ch| {
                    probe("stream.prefetch")?;
                    let x = reader.read_chunk_f32s(ch)?;
                    governor.admit(per_chunk)?;
                    Ok(x)
                },
                |_, x| {
                    probe("pipeline.stage")?;
                    let fwd = ops.forward(state, l, &x)?;
                    drop(x);
                    governor.release(per_chunk);
                    accumulate_sites(&mut stats, &fwd, a, &mut hes)
                },
            )?;
        }
        self.hessian_secs += hes;
        self.capture_secs += clock::secs_since(t0) - hes;
        Ok(stats)
    }

    fn reforward(&mut self, state: &ModelState, l: usize) -> Result<()> {
        let (res, secs) = trace::timed("coordinator.reforward", || -> Result<()> {
            let n = self.ops.n_chunks();
            if !self.streamed() {
                for ch in 0..n {
                    let fwd = self.ops.forward(state, l, &self.xs[ch])?;
                    self.xs[ch] = fwd.y;
                }
                return Ok(());
            }
            // Read the old generation through a held descriptor while
            // the new generation streams into an atomic rewrite of the
            // same path: a kill anywhere here leaves the old spill (and
            // its journaled block state) intact for --resume.
            let popts = self.pipe_opts();
            let per_chunk = self.chunk_bytes();
            let mut reader = ChunkReader::open(&self.opts.spill)?;
            let mut writer = ChunkWriter::create(&self.opts.spill)?;
            let ops = &mut self.ops;
            let governor = &self.governor;
            run_pipeline(
                n,
                &popts,
                |ch| {
                    probe("stream.prefetch")?;
                    let x = reader.read_chunk_f32s(ch)?;
                    governor.admit(per_chunk)?;
                    Ok(x)
                },
                |_, x| {
                    probe("pipeline.stage")?;
                    let fwd = ops.forward(state, l, &x)?;
                    drop(x);
                    governor.release(per_chunk);
                    writer.write_chunk_f32s(&fwd.y)
                },
            )?;
            writer.finish()
        });
        self.reforward_secs += secs;
        res
    }

    fn take_stage_secs(&mut self) -> (f64, f64, f64) {
        let out = (self.capture_secs, self.hessian_secs, self.reforward_secs);
        self.capture_secs = 0.0;
        self.hessian_secs = 0.0;
        self.reforward_secs = 0.0;
        out
    }
}

impl<O: ChunkOps> Drop for StreamingPipeline<O> {
    fn drop(&mut self) {
        if self.spilled {
            // Best-effort cleanup of the committed spill; a resumed run
            // re-creates it in `begin`, so losing it costs nothing.
            let _ = std::fs::remove_file(&self.opts.spill);
        }
    }
}

/// [`ChunkOps`] over the AOT runtime executables — the same embed /
/// block-capture passes as [`RuntimePipeline`], decoded to plain `f32`
/// buffers so chunks can spill through the [`ChunkWriter`] instead of
/// staying resident as literals.
pub struct RuntimeChunkOps<'a> {
    rt: &'a Runtime,
    cfg: crate::config::ModelConfig,
    nbc: usize,
    tok_chunks: Vec<Vec<i32>>,
}

impl<'a> RuntimeChunkOps<'a> {
    pub fn new(rt: &'a Runtime, state: &ModelState, calib: &Sequences) -> Result<Self> {
        let cfg = state.config.clone();
        let nbc = rt.manifest.nb_calib;
        let seq = cfg.seq_len;
        ensure!(calib.seq_len == seq, "calibration seq_len mismatch");
        ensure!(calib.n_seqs() >= nbc, "need at least {nbc} calibration sequences");
        let n_chunks = (calib.n_seqs() / nbc).max(1);
        let a = nbc * seq;
        let mut tok_chunks = Vec::with_capacity(n_chunks);
        for ch in 0..n_chunks {
            let mut toks: Vec<i32> = Vec::with_capacity(a);
            for s in 0..nbc {
                toks.extend(calib.seq(ch * nbc + s).iter().map(|&t| t as i32));
            }
            tok_chunks.push(toks);
        }
        Ok(Self { rt, cfg, nbc, tok_chunks })
    }
}

impl ChunkOps for RuntimeChunkOps<'_> {
    fn n_blocks(&self) -> usize {
        self.cfg.n_layers
    }

    fn n_chunks(&self) -> usize {
        self.tok_chunks.len()
    }

    fn tokens_per_chunk(&self) -> usize {
        self.nbc * self.cfg.seq_len
    }

    fn site_dims(&self) -> [usize; 4] {
        let d = self.cfg.d_model;
        [d, d, d, self.cfg.d_ff]
    }

    fn embed(&mut self, state: &ModelState, ch: usize) -> Result<Vec<f32>> {
        let flat_lit = lit_f32(&state.flat, &[state.flat.len()])?;
        let out = self.rt.exec(
            &format!("embed_{}", self.cfg.name),
            &[flat_lit, lit_i32(&self.tok_chunks[ch], &[self.nbc, self.cfg.seq_len])?],
        )?;
        to_vec_f32(&out[0])
    }

    fn forward(&mut self, state: &ModelState, l: usize, x: &[f32]) -> Result<ChunkForward> {
        let block_lit = lit_f32(state.block_slice(l)?, &[state.block_flat_size])?;
        let x_lit = lit_f32(x, &[self.nbc, self.cfg.seq_len, self.cfg.d_model])?;
        let out = self.rt.exec(
            &format!("block_capture_{}", self.cfg.name),
            &[block_lit, x_lit],
        )?;
        ensure!(
            out.len() == 5,
            "block_capture returned {} outputs (expected y + 4 capture sites)",
            out.len()
        );
        let y = to_vec_f32(&out[0])?;
        let sites = [
            to_vec_f32(&out[1])?,
            to_vec_f32(&out[2])?,
            to_vec_f32(&out[3])?,
            to_vec_f32(&out[4])?,
        ];
        Ok(ChunkForward { y, sites })
    }
}

/// The coordinator itself.
pub struct Coordinator<'a> {
    pub rt: &'a Runtime,
}

impl<'a> Coordinator<'a> {
    pub fn new(rt: &'a Runtime) -> Coordinator<'a> {
        Coordinator { rt }
    }

    /// Prune every linear layer of `state` per `spec`, using `calib`
    /// sequences as the calibration set (paper: 128 C4 sequences).
    pub fn prune_model(
        &self,
        state: &mut ModelState,
        calib: &Sequences,
        spec: &PruneSpec,
    ) -> Result<PruneReport> {
        self.prune_model_robust(state, calib, spec, &RobustOpts::default())
    }

    /// [`Self::prune_model`] with journaling/resume and optional
    /// bounded-memory streaming. The Rust backend routes through
    /// [`run_pruning`] over a [`RuntimePipeline`] (all-in-RAM), or over
    /// a [`StreamingPipeline`] when `robust.mem_budget` is set — same
    /// bits, bounded activation memory. The AOT backend keeps the
    /// legacy sequential loop (device-side layer pruning has no
    /// per-block progress checkpoint, so journaling and streaming
    /// require `--backend=rust`).
    pub fn prune_model_robust(
        &self,
        state: &mut ModelState,
        calib: &Sequences,
        spec: &PruneSpec,
        robust: &RobustOpts,
    ) -> Result<PruneReport> {
        if spec.backend == Backend::Rust {
            let report = if robust.mem_budget.is_some() {
                let ops = RuntimeChunkOps::new(self.rt, state, calib)?;
                let mut pipe = StreamingPipeline::new(
                    ops,
                    StreamOpts::new(robust.mem_budget, spill_path(robust)),
                );
                let report = run_pruning(state, &mut pipe, spec, robust)?;
                self.rt
                    .metrics
                    .set_gauge("stream.peak_bytes", pipe.governor().peak_bytes() as f64);
                report
            } else {
                let mut pipe = RuntimePipeline::new(self.rt, state, calib)?;
                run_pruning(state, &mut pipe, spec, robust)?
            };
            self.rt
                .metrics
                .record_engine("engine.prune_model", &report.engine, report.total_secs);
            self.rt
                .metrics
                .set_gauge("robust.resumed_layers", report.resumed_layers as f64);
            self.rt.metrics.set_gauge("robust.retries", report.retries as f64);
            self.rt
                .metrics
                .set_gauge("robust.faults_injected", report.faults_injected as f64);
            return Ok(report);
        }
        ensure!(
            robust.journal.is_none() && !robust.resume && robust.mem_budget.is_none(),
            "journaled/streamed pruning requires the Rust backend (--backend=rust): the AOT \
             path prunes through device executables and keeps no per-block progress checkpoint"
        );
        self.prune_model_aot(state, calib, spec)
    }

    /// The legacy sequential loop (AOT backend): per-layer device
    /// executables, no journaling.
    fn prune_model_aot(
        &self,
        state: &mut ModelState,
        calib: &Sequences,
        spec: &PruneSpec,
    ) -> Result<PruneReport> {
        let t_total = clock::now_nanos();
        let stages0 = trace::stage_totals();
        let engine_stats0 = crate::engine::global().stats();
        let cfg = state.config.clone();
        let rt = self.rt;
        let nbc = rt.manifest.nb_calib;
        let seq = cfg.seq_len;
        ensure!(calib.seq_len == seq, "calibration seq_len mismatch");
        let n_chunks = (calib.n_seqs() / nbc).max(1);
        ensure!(calib.n_seqs() >= nbc, "need at least {nbc} calibration sequences");
        let a = nbc * seq; // tokens per chunk
        let d = cfg.d_model;

        let mut report = PruneReport { pattern: Some(spec.pattern), ..Default::default() };

        // embed calibration chunks → x literals
        let (xs_res, cap_secs) = trace::timed("coordinator.capture", || -> Result<Vec<_>> {
            let flat_lit = lit_f32(&state.flat, &[state.flat.len()])?;
            let mut xs: Vec<xla::Literal> = Vec::with_capacity(n_chunks);
            for ch in 0..n_chunks {
                let mut toks: Vec<i32> = Vec::with_capacity(a);
                for s in 0..nbc {
                    toks.extend(calib.seq(ch * nbc + s).iter().map(|&t| t as i32));
                }
                let out = rt.exec(
                    &format!("embed_{}", cfg.name),
                    &[flat_lit.clone(), lit_i32(&toks, &[nbc, seq])?],
                )?;
                xs.push(out.into_iter().next().unwrap());
            }
            Ok(xs)
        });
        report.capture_secs += cap_secs;
        let mut xs = xs_res?;

        // layer name → capture-output index (1-based in the exe outputs)
        // outputs: (y, x_attn, x_o, x_ff1, x_ff2)
        let site_of = |layer: &str| match layer {
            "wq" | "wk" | "wv" => 0usize,
            "wo" => 1,
            "w1" => 2,
            "w2" => 3,
            _ => unreachable!(),
        };
        let site_b = |site: usize| if site == 3 { cfg.d_ff } else { d };

        for l in 0..cfg.n_layers {
            // -- capture pass ---------------------------------------------
            let (captures_res, cap_secs) =
                trace::timed("coordinator.capture", || -> Result<Vec<_>> {
                    let block_lit = lit_f32(state.block_slice(l)?, &[state.block_flat_size])?;
                    let mut captures: Vec<Vec<xla::Literal>> = Vec::with_capacity(n_chunks);
                    for x in &xs {
                        let out = rt.exec(
                            &format!("block_capture_{}", cfg.name),
                            &[block_lit.clone(), x.clone()],
                        )?;
                        captures.push(out);
                    }
                    Ok(captures)
                });
            report.capture_secs += cap_secs;
            let captures = captures_res?;

            // -- calibration statistics per site --------------------------
            let (accums_res, h_secs) = trace::timed("coordinator.hessian", || -> Result<Vec<_>> {
                let mut accums: Vec<Accum> = (0..4)
                    .map(|s| Accum::new(spec.backend, site_b(s)))
                    .collect();
                // strictly sequential (needs the runtime): decode one
                // chunk at a time so peak memory stays at one decoded
                // chunk (the Rust backend's engine fan-out lives in
                // `RuntimePipeline::capture`)
                for cap in &captures {
                    for (site, accum) in accums.iter_mut().enumerate() {
                        let xt = to_vec_f32(&cap[1 + site])?;
                        accum.add_chunk(rt, &xt, a)?;
                    }
                }
                Ok(accums)
            });
            report.hessian_secs += h_secs;
            let accums = accums_res?;

            // -- prune the six layers --------------------------------------
            let lnames = ["wq", "wk", "wv", "wo", "w1", "w2"];
            let (prune_res, p_secs) = trace::timed("coordinator.prune", || -> Result<()> {
                for lname in lnames {
                    let full = format!("blocks.{l}.{lname}");
                    let w = state.get_mat(&full)?;
                    let site = site_of(lname);
                    let t_layer = clock::now_nanos();
                    let (w_new, used_aot) = self
                        .prune_layer(&w, &accums[site], spec)
                        .with_context(|| full.clone())?;
                    report.layers.push(LayerReport {
                        name: full.clone(),
                        c: w.rows,
                        b: w.cols,
                        sparsity: w_new.sparsity(),
                        secs: clock::secs_since(t_layer),
                        aot: used_aot,
                    });
                    state.set_mat(&full, &w_new)?;
                }
                Ok(())
            });
            report.prune_secs += p_secs;
            prune_res?;

            // -- re-forward through the pruned block -----------------------
            let (rf_res, rf_secs) = trace::timed("coordinator.reforward", || -> Result<()> {
                let block_lit = lit_f32(state.block_slice(l)?, &[state.block_flat_size])?;
                for x in xs.iter_mut() {
                    let out = rt.exec(
                        &format!("block_capture_{}", cfg.name),
                        &[block_lit.clone(), x.clone()],
                    )?;
                    *x = out.into_iter().next().unwrap();
                }
                Ok(())
            });
            report.reforward_secs += rf_secs;
            rf_res?;
        }

        report.total_secs = clock::secs_since(t_total);
        report.engine = crate::engine::global().stats().delta_since(&engine_stats0);
        report.stages = trace::stage_delta(&stages0);
        rt.metrics
            .record_engine("engine.prune_model", &report.engine, report.total_secs);
        Ok(report)
    }

    /// Prune a single layer with the requested backend; returns the new
    /// weights and whether the AOT path was used.
    fn prune_layer(&self, w: &Mat, accum: &Accum, spec: &PruneSpec) -> Result<(Mat, bool)> {
        match accum {
            Accum::Rust(stats) => {
                let pruned = pruning::prune(spec.method, w, stats, spec.pattern, &spec.opts)?;
                Ok((pruned.w, false))
            }
            Accum::Aot { h, xnorm_sq, b } => {
                match self.prune_layer_aot(w, h, xnorm_sq, *b, spec) {
                    Ok(Some(m)) => Ok((m, true)),
                    Ok(None) => {
                        // no artifact for this combo (e.g. SparseGPT):
                        // rebuild Rust stats from the f32 accumulators
                        let stats = stats_from_f32(h, xnorm_sq, *b);
                        let pruned =
                            pruning::prune(spec.method, w, &stats, spec.pattern, &spec.opts)?;
                        Ok((pruned.w, false))
                    }
                    Err(e) => Err(e),
                }
            }
        }
    }

    /// AOT dispatch; Ok(None) = no executable for this combination.
    fn prune_layer_aot(
        &self,
        w: &Mat,
        h: &[f32],
        xnorm_sq: &[f32],
        b: usize,
        spec: &PruneSpec,
    ) -> Result<Option<Mat>> {
        let rt = self.rt;
        let (c, bb) = (w.rows, w.cols);
        ensure!(bb == b, "stats/layer dim mismatch");
        let sname = format!("{c}x{b}");
        let w_lit = mat_lit(w)?;
        let out = match (spec.method, spec.pattern) {
            (Method::Magnitude, Pattern::Unstructured { p }) => {
                let r = (p * (c * b) as f64).floor() as i32;
                rt.exec(&format!("prune_magnitude_{sname}"), &[w_lit, lit_scalar_i32(r)])?
            }
            (Method::Magnitude, Pattern::SemiStructured { n, m, .. }) => {
                let name = format!("prune_magnitude_nm_{sname}_{n}_{m}");
                if !rt.has_exe(&name) {
                    return Ok(None);
                }
                rt.exec(&name, &[w_lit])?
            }
            (Method::Wanda, Pattern::Unstructured { p }) => {
                let k = (p * b as f64).floor() as i32;
                rt.exec(
                    &format!("prune_wanda_{sname}"),
                    &[w_lit, lit_f32(xnorm_sq, &[b])?, lit_scalar_i32(k)],
                )?
            }
            (Method::Wanda, Pattern::SemiStructured { n, m, .. }) => {
                let name = format!("prune_wanda_nm_{sname}_{n}_{m}");
                if !rt.has_exe(&name) {
                    return Ok(None);
                }
                rt.exec(&name, &[w_lit, lit_f32(xnorm_sq, &[b])?])?
            }
            (Method::Thanos, Pattern::Unstructured { p }) => {
                let name = self.find_exe(&format!("prune_thanos_unstr_{sname}_B"))?;
                rt.exec(
                    &name,
                    &[
                        w_lit,
                        lit_f32(h, &[b, b])?,
                        lit_f32(xnorm_sq, &[b])?,
                        lit_scalar_f32(p as f32),
                    ],
                )?
            }
            (Method::Thanos, Pattern::SemiStructured { n, m, alpha }) => {
                let name = self.find_exe(&format!("prune_thanos_nm_{sname}_{n}_{m}_B"))?;
                rt.exec(
                    &name,
                    &[
                        w_lit,
                        lit_f32(h, &[b, b])?,
                        lit_f32(xnorm_sq, &[b])?,
                        lit_scalar_f32(alpha as f32),
                    ],
                )?
            }
            (Method::Thanos, Pattern::Structured { p, alpha }) => rt.exec(
                &format!("prune_thanos_struct_{sname}"),
                &[
                    w_lit,
                    lit_f32(h, &[b, b])?,
                    lit_f32(xnorm_sq, &[b])?,
                    lit_scalar_f32(p as f32),
                    lit_scalar_f32(alpha as f32),
                ],
            )?,
            // SparseGPT and the structured baselines run on the Rust path
            _ => return Ok(None),
        };
        Ok(Some(to_mat(&out[0], c, b)?))
    }

    fn find_exe(&self, prefix: &str) -> Result<String> {
        self.rt
            .manifest
            .executables
            .keys()
            .find(|k| k.starts_with(prefix))
            .cloned()
            .with_context(|| format!("no executable matching '{prefix}*' in manifest"))
    }
}

/// Convert the AOT f32 accumulators into Rust [`CalibStats`] (used when
/// an AOT-backend run needs a Rust-only method like SparseGPT).
fn stats_from_f32(h: &[f32], xnorm_sq: &[f32], b: usize) -> CalibStats {
    let mut stats = CalibStats::new(b);
    for (dst, &v) in stats.h_sum.data.iter_mut().zip(h) {
        *dst = v as f64;
    }
    for (dst, &v) in stats.xnorm_sq.iter_mut().zip(xnorm_sq) {
        *dst = v as f64;
    }
    // n_cols only matters for averaging; the methods are scale-invariant
    stats.n_cols = 1;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_conversion_preserves_values() {
        let h = vec![1.0f32, 2.0, 2.0, 5.0];
        let xn = vec![3.0f32, 4.0];
        let s = stats_from_f32(&h, &xn, 2);
        assert_eq!(s.h_sum.at(1, 1), 5.0);
        assert_eq!(s.xnorm_sq, vec![3.0, 4.0]);
    }

    #[test]
    fn sparse_model_requires_pattern() {
        let cfg = crate::config::ModelConfig {
            name: "t".into(),
            vocab: 4,
            d_model: 2,
            n_layers: 0,
            n_heads: 1,
            d_ff: 4,
            seq_len: 2,
        };
        let state = ModelState { config: cfg, layout: vec![], block_flat_size: 0, flat: vec![] };
        assert!(PruneReport::default().sparse_model(&state).is_err());
        let r = PruneReport {
            pattern: Some(Pattern::Unstructured { p: 0.5 }),
            ..Default::default()
        };
        assert!(r.sparse_model(&state).unwrap().layers.is_empty());
    }

    #[test]
    fn journal_records_roundtrip_through_parse_resume() {
        let spec = PruneSpec {
            method: Method::Thanos,
            pattern: Pattern::Unstructured { p: 0.5 },
            opts: PruneOpts::default(),
            backend: Backend::Rust,
        };
        let cfg = crate::config::ModelConfig {
            name: "t".into(),
            vocab: 4,
            d_model: 2,
            n_layers: 2,
            n_heads: 1,
            d_ff: 4,
            seq_len: 2,
        };
        let state = ModelState { config: cfg, layout: vec![], block_flat_size: 0, flat: vec![] };
        let desc = run_descriptor(&spec, 2, &state);
        let w = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 2.0]);
        let orig = Mat::from_vec(2, 2, vec![1.0, 3.0, 4.0, 2.0]);
        let pruned = Pruned::from_w(w, &orig);
        let lr = LayerReport {
            name: "blocks.0.wq".into(),
            c: 2,
            b: 2,
            sparsity: 0.5,
            secs: 0.01,
            aot: false,
        };
        let records = vec![
            run_record(&desc, 2, &spec),
            layer_record(0, &lr, &pruned),
            block_record(0, 123, 0xABCD_EF00_1122_3344),
            layer_record(1, &lr, &pruned), // incomplete block 1: dropped
        ];
        let p = parse_resume(&records, &desc).unwrap().unwrap();
        assert_eq!(p.next_block, 1);
        assert_eq!(p.ckpt_len, 123);
        assert_eq!(p.ckpt_crc, 0xABCD_EF00_1122_3344);
        assert_eq!(p.layers.len(), 1);
        assert_eq!(p.layers[0].report.name, "blocks.0.wq");
        assert_eq!(p.layers[0].weight_crc, crc64_f32s(&pruned.w.data));
        let keep: u64 = records[..3].iter().map(|r| journal_frame_len(r)).sum();
        assert_eq!(p.keep_len, keep);
        // a journal from a different run is refused
        assert!(parse_resume(&records, "other-desc").is_err());
        // no completed block → fresh start
        assert!(parse_resume(&records[..2], &desc).unwrap().is_none());
        assert!(parse_resume(&[], &desc).unwrap().is_none());
    }

    /// Deterministic synthetic [`ChunkOps`]: embed derives chunks from a
    /// seeded RNG, forward is a fixed affine map per block — enough
    /// state-dependence that any ordering or framing bug changes bits.
    struct SynthOps {
        blocks: usize,
        chunks: usize,
        a: usize,
        d: usize,
        d_ff: usize,
    }

    impl ChunkOps for SynthOps {
        fn n_blocks(&self) -> usize {
            self.blocks
        }
        fn n_chunks(&self) -> usize {
            self.chunks
        }
        fn tokens_per_chunk(&self) -> usize {
            self.a
        }
        fn site_dims(&self) -> [usize; 4] {
            [self.d, self.d, self.d, self.d_ff]
        }
        fn embed(&mut self, _state: &ModelState, ch: usize) -> Result<Vec<f32>> {
            let mut rng = crate::rng::Rng::new(0x51EE_D000 + ch as u64);
            Ok((0..self.a * self.d).map(|_| rng.uniform_f32() - 0.5).collect())
        }
        fn forward(&mut self, _state: &ModelState, l: usize, x: &[f32]) -> Result<ChunkForward> {
            ensure!(x.len() == self.a * self.d, "bad chunk shape");
            let bump = (l as f32 + 1.0) * 0.25;
            let y: Vec<f32> = x.iter().map(|v| v * 0.75 + bump).collect();
            let site = |b: usize, scale: f32| -> Vec<f32> {
                (0..self.a * b).map(|i| x[i % x.len()] * scale).collect()
            };
            Ok(ChunkForward {
                y,
                sites: [
                    site(self.d, 1.0),
                    site(self.d, 0.5),
                    site(self.d, -1.25),
                    site(self.d_ff, 2.0),
                ],
            })
        }
    }

    fn trivial_state() -> ModelState {
        let cfg = crate::config::ModelConfig {
            name: "t".into(),
            vocab: 4,
            d_model: 2,
            n_layers: 0,
            n_heads: 1,
            d_ff: 4,
            seq_len: 2,
        };
        ModelState { config: cfg, layout: vec![], block_flat_size: 0, flat: vec![] }
    }

    /// Drive the full walk and digest every Hessian bit plus the
    /// post-reforward activations (via the final block's stats).
    fn walk(budget: Option<u64>, tag: &str) -> (Vec<u64>, u64, u64) {
        let state = trivial_state();
        let ops = SynthOps { blocks: 3, chunks: 4, a: 6, d: 3, d_ff: 5 };
        let blocks = ops.blocks;
        let spill = std::env::temp_dir()
            .join(format!("thanos-coord-{tag}-{}.thsc", std::process::id()));
        let mut pipe = StreamingPipeline::new(ops, StreamOpts::new(budget, spill.clone()));
        pipe.begin(&state).unwrap();
        let mut bits = Vec::new();
        for l in 0..blocks {
            let stats = pipe.capture(&state, l).unwrap();
            assert_eq!(stats.len(), 4);
            for s in &stats {
                bits.extend(s.h_sum.data.iter().map(|v| v.to_bits()));
                bits.extend(s.xnorm_sq.iter().map(|v| v.to_bits()));
            }
            pipe.reforward(&state, l).unwrap();
        }
        let (peak, admitted) = (pipe.governor().peak_bytes(), pipe.governor().admitted());
        drop(pipe);
        assert!(!spill.exists(), "spill container must be cleaned up on drop");
        (bits, peak, admitted)
    }

    #[test]
    fn streamed_walk_is_bitwise_identical_to_in_ram() {
        let (reference, peak0, _) = walk(None, "inram");
        assert_eq!(peak0, 0, "in-RAM mode never admits into the governor");
        // chunk_bytes = a·d·4 = 72; budget 216 = 3 chunks → capacity
        // max(1, 3−2) = 1, so queued + in-hand + in-consumption ≤ budget
        let (streamed, peak, admitted) = walk(Some(216), "streamed");
        assert_eq!(streamed, reference);
        // every capture + reforward admits each chunk once: 3 blocks × 2
        // passes × 4 chunks
        assert_eq!(admitted, 24);
        assert!(peak > 0 && peak <= 216, "peak {peak} exceeds the byte budget");
        // serial engine mode takes the inline path and still matches
        let (serial, _, _) = crate::engine::with_serial(|| walk(Some(216), "serial"));
        assert_eq!(serial, reference);
    }

    #[test]
    fn spill_path_follows_the_journal() {
        let r = RobustOpts {
            journal: Some(PathBuf::from("/tmp/run.journal")),
            resume: false,
            mem_budget: Some(1),
        };
        assert_eq!(spill_path(&r), PathBuf::from("/tmp/run.journal.spill.thsc"));
        let tmp = spill_path(&RobustOpts::default());
        assert!(tmp.to_string_lossy().ends_with(".thsc"));
    }

    #[test]
    fn report_aggregation() {
        let mut r = PruneReport::default();
        r.layers.push(LayerReport {
            name: "a".into(),
            c: 2,
            b: 2,
            sparsity: 0.5,
            secs: 0.1,
            aot: true,
        });
        r.layers.push(LayerReport {
            name: "b".into(),
            c: 2,
            b: 2,
            sparsity: 1.0,
            secs: 0.1,
            aot: false,
        });
        assert!((r.overall_sparsity() - 0.75).abs() < 1e-12);
        assert!(r.summary().contains("2 layers"));
        // re-forward is its own summary stage (not folded into capture)
        assert!(r.summary().contains("re-forward"));
        // traced stage lines appear only when a run recorded spans
        assert!(!r.summary().contains("traced stages"));
        r.stages.push(trace::StageLine { name: "walk.solve", count: 3, secs: 0.5 });
        let s = r.summary();
        assert!(s.contains("traced stages") && s.contains("walk.solve"));
        // robust line appears only when the run resumed/retried/faulted
        assert!(!s.contains("robust:"));
        r.resumed_layers = 6;
        assert!(r.summary().contains("6 resumed layer(s)"));
    }
}
