//! Permutation handling for structured pruning (paper §G.4.4).
//!
//! Structured Thanos permutes rows of `W` so outlier rows sit at the
//! end, and columns so the `s` cheapest-to-remove columns sit first;
//! after pruning the inverse permutations restore the original order.
//! Permutations are represented as index vectors (`perm[new] = old`),
//! never as dense 0/1 matrices — applying one is O(c·b) instead of a
//! full GEMM.

use super::{Mat, MatF64};

/// A permutation `σ`: position `i` of the permuted object is taken from
/// position `sigma[i]` of the original.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Perm {
    pub sigma: Vec<usize>,
}

impl Perm {
    pub fn identity(n: usize) -> Self {
        Perm { sigma: (0..n).collect() }
    }

    /// Permutation that sorts `keys` ascending (stable).
    pub fn sorting(keys: &[f64]) -> Self {
        let mut sigma: Vec<usize> = (0..keys.len()).collect();
        sigma.sort_by(|&a, &b| {
            keys[a]
                .partial_cmp(&keys[b])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        Perm { sigma }
    }

    pub fn len(&self) -> usize {
        self.sigma.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sigma.is_empty()
    }

    /// Inverse permutation: `inv.sigma[old] = new`.
    pub fn inverse(&self) -> Perm {
        let mut inv = vec![0usize; self.sigma.len()];
        for (new, &old) in self.sigma.iter().enumerate() {
            inv[old] = new;
        }
        Perm { sigma: inv }
    }

    /// Validity check: `sigma` must be a bijection on `0..n`.
    pub fn is_valid(&self) -> bool {
        let n = self.sigma.len();
        let mut seen = vec![false; n];
        for &s in &self.sigma {
            if s >= n || seen[s] {
                return false;
            }
            seen[s] = true;
        }
        true
    }

    /// Apply to the rows of `m`: `out.row(i) = m.row(sigma[i])` (the
    /// paper's `W' = Q·W`).
    pub fn apply_rows(&self, m: &Mat) -> Mat {
        assert_eq!(self.sigma.len(), m.rows);
        let mut out = Mat::zeros(m.rows, m.cols);
        for (new, &old) in self.sigma.iter().enumerate() {
            out.row_mut(new).copy_from_slice(m.row(old));
        }
        out
    }

    /// Apply to the columns of `m`: `out[:, j] = m[:, sigma[j]]`
    /// (the paper's `W·P` with our index convention).
    pub fn apply_cols(&self, m: &Mat) -> Mat {
        assert_eq!(self.sigma.len(), m.cols);
        let mut out = Mat::zeros(m.rows, m.cols);
        for i in 0..m.rows {
            let src = m.row(i);
            let dst = out.row_mut(i);
            for (new, &old) in self.sigma.iter().enumerate() {
                dst[new] = src[old];
            }
        }
        out
    }

    /// Conjugate a symmetric matrix: `out[i][j] = h[sigma[i]][sigma[j]]`.
    /// Column-permuting `W` permutes the input features, so the Hessian
    /// must be permuted on both axes.
    pub fn conjugate_sym(&self, h: &MatF64) -> MatF64 {
        assert_eq!(h.rows, h.cols);
        assert_eq!(self.sigma.len(), h.rows);
        let n = h.rows;
        let mut out = MatF64::zeros(n, n);
        for (ni, &oi) in self.sigma.iter().enumerate() {
            for (nj, &oj) in self.sigma.iter().enumerate() {
                *out.at_mut(ni, nj) = h.at(oi, oj);
            }
        }
        out
    }

    /// Apply to a plain vector.
    pub fn apply_vec<T: Copy>(&self, v: &[T]) -> Vec<T> {
        assert_eq!(self.sigma.len(), v.len());
        self.sigma.iter().map(|&old| v[old]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn sorting_perm_sorts() {
        let keys = vec![3.0, 1.0, 2.0, 0.5];
        let p = Perm::sorting(&keys);
        let sorted = p.apply_vec(&keys);
        assert_eq!(sorted, vec![0.5, 1.0, 2.0, 3.0]);
        assert!(p.is_valid());
    }

    #[test]
    fn inverse_roundtrip_rows_cols() {
        let mut r = Rng::new(8);
        let m = Mat::from_fn(6, 5, |_, _| r.normal_f32(0.0, 1.0));
        let keys: Vec<f64> = (0..6).map(|_| r.normal()).collect();
        let q = Perm::sorting(&keys);
        let back = q.inverse().apply_rows(&q.apply_rows(&m));
        assert_eq!(back, m);

        let ck: Vec<f64> = (0..5).map(|_| r.normal()).collect();
        let p = Perm::sorting(&ck);
        let back = p.inverse().apply_cols(&p.apply_cols(&m));
        assert_eq!(back, m);
    }

    #[test]
    fn conjugate_sym_matches_definition_and_preserves_symmetry() {
        let mut r = Rng::new(9);
        let x = Mat::from_fn(5, 8, |_, _| r.normal_f32(0.0, 1.0));
        let h = crate::linalg::gemm::xxt_f64(&x);
        let keys: Vec<f64> = (0..5).map(|_| r.normal()).collect();
        let p = Perm::sorting(&keys);
        let hp = p.conjugate_sym(&h);
        for i in 0..5 {
            for j in 0..5 {
                assert_eq!(hp.at(i, j), h.at(p.sigma[i], p.sigma[j]));
                assert_eq!(hp.at(i, j), hp.at(j, i));
            }
        }
    }

    #[test]
    fn permuted_matmul_consistency() {
        // (QW)(permuted X) == Q(W X) when X rows are permuted to match
        // the column permutation of W.
        let mut r = Rng::new(10);
        let w = Mat::from_fn(4, 6, |_, _| r.normal_f32(0.0, 1.0));
        let x = Mat::from_fn(6, 3, |_, _| r.normal_f32(0.0, 1.0));
        let keys: Vec<f64> = (0..6).map(|_| r.normal()).collect();
        let p = Perm::sorting(&keys);
        let wp = p.apply_cols(&w);
        let xp = p.apply_rows(&x);
        let direct = crate::linalg::gemm::matmul(&w, &x);
        let via_perm = crate::linalg::gemm::matmul(&wp, &xp);
        assert!(direct.max_abs_diff(&via_perm) < 1e-5);
    }

    #[test]
    fn invalid_perm_detected() {
        assert!(!Perm { sigma: vec![0, 0, 1] }.is_valid());
        assert!(!Perm { sigma: vec![0, 3] }.is_valid());
        assert!(Perm::identity(4).is_valid());
    }
}
