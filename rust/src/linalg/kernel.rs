//! Packed, register-tiled micro-kernel GEMM core (DESIGN.md §Perf-L3).
//!
//! Every dense O(n³) kernel in the crate (GEMM, the `XXᵀ` SYRK, the
//! blocked-Cholesky trailing update, the blocked TRSM solves) runs
//! through the classic three-level blocked loop nest implemented here:
//!
//! * **Register tile** — an `MR × NR` accumulator block lives entirely
//!   in registers across the `k` loop; the inner loops are written over
//!   constant bounds so the compiler fully unrolls and auto-vectorizes
//!   them (with explicit `mul_add` when the target has FMA).
//! * **Panel packing** — the A operand is packed into `MR`-row panels
//!   (k-major within a panel) and the B operand into `NR`-column panels
//!   (k-major), so the micro-kernel streams both operands contiguously
//!   with no strides, no bounds logic and no branches.
//! * **Cache blocking** — `KC` splits the `k` dimension (a packed B
//!   micro-panel of `KC × NR` stays cache-resident while every A panel
//!   sweeps it), `MC` bounds the packed-A block.
//!
//! **Shared packed B.** B is packed once per operation ([`PackedB`])
//! and shared read-only by every row band, so the engine-parallel
//! drivers repack nothing per thread: each band packs only its own A
//! rows into a per-worker scratch. This matters most for the SYRK and
//! trailing-update paths whose B operand is a transposed (strided)
//! view.
//!
//! **Determinism.** A C element's value is a single accumulation chain:
//! `KC` chunks in ascending order, ascending `k` within a chunk. The
//! chain never depends on which band, `MC` block or register tile the
//! element landed in, so results are bit-identical for any thread
//! count — the same serial==parallel contract as every other kernel in
//! the crate (pinned by `tests/linalg_kernels.rs`).
//!
//! **Naive mode.** [`set_naive_mode`] /`THANOS_LINALG_NAIVE=1` force
//! every rewired caller back onto the seed loop nests — the in-process
//! old-path/new-path switch the `linalg_kernels` bench and the CI
//! `bench-smoke` divergence gate are built on.
//!
//! Tile sizes were tuned empirically (see DESIGN.md §Perf-L3 for the
//! numbers): f32 `8×32`, f64 `6×32`, `KC=256` — wide-`NR` shapes so a
//! 512-bit SIMD target holds a row of the accumulator in 2–4 vectors
//! and the broadcast-FMA inner step dominates.

use crate::engine;
use std::sync::atomic::{AtomicU8, Ordering};

/// Env var: `1` forces the seed (naive) kernel paths process-wide.
pub const NAIVE_ENV: &str = "THANOS_LINALG_NAIVE";

/// 0 = unread, 1 = packed, 2 = naive.
static NAIVE_MODE: AtomicU8 = AtomicU8::new(0);

/// True when the seed loop nests should be used instead of the packed
/// core (set by [`NAIVE_ENV`] or [`set_naive_mode`]).
pub fn naive_mode() -> bool {
    match NAIVE_MODE.load(Ordering::Relaxed) {
        0 => {
            let on = std::env::var(NAIVE_ENV).map(|v| v == "1").unwrap_or(false);
            NAIVE_MODE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
            on
        }
        2 => true,
        _ => false,
    }
}

/// Runtime switch between the packed and seed kernel paths (bench /
/// test hook; overrides [`NAIVE_ENV`]).
pub fn set_naive_mode(on: bool) {
    NAIVE_MODE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
}

/// Read-only strided 2-D view: element `(i, j)` is
/// `data[i * rs + j * cs]`. `rs = ld, cs = 1` is a row-major matrix;
/// `rs = 1, cs = ld` is its transpose — which is how the SYRK and
/// trailing-update paths feed `Xᵀ` / `L₂₁ᵀ` to the packers without
/// materializing a transposed copy.
#[derive(Clone, Copy)]
pub struct View<'a, T> {
    pub data: &'a [T],
    pub rs: usize,
    pub cs: usize,
}

impl<'a, T: Copy> View<'a, T> {
    /// Row-major matrix with leading dimension `ld`.
    pub fn row_major(data: &'a [T], ld: usize) -> View<'a, T> {
        View { data, rs: ld, cs: 1 }
    }

    /// Transpose of a row-major matrix with leading dimension `ld`.
    pub fn transposed(data: &'a [T], ld: usize) -> View<'a, T> {
        View { data, rs: 1, cs: ld }
    }

    /// View shifted so its `(0, 0)` is `(i0, j0)` of `self`.
    pub fn offset(&self, i0: usize, j0: usize) -> View<'a, T> {
        View {
            data: &self.data[i0 * self.rs + j0 * self.cs..],
            rs: self.rs,
            cs: self.cs,
        }
    }

    #[inline(always)]
    pub fn at(&self, i: usize, j: usize) -> T {
        self.data[i * self.rs + j * self.cs]
    }
}

/// B operand packed into `NR`-column panels, chunked by `KC`: layout is
/// `[kc-chunk][column-panel][k][column-in-panel]`, with ragged columns
/// zero-padded to `NR`. Packed once, shared read-only across bands.
pub struct PackedB<T> {
    /// logical inner (`k`) dimension
    pub k: usize,
    /// logical column count
    pub n: usize,
    pub buf: Vec<T>,
}

macro_rules! kernel_mod {
    ($name:ident, $t:ty, $mr:expr, $nr:expr, $kc:expr, $mc:expr) => {
        pub mod $name {
            use super::{PackedB, View};
            use crate::engine;
            use std::cell::RefCell;

            /// Register-tile rows.
            pub const MR: usize = $mr;
            /// Register-tile columns (one accumulator row = `NR` lanes).
            pub const NR: usize = $nr;
            /// k-dimension cache-block depth.
            pub const KC: usize = $kc;
            /// Packed-A block rows (multiple of `MR`).
            pub const MC: usize = $mc;

            /// Fused multiply-add when the target really has FMA;
            /// `mul_add` without it lowers to a libm call, so fall back
            /// to separate ops there.
            #[inline(always)]
            pub fn fmadd(a: $t, b: $t, c: $t) -> $t {
                if cfg!(target_feature = "fma") {
                    a.mul_add(b, c)
                } else {
                    a * b + c
                }
            }

            thread_local! {
                static PACK_A: RefCell<Vec<$t>> = const { RefCell::new(Vec::new()) };
                static PACK_B: RefCell<Vec<$t>> = const { RefCell::new(Vec::new()) };
            }

            /// Pack `b` (logical `k × n`) into the shared panel layout.
            pub fn pack_b(b: View<$t>, k: usize, n: usize) -> PackedB<$t> {
                let npan = n.div_ceil(NR).max(1);
                let mut buf = vec![0.0 as $t; k * npan * NR];
                let mut base = 0;
                let mut pc = 0;
                while pc < k {
                    let kc = KC.min(k - pc);
                    pack_b_chunk(&mut buf[base..base + kc * npan * NR], b, pc, kc, n);
                    base += kc * npan * NR;
                    pc += KC;
                }
                PackedB { k, n, buf }
            }

            /// Pack one `kc × n` chunk of `b` into `buf` (panel layout).
            fn pack_b_chunk(buf: &mut [$t], b: View<$t>, k0: usize, kc: usize, n: usize) {
                let npan = n.div_ceil(NR).max(1);
                for jp in 0..npan {
                    let j0 = jp * NR;
                    let nr = NR.min(n - j0);
                    let panel = &mut buf[jp * kc * NR..(jp + 1) * kc * NR];
                    for p in 0..kc {
                        let row = &mut panel[p * NR..(p + 1) * NR];
                        for (j, slot) in row.iter_mut().enumerate() {
                            *slot = if j < nr { b.at(k0 + p, j0 + j) } else { 0.0 };
                        }
                    }
                }
            }

            /// Pack rows `[i0, i0 + mc)` of `a`, k-range `[k0, k0 + kc)`,
            /// into `MR`-row panels (ragged rows zero-padded). `pub` so
            /// same-layout kernel variants (the mixed-precision `kmix`)
            /// can reuse the packing code instead of duplicating it.
            pub fn pack_a_block(
                buf: &mut Vec<$t>,
                a: View<$t>,
                i0: usize,
                mc: usize,
                k0: usize,
                kc: usize,
            ) {
                let mc_pad = mc.div_ceil(MR) * MR;
                buf.clear();
                buf.resize(mc_pad * kc, 0.0);
                let mut ir = 0;
                while ir < mc {
                    let mr = MR.min(mc - ir);
                    let panel = &mut buf[ir * kc..(ir + MR) * kc];
                    for p in 0..kc {
                        let col = &mut panel[p * MR..(p + 1) * MR];
                        for (r, slot) in col.iter_mut().enumerate() {
                            *slot = if r < mr { a.at(i0 + ir + r, k0 + p) } else { 0.0 };
                        }
                    }
                    ir += MR;
                }
            }

            /// The register tile: `acc[r][j] = Σ_p ap[p][r] · bp[p][j]`
            /// over `kc` packed steps, ascending `p`. `pub` for the
            /// mixed-precision variant (same tile, different C store).
            #[inline(always)]
            pub fn micro_acc(kc: usize, ap: &[$t], bp: &[$t]) -> [[$t; NR]; MR] {
                let mut acc = [[0.0 as $t; NR]; MR];
                for (av, bv) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)).take(kc) {
                    for r in 0..MR {
                        let ar = av[r];
                        for j in 0..NR {
                            acc[r][j] = fmadd(ar, bv[j], acc[r][j]);
                        }
                    }
                }
                acc
            }

            /// Accumulate one tile into C: rows `[row, row + mr)` of the
            /// band slice `c` (stride `ldc`), columns
            /// `[c_col0 + j0, c_col0 + j0 + nr)`.
            #[inline]
            #[allow(clippy::too_many_arguments)]
            fn write_tile(
                c: &mut [$t],
                ldc: usize,
                c_col0: usize,
                row: usize,
                j0: usize,
                acc: &[[$t; NR]; MR],
                mr: usize,
                nr: usize,
                sub: bool,
            ) {
                for (r, arow) in acc.iter().enumerate().take(mr) {
                    let off = (row + r) * ldc + c_col0 + j0;
                    let crow = &mut c[off..off + nr];
                    if sub {
                        for (dst, &v) in crow.iter_mut().zip(arow.iter()) {
                            *dst -= v;
                        }
                    } else {
                        for (dst, &v) in crow.iter_mut().zip(arow.iter()) {
                            *dst += v;
                        }
                    }
                }
            }

            /// Serial packed core against a pre-packed B:
            /// `C[i][j] (±)= Σ_k A[row0 + i][k] · B[k][j]` for
            /// `i < mrows`, `j < ncols`, written at
            /// `c[i * ldc + c_col0 + j]`. Per-element accumulation order
            /// is ascending `KC` chunk then ascending `k` — independent
            /// of banding, so callers may split rows freely.
            #[allow(clippy::too_many_arguments)]
            pub fn gemm_core(
                c: &mut [$t],
                ldc: usize,
                c_col0: usize,
                a: View<$t>,
                row0: usize,
                mrows: usize,
                bp: &PackedB<$t>,
                ncols: usize,
                sub: bool,
            ) {
                if mrows == 0 || ncols == 0 || bp.k == 0 {
                    return;
                }
                assert!(ncols <= bp.n, "packed B has too few columns");
                let npan = bp.n.div_ceil(NR).max(1);
                let use_pan = ncols.div_ceil(NR);
                PACK_A.with(|cell| {
                    let abuf = &mut *cell.borrow_mut();
                    let mut base = 0;
                    let mut pc = 0;
                    while pc < bp.k {
                        let kc = KC.min(bp.k - pc);
                        let mut ic = 0;
                        while ic < mrows {
                            let mc = MC.min(mrows - ic);
                            pack_a_block(abuf, a, row0 + ic, mc, pc, kc);
                            for jp in 0..use_pan {
                                let j0 = jp * NR;
                                let nr = NR.min(ncols - j0);
                                let pan0 = base + jp * kc * NR;
                                let bpanel = &bp.buf[pan0..pan0 + kc * NR];
                                let mut ir = 0;
                                while ir < mc {
                                    let mr = MR.min(mc - ir);
                                    let acc = micro_acc(kc, &abuf[ir * kc..], bpanel);
                                    write_tile(c, ldc, c_col0, ic + ir, j0, &acc, mr, nr, sub);
                                    ir += MR;
                                }
                            }
                            ic += MC;
                        }
                        base += kc * npan * NR;
                        pc += KC;
                    }
                });
            }

            /// Like [`gemm_core`] but with an unpacked B view: each `KC`
            /// chunk of B is packed on the fly into a per-worker scratch.
            /// For the small inner updates of the blocked triangular
            /// solves, where B is produced block-by-block and cannot be
            /// pre-packed once.
            ///
            /// `k_phase` anchors the `KC` chunk grid: boundaries sit at
            /// absolute positions `(k_phase + pc) % KC == 0`. Callers
            /// whose k-range *start* varies with band decomposition
            /// (the triangular inverse skips leading zero blocks) pass
            /// the absolute start so partial-sum grouping — and hence
            /// every accumulation chain — is identical for any band
            /// width / thread count.
            #[allow(clippy::too_many_arguments)]
            pub fn gemm_core_viewb(
                c: &mut [$t],
                ldc: usize,
                c_col0: usize,
                a: View<$t>,
                row0: usize,
                mrows: usize,
                k: usize,
                k_phase: usize,
                b: View<$t>,
                ncols: usize,
                sub: bool,
            ) {
                if mrows == 0 || ncols == 0 || k == 0 {
                    return;
                }
                let npan = ncols.div_ceil(NR).max(1);
                PACK_B.with(|bcell| {
                    let bbuf = &mut *bcell.borrow_mut();
                    PACK_A.with(|acell| {
                        let abuf = &mut *acell.borrow_mut();
                        let mut pc = 0;
                        while pc < k {
                            let next_abs = ((k_phase + pc) / KC + 1) * KC;
                            let kc = (next_abs - k_phase - pc).min(k - pc);
                            bbuf.clear();
                            bbuf.resize(kc * npan * NR, 0.0);
                            pack_b_chunk(bbuf, b, pc, kc, ncols);
                            let mut ic = 0;
                            while ic < mrows {
                                let mc = MC.min(mrows - ic);
                                pack_a_block(abuf, a, row0 + ic, mc, pc, kc);
                                for jp in 0..npan {
                                    let j0 = jp * NR;
                                    let nr = NR.min(ncols - j0);
                                    let bpanel = &bbuf[jp * kc * NR..(jp + 1) * kc * NR];
                                    let mut ir = 0;
                                    while ir < mc {
                                        let mr = MR.min(mc - ir);
                                        let acc = micro_acc(kc, &abuf[ir * kc..], bpanel);
                                        write_tile(c, ldc, c_col0, ic + ir, j0, &acc, mr, nr, sub);
                                        ir += MR;
                                    }
                                }
                                ic += MC;
                            }
                            pc += kc;
                        }
                    });
                });
            }

            /// Engine-parallel driver: `C (±)= A[row0..row0+m] · B` where
            /// `c` is the contiguous row-major `m × n` output slice.
            /// Rows are split into `MR`-aligned bands on the shared
            /// pool; each band runs [`gemm_core`] against the shared
            /// packed B (bit-identical for any thread count).
            pub fn gemm_banded(
                c: &mut [$t],
                n: usize,
                a: View<$t>,
                row0: usize,
                m: usize,
                bp: &PackedB<$t>,
                sub: bool,
            ) {
                if m == 0 || n == 0 {
                    return;
                }
                assert_eq!(c.len(), m * n, "output slice shape mismatch");
                let eng = engine::global();
                let rows_per = eng.chunk_aligned(m, MR);
                eng.for_each_band(c, rows_per * n, |bi, band| {
                    let r0 = bi * rows_per;
                    gemm_core(band, n, 0, a, row0 + r0, band.len() / n, bp, n, sub);
                });
            }
        }
    };
}

// Tile shapes chosen by measurement (DESIGN.md §Perf-L3): wide NR keeps
// an accumulator row in 2 native 512-bit vectors; MR bounds the live
// register set (f32: 8×2 = 16 accumulator vectors, f64: 6×4 = 24).
kernel_mod!(kf32, f32, 8, 32, 256, 128);
kernel_mod!(kf64, f64, 6, 32, 256, 132);

/// Mixed-precision packed kernel (DESIGN.md §Perf-L4): **f32 storage,
/// f64 accumulation**. The A operand (the Λ / error panels of the
/// pruning block updates) and the pre-packed B operand (`hinv_rows` /
/// `U` / `Z` panels) are f64; the C operand is the f32 weight matrix.
/// The micro-kernel is [`kf64`]'s `MR × NR` register tile verbatim —
/// same packing, same ascending-`KC`-chunk / ascending-`k` accumulation
/// chain — and each C element is rounded to f32 exactly once per `KC`
/// chunk at the tile write, so results are bit-identical for any band
/// decomposition / thread count (the same determinism contract as the
/// homogeneous kernels).
pub mod kmix {
    use super::{kf64, PackedB, View};
    use std::cell::RefCell;

    thread_local! {
        static PACK_A: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
    }

    /// Accumulate one f64 tile into the f32 C block: one f32 rounding
    /// per element per call.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    fn write_tile_f32(
        c: &mut [f32],
        ldc: usize,
        c_col0: usize,
        row: usize,
        j0: usize,
        acc: &[[f64; kf64::NR]; kf64::MR],
        mr: usize,
        nr: usize,
        sub: bool,
    ) {
        for (r, arow) in acc.iter().enumerate().take(mr) {
            let off = (row + r) * ldc + c_col0 + j0;
            let crow = &mut c[off..off + nr];
            if sub {
                for (dst, &v) in crow.iter_mut().zip(arow.iter()) {
                    *dst -= v as f32;
                }
            } else {
                for (dst, &v) in crow.iter_mut().zip(arow.iter()) {
                    *dst += v as f32;
                }
            }
        }
    }

    /// Serial mixed-precision core against a pre-packed f64 B:
    /// `C[i][j] (±)= Σ_k A[row0 + i][k] · B[k][j]` with f64 tile
    /// accumulation, written at `c[i * ldc + c_col0 + j]` (f32). Same
    /// loop structure and per-element chain order as
    /// [`kf64::gemm_core`]; callers may band rows freely.
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_core(
        c: &mut [f32],
        ldc: usize,
        c_col0: usize,
        a: View<f64>,
        row0: usize,
        mrows: usize,
        bp: &PackedB<f64>,
        ncols: usize,
        sub: bool,
    ) {
        if mrows == 0 || ncols == 0 || bp.k == 0 {
            return;
        }
        assert!(ncols <= bp.n, "packed B has too few columns");
        let npan = bp.n.div_ceil(kf64::NR).max(1);
        let use_pan = ncols.div_ceil(kf64::NR);
        PACK_A.with(|cell| {
            let abuf = &mut *cell.borrow_mut();
            let mut base = 0;
            let mut pc = 0;
            while pc < bp.k {
                let kc = kf64::KC.min(bp.k - pc);
                let mut ic = 0;
                while ic < mrows {
                    let mc = kf64::MC.min(mrows - ic);
                    kf64::pack_a_block(abuf, a, row0 + ic, mc, pc, kc);
                    for jp in 0..use_pan {
                        let j0 = jp * kf64::NR;
                        let nr = kf64::NR.min(ncols - j0);
                        let pan0 = base + jp * kc * kf64::NR;
                        let bpanel = &bp.buf[pan0..pan0 + kc * kf64::NR];
                        let mut ir = 0;
                        while ir < mc {
                            let mr = kf64::MR.min(mc - ir);
                            let acc = kf64::micro_acc(kc, &abuf[ir * kc..], bpanel);
                            write_tile_f32(c, ldc, c_col0, ic + ir, j0, &acc, mr, nr, sub);
                            ir += kf64::MR;
                        }
                    }
                    ic += kf64::MC;
                }
                base += kc * npan * kf64::NR;
                pc += kf64::KC;
            }
        });
    }
}

// ---------------------------------------------------------------------------
// Register-tiled row kernels (f32) — shared by the sparse execution
// paths and the reconstruction-loss probe. Each accumulates a j-block
// of the output row in registers while walking the (sparse) column
// list, instead of read-modify-writing the output row once per nonzero.
// Per-element chains stay in ascending-`t` order over the nonzero
// entries — the scalar loop's order; only the per-step rounding changes
// where the target fuses the multiply-add.
// ---------------------------------------------------------------------------

/// Output-row j-block width for the row kernels (f32 lanes).
pub const ROW_BLOCK: usize = 32;

/// `orow += Σ_t vals[t] · x[cols[t] * ldx ..][j]`, skipping `vals[t] ==
/// 0.0` (stored negative zeros / padded slots) like the scalar path.
pub fn sparse_row_axpy(orow: &mut [f32], cols: &[u32], vals: &[f32], x: &[f32], ldx: usize) {
    debug_assert_eq!(cols.len(), vals.len());
    let k = orow.len();
    let mut j0 = 0;
    while j0 + ROW_BLOCK <= k {
        let mut acc = [0.0f32; ROW_BLOCK];
        for (t, &v) in vals.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            let xrow = &x[cols[t] as usize * ldx + j0..cols[t] as usize * ldx + j0 + ROW_BLOCK];
            for j in 0..ROW_BLOCK {
                acc[j] = kf32::fmadd(v, xrow[j], acc[j]);
            }
        }
        let out = &mut orow[j0..j0 + ROW_BLOCK];
        for (dst, &v) in out.iter_mut().zip(acc.iter()) {
            *dst += v;
        }
        j0 += ROW_BLOCK;
    }
    if j0 < k {
        let w = k - j0;
        let mut acc = [0.0f32; ROW_BLOCK];
        for (t, &v) in vals.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            let xrow = &x[cols[t] as usize * ldx + j0..cols[t] as usize * ldx + j0 + w];
            for (j, &xv) in xrow.iter().enumerate() {
                acc[j] = kf32::fmadd(v, xv, acc[j]);
            }
        }
        for (dst, &v) in orow[j0..].iter_mut().zip(acc.iter()) {
            *dst += v;
        }
    }
}

/// Dense-row variant (outlier rows): `orow += Σ_t wrow[t] · X[t, :]`
/// with the same zero-skip as the scalar path.
pub fn dense_row_axpy(orow: &mut [f32], wrow: &[f32], x: &[f32], ldx: usize) {
    let k = orow.len();
    let mut j0 = 0;
    while j0 < k {
        let w = ROW_BLOCK.min(k - j0);
        let mut acc = [0.0f32; ROW_BLOCK];
        for (t, &v) in wrow.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            let xrow = &x[t * ldx + j0..t * ldx + j0 + w];
            for (j, &xv) in xrow.iter().enumerate() {
                acc[j] = kf32::fmadd(v, xv, acc[j]);
            }
        }
        for (dst, &v) in orow[j0..j0 + w].iter_mut().zip(acc.iter()) {
            *dst += v;
        }
        j0 += w;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive_gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a[i * k + p] as f64 * b[p * n + j] as f64;
                }
                c[i * n + j] = acc as f32;
            }
        }
        c
    }

    #[test]
    fn packed_gemm_matches_naive_odd_shapes() {
        let mut r = Rng::new(41);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (1, 7, 13),
            (13, 7, 1),
            (17, 31, 29),
            (40, 64, 33),
            (9, 0, 5),
        ] {
            let a: Vec<f32> = (0..m * k).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..k * n).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let mut c = vec![0.0f32; m * n];
            let bp = kf32::pack_b(View::row_major(&b, n), k, n);
            kf32::gemm_banded(&mut c, n, View::row_major(&a, k), 0, m, &bp, false);
            let want = naive_gemm(m, k, n, &a, &b);
            for (got, want) in c.iter().zip(&want) {
                assert!(
                    (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                    "{m}x{k}x{n}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn packed_gemm_sub_inverts_add() {
        let mut r = Rng::new(42);
        let (m, k, n) = (11, 19, 23);
        let a: Vec<f32> = (0..m * k).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let mut c = vec![0.0f32; m * n];
        let bp = kf32::pack_b(View::row_major(&b, n), k, n);
        kf32::gemm_banded(&mut c, n, View::row_major(&a, k), 0, m, &bp, false);
        kf32::gemm_banded(&mut c, n, View::row_major(&a, k), 0, m, &bp, true);
        assert!(c.iter().all(|&v| v == 0.0), "add then sub must cancel exactly");
    }

    #[test]
    fn transposed_view_packs_transpose() {
        // B via a transposed view must equal B via its materialized
        // transpose, bit for bit.
        let mut r = Rng::new(43);
        let (k, n) = (37, 21);
        let bt: Vec<f64> = (0..k * n).map(|_| r.normal()).collect(); // n x k row-major
        let mut b = vec![0.0f64; k * n]; // k x n row-major
        for j in 0..n {
            for p in 0..k {
                b[p * n + j] = bt[j * k + p];
            }
        }
        let p1 = kf64::pack_b(View::row_major(&b, n), k, n);
        let p2 = kf64::pack_b(View::transposed(&bt, k), k, n);
        assert_eq!(p1.buf, p2.buf);
    }

    #[test]
    fn sparse_row_axpy_matches_scalar() {
        let mut r = Rng::new(44);
        let (b, k) = (23, 37); // weight cols, batch width
        let x: Vec<f32> = (0..b * k).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let cols: Vec<u32> = vec![0, 3, 4, 9, 17, 22];
        let mut vals: Vec<f32> = cols.iter().map(|_| r.normal_f32(0.0, 1.0)).collect();
        vals[2] = 0.0; // padded slot must be skipped
        let mut got = vec![0.0f32; k];
        sparse_row_axpy(&mut got, &cols, &vals, &x, k);
        let mut want = vec![0.0f32; k];
        for (t, &v) in vals.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            for j in 0..k {
                want[j] += v * x[cols[t] as usize * k + j];
            }
        }
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-5 * w.abs().max(1.0));
        }
    }

    #[test]
    fn dense_row_axpy_matches_scalar() {
        let mut r = Rng::new(45);
        let (b, k) = (19, 33);
        let x: Vec<f32> = (0..b * k).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let mut wrow: Vec<f32> = (0..b).map(|_| r.normal_f32(0.0, 1.0)).collect();
        wrow[7] = 0.0;
        let mut got = vec![0.0f32; k];
        dense_row_axpy(&mut got, &wrow, &x, k);
        let mut want = vec![0.0f32; k];
        for (t, &v) in wrow.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            for j in 0..k {
                want[j] += v * x[t * k + j];
            }
        }
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-5 * w.abs().max(1.0));
        }
    }

    #[test]
    fn mixed_kernel_matches_f64_reference() {
        // kmix: f32 C, f64 A/B, f64 accumulation — must match the
        // direct f64 product rounded to f32 within one extra rounding,
        // for both add and sub, at ragged shapes.
        let mut r = Rng::new(46);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (7, 13, 19), (23, 31, 65), (12, 0, 9)] {
            let a: Vec<f64> = (0..m * k).map(|_| r.normal()).collect();
            let b: Vec<f64> = (0..k * n).map(|_| r.normal()).collect();
            let mut c: Vec<f32> = (0..m * n).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let c0 = c.clone();
            let bp = kf64::pack_b(View::row_major(&b, n), k, n);
            kmix::gemm_core(&mut c, n, 0, View::row_major(&a, k), 0, m, &bp, n, true);
            for i in 0..m {
                for j in 0..n {
                    let dot: f64 = (0..k).map(|p| a[i * k + p] * b[p * n + j]).sum();
                    let want = c0[i * n + j] - dot as f32;
                    let got = c[i * n + j];
                    assert!(
                        (got - want).abs() <= 1e-5 * want.abs().max(1.0),
                        "{m}x{k}x{n} ({i},{j}): {got} vs {want}"
                    );
                }
            }
            // sub then add must restore the original bits
            kmix::gemm_core(&mut c, n, 0, View::row_major(&a, k), 0, m, &bp, n, false);
            // (one f32 round-trip each way: tolerance, not bit equality)
            for (got, want) in c.iter().zip(&c0) {
                assert!((got - want).abs() <= 2e-5 * want.abs().max(1.0));
            }
        }
    }

    #[test]
    fn mixed_kernel_offset_columns() {
        // c_col0 / ldc addressing: update only the right part of a
        // wider row-major C.
        let mut r = Rng::new(47);
        let (m, k, ld, col0) = (9usize, 11usize, 40usize, 8usize);
        let n = ld - col0;
        let a: Vec<f64> = (0..m * k).map(|_| r.normal()).collect();
        let b: Vec<f64> = (0..k * n).map(|_| r.normal()).collect();
        let mut c: Vec<f32> = (0..m * ld).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let c0 = c.clone();
        let bp = kf64::pack_b(View::row_major(&b, n), k, n);
        kmix::gemm_core(&mut c, ld, col0, View::row_major(&a, k), 0, m, &bp, n, true);
        for i in 0..m {
            for j in 0..ld {
                if j < col0 {
                    assert_eq!(c[i * ld + j], c0[i * ld + j], "left of col0 untouched");
                } else {
                    let dot: f64 = (0..k).map(|p| a[i * k + p] * b[p * n + j - col0]).sum();
                    let want = c0[i * ld + j] - dot as f32;
                    assert!((c[i * ld + j] - want).abs() <= 1e-5 * want.abs().max(1.0));
                }
            }
        }
    }

    // NOTE: no unit test toggles `set_naive_mode` here — the switch is
    // process-global and `cargo test` runs tests concurrently; the
    // bench binaries (separate processes) exercise both settings.
}
