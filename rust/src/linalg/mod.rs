//! From-scratch dense linear algebra.
//!
//! The pruning algorithms (SparseGPT's OBS updates, Thanos' block
//! systems, the structured update rule eq. (13)) need GEMM, Cholesky
//! factorization, triangular / general solves, matrix inversion and
//! permutation handling. No linear-algebra crates exist in the offline
//! vendor set, so everything here is implemented directly:
//!
//! * [`Mat`] — row-major `f32` matrix (weights, activations).
//! * [`MatF64`] — row-major `f64` matrix (Hessians and all solve paths;
//!   pruning quality is sensitive to the conditioning of `H = 2XXᵀ`,
//!   so the numeric core runs in double precision like the paper's
//!   PyTorch implementation effectively does for small models).
//! * [`kernel`] — the packed, register-tiled micro-kernel GEMM core
//!   every O(n³) path below is built on (DESIGN.md §Perf-L3).
//! * [`gemm`] — matrix multiply + `XXᵀ` SYRK over the packed core,
//!   with a density-probed zero-skip fast path for sparse operands.
//! * [`chol`] — blocked Cholesky, blocked triangular solves, PSD
//!   inverse, LU solve.
//! * [`perm`] — permutation vectors/matrices (structured pruning).
//! * [`batched`] — the paper's §H.1 padded batched-systems path.

pub mod batched;
pub mod chol;
pub mod gemm;
pub mod kernel;
pub mod perm;

/// Row-major `f32` matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

/// Row-major `f64` matrix used for Hessian-side math.
#[derive(Clone, Debug, PartialEq)]
pub struct MatF64 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// Build from a generator `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                *t.at_mut(j, i) = self.at(i, j);
            }
        }
        t
    }

    /// Columns `[c0, c1)` as a new matrix.
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Mat {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut out = Mat::zeros(self.rows, c1 - c0);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Rows `[r0, r1)` as a new matrix.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat::from_vec(
            r1 - r0,
            self.cols,
            self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        )
    }

    pub fn frob_norm_sq(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Fraction of exactly-zero entries.
    pub fn sparsity(&self) -> f64 {
        let zeros = self.data.iter().filter(|&&v| v == 0.0).count();
        zeros as f64 / self.data.len() as f64
    }

    pub fn to_f64(&self) -> MatF64 {
        MatF64 {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| v as f64).collect(),
        }
    }

    /// Max absolute elementwise difference (test helper).
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl MatF64 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatF64 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            *m.at_mut(i, i) = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        MatF64 { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        MatF64 { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> MatF64 {
        let mut t = MatF64::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                *t.at_mut(j, i) = self.at(i, j);
            }
        }
        t
    }

    /// Principal submatrix with the given (row == col) indices. For a
    /// symmetric PD matrix the result is symmetric PD — this is how the
    /// per-row Thanos system `R̂ = Hinv[q][:, q]` is extracted.
    pub fn principal_submatrix(&self, idx: &[usize]) -> MatF64 {
        assert_eq!(self.rows, self.cols);
        let s = idx.len();
        let mut out = MatF64::zeros(s, s);
        for (oi, &i) in idx.iter().enumerate() {
            for (oj, &j) in idx.iter().enumerate() {
                *out.at_mut(oi, oj) = self.at(i, j);
            }
        }
        out
    }

    /// Select rows by index (the `R` matrix of eq. (7)).
    pub fn select_rows(&self, idx: &[usize]) -> MatF64 {
        let mut out = MatF64::zeros(idx.len(), self.cols);
        for (oi, &i) in idx.iter().enumerate() {
            out.row_mut(oi).copy_from_slice(self.row(i));
        }
        out
    }

    /// Submatrix `[r0, r1) × [c0, c1)`.
    pub fn block(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> MatF64 {
        assert!(r0 <= r1 && r1 <= self.rows && c0 <= c1 && c1 <= self.cols);
        let mut out = MatF64::zeros(r1 - r0, c1 - c0);
        for i in r0..r1 {
            out.row_mut(i - r0).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    pub fn max_abs_diff(&self, other: &MatF64) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    pub fn to_f32(&self) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| v as f32).collect(),
        }
    }
}

/// Squared ℓ² norms of the rows of `x` (the `‖X_{j:}‖₂²` terms of the
/// Wanda / OBD metric), accumulated in f64.
pub fn row_norms_sq(x: &Mat) -> Vec<f64> {
    (0..x.rows)
        .map(|i| x.row(i).iter().map(|&v| (v as f64) * (v as f64)).sum())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_fn(3, 5, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().at(4, 2), m.at(2, 4));
    }

    #[test]
    fn slice_cols_matches_manual() {
        let m = Mat::from_fn(4, 6, |i, j| (i + j) as f32);
        let s = m.slice_cols(2, 5);
        assert_eq!(s.rows, 4);
        assert_eq!(s.cols, 3);
        for i in 0..4 {
            for j in 0..3 {
                assert_eq!(s.at(i, j), m.at(i, j + 2));
            }
        }
    }

    #[test]
    fn principal_submatrix_symmetric() {
        let h = MatF64::from_fn(5, 5, |i, j| 1.0 / (1.0 + (i + j) as f64));
        let sub = h.principal_submatrix(&[0, 2, 4]);
        assert_eq!(sub.rows, 3);
        for i in 0..3 {
            for j in 0..3 {
                assert!((sub.at(i, j) - sub.at(j, i)).abs() < 1e-15);
            }
        }
        assert_eq!(sub.at(1, 2), h.at(2, 4));
    }

    #[test]
    fn sparsity_counts_zeros() {
        let mut m = Mat::zeros(2, 4);
        m.data[1] = 3.0;
        m.data[6] = -1.0;
        assert_eq!(m.sparsity(), 6.0 / 8.0);
    }

    #[test]
    fn row_norms_sq_basic() {
        let x = Mat::from_vec(2, 3, vec![1.0, 2.0, 2.0, 0.0, 3.0, 4.0]);
        let n = row_norms_sq(&x);
        assert_eq!(n, vec![9.0, 25.0]);
    }
}
