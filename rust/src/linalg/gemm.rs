//! Blocked, multi-threaded GEMM and Gram-matrix (`XXᵀ`) kernels.
//!
//! These are the L3-side compute hot spots: the Fig. 9 pruning-time
//! bench and every pure-Rust pruning path run through here. The design
//! mirrors the classic cache-blocked loop nest: pack nothing, walk the
//! `k` dimension innermost over a transposed-B access pattern, and
//! split the output row range into bands executed on the shared
//! [`crate::engine::PruneEngine`] pool (row-band tasks are independent,
//! so results are bit-identical for any thread count).

use crate::engine;

use super::{Mat, MatF64};

/// Number of worker threads available to row-parallel kernels (the
/// shared engine's pool size; honours `THANOS_THREADS`).
pub fn num_threads() -> usize {
    engine::global().threads()
}

/// `C = A · B` for f32 matrices (f32 accumulate, k-blocked).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul inner-dim mismatch");
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// `C = A · B` writing into a preallocated output (hot-loop reuse).
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    c.data.iter_mut().for_each(|v| *v = 0.0);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let eng = engine::global();
    if m * n * k < 64 * 64 * 64 || eng.threads() == 1 {
        matmul_rows(a, b, &mut c.data, 0, m, k, n);
        return;
    }
    let rows_per = eng.chunk(m);
    eng.for_each_band(&mut c.data, rows_per * n, |bi, out| {
        let r0 = bi * rows_per;
        matmul_rows(a, b, out, r0, r0 + out.len() / n, k, n);
    });
}

/// Row-band worker: computes rows `[r0, r1)` of `A·B` into `out`
/// (`out` covers exactly those rows). 4-wide k-unrolled inner loop over
/// contiguous B rows, which the compiler auto-vectorizes.
fn matmul_rows(a: &Mat, b: &Mat, out: &mut [f32], r0: usize, r1: usize, k: usize, n: usize) {
    const KB: usize = 256; // k-blocking keeps the active B panel in L2
    for kb in (0..k).step_by(KB) {
        let kend = (kb + KB).min(k);
        for i in r0..r1 {
            let arow = a.row(i);
            let orow = &mut out[(i - r0) * n..(i - r0 + 1) * n];
            for kk in kb..kend {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue; // sparse-aware: pruned weights skip work
                }
                let brow = b.row(kk);
                for j in 0..n {
                    orow[j] += aik * brow[j];
                }
            }
        }
    }
}

/// `C = A · B` in f64, row-parallel above a small-problem threshold.
pub fn matmul_f64(a: &MatF64, b: &MatF64) -> MatF64 {
    assert_eq!(a.cols, b.rows, "matmul inner-dim mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = MatF64::zeros(m, n);
    let body = |i0: usize, out: &mut [f64]| {
        for (ri, crow) in out.chunks_mut(n).enumerate() {
            let arow = a.row(i0 + ri);
            for (kk, &aik) in arow.iter().enumerate().take(k) {
                if aik == 0.0 {
                    continue;
                }
                let brow = b.row(kk);
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
    };
    let eng = engine::global();
    if m * n * k < 64 * 64 * 64 || eng.threads() == 1 {
        body(0, &mut c.data);
        return c;
    }
    let rows_per = eng.chunk(m);
    eng.for_each_band(&mut c.data, rows_per * n, |bi, out| body(bi * rows_per, out));
    c
}

/// Gram matrix `X · Xᵀ` with f64 accumulation (`X` is `b × a`); the
/// Hessian of the layer-reconstruction objective is `H = 2·XXᵀ`
/// (possibly averaged over calibration samples). Exploits symmetry:
/// only the upper triangle is computed, then mirrored.
pub fn xxt_f64(x: &Mat) -> MatF64 {
    let b = x.rows;
    let mut h = MatF64::zeros(b, b);
    if b == 0 {
        return h;
    }
    let eng = engine::global();
    let band_body = |r0: usize, head: &mut [f64]| {
        let rows_here = head.len() / b;
        for i in r0..r0 + rows_here {
            let xi = x.row(i);
            let hrow = &mut head[(i - r0) * b..(i - r0 + 1) * b];
            for j in i..b {
                let xj = x.row(j);
                let mut acc = 0.0f64;
                for (p, &v) in xi.iter().enumerate() {
                    acc += (v as f64) * (xj[p] as f64);
                }
                hrow[j] = acc;
            }
        }
    };
    // ~b²·a/2 useful flops: run tiny Gram matrices inline.
    if b * b * x.cols < 32 * 32 * 32 || eng.threads() == 1 {
        band_body(0, &mut h.data);
    } else {
        let rows_per = eng.chunk(b);
        // Parallel over row bands; band bi fills h[i][i..] for its rows.
        eng.for_each_band(&mut h.data, rows_per * b, |bi, head| {
            band_body(bi * rows_per, head);
        });
    }
    // mirror upper → lower
    for i in 0..b {
        for j in 0..i {
            let v = h.at(j, i);
            *h.at_mut(i, j) = v;
        }
    }
    h
}

/// `y = w · X` for a single row `w` (`1×b`) against `X` (`b×a`),
/// f64 accumulation. Used by loss probes in tests.
pub fn row_times_mat(w: &[f32], x: &Mat) -> Vec<f64> {
    assert_eq!(w.len(), x.rows);
    let mut y = vec![0.0f64; x.cols];
    for (k, &wk) in w.iter().enumerate() {
        if wk == 0.0 {
            continue;
        }
        let xrow = x.row(k);
        let wk = wk as f64;
        for (j, &v) in xrow.iter().enumerate() {
            y[j] += wk * v as f64;
        }
    }
    y
}

/// Reconstruction loss `‖(Ŵ − W)·X‖_F²` — the paper's objective (1).
/// This is the ground-truth quality probe every pruning test uses.
pub fn recon_loss(w_hat: &Mat, w: &Mat, x: &Mat) -> f64 {
    assert_eq!((w_hat.rows, w_hat.cols), (w.rows, w.cols));
    assert_eq!(w.cols, x.rows);
    let mut total = 0.0f64;
    for i in 0..w.rows {
        let mut delta: Vec<f32> = w_hat.row(i).to_vec();
        for (j, d) in delta.iter_mut().enumerate() {
            *d -= w.row(i)[j];
        }
        let y = row_times_mat(&delta, x);
        total += y.iter().map(|v| v * v).sum::<f64>();
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f32;
                for k in 0..a.cols {
                    acc += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = acc;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_small() {
        let mut r = Rng::new(1);
        let a = Mat::from_fn(7, 5, |_, _| r.normal_f32(0.0, 1.0));
        let b = Mat::from_fn(5, 9, |_, _| r.normal_f32(0.0, 1.0));
        let c = matmul(&a, &b);
        let cn = naive_matmul(&a, &b);
        assert!(c.max_abs_diff(&cn) < 1e-4);
    }

    #[test]
    fn matmul_matches_naive_threaded_size() {
        let mut r = Rng::new(2);
        let a = Mat::from_fn(130, 70, |_, _| r.normal_f32(0.0, 1.0));
        let b = Mat::from_fn(70, 90, |_, _| r.normal_f32(0.0, 1.0));
        let c = matmul(&a, &b);
        let cn = naive_matmul(&a, &b);
        assert!(c.max_abs_diff(&cn) < 1e-3);
    }

    #[test]
    fn matmul_identity() {
        let mut r = Rng::new(3);
        let a = Mat::from_fn(12, 12, |_, _| r.normal_f32(0.0, 1.0));
        let eye = Mat::from_fn(12, 12, |i, j| if i == j { 1.0 } else { 0.0 });
        assert!(matmul(&a, &eye).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&eye, &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn xxt_is_symmetric_and_correct() {
        let mut r = Rng::new(4);
        let x = Mat::from_fn(33, 21, |_, _| r.normal_f32(0.0, 1.0));
        let h = xxt_f64(&x);
        for i in 0..33 {
            for j in 0..33 {
                assert_eq!(h.at(i, j), h.at(j, i));
                let direct: f64 = (0..21)
                    .map(|p| x.at(i, p) as f64 * x.at(j, p) as f64)
                    .sum();
                assert!((h.at(i, j) - direct).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn recon_loss_zero_when_unchanged() {
        let mut r = Rng::new(5);
        let w = Mat::from_fn(6, 8, |_, _| r.normal_f32(0.0, 1.0));
        let x = Mat::from_fn(8, 10, |_, _| r.normal_f32(0.0, 1.0));
        assert_eq!(recon_loss(&w, &w, &x), 0.0);
    }

    #[test]
    fn recon_loss_matches_manual_single_entry() {
        // zeroing one weight w_kq with no compensation costs
        // w_kq^2 * ||X_q:||^2 — exactly the OBD metric (eq. 5).
        let mut r = Rng::new(6);
        let w = Mat::from_fn(4, 5, |_, _| r.normal_f32(0.0, 1.0));
        let x = Mat::from_fn(5, 7, |_, _| r.normal_f32(0.0, 1.0));
        let mut w_hat = w.clone();
        *w_hat.at_mut(2, 3) = 0.0;
        let loss = recon_loss(&w_hat, &w, &x);
        let xnorm: f64 = x.row(3).iter().map(|&v| (v as f64) * (v as f64)).sum();
        let expected = (w.at(2, 3) as f64).powi(2) * xnorm;
        assert!((loss - expected).abs() / expected.max(1e-12) < 1e-5);
    }
}
