//! GEMM and Gram-matrix (`XXᵀ`) kernels over the packed micro-kernel
//! core ([`super::kernel`], DESIGN.md §Perf-L3).
//!
//! * [`matmul`] / [`matmul_f64`] run the packed register-tiled GEMM
//!   with a **density-probed** fast-path split: rows are classified by
//!   measured nonzero density, dense row runs take the branch-free
//!   packed kernel, and the seed's zero-skipping loop nest survives
//!   only for row runs sparse enough that skipping beats vectorizing
//!   (`ZERO_SKIP_MAX_DENSITY`).
//! * [`xxt_f64`] is a blocked SYRK over packed panels: each row band
//!   computes its full output rows against the shared packed `Xᵀ`, so
//!   the upper→lower mirror is folded into the band work — element
//!   `(i,j)` and `(j,i)` are the same fused accumulation chain, making
//!   the result symmetric bit-for-bit with no serial mirror pass.
//! * [`recon_loss`] (the quality probe every pruning test calls) is
//!   band-parallel over output rows with per-worker scratch reuse and
//!   a register-blocked row kernel.
//!
//! All parallelism is row-banded on the shared
//! [`crate::engine::PruneEngine`] pool; per-element accumulation chains
//! never depend on band boundaries, so results are bit-identical for
//! any thread count. `THANOS_LINALG_NAIVE=1` (or
//! [`kernel::set_naive_mode`]) restores the seed loop nests — the
//! old-path baseline the `linalg_kernels` bench measures against.

use crate::engine;

use super::kernel::{self, kf32, kf64, View};
use super::{Mat, MatF64};

/// Number of worker threads available to row-parallel kernels (the
/// shared engine's pool size; honours `THANOS_THREADS`).
pub fn num_threads() -> usize {
    engine::global().threads()
}

/// Below this output width the packed path cannot amortize packing
/// (matvec-like shapes are memory-bound anyway).
const PACKED_MIN_N: usize = 8;
/// Below this row count the shared B packing (`k·n` copies) is not
/// amortized by the `m·k·n` compute.
const PACKED_MIN_M: usize = 16;
/// Problems smaller than this run the seed loop nest outright.
const PACKED_MIN_FLOPS: usize = 64 * 64 * 64;
/// A row keeps the zero-skipping scalar path only below this measured
/// nonzero density: skipping saves `1 − density` of the multiplies but
/// runs ~6–8× slower per multiply than the packed tile, so the
/// crossover sits well under 20% (DESIGN.md §Perf-L3).
const ZERO_SKIP_MAX_DENSITY: f64 = 0.15;

/// `C = A · B` for f32 matrices (f32 accumulate).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul inner-dim mismatch");
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// `C = A · B` writing into a preallocated output (hot-loop reuse).
/// Packed register-tiled kernel for dense row runs; the zero-skip loop
/// nest for measured-sparse row runs and for shapes too small to pack.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    c.data.iter_mut().for_each(|v| *v = 0.0);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    if kernel::naive_mode()
        || n < PACKED_MIN_N
        || m < PACKED_MIN_M
        || m * n * k < PACKED_MIN_FLOPS
    {
        matmul_legacy(a, b, c);
        return;
    }
    let runs = density_runs(m, k, |i| a.row(i).iter().filter(|&&v| v != 0.0).count());
    if runs.iter().all(|r| !r.2) {
        matmul_legacy(a, b, c);
        return;
    }
    let bp = kf32::pack_b(View::row_major(&b.data, n), k, n);
    let av = View::row_major(&a.data, k);
    for &(r0, r1, dense) in &runs {
        let cband = &mut c.data[r0 * n..r1 * n];
        if dense {
            kf32::gemm_banded(cband, n, av, r0, r1 - r0, &bp, false);
        } else {
            legacy_rows_banded(a, b, cband, r0, r1, k, n);
        }
    }
}

/// Seed-path `C = A · B` (zero-skipping loop nest, fully serial): the
/// naive reference the packed kernel is property-tested and
/// bench-gated against.
pub fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul inner-dim mismatch");
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_rows(a, b, &mut c.data, 0, a.rows, a.cols, b.cols);
    c
}

/// Seed behavior of `matmul_into`: small problems inline, otherwise
/// row-banded zero-skip workers.
fn matmul_legacy(a: &Mat, b: &Mat, c: &mut Mat) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let eng = engine::global();
    if m * n * k < PACKED_MIN_FLOPS || eng.threads() == 1 {
        matmul_rows(a, b, &mut c.data, 0, m, k, n);
        return;
    }
    legacy_rows_banded(a, b, &mut c.data, 0, m, k, n);
}

/// Row range `[r0, r1)` of the zero-skip path, banded on the engine.
fn legacy_rows_banded(a: &Mat, b: &Mat, out: &mut [f32], r0: usize, r1: usize, k: usize, n: usize) {
    let eng = engine::global();
    let rows_per = eng.chunk(r1 - r0);
    eng.for_each_band(out, rows_per * n, |bi, band| {
        let s = r0 + bi * rows_per;
        matmul_rows(a, b, band, s, s + band.len() / n, k, n);
    });
}

/// Row-band worker: computes rows `[r0, r1)` of `A·B` into `out`
/// (`out` covers exactly those rows). The seed kernel: 4-wide
/// k-unrolled inner loop over contiguous B rows with a per-`k`
/// zero-check — the path that still wins for very sparse rows.
fn matmul_rows(a: &Mat, b: &Mat, out: &mut [f32], r0: usize, r1: usize, k: usize, n: usize) {
    const KB: usize = 256; // k-blocking keeps the active B panel in L2
    for kb in (0..k).step_by(KB) {
        let kend = (kb + KB).min(k);
        for i in r0..r1 {
            let arow = a.row(i);
            let orow = &mut out[(i - r0) * n..(i - r0 + 1) * n];
            for kk in kb..kend {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue; // sparse-aware: pruned weights skip work
                }
                let brow = b.row(kk);
                for j in 0..n {
                    orow[j] += aik * brow[j];
                }
            }
        }
    }
}

/// Classify rows into maximal runs of equal density class:
/// `(row_start, row_end, dense)`. The probe is O(m·k) — negligible
/// against the O(m·k·n) multiply it routes (`n ≥ PACKED_MIN_N`).
fn density_runs(
    m: usize,
    k: usize,
    nnz_of_row: impl Fn(usize) -> usize,
) -> Vec<(usize, usize, bool)> {
    let cutoff = ZERO_SKIP_MAX_DENSITY * k as f64;
    let mut runs: Vec<(usize, usize, bool)> = Vec::new();
    for i in 0..m {
        let dense = nnz_of_row(i) as f64 > cutoff;
        match runs.last_mut() {
            Some(r) if r.2 == dense => r.1 = i + 1,
            _ => runs.push((i, i + 1, dense)),
        }
    }
    runs
}

/// `C = A · B` in f64: packed kernel with the same density-probed
/// row-run split as the f32 path.
pub fn matmul_f64(a: &MatF64, b: &MatF64) -> MatF64 {
    assert_eq!(a.cols, b.rows, "matmul inner-dim mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = MatF64::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    if kernel::naive_mode()
        || n < PACKED_MIN_N
        || m < PACKED_MIN_M
        || m * n * k < PACKED_MIN_FLOPS
    {
        matmul_f64_legacy(a, b, &mut c);
        return c;
    }
    let runs = density_runs(m, k, |i| a.row(i).iter().filter(|&&v| v != 0.0).count());
    if runs.iter().all(|r| !r.2) {
        matmul_f64_legacy(a, b, &mut c);
        return c;
    }
    let bp = kf64::pack_b(View::row_major(&b.data, n), k, n);
    let av = View::row_major(&a.data, k);
    for &(r0, r1, dense) in &runs {
        let cband = &mut c.data[r0 * n..r1 * n];
        if dense {
            kf64::gemm_banded(cband, n, av, r0, r1 - r0, &bp, false);
        } else {
            let eng = engine::global();
            let rows_per = eng.chunk(r1 - r0);
            eng.for_each_band(cband, rows_per * n, |bi, band| {
                let s = r0 + bi * rows_per;
                matmul_rows_f64(a, b, band, s, s + band.len() / n, k, n);
            });
        }
    }
    c
}

/// Seed behavior of `matmul_f64`.
fn matmul_f64_legacy(a: &MatF64, b: &MatF64, c: &mut MatF64) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let eng = engine::global();
    if m * n * k < PACKED_MIN_FLOPS || eng.threads() == 1 {
        matmul_rows_f64(a, b, &mut c.data, 0, m, k, n);
        return;
    }
    let rows_per = eng.chunk(m);
    eng.for_each_band(&mut c.data, rows_per * n, |bi, band| {
        let s = bi * rows_per;
        matmul_rows_f64(a, b, band, s, s + band.len() / n, k, n);
    });
}

/// Seed f64 row worker (zero-skip, j-inner).
fn matmul_rows_f64(
    a: &MatF64,
    b: &MatF64,
    out: &mut [f64],
    r0: usize,
    r1: usize,
    k: usize,
    n: usize,
) {
    for (ri, crow) in out.chunks_mut(n).enumerate() {
        let arow = a.row(r0 + ri);
        for (kk, &aik) in arow.iter().enumerate().take(k) {
            if aik == 0.0 {
                continue;
            }
            let brow = b.row(kk);
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// Gram matrix `X · Xᵀ` with f64 accumulation (`X` is `b × a`); the
/// Hessian of the layer-reconstruction objective is `H = 2·XXᵀ`
/// (possibly averaged over calibration samples).
///
/// Packed blocked SYRK: `X` is widened to f64 once, `Xᵀ` is packed once
/// (shared), and each engine band computes its full output rows with
/// the register-tiled kernel. Symmetry comes for free — `(i,j)` and
/// `(j,i)` run the bitwise-identical accumulation chain — so no mirror
/// pass exists and bands stay perfectly load-balanced.
pub fn xxt_f64(x: &Mat) -> MatF64 {
    let b = x.rows;
    let mut h = MatF64::zeros(b, b);
    if b == 0 {
        return h;
    }
    // ~b²·a/2 useful flops: run tiny Gram matrices on the seed path.
    if kernel::naive_mode() || b * b * x.cols < 32 * 32 * 32 {
        xxt_f64_naive_into(x, &mut h);
        return h;
    }
    let a_len = x.cols;
    let xd: Vec<f64> = x.data.iter().map(|&v| v as f64).collect();
    let bp = kf64::pack_b(View::transposed(&xd, a_len), a_len, b);
    kf64::gemm_banded(&mut h.data, b, View::row_major(&xd, a_len), 0, b, &bp, false);
    h
}

/// Seed-path `X · Xᵀ` (scalar upper-triangle dots + mirror): the naive
/// reference for the packed SYRK.
pub fn xxt_f64_naive(x: &Mat) -> MatF64 {
    let mut h = MatF64::zeros(x.rows, x.rows);
    if x.rows > 0 {
        xxt_f64_naive_into(x, &mut h);
    }
    h
}

fn xxt_f64_naive_into(x: &Mat, h: &mut MatF64) {
    let b = x.rows;
    let eng = engine::global();
    let band_body = |r0: usize, head: &mut [f64]| {
        let rows_here = head.len() / b;
        for i in r0..r0 + rows_here {
            let xi = x.row(i);
            let hrow = &mut head[(i - r0) * b..(i - r0 + 1) * b];
            for j in i..b {
                let xj = x.row(j);
                let mut acc = 0.0f64;
                for (p, &v) in xi.iter().enumerate() {
                    acc += (v as f64) * (xj[p] as f64);
                }
                hrow[j] = acc;
            }
        }
    };
    if b * b * x.cols < 32 * 32 * 32 || eng.threads() == 1 {
        band_body(0, &mut h.data);
    } else {
        let rows_per = eng.chunk(b);
        eng.for_each_band(&mut h.data, rows_per * b, |bi, head| {
            band_body(bi * rows_per, head);
        });
    }
    // mirror upper → lower
    for i in 0..b {
        for j in 0..i {
            let v = h.at(j, i);
            *h.at_mut(i, j) = v;
        }
    }
}

/// `y = w · X` for a single row `w` (`1×b`) against `X` (`b×a`),
/// f64 accumulation. Used by loss probes in tests.
pub fn row_times_mat(w: &[f32], x: &Mat) -> Vec<f64> {
    assert_eq!(w.len(), x.rows);
    let mut y = vec![0.0f64; x.cols];
    for (k, &wk) in w.iter().enumerate() {
        if wk == 0.0 {
            continue;
        }
        let xrow = x.row(k);
        let wk = wk as f64;
        for (j, &v) in xrow.iter().enumerate() {
            y[j] += wk * v as f64;
        }
    }
    y
}

thread_local! {
    /// Per-worker `Ŵ − W` row buffer for [`recon_loss`], reused across
    /// rows, calls and layers.
    static RECON_SCRATCH: std::cell::RefCell<Vec<f32>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Reconstruction loss `‖(Ŵ − W)·X‖_F²` — the paper's objective (1).
/// This is the ground-truth quality probe every pruning test uses.
///
/// Band-parallel over weight rows on the engine pool with a per-worker
/// delta scratch (no allocation per row) and a register-blocked row
/// kernel; per-row losses land in a slot vector reduced in ascending
/// row order, so the result is bit-identical for any thread count.
pub fn recon_loss(w_hat: &Mat, w: &Mat, x: &Mat) -> f64 {
    assert_eq!((w_hat.rows, w_hat.cols), (w.rows, w.cols));
    assert_eq!(w.cols, x.rows);
    let rows = w.rows;
    if rows == 0 {
        return 0.0;
    }
    let mut row_loss = vec![0.0f64; rows];
    let eng = engine::global();
    let rows_per = eng.chunk(rows);
    eng.for_each_band(&mut row_loss, rows_per, |bi, slots| {
        RECON_SCRATCH.with(|cell| {
            let delta = &mut *cell.borrow_mut();
            for (si, slot) in slots.iter_mut().enumerate() {
                let i = bi * rows_per + si;
                delta.clear();
                delta.extend(w_hat.row(i).iter().zip(w.row(i)).map(|(&wh, &wv)| wh - wv));
                *slot = row_sq_loss(delta, x);
            }
        });
    });
    row_loss.iter().sum()
}

/// `‖δ·X‖²` for one row: j-blocked f64 register accumulation with the
/// same zero-skip as [`row_times_mat`], squared and summed in ascending
/// `j` order.
fn row_sq_loss(delta: &[f32], x: &Mat) -> f64 {
    let n = x.cols;
    let mut total = 0.0f64;
    let mut j0 = 0;
    while j0 < n {
        let w = kernel::ROW_BLOCK.min(n - j0);
        let mut acc = [0.0f64; kernel::ROW_BLOCK];
        for (t, &d) in delta.iter().enumerate() {
            if d == 0.0 {
                continue;
            }
            let dd = d as f64;
            let xrow = &x.row(t)[j0..j0 + w];
            for (j, &xv) in xrow.iter().enumerate() {
                acc[j] = kf64::fmadd(dd, xv as f64, acc[j]);
            }
        }
        for &v in acc.iter().take(w) {
            total += v * v;
        }
        j0 += w;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f32;
                for k in 0..a.cols {
                    acc += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = acc;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_small() {
        let mut r = Rng::new(1);
        let a = Mat::from_fn(7, 5, |_, _| r.normal_f32(0.0, 1.0));
        let b = Mat::from_fn(5, 9, |_, _| r.normal_f32(0.0, 1.0));
        let c = matmul(&a, &b);
        let cn = naive_matmul(&a, &b);
        assert!(c.max_abs_diff(&cn) < 1e-4);
    }

    #[test]
    fn matmul_matches_naive_threaded_size() {
        let mut r = Rng::new(2);
        let a = Mat::from_fn(130, 70, |_, _| r.normal_f32(0.0, 1.0));
        let b = Mat::from_fn(70, 90, |_, _| r.normal_f32(0.0, 1.0));
        let c = matmul(&a, &b);
        let cn = naive_matmul(&a, &b);
        assert!(c.max_abs_diff(&cn) < 1e-3);
    }

    #[test]
    fn matmul_identity() {
        let mut r = Rng::new(3);
        let a = Mat::from_fn(12, 12, |_, _| r.normal_f32(0.0, 1.0));
        let eye = Mat::from_fn(12, 12, |i, j| if i == j { 1.0 } else { 0.0 });
        assert!(matmul(&a, &eye).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&eye, &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn packed_matmul_matches_naive_mixed_density() {
        // sparse and dense row runs split between the two paths must
        // still produce one coherent product
        let mut r = Rng::new(71);
        let mut a = Mat::from_fn(64, 96, |_, _| r.normal_f32(0.0, 1.0));
        for i in 20..44 {
            for (j, v) in a.row_mut(i).iter_mut().enumerate() {
                if j % 10 != 0 {
                    *v = 0.0; // 10% density -> zero-skip class
                }
            }
        }
        let b = Mat::from_fn(96, 80, |_, _| r.normal_f32(0.0, 1.0));
        let c = matmul(&a, &b);
        let cn = matmul_naive(&a, &b);
        assert!(c.max_abs_diff(&cn) < 1e-3);
    }

    #[test]
    fn matmul_f64_matches_f32_path_shapewise() {
        let mut r = Rng::new(72);
        let a = MatF64::from_fn(33, 45, |_, _| r.normal());
        let b = MatF64::from_fn(45, 29, |_, _| r.normal());
        let c = matmul_f64(&a, &b);
        for i in [0usize, 7, 32] {
            for j in [0usize, 11, 28] {
                let direct: f64 = (0..45).map(|k| a.at(i, k) * b.at(k, j)).sum();
                assert!((c.at(i, j) - direct).abs() < 1e-10 * direct.abs().max(1.0));
            }
        }
    }

    #[test]
    fn xxt_is_symmetric_and_correct() {
        let mut r = Rng::new(4);
        let x = Mat::from_fn(33, 21, |_, _| r.normal_f32(0.0, 1.0));
        let h = xxt_f64(&x);
        for i in 0..33 {
            for j in 0..33 {
                assert_eq!(h.at(i, j), h.at(j, i));
                let direct: f64 = (0..21)
                    .map(|p| x.at(i, p) as f64 * x.at(j, p) as f64)
                    .sum();
                assert!((h.at(i, j) - direct).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn packed_xxt_matches_naive_and_stays_symmetric() {
        // large enough to take the packed SYRK path
        let mut r = Rng::new(73);
        let x = Mat::from_fn(48, 40, |_, _| r.normal_f32(0.0, 1.0));
        let h = xxt_f64(&x);
        let hn = xxt_f64_naive(&x);
        assert!(h.max_abs_diff(&hn) < 1e-9);
        for i in 0..48 {
            for j in 0..i {
                assert_eq!(h.at(i, j), h.at(j, i), "({i},{j})");
            }
        }
    }

    #[test]
    fn recon_loss_zero_when_unchanged() {
        let mut r = Rng::new(5);
        let w = Mat::from_fn(6, 8, |_, _| r.normal_f32(0.0, 1.0));
        let x = Mat::from_fn(8, 10, |_, _| r.normal_f32(0.0, 1.0));
        assert_eq!(recon_loss(&w, &w, &x), 0.0);
    }

    #[test]
    fn recon_loss_matches_manual_single_entry() {
        // zeroing one weight w_kq with no compensation costs
        // w_kq^2 * ||X_q:||^2 — exactly the OBD metric (eq. 5).
        let mut r = Rng::new(6);
        let w = Mat::from_fn(4, 5, |_, _| r.normal_f32(0.0, 1.0));
        let x = Mat::from_fn(5, 7, |_, _| r.normal_f32(0.0, 1.0));
        let mut w_hat = w.clone();
        *w_hat.at_mut(2, 3) = 0.0;
        let loss = recon_loss(&w_hat, &w, &x);
        let xnorm: f64 = x.row(3).iter().map(|&v| (v as f64) * (v as f64)).sum();
        let expected = (w.at(2, 3) as f64).powi(2) * xnorm;
        assert!((loss - expected).abs() / expected.max(1e-12) < 1e-5);
    }

    #[test]
    fn recon_loss_serial_parallel_bit_identical() {
        let mut r = Rng::new(74);
        let w = Mat::from_fn(40, 64, |_, _| r.normal_f32(0.0, 1.0));
        let mut w_hat = w.clone();
        for v in w_hat.data.iter_mut().step_by(3) {
            *v = 0.0;
        }
        let x = Mat::from_fn(64, 50, |_, _| r.normal_f32(0.0, 1.0));
        let par = recon_loss(&w_hat, &w, &x);
        let ser = crate::engine::with_serial(|| recon_loss(&w_hat, &w, &x));
        assert_eq!(par.to_bits(), ser.to_bits());
    }
}
