//! Padded batched row-systems — the paper's §H.1 implementation detail.
//!
//! In a Thanos block step every row `i` needs the solution of
//! `λ·R̂ = u` where `R̂ = Hinv[q][:, q]` for that row's removal indices
//! `q` (eq. 7–10). Rows remove *different numbers* of weights, so the
//! systems have different sizes. The paper pads every system to
//! `r_max = max_i s_i` with an identity block (eq. 77–79) so a single
//! uniform batched solver can run them all; padded components of the
//! solution are exactly zero by construction.
//!
//! Both paths are provided — `solve_rows_direct` (exact-size per-row
//! Cholesky) and `solve_rows_padded` (the §H.1 scheme) — and the test
//! suite pins them to produce identical results. The JAX/Pallas L2
//! graph uses the padded formulation (static shapes), so this module is
//! also the cross-check oracle for the AOT path.
//!
//! The per-row systems here are small (`s ≤ block_size`), so they run
//! the blocked [`cholesky_in_place`]'s unblocked small-system path —
//! which reproduces the seed factorization bit-for-bit (pinned by
//! `small_systems_keep_seed_arithmetic`), keeping every row solve's
//! numerics stable across the §Perf-L3 kernel rewrite.

use super::chol::{chol_solve, chol_solve_into, cholesky, cholesky_in_place};
use super::MatF64;
use anyhow::Result;

/// Reusable workspace for one Thanos row system, pooled **per engine
/// worker** through [`with_row_solve_scratch`]: the removal indices
/// `q`, the rhs `u = w[q]`, the `R̂` buffer (factorized in place) and
/// the solve temporaries all persist across rows, blocks and layers
/// instead of being reallocated for every row solve.
pub struct RowSolveScratch {
    /// removal indices of the current row (caller-filled)
    pub q: Vec<usize>,
    /// rhs `u = w[q]` (caller-filled)
    pub u: Vec<f64>,
    /// solution `λ` (output of [`solve_row_in_scratch`])
    pub lam: Vec<f64>,
    rhat: MatF64,
    y: Vec<f64>,
}

impl RowSolveScratch {
    pub fn new() -> RowSolveScratch {
        RowSolveScratch {
            q: Vec::new(),
            u: Vec::new(),
            lam: Vec::new(),
            rhat: MatF64::zeros(0, 0),
            y: Vec::new(),
        }
    }
}

impl Default for RowSolveScratch {
    fn default() -> RowSolveScratch {
        RowSolveScratch::new()
    }
}

thread_local! {
    static ROW_SOLVE_SCRATCH: std::cell::RefCell<RowSolveScratch> =
        std::cell::RefCell::new(RowSolveScratch::new());
}

/// Borrow this worker's pooled [`RowSolveScratch`]. Must not be nested
/// (the per-thread buffer is handed out exclusively).
pub fn with_row_solve_scratch<R>(f: impl FnOnce(&mut RowSolveScratch) -> R) -> R {
    ROW_SOLVE_SCRATCH.with(|cell| f(&mut cell.borrow_mut()))
}

/// The shared live-block solve body: gather `R̂ = hinv[q][:, q]` into
/// `rhat`, factor in place, and solve `λ·R̂ = u` into `x` (forward
/// temp `y`). Both [`solve_row_in_scratch`] and
/// [`solve_band_padded_into_panel`] delegate here, so their documented
/// bit-identity is identity of code, not of two maintained copies.
fn solve_gathered_in(
    hinv: &MatF64,
    q: &[usize],
    u: &[f64],
    rhat: &mut MatF64,
    y: &mut Vec<f64>,
    x: &mut Vec<f64>,
) -> Result<()> {
    let n = q.len();
    rhat.rows = n;
    rhat.cols = n;
    rhat.data.clear();
    rhat.data.resize(n * n, 0.0);
    for (a, &qa) in q.iter().enumerate() {
        for (b, &qb) in q.iter().enumerate() {
            rhat.data[a * n + b] = hinv.at(qa, qb);
        }
    }
    cholesky_in_place(rhat)?;
    chol_solve_into(rhat, u, y, x);
    Ok(())
}

/// Solve `λ·R̂ = u` for the row system described by `s.q` / `s.u`
/// (`R̂ = hinv[q][:, q]`), writing `λ` into `s.lam`. Identical
/// arithmetic to the allocating path ([`cholesky`] + [`chol_solve`]),
/// only the storage is reused — pinned bit-identical by tests.
pub fn solve_row_in_scratch(hinv: &MatF64, s: &mut RowSolveScratch) -> Result<()> {
    let RowSolveScratch { q, u, lam, rhat, y } = s;
    assert_eq!(q.len(), u.len());
    lam.clear();
    if q.is_empty() {
        return Ok(());
    }
    solve_gathered_in(hinv, q, u, rhat, y, lam)
}

/// Solve `λ_i · R̂_i = u_i` for every row, where
/// `R̂_i = hinv[q_i][:, q_i]` — exact-size Cholesky per row.
/// `R̂` is a principal submatrix of the symmetric-PD `hinv`, hence
/// symmetric-PD itself; `λ·R̂ = u  ⇔  R̂·λᵀ = uᵀ`.
///
/// Rows are independent systems: multi-row calls fan out across the
/// shared [`crate::engine`] pool, each worker reusing its pooled
/// scratch. Single-row calls (the per-row path inside already-parallel
/// block updates) stay inline on the calling worker.
pub fn solve_rows_direct(
    hinv: &MatF64,
    qs: &[Vec<usize>],
    us: &[Vec<f64>],
) -> Result<Vec<Vec<f64>>> {
    assert_eq!(qs.len(), us.len());
    let solve_one = |i: usize, s: &mut RowSolveScratch| -> Result<Vec<f64>> {
        assert_eq!(qs[i].len(), us[i].len());
        s.q.clear();
        s.q.extend_from_slice(&qs[i]);
        s.u.clear();
        s.u.extend_from_slice(&us[i]);
        solve_row_in_scratch(hinv, s)?;
        Ok(s.lam.clone())
    };
    let n_rows = qs.len();
    let eng = crate::engine::global();
    if n_rows > 1 && eng.threads() > 1 {
        let mut slots: Vec<Result<Vec<f64>>> = Vec::with_capacity(n_rows);
        slots.resize_with(n_rows, || Ok(Vec::new()));
        eng.for_each_band(&mut slots, 1, |i, slot| {
            slot[0] = with_row_solve_scratch(|s| solve_one(i, s));
        });
        slots.into_iter().collect()
    } else {
        let mut s = RowSolveScratch::new();
        let mut out = Vec::with_capacity(n_rows);
        for i in 0..n_rows {
            out.push(solve_one(i, &mut s)?);
        }
        Ok(out)
    }
}

/// §H.1 padded formulation: every system is embedded into an
/// `r_max × r_max` block-diagonal matrix `R̂′ = diag(R̂, I)` with
/// rhs `u′ = (u, 0)`; the trailing components of the solution are zero
/// and are stripped before returning. Produces bit-comparable results
/// to [`solve_rows_direct`] up to factorization round-off.
pub fn solve_rows_padded(
    hinv: &MatF64,
    qs: &[Vec<usize>],
    us: &[Vec<f64>],
) -> Result<Vec<Vec<f64>>> {
    assert_eq!(qs.len(), us.len());
    let r_max = qs.iter().map(|q| q.len()).max().unwrap_or(0);
    if r_max == 0 {
        return Ok(vec![Vec::new(); qs.len()]);
    }
    let mut out = Vec::with_capacity(qs.len());
    let mut rhat_p = MatF64::zeros(r_max, r_max);
    let mut u_p = vec![0.0f64; r_max];
    for (q, u) in qs.iter().zip(us) {
        let s = q.len();
        if s == 0 {
            out.push(Vec::new());
            continue;
        }
        // build R̂′ = diag(R̂, I) in the reused buffer
        for v in rhat_p.data.iter_mut() {
            *v = 0.0;
        }
        for (a, &qa) in q.iter().enumerate() {
            for (b, &qb) in q.iter().enumerate() {
                *rhat_p.at_mut(a, b) = hinv.at(qa, qb);
            }
        }
        for d in s..r_max {
            *rhat_p.at_mut(d, d) = 1.0;
        }
        u_p.iter_mut().for_each(|v| *v = 0.0);
        u_p[..s].copy_from_slice(u);
        let l = cholesky(&rhat_p)?;
        let mut lam = chol_solve(&l, &u_p);
        // padded components must vanish by construction
        for &v in &lam[s..] {
            debug_assert!(v.abs() < 1e-9, "padded solution component {v} != 0");
        }
        lam.truncate(s);
        out.push(lam);
    }
    Ok(out)
}

/// Per-worker workspace for the Λ-panel block update (§Perf-L4): one
/// engine band's row systems are gathered (`qs`/`q_off`/`us`), solved
/// through the §H.1 padded batch, and scattered into the band's Λ panel
/// (`lam`, rows×width row-major f64, zero off-support). All buffers
/// persist across bands, blocks and layers.
pub struct PanelSolveScratch {
    /// flattened removal indices of the band's rows (local to the block)
    pub qs: Vec<usize>,
    /// per-row offsets into `qs` / `us` (length rows + 1)
    pub q_off: Vec<usize>,
    /// flattened right-hand sides `u = w[q]`
    pub us: Vec<f64>,
    /// Λ panel output: rows×width, zero off-support
    pub lam: Vec<f64>,
    width: usize,
    rhat: MatF64,
    y: Vec<f64>,
    x: Vec<f64>,
    /// interleaved-batch state (§Perf-L5): `(size, row)` dispatch order
    /// plus the structure-of-arrays factor/rhs/solve buffers
    order: Vec<(u32, u32)>,
    ia: Vec<f64>,
    iu: Vec<f64>,
    iy: Vec<f64>,
    ix: Vec<f64>,
}

impl PanelSolveScratch {
    pub fn new() -> PanelSolveScratch {
        PanelSolveScratch {
            qs: Vec::new(),
            q_off: Vec::new(),
            us: Vec::new(),
            lam: Vec::new(),
            width: 0,
            rhat: MatF64::zeros(0, 0),
            y: Vec::new(),
            x: Vec::new(),
            order: Vec::new(),
            ia: Vec::new(),
            iu: Vec::new(),
            iy: Vec::new(),
            ix: Vec::new(),
        }
    }

    /// Reset for a band of `rows` rows at block width `width`.
    pub fn begin(&mut self, rows: usize, width: usize) {
        self.qs.clear();
        self.us.clear();
        self.q_off.clear();
        self.q_off.push(0);
        self.width = width;
        self.lam.clear();
        self.lam.resize(rows * width, 0.0);
    }

    /// Record one removal cell of the current row: local index `k`
    /// (< width) with weight value `u`.
    #[inline]
    pub fn push(&mut self, k: usize, u: f64) {
        self.qs.push(k);
        self.us.push(u);
    }

    /// Record a support cell whose multiplier the caller already solved
    /// (it writes `lam` directly): index only, no rhs. Bands recorded
    /// this way must not be passed to [`solve_band_padded_into_panel`].
    #[inline]
    pub fn push_support(&mut self, k: usize) {
        self.qs.push(k);
    }

    /// Close the current row's support list.
    #[inline]
    pub fn end_row(&mut self) {
        self.q_off.push(self.qs.len());
    }

    /// Support indices of row `ri` (valid after `end_row`).
    #[inline]
    pub fn row_support(&self, ri: usize) -> &[usize] {
        &self.qs[self.q_off[ri]..self.q_off[ri + 1]]
    }

    fn rows(&self) -> usize {
        self.q_off.len().saturating_sub(1)
    }
}

impl Default for PanelSolveScratch {
    fn default() -> PanelSolveScratch {
        PanelSolveScratch::new()
    }
}

thread_local! {
    static PANEL_SCRATCH: std::cell::RefCell<PanelSolveScratch> =
        std::cell::RefCell::new(PanelSolveScratch::new());
}

/// Borrow this worker's pooled [`PanelSolveScratch`]. Must not be
/// nested.
pub fn with_panel_scratch<R>(f: impl FnOnce(&mut PanelSolveScratch) -> R) -> R {
    PANEL_SCRATCH.with(|cell| f(&mut cell.borrow_mut()))
}

/// Interleaved-batch lane count: one AVX-512 f64 vector; the batched
/// sweep processes `LANES` systems element-parallel.
pub const LANES: usize = 8;
/// Interleaved headroom: the batched buffers are sized for systems up
/// to this order (systems above [`INTERLEAVE_MAX`] never enter).
const INTERLEAVE_CAP: usize = 64;
/// Measured interleave/per-row crossover (C mirror, AVX-512, DESIGN.md
/// §Perf-L5): the lanes-interleaved sweep wins while the per-row
/// sweep's contiguous `t`-loops are too short to fill vector width
/// (2.0× at s=4, 1.8× at s=8, ~1× at s=24); beyond that both
/// formulations are 8-wide and port-bound and per-row's L1-resident
/// gather wins, so larger systems keep the per-row sweep.
const INTERLEAVE_MAX: usize = 24;

/// Factor + solve one identity-padded interleaved batch: `a` holds
/// `LANES` gathered SPD systems in structure-of-arrays layout
/// (`a[(i·smax + j)·LANES + lane]`), `u`/`y`/`x` the interleaved
/// rhs/temporary/solution. Every lane runs the EXACT seed arithmetic —
/// the unblocked right-looking sweep of `chol_unblocked` (contiguous
/// `colj` copy, per-lane `ci == 0` skip preserved) and the
/// `chol_solve_into` substitution order — so each lane's solution is
/// bit-identical to [`solve_row_in_scratch`] on that system. Padding
/// lanes carry `diag(R̂, I)` per §H.1 eq. 77–79: the identity block
/// factors to itself and contributes exact zeros, which the
/// substitutions absorb without changing any live bit.
fn batch_factor_solve(
    a: &mut [f64],
    u: &[f64],
    y: &mut [f64],
    x: &mut [f64],
    smax: usize,
) -> Result<()> {
    assert!(smax <= INTERLEAVE_CAP);
    let mut colj = [[0.0f64; LANES]; INTERLEAVE_CAP];
    for j in 0..smax {
        let mut piv = [0.0f64; LANES];
        {
            let d = &mut a[(j * smax + j) * LANES..(j * smax + j) * LANES + LANES];
            for (l, p) in piv.iter_mut().enumerate() {
                let dv = d[l];
                if dv <= 0.0 || !dv.is_finite() {
                    anyhow::bail!(
                        "batched system not positive definite at pivot {j} (value {dv:.3e})"
                    );
                }
                *p = dv.sqrt();
                d[l] = *p;
            }
        }
        for i in j + 1..smax {
            let off = (i * smax + j) * LANES;
            let cc = &mut colj[i];
            for l in 0..LANES {
                let v = a[off + l] / piv[l];
                a[off + l] = v;
                cc[l] = v;
            }
        }
        for i in j + 1..smax {
            let ci = colj[i];
            if ci.iter().all(|&v| v != 0.0) {
                // all lanes live: the vector fast path (identical ops)
                for t in j + 1..=i {
                    let cj = colj[t];
                    let dst = &mut a[(i * smax + t) * LANES..(i * smax + t) * LANES + LANES];
                    for l in 0..LANES {
                        dst[l] -= ci[l] * cj[l];
                    }
                }
            } else {
                // some lane's ci is zero: preserve the seed's skip
                // exactly, lane by lane
                for (l, &cil) in ci.iter().enumerate() {
                    if cil == 0.0 {
                        continue;
                    }
                    for t in j + 1..=i {
                        a[(i * smax + t) * LANES + l] -= cil * colj[t][l];
                    }
                }
            }
        }
    }
    // forward substitution (chol_solve_into order)
    for i in 0..smax {
        let mut sum = [0.0f64; LANES];
        sum.copy_from_slice(&u[i * LANES..(i + 1) * LANES]);
        for k in 0..i {
            let lrow = &a[(i * smax + k) * LANES..(i * smax + k) * LANES + LANES];
            let yk = &y[k * LANES..(k + 1) * LANES];
            for l in 0..LANES {
                sum[l] -= lrow[l] * yk[l];
            }
        }
        let d = &a[(i * smax + i) * LANES..(i * smax + i) * LANES + LANES];
        let yi = &mut y[i * LANES..(i + 1) * LANES];
        for l in 0..LANES {
            yi[l] = sum[l] / d[l];
        }
    }
    // back substitution
    for i in (0..smax).rev() {
        let mut sum = [0.0f64; LANES];
        sum.copy_from_slice(&y[i * LANES..(i + 1) * LANES]);
        for k in i + 1..smax {
            let lki = &a[(k * smax + i) * LANES..(k * smax + i) * LANES + LANES];
            let xk = &x[k * LANES..(k + 1) * LANES];
            for l in 0..LANES {
                sum[l] -= lki[l] * xk[l];
            }
        }
        let d = &a[(i * smax + i) * LANES..(i * smax + i) * LANES + LANES];
        let xi = &mut x[i * LANES..(i + 1) * LANES];
        for l in 0..LANES {
            xi[l] = sum[l] / d[l];
        }
    }
    Ok(())
}

/// §H.1 padded batched solve over one band: for every row recorded in
/// `s` (via `begin`/`push`/`end_row`), solves `λ·R̂ = u` with
/// `R̂ = hinv[q][:, q]` and scatters `λ` into the row's Λ-panel slots
/// (`s.lam[ri * width + q[t]] = λ[t]`, zeros elsewhere).
///
/// §Perf-L5 interleaved batching: the band's systems are ordered by
/// (size descending, row ascending) and dispatched on the measured
/// crossover — systems with `s_i ≤ 24` are gathered `LANES` at a time
/// into a structure-of-arrays buffer, identity-padded to the batch
/// max (the §H.1 embedding, eq. 77–79, now *materialized* but only
/// across the near-uniform sorted batch — the sort keeps the padding
/// wedge tiny), and factored+solved SIMD-style across the systems
/// axis by [`batch_factor_solve`]; larger systems keep the per-row
/// live-block sweep ([`solve_gathered_in`]), whose contiguous
/// `t`-loops already fill vector width. The materialized-padding
/// formulation survives as [`solve_rows_padded`], the AOT-path
/// oracle, pinned equal by `padded_matches_direct`.
///
/// **Bit-identity.** Both dispatch targets run the exact arithmetic of
/// the per-row solve ([`solve_row_in_scratch`]) — the interleaved
/// sweep per lane, the fallback directly — and lanes never interact,
/// so `λ` never depends on the dispatch order, batch composition, band
/// decomposition or thread count. Pinned by
/// `tests/prune_panel.rs::padded_band_solver_bit_identical_to_per_row`
/// and `tests/selection.rs`.
pub fn solve_band_padded_into_panel(hinv: &MatF64, s: &mut PanelSolveScratch) -> Result<()> {
    let rows = s.rows();
    let PanelSolveScratch { qs, q_off, us, lam, width, rhat, y, x, order, ia, iu, iy, ix } = s;
    let width = *width;
    debug_assert_eq!(lam.len(), rows * width);
    // bands recorded via `push_support` (index-only, caller-solved)
    // must not reach this solver — their rhs slots don't exist
    debug_assert_eq!(qs.len(), us.len(), "band mixes push and push_support recording");
    order.clear();
    for ri in 0..rows {
        let sz = q_off[ri + 1] - q_off[ri];
        if sz > 0 {
            order.push((sz as u32, ri as u32));
        }
    }
    order.sort_unstable_by(|p, q| q.0.cmp(&p.0).then(p.1.cmp(&q.1)));
    let mut k0 = 0;
    // (sorted-first) systems above the crossover: per-row sweep
    while k0 < order.len() && order[k0].0 as usize > INTERLEAVE_MAX {
        let ri = order[k0].1 as usize;
        let (o0, o1) = (q_off[ri], q_off[ri + 1]);
        let q = &qs[o0..o1];
        solve_gathered_in(hinv, q, &us[o0..o1], rhat, y, x)?;
        let lrow = &mut lam[ri * width..(ri + 1) * width];
        for (t, &qt) in q.iter().enumerate() {
            lrow[qt] = x[t];
        }
        k0 += 1;
    }
    // the rest interleave in LANES-wide sorted batches
    while k0 < order.len() {
        let nb = LANES.min(order.len() - k0);
        let smax = order[k0].0 as usize;
        let alen = smax * smax * LANES;
        // grow-only buffers: stale cells from earlier batches are fully
        // overwritten by the targeted gather + identity-pad below
        if ia.len() < alen {
            ia.resize(alen, 0.0);
        }
        let ulen = smax * LANES;
        if iu.len() < ulen {
            iu.resize(ulen, 0.0);
        }
        if iy.len() < ulen {
            iy.resize(ulen, 0.0);
        }
        if ix.len() < ulen {
            ix.resize(ulen, 0.0);
        }
        let a = &mut ia[..alen];
        let ub = &mut iu[..ulen];
        for l in 0..LANES {
            let sz = if l < nb { order[k0 + l].0 as usize } else { 0 };
            if l < nb {
                let ri = order[k0 + l].1 as usize;
                let (o0, o1) = (q_off[ri], q_off[ri + 1]);
                let q = &qs[o0..o1];
                for (a0, &qa) in q.iter().enumerate() {
                    let hr = hinv.row(qa);
                    for (b0, &qb) in q.iter().enumerate() {
                        a[(a0 * smax + b0) * LANES + l] = hr[qb];
                    }
                }
                for (t, &uv) in us[o0..o1].iter().enumerate() {
                    ub[t * LANES + l] = uv;
                }
            }
            // identity-pad the wedge beyond this lane's live block
            for i in 0..smax {
                let lo = if i < sz { sz } else { 0 };
                for j in lo..smax {
                    a[(i * smax + j) * LANES + l] = if i == j { 1.0 } else { 0.0 };
                }
                if i >= sz {
                    ub[i * LANES + l] = 0.0;
                }
            }
        }
        batch_factor_solve(a, ub, &mut iy[..ulen], &mut ix[..ulen], smax)?;
        for (l, &(szu, riu)) in order[k0..k0 + nb].iter().enumerate() {
            let (sz, ri) = (szu as usize, riu as usize);
            let q = &qs[q_off[ri]..q_off[ri] + sz];
            let lrow = &mut lam[ri * width..(ri + 1) * width];
            for (t, &qt) in q.iter().enumerate() {
                lrow[qt] = ix[t * LANES + l];
            }
        }
        k0 += nb;
    }
    Ok(())
}

/// Forward substitution through a gathered upper-triangular principal
/// submatrix: solves `e · U[q][:, q] = rhs` for ascending `q` (so the
/// gathered matrix is upper triangular), i.e.
/// `e_t = (rhs_t − Σ_{a<t} e_a · U[q_a, q_t]) / U[q_t, q_t]`.
///
/// This is the batched form of SparseGPT's column-sequential error
/// chain: with `row ← row₀ − e·U[q, :]` every masked column lands at
/// exactly the value the one-column-at-a-time OBS walk drives it to
/// (§Perf-L4), so the whole per-row update collapses into one Λ-panel
/// GEMM row.
pub fn forward_subst_upper_gather(u: &MatF64, q: &[usize], rhs: &[f64], out: &mut Vec<f64>) {
    debug_assert_eq!(q.len(), rhs.len());
    out.clear();
    out.resize(q.len(), 0.0);
    for t in 0..q.len() {
        let qt = q[t];
        let mut sum = rhs[t];
        for a in 0..t {
            sum -= out[a] * u.at(q[a], qt);
        }
        out[t] = sum / u.at(qt, qt);
    }
}

/// Apply the Thanos row update `w ← w − λ·R` (eq. 10) where
/// `R = hinv[q]` are the selected rows of the inverse Hessian. The
/// entries at the removal indices land at (numerically) zero; they are
/// clamped to exact zero so downstream sparsity accounting is crisp.
pub fn apply_row_update(w: &mut [f32], hinv: &MatF64, q: &[usize], lam: &[f64]) {
    assert_eq!(q.len(), lam.len());
    assert_eq!(w.len(), hinv.cols);
    for (t, &qt) in q.iter().enumerate() {
        let l = lam[t];
        if l == 0.0 {
            continue;
        }
        let hrow = hinv.row(qt);
        for (j, wj) in w.iter_mut().enumerate() {
            *wj -= (l * hrow[j]) as f32;
        }
    }
    for &qt in q {
        w[qt] = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::{chol_inverse, damp_hessian};
    use crate::linalg::gemm::xxt_f64;
    use crate::linalg::Mat;
    use crate::rng::Rng;

    fn setup(b: usize, seed: u64) -> MatF64 {
        let mut r = Rng::new(seed);
        let x = Mat::from_fn(b, b + 5, |_, _| r.normal_f32(0.0, 1.0));
        let mut h = xxt_f64(&x);
        for v in h.data.iter_mut() {
            *v *= 2.0;
        }
        damp_hessian(&mut h, 0.01);
        chol_inverse(&h).unwrap()
    }

    #[test]
    fn padded_matches_direct() {
        let hinv = setup(16, 11);
        let mut r = Rng::new(12);
        let qs: Vec<Vec<usize>> = vec![
            vec![1, 4, 7],
            vec![0],
            vec![2, 3, 5, 8, 13],
            vec![],
            vec![15],
        ];
        let us: Vec<Vec<f64>> = qs
            .iter()
            .map(|q| q.iter().map(|_| r.normal()).collect())
            .collect();
        let direct = solve_rows_direct(&hinv, &qs, &us).unwrap();
        let padded = solve_rows_padded(&hinv, &qs, &us).unwrap();
        assert_eq!(direct.len(), padded.len());
        for (d, p) in direct.iter().zip(&padded) {
            assert_eq!(d.len(), p.len());
            for (a, b) in d.iter().zip(p) {
                assert!((a - b).abs() < 1e-9, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn scratch_solver_bit_identical_to_allocating_path() {
        // the pooled-scratch path must reproduce the allocating
        // cholesky + chol_solve chain bit-for-bit, and must be
        // independent of engine thread count
        let hinv = setup(16, 19);
        let mut r = Rng::new(20);
        let qs: Vec<Vec<usize>> = vec![vec![0, 2, 9], vec![5], vec![1, 3, 4, 11, 14], vec![]];
        let us: Vec<Vec<f64>> = qs
            .iter()
            .map(|q| q.iter().map(|_| r.normal()).collect())
            .collect();
        let got = solve_rows_direct(&hinv, &qs, &us).unwrap();
        let serial =
            crate::engine::with_serial(|| solve_rows_direct(&hinv, &qs, &us).unwrap());
        for (q, (u, (g, s))) in qs.iter().zip(us.iter().zip(got.iter().zip(&serial))) {
            if q.is_empty() {
                assert!(g.is_empty());
                continue;
            }
            let rhat = hinv.principal_submatrix(q);
            let l = cholesky(&rhat).unwrap();
            let reference = chol_solve(&l, u);
            assert_eq!(g, &reference, "scratch vs allocating");
            assert_eq!(g, s, "parallel vs serial");
        }
    }

    #[test]
    fn panel_band_solver_matches_per_row_bitwise() {
        // the §H.1 padded band solver must reproduce the exact-size
        // per-row scratch solve bit-for-bit, whatever the band's r_max
        // padding turns out to be (including rows with empty support)
        let hinv = setup(16, 30);
        let mut r = Rng::new(31);
        let qs: Vec<Vec<usize>> = vec![
            vec![0, 2, 9, 14],
            vec![],
            vec![5],
            vec![1, 3, 4, 7, 11, 12, 15],
            vec![8, 10],
        ];
        let us: Vec<Vec<f64>> = qs
            .iter()
            .map(|q| q.iter().map(|_| r.normal()).collect())
            .collect();
        let width = 16;
        let mut ps = PanelSolveScratch::new();
        ps.begin(qs.len(), width);
        for (q, u) in qs.iter().zip(&us) {
            for (&k, &v) in q.iter().zip(u) {
                ps.push(k, v);
            }
            ps.end_row();
        }
        solve_band_padded_into_panel(&hinv, &mut ps).unwrap();
        for (ri, (q, u)) in qs.iter().zip(&us).enumerate() {
            let mut s = RowSolveScratch::new();
            s.q.extend_from_slice(q);
            s.u.extend_from_slice(u);
            solve_row_in_scratch(&hinv, &mut s).unwrap();
            let lrow = &ps.lam[ri * width..(ri + 1) * width];
            let mut expect = vec![0.0f64; width];
            for (t, &qt) in q.iter().enumerate() {
                expect[qt] = s.lam[t];
            }
            for (k, (&got, &want)) in lrow.iter().zip(&expect).enumerate() {
                assert_eq!(got.to_bits(), want.to_bits(), "row {ri} slot {k}");
            }
        }
    }

    #[test]
    fn forward_subst_gather_matches_sequential_obs_chain() {
        // e from the triangular gather must drive the same columns to
        // zero as SparseGPT's sequential per-column updates (f64 chain)
        let b = 12;
        let mut r = Rng::new(32);
        let x = Mat::from_fn(b, b + 6, |_, _| r.normal_f32(0.0, 1.0));
        let mut h = xxt_f64(&x);
        damp_hessian(&mut h, 0.01);
        let u = crate::linalg::chol::inverse_factor_upper(&h).unwrap();
        let q = vec![1usize, 4, 5, 9];
        let row0: Vec<f64> = (0..b).map(|_| r.normal()).collect();
        // sequential reference, all in f64
        let mut row_seq = row0.clone();
        for &j in &q {
            let err = row_seq[j] / u.at(j, j);
            for t in j..b {
                row_seq[t] -= err * u.at(j, t);
            }
            row_seq[j] = 0.0;
        }
        // batched: forward substitution + one panel apply
        let rhs: Vec<f64> = q.iter().map(|&j| row0[j]).collect();
        let mut e = Vec::new();
        forward_subst_upper_gather(&u, &q, &rhs, &mut e);
        let mut row_bat = row0.clone();
        for (t, &j) in q.iter().enumerate() {
            for col in 0..b {
                row_bat[col] -= e[t] * u.at(j, col);
            }
        }
        for &j in &q {
            row_bat[j] = 0.0;
        }
        for (col, (a, b_)) in row_seq.iter().zip(&row_bat).enumerate() {
            assert!((a - b_).abs() < 1e-9, "col {col}: {a} vs {b_}");
        }
    }

    #[test]
    fn solution_satisfies_constraints() {
        // After the update, w[q] == 0 exactly.
        let hinv = setup(12, 13);
        let mut r = Rng::new(14);
        let mut w: Vec<f32> = (0..12).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let q = vec![2usize, 5, 9];
        let u: Vec<f64> = q.iter().map(|&i| w[i] as f64).collect();
        let lam = solve_rows_direct(&hinv, &[q.clone()], &[u]).unwrap();
        apply_row_update(&mut w, &hinv, &q, &lam[0]);
        for &qi in &q {
            assert_eq!(w[qi], 0.0);
        }
    }

    #[test]
    fn update_is_obs_for_single_index() {
        // s=1 must reduce to the OBS rule δ* = -(w_q / Hinv_qq)·Hinv_q: (eq. 4)
        let hinv = setup(10, 15);
        let mut r = Rng::new(16);
        let w0: Vec<f32> = (0..10).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let q = 4usize;
        let lam = solve_rows_direct(&hinv, &[vec![q]], &[vec![w0[q] as f64]]).unwrap();
        let mut w = w0.clone();
        apply_row_update(&mut w, &hinv, &[q], &lam[0]);
        let coef = w0[q] as f64 / hinv.at(q, q);
        for j in 0..10 {
            let expected = if j == q {
                0.0
            } else {
                w0[j] as f64 - coef * hinv.at(q, j)
            };
            assert!((w[j] as f64 - expected).abs() < 1e-5, "j={j}");
        }
    }

    #[test]
    fn joint_update_beats_sequential_single_updates() {
        // The core claim of the paper (§4 / §A.1): solving for several
        // removals jointly gives lower reconstruction loss than applying
        // the single-weight OBS rule one at a time with a stale Hessian.
        let b = 14;
        let mut r = Rng::new(17);
        let x = Mat::from_fn(b, 40, |_, _| r.normal_f32(0.0, 1.0));
        let mut h = xxt_f64(&x);
        for v in h.data.iter_mut() {
            *v *= 2.0;
        }
        damp_hessian(&mut h, 0.001);
        let hinv = chol_inverse(&h).unwrap();
        let w0 = Mat::from_fn(1, b, |_, _| r.normal_f32(0.0, 1.0));
        let q = vec![1usize, 3, 6, 10];

        // joint (Thanos)
        let u: Vec<f64> = q.iter().map(|&i| w0.at(0, i) as f64).collect();
        let lam = solve_rows_direct(&hinv, &[q.clone()], &[u]).unwrap();
        let mut w_joint = w0.clone();
        apply_row_update(w_joint.row_mut(0), &hinv, &q, &lam[0]);

        // sequential independent OBS deltas summed (what SparseGPT's
        // one-at-a-time rule would do without refreshing H between the
        // removals of the same block)
        let mut w_seq = w0.clone();
        for &qi in &q {
            let coef = w0.at(0, qi) as f64 / hinv.at(qi, qi);
            for j in 0..b {
                *w_seq.at_mut(0, j) -= (coef * hinv.at(qi, j)) as f32;
            }
        }
        for &qi in &q {
            *w_seq.at_mut(0, qi) = 0.0;
        }

        let loss_joint = crate::linalg::gemm::recon_loss(&w_joint, &w0, &x);
        let loss_seq = crate::linalg::gemm::recon_loss(&w_seq, &w0, &x);
        assert!(
            loss_joint <= loss_seq + 1e-9,
            "joint {loss_joint} vs sequential {loss_seq}"
        );
    }
}
