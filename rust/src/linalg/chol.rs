//! Cholesky factorization, triangular solves, PSD inverse, LU solve.
//!
//! All in f64: the quality gap between pruning methods is driven by the
//! conditioning of `H = 2XXᵀ`, and f32 factorization visibly degrades
//! SparseGPT/Thanos updates at b ≥ 1024.

use super::MatF64;
use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor `L` with `A = L·Lᵀ`.
/// Fails if `A` is not (numerically) positive definite — callers damp
/// the Hessian first (see [`damp_hessian`]).
pub fn cholesky(a: &MatF64) -> Result<MatF64> {
    assert_eq!(a.rows, a.cols, "cholesky needs a square matrix");
    let mut m = a.clone();
    cholesky_in_place(&mut m)?;
    Ok(m)
}

/// In-place variant of [`cholesky`]: factorizes `m` into its own
/// storage (hot loops reuse one buffer across thousands of small row
/// systems instead of cloning — see `batched::RowSolveScratch`).
///
/// Right-looking: per column, the trailing-submatrix rank-1 downdate
/// (the O(n²) part of every step) is split into row bands on the shared
/// [`crate::engine`] pool once the trailing size is large enough to
/// amortize submission (DESIGN.md §Perf-L3). Band splits never change
/// per-row arithmetic, so the factor is bit-identical for any thread
/// count.
pub fn cholesky_in_place(m: &mut MatF64) -> Result<()> {
    assert_eq!(m.rows, m.cols, "cholesky needs a square matrix");
    let n = m.rows;
    let eng = crate::engine::global();
    // threshold below which the serial update is faster than submitting
    const PAR_MIN: usize = 192;
    let mut colj = vec![0.0f64; n];
    for j in 0..n {
        let pivot = m.at(j, j);
        if pivot <= 0.0 || !pivot.is_finite() {
            bail!("matrix not positive definite at pivot {j} (value {pivot:.3e})");
        }
        let pivot = pivot.sqrt();
        *m.at_mut(j, j) = pivot;
        for i in j + 1..n {
            let v = m.at(i, j) / pivot;
            *m.at_mut(i, j) = v;
            colj[i] = v;
        }
        let trailing = n - (j + 1);
        if trailing == 0 {
            continue;
        }
        if trailing < PAR_MIN || eng.threads() == 1 {
            for i in j + 1..n {
                let ci = colj[i];
                if ci == 0.0 {
                    continue;
                }
                let row = m.row_mut(i);
                for k in j + 1..=i {
                    row[k] -= ci * colj[k];
                }
            }
        } else {
            let colj_ref = &colj;
            let rows_per = eng.chunk(trailing);
            let tail = &mut m.data[(j + 1) * n..];
            eng.for_each_band(tail, rows_per * n, |bi, head| {
                let start = j + 1 + bi * rows_per;
                let rows_here = head.len() / n;
                for ri in 0..rows_here {
                    let i = start + ri;
                    let ci = colj_ref[i];
                    if ci == 0.0 {
                        continue;
                    }
                    let row = &mut head[ri * n..(ri + 1) * n];
                    for k in j + 1..=i {
                        row[k] -= ci * colj_ref[k];
                    }
                }
            });
        }
    }
    // zero the (stale) upper triangle
    for i in 0..n {
        for j in i + 1..n {
            *m.at_mut(i, j) = 0.0;
        }
    }
    Ok(())
}

/// Inverse of a lower-triangular matrix, column-parallel: column `j`
/// of `L⁻¹` is the forward-substitution solve of `L·x = e_j`, which
/// only touches indices `≥ j` (total n³/6 flops, embarrassingly
/// parallel across columns).
pub fn lower_tri_inverse(l: &MatF64) -> MatF64 {
    let n = l.rows;
    let mut inv = MatF64::zeros(n, n);
    let eng = crate::engine::global();
    let cols_per = eng.chunk(n);
    let n_bands = n.div_ceil(cols_per.max(1));
    let mut bands: Vec<Vec<Vec<f64>>> = vec![Vec::new(); n_bands];
    eng.for_each_band(&mut bands, 1, |bi, slot| {
        let j0 = bi * cols_per;
        let jend = (j0 + cols_per).min(n);
        let mut cols = Vec::with_capacity(jend - j0);
        for j in j0..jend {
            let mut x = vec![0.0f64; n];
            x[j] = 1.0 / l.at(j, j);
            for i in j + 1..n {
                let li = l.row(i);
                let mut sum = 0.0;
                for (k, &xk) in x.iter().enumerate().take(i).skip(j) {
                    sum += li[k] * xk;
                }
                x[i] = -sum / li[i];
            }
            cols.push(x);
        }
        slot[0] = cols;
    });
    for (bi, cols) in bands.into_iter().enumerate() {
        let j0 = bi * cols_per;
        for (dj, col) in cols.into_iter().enumerate() {
            let j = j0 + dj;
            for i in j..n {
                *inv.at_mut(i, j) = col[i];
            }
        }
    }
    inv
}

/// Solve `U·X = RHS` for upper-triangular `U` (s×s) against an s×n
/// right-hand-side matrix, column-parallel back substitution.
pub fn upper_tri_solve_many(u: &MatF64, rhs: &MatF64) -> MatF64 {
    let s = u.rows;
    assert_eq!(u.cols, s);
    assert_eq!(rhs.rows, s);
    let n = rhs.cols;
    let mut x = MatF64::zeros(s, n);
    let eng = crate::engine::global();
    let cols_per = eng.chunk(n);
    let n_bands = n.div_ceil(cols_per.max(1));
    let mut bands: Vec<Vec<Vec<f64>>> = vec![Vec::new(); n_bands];
    eng.for_each_band(&mut bands, 1, |bi, slot| {
        let j0 = bi * cols_per;
        let jend = (j0 + cols_per).min(n);
        let mut cols = Vec::with_capacity(jend - j0);
        for j in j0..jend {
            let mut col = vec![0.0f64; s];
            for i in (0..s).rev() {
                let urow = u.row(i);
                let mut sum = rhs.at(i, j);
                for (k, &ck) in col.iter().enumerate().skip(i + 1) {
                    sum -= urow[k] * ck;
                }
                col[i] = sum / urow[i];
            }
            cols.push(col);
        }
        slot[0] = cols;
    });
    for (bi, cols) in bands.into_iter().enumerate() {
        let j0 = bi * cols_per;
        for (dj, col) in cols.into_iter().enumerate() {
            for i in 0..s {
                *x.at_mut(i, j0 + dj) = col[i];
            }
        }
    }
    x
}

/// Upper-triangular `U` with `A⁻¹ = Uᵀ·U`, computed WITHOUT forming
/// `A⁻¹`: with `J` the index-reversal permutation and
/// `M = J·A·J = Lₘ·Lₘᵀ`, one has `A⁻¹ = J·Lₘ⁻ᵀ·Lₘ⁻¹·J = UᵀU` for
/// `U = J·Lₘ⁻¹·J` (upper triangular). Cost ≈ n³/3 (cholesky) + n³/6
/// (triangular inverse), vs ≈ 2.7·n³ for the naive
/// chol→full-inverse→chol chain — the §Perf-L3 optimization that makes
/// SparseGPT/Thanos feasible at OPT layer shapes on CPU.
pub fn inverse_factor_upper(a: &MatF64) -> Result<MatF64> {
    let n = a.rows;
    let m = MatF64::from_fn(n, n, |i, j| a.at(n - 1 - i, n - 1 - j));
    let lm = cholesky(&m)?;
    let linv = lower_tri_inverse(&lm);
    Ok(MatF64::from_fn(n, n, |i, j| linv.at(n - 1 - i, n - 1 - j)))
}

/// Solve `L·y = b` (forward substitution), `L` lower triangular.
pub fn solve_lower(l: &MatF64, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        let lrow = l.row(i);
        for k in 0..i {
            sum -= lrow[k] * y[k];
        }
        y[i] = sum / lrow[i];
    }
    y
}

/// Solve `Lᵀ·x = y` (backward substitution), `L` lower triangular.
pub fn solve_lower_t(l: &MatF64, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(y.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l.at(k, i) * x[k];
        }
        x[i] = sum / l.at(i, i);
    }
    x
}

/// Solve `A·x = b` given the Cholesky factor of `A`.
pub fn chol_solve(l: &MatF64, b: &[f64]) -> Vec<f64> {
    solve_lower_t(l, &solve_lower(l, b))
}

/// Allocation-free [`chol_solve`]: forward substitution into `y`, back
/// substitution into `x` (both resized in place). Exactly the same
/// arithmetic as [`solve_lower`] + [`solve_lower_t`], so results are
/// bit-identical — the buffer-reuse variant the per-row Thanos solves
/// use through `batched::RowSolveScratch`.
pub fn chol_solve_into(l: &MatF64, b: &[f64], y: &mut Vec<f64>, x: &mut Vec<f64>) {
    let n = l.rows;
    assert_eq!(b.len(), n);
    y.clear();
    y.resize(n, 0.0);
    for i in 0..n {
        let mut sum = b[i];
        let lrow = l.row(i);
        for k in 0..i {
            sum -= lrow[k] * y[k];
        }
        y[i] = sum / lrow[i];
    }
    x.clear();
    x.resize(n, 0.0);
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l.at(k, i) * x[k];
        }
        x[i] = sum / l.at(i, i);
    }
}

/// Full inverse of a symmetric PD matrix via Cholesky. The n identity
/// columns are independent solves, so they are fanned out across
/// threads (the dominant 2n³ of the ~2.3n³ total cost parallelizes).
pub fn chol_inverse(a: &MatF64) -> Result<MatF64> {
    let n = a.rows;
    let l = cholesky(a)?;
    let mut inv = MatF64::zeros(n, n);
    let eng = crate::engine::global();
    let cols_per = eng.chunk(n);
    let n_bands = n.div_ceil(cols_per.max(1));
    // collect per-band column groups, then transpose into `inv`
    let l_ref = &l;
    let mut bands: Vec<Vec<Vec<f64>>> = vec![Vec::new(); n_bands];
    eng.for_each_band(&mut bands, 1, |bi, slot| {
        let j0 = bi * cols_per;
        let jend = (j0 + cols_per).min(n);
        let mut cols = Vec::with_capacity(jend - j0);
        let mut e = vec![0.0f64; n];
        for j in j0..jend {
            e[j] = 1.0;
            cols.push(chol_solve(l_ref, &e));
            e[j] = 0.0;
        }
        slot[0] = cols;
    });
    for (bi, cols) in bands.into_iter().enumerate() {
        let j0 = bi * cols_per;
        for (dj, col) in cols.into_iter().enumerate() {
            let j = j0 + dj;
            for i in 0..n {
                *inv.at_mut(i, j) = col[i];
            }
        }
    }
    // symmetrize to remove round-off asymmetry — downstream code relies
    // on Hinv being exactly symmetric (principal submatrices → Cholesky).
    for i in 0..n {
        for j in 0..i {
            let v = 0.5 * (inv.at(i, j) + inv.at(j, i));
            *inv.at_mut(i, j) = v;
            *inv.at_mut(j, i) = v;
        }
    }
    Ok(inv)
}

/// General square solve `A·x = b` via LU with partial pivoting.
/// Used where symmetry is not guaranteed (padded batched systems of
/// §H.1 mix identity rows into `R̂′`).
pub fn lu_solve(a: &MatF64, b: &[f64]) -> Result<Vec<f64>> {
    let n = a.rows;
    assert_eq!(a.cols, n);
    assert_eq!(b.len(), n);
    let mut lu = a.clone();
    let mut x: Vec<f64> = b.to_vec();
    let mut piv: Vec<usize> = (0..n).collect();
    for k in 0..n {
        // pivot
        let mut pmax = lu.at(k, k).abs();
        let mut prow = k;
        for i in k + 1..n {
            let v = lu.at(i, k).abs();
            if v > pmax {
                pmax = v;
                prow = i;
            }
        }
        if pmax == 0.0 || !pmax.is_finite() {
            bail!("singular matrix in lu_solve at column {k}");
        }
        if prow != k {
            piv.swap(k, prow);
            for j in 0..n {
                let t = lu.at(k, j);
                *lu.at_mut(k, j) = lu.at(prow, j);
                *lu.at_mut(prow, j) = t;
            }
            x.swap(k, prow);
        }
        let pivot = lu.at(k, k);
        for i in k + 1..n {
            let f = lu.at(i, k) / pivot;
            *lu.at_mut(i, k) = f;
            if f != 0.0 {
                for j in k + 1..n {
                    let v = lu.at(k, j);
                    *lu.at_mut(i, j) -= f * v;
                }
                x[i] -= f * x[k];
            }
        }
    }
    // back substitution
    for i in (0..n).rev() {
        let mut sum = x[i];
        for j in i + 1..n {
            sum -= lu.at(i, j) * x[j];
        }
        x[i] = sum / lu.at(i, i);
    }
    Ok(x)
}

/// Add the standard SparseGPT-style damping `λ·I` with
/// `λ = percdamp · mean(diag(H))`, and replace zero diagonal entries
/// (dead input channels) with 1 so `H` stays invertible — mirroring the
/// reference implementations of SparseGPT/Wanda.
pub fn damp_hessian(h: &mut MatF64, percdamp: f64) {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    let mut trace = 0.0;
    for i in 0..n {
        trace += h.at(i, i);
    }
    let lambda = percdamp * (trace / n as f64).max(f64::MIN_POSITIVE);
    for i in 0..n {
        let d = h.at(i, i);
        if d == 0.0 {
            *h.at_mut(i, i) = 1.0;
        } else {
            *h.at_mut(i, i) = d + lambda;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_f64;
    use crate::linalg::Mat;
    use crate::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> MatF64 {
        let mut r = Rng::new(seed);
        let x = Mat::from_fn(n, n + 3, |_, _| r.normal_f32(0.0, 1.0));
        let mut h = crate::linalg::gemm::xxt_f64(&x);
        damp_hessian(&mut h, 0.01);
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(12, 1);
        let l = cholesky(&a).unwrap();
        let rec = matmul_f64(&l, &l.transpose());
        assert!(a.max_abs_diff(&rec) < 1e-9);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = MatF64::eye(3);
        *a.at_mut(2, 2) = -1.0;
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn chol_solve_solves() {
        let a = random_spd(20, 2);
        let mut r = Rng::new(3);
        let b: Vec<f64> = (0..20).map(|_| r.normal()).collect();
        let l = cholesky(&a).unwrap();
        let x = chol_solve(&l, &b);
        // residual check
        for i in 0..20 {
            let ax: f64 = (0..20).map(|j| a.at(i, j) * x[j]).sum();
            assert!((ax - b[i]).abs() < 1e-8, "row {i}");
        }
    }

    #[test]
    fn chol_inverse_is_inverse() {
        let a = random_spd(15, 4);
        let inv = chol_inverse(&a).unwrap();
        let prod = matmul_f64(&a, &inv);
        let eye = MatF64::eye(15);
        assert!(prod.max_abs_diff(&eye) < 1e-8);
    }

    #[test]
    fn chol_inverse_symmetric() {
        let a = random_spd(10, 5);
        let inv = chol_inverse(&a).unwrap();
        assert!(inv.max_abs_diff(&inv.transpose()) == 0.0);
    }

    #[test]
    fn lower_tri_inverse_inverts() {
        let a = random_spd(20, 8);
        let l = cholesky(&a).unwrap();
        let linv = lower_tri_inverse(&l);
        let prod = matmul_f64(&l, &linv);
        assert!(prod.max_abs_diff(&MatF64::eye(20)) < 1e-9);
        // strictly lower triangular result
        for i in 0..20 {
            for j in i + 1..20 {
                assert_eq!(linv.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn inverse_factor_upper_identity() {
        let a = random_spd(24, 9);
        let u = inverse_factor_upper(&a).unwrap();
        // upper triangular
        for i in 0..24 {
            for j in 0..i {
                assert_eq!(u.at(i, j), 0.0, "({i},{j})");
            }
        }
        // U^T U == A^{-1}  (check A · U^T U == I)
        let utu = matmul_f64(&u.transpose(), &u);
        let prod = matmul_f64(&a, &utu);
        assert!(prod.max_abs_diff(&MatF64::eye(24)) < 1e-8);
        // must agree with the naive chain
        let naive = cholesky(&chol_inverse(&a).unwrap()).unwrap().transpose();
        let utu2 = matmul_f64(&naive.transpose(), &naive);
        assert!(utu.max_abs_diff(&utu2) < 1e-8);
    }

    #[test]
    fn parallel_cholesky_matches_large() {
        // exercise the threaded trailing-update path (n > PAR_MIN)
        let a = random_spd(300, 10);
        let l = cholesky(&a).unwrap();
        let rec = matmul_f64(&l, &l.transpose());
        assert!(a.max_abs_diff(&rec) < 1e-7);
    }

    #[test]
    fn cholesky_in_place_matches_cholesky() {
        let a = random_spd(40, 21);
        let l = cholesky(&a).unwrap();
        let mut m = a.clone();
        cholesky_in_place(&mut m).unwrap();
        assert_eq!(l.data, m.data, "in-place factor must be bit-identical");
    }

    #[test]
    fn chol_solve_into_matches_chol_solve() {
        let a = random_spd(18, 22);
        let l = cholesky(&a).unwrap();
        let mut r = Rng::new(23);
        let b: Vec<f64> = (0..18).map(|_| r.normal()).collect();
        let direct = chol_solve(&l, &b);
        let mut y = Vec::new();
        let mut x = Vec::new();
        chol_solve_into(&l, &b, &mut y, &mut x);
        assert_eq!(direct, x, "scratch solve must be bit-identical");
    }

    #[test]
    fn lu_solve_matches_chol_solve_on_spd() {
        let a = random_spd(16, 6);
        let mut r = Rng::new(7);
        let b: Vec<f64> = (0..16).map(|_| r.normal()).collect();
        let l = cholesky(&a).unwrap();
        let x1 = chol_solve(&l, &b);
        let x2 = lu_solve(&a, &b).unwrap();
        for i in 0..16 {
            assert!((x1[i] - x2[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn lu_solve_handles_permutation_needs() {
        // leading zero pivot forces row exchange
        let a = MatF64::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let x = lu_solve(&a, &[3.0, 5.0]).unwrap();
        assert!((x[0] - 5.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn lu_solve_rejects_singular() {
        let a = MatF64::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(lu_solve(&a, &[1.0, 1.0]).is_err());
    }

    #[test]
    fn damp_hessian_fixes_dead_channels() {
        let mut h = MatF64::zeros(3, 3);
        *h.at_mut(0, 0) = 2.0;
        damp_hessian(&mut h, 0.01);
        assert!(h.at(1, 1) == 1.0 && h.at(2, 2) == 1.0);
        assert!(h.at(0, 0) > 2.0);
        assert!(cholesky(&h).is_ok());
    }
}
