//! Cholesky factorization, triangular solves, PSD inverse, LU solve.
//!
//! All in f64: the quality gap between pruning methods is driven by the
//! conditioning of `H = 2XXᵀ`, and f32 factorization visibly degrades
//! SparseGPT/Thanos updates at b ≥ 1024.
//!
//! The O(n³) paths are blocked over the packed micro-kernel core
//! (DESIGN.md §Perf-L3):
//!
//! * [`cholesky_in_place`] — blocked right-looking factorization:
//!   unblocked panel factor, a vectorized row-sweep TRSM for the
//!   below-panel block column, and the trailing update `A₂₂ −= L₂₁L₂₁ᵀ`
//!   expressed as the packed GEMM kernel against a pre-packed `L₂₁ᵀ`.
//! * [`upper_tri_solve_many`] / [`lower_tri_inverse`] — blocked TRSM:
//!   per column band, diagonal-block substitution sweeps plus packed
//!   GEMM updates for the off-diagonal blocks (the triangular-inverse
//!   variant skips the structurally-zero leading blocks, preserving the
//!   n³/6 flop count).
//!
//! Systems at or below the panel width (`NB`) run the exact seed
//! arithmetic — the thousands of per-row Thanos systems
//! (`batched::solve_row_in_scratch`) keep their bit behavior.
//! `THANOS_LINALG_NAIVE=1` restores the seed paths everywhere (the
//! `linalg_kernels` bench baseline).

use super::kernel::{self, kf64, View};
use super::MatF64;
use anyhow::{bail, Result};

/// Blocked-factorization panel width (also the block size of the TRSM
/// solves). Systems with `n ≤ NB` run the unblocked seed arithmetic.
const NB: usize = 96;
/// Below this trailing size the engine submission is not worth it and
/// the blocked steps run inline on the caller (same arithmetic).
const PAR_MIN: usize = 192;
/// Triangular solves below this system size keep the seed
/// column-solver (the blocked machinery cannot amortize there).
const TRSM_MIN_S: usize = 64;

/// Lower-triangular Cholesky factor `L` with `A = L·Lᵀ`.
/// Fails if `A` is not (numerically) positive definite — callers damp
/// the Hessian first (see [`damp_hessian`]).
pub fn cholesky(a: &MatF64) -> Result<MatF64> {
    assert_eq!(a.rows, a.cols, "cholesky needs a square matrix");
    let mut m = a.clone();
    cholesky_in_place(&mut m)?;
    Ok(m)
}

/// In-place variant of [`cholesky`]: factorizes `m` into its own
/// storage (hot loops reuse one buffer across thousands of small row
/// systems instead of cloning — see `batched::RowSolveScratch`).
///
/// Blocked right-looking (DESIGN.md §Perf-L3): per `NB`-column panel,
/// factor the diagonal block unblocked, solve the below-panel block
/// column against `L₁₁ᵀ` with a per-row forward sweep, then downdate
/// the trailing submatrix with the packed GEMM kernel
/// (`A₂₂ −= L₂₁·L₂₁ᵀ`). Row bands of the TRSM and trailing update run
/// on the shared [`crate::engine`] pool; per-element accumulation
/// chains are independent of the banding, so the factor is
/// bit-identical for any thread count.
pub fn cholesky_in_place(m: &mut MatF64) -> Result<()> {
    assert_eq!(m.rows, m.cols, "cholesky needs a square matrix");
    if kernel::naive_mode() {
        return cholesky_naive_in_place(m);
    }
    let n = m.rows;
    let mut colj: Vec<f64> = Vec::new();
    if n <= NB {
        chol_unblocked(&mut m.data, n, 0, n, &mut colj)?;
        zero_upper(m);
        return Ok(());
    }
    let eng = crate::engine::global();
    let mut panel: Vec<f64> = Vec::new();
    let mut l11t = vec![0.0f64; NB * NB];
    let mut j0 = 0;
    while j0 < n {
        let jb = NB.min(n - j0);
        chol_unblocked(&mut m.data, n, j0, jb, &mut colj)?;
        let t0 = j0 + jb;
        if t0 >= n {
            break;
        }
        let trailing = n - t0;
        // transposed diagonal block, so the row sweep below reads
        // contiguous slices
        for c in 0..jb {
            for t in 0..=c {
                l11t[t * jb + c] = m.data[(j0 + c) * n + j0 + t];
            }
        }
        // TRSM: rows [t0, n) of the panel columns solve against L11ᵀ.
        // Banded on the engine; bands run inline under one thread (the
        // pool never queues then), so there is no separate serial path.
        {
            let l11t_ref = &l11t;
            let tail = &mut m.data[t0 * n..];
            let rows_per = eng.chunk(trailing);
            eng.for_each_band(tail, rows_per * n, |_bi, band| {
                for rrow in band.chunks_mut(n) {
                    let arow = &mut rrow[j0..j0 + jb];
                    for t in 0..jb {
                        let v = arow[t] / l11t_ref[t * jb + t];
                        arow[t] = v;
                        if v != 0.0 {
                            let lrow = &l11t_ref[t * jb..(t + 1) * jb];
                            for c in t + 1..jb {
                                arow[c] -= v * lrow[c];
                            }
                        }
                    }
                }
            });
        }
        // copy the solved panel and pre-pack its transpose once
        panel.clear();
        for i in t0..n {
            panel.extend_from_slice(&m.data[i * n + j0..i * n + j0 + jb]);
        }
        let bp = kf64::pack_b(View::transposed(&panel, jb), jb, trailing);
        let pv = View::row_major(&panel, jb);
        // trailing update: lower triangle at band granularity — each
        // band's `ncols` stops at its own last row, so only the band's
        // thin stale upper wedge is touched (never read, zeroed at the
        // end) and the flop count tracks the triangle in serial and
        // parallel alike
        let tail = &mut m.data[t0 * n..];
        let rows_per = eng.chunk_aligned(trailing, kf64::MR);
        eng.for_each_band(tail, rows_per * n, |bi, band| {
            let r0 = bi * rows_per;
            let rows_here = band.len() / n;
            kf64::gemm_core(band, n, t0, pv, r0, rows_here, &bp, r0 + rows_here, true);
        });
        j0 = t0;
    }
    zero_upper(m);
    Ok(())
}

/// Seed right-looking factorization (column-at-a-time rank-1
/// downdates, engine-banded past `PAR_MIN`): the naive reference the
/// blocked factorization is bench-gated against.
pub fn cholesky_naive_in_place(m: &mut MatF64) -> Result<()> {
    assert_eq!(m.rows, m.cols, "cholesky needs a square matrix");
    let n = m.rows;
    let eng = crate::engine::global();
    let mut colj = vec![0.0f64; n];
    for j in 0..n {
        let pivot = m.at(j, j);
        if pivot <= 0.0 || !pivot.is_finite() {
            bail!("matrix not positive definite at pivot {j} (value {pivot:.3e})");
        }
        let pivot = pivot.sqrt();
        *m.at_mut(j, j) = pivot;
        for i in j + 1..n {
            let v = m.at(i, j) / pivot;
            *m.at_mut(i, j) = v;
            colj[i] = v;
        }
        let trailing = n - (j + 1);
        if trailing == 0 {
            continue;
        }
        if trailing < PAR_MIN || eng.threads() == 1 {
            for i in j + 1..n {
                let ci = colj[i];
                if ci == 0.0 {
                    continue;
                }
                let row = m.row_mut(i);
                for k in j + 1..=i {
                    row[k] -= ci * colj[k];
                }
            }
        } else {
            let colj_ref = &colj;
            let rows_per = eng.chunk(trailing);
            let tail = &mut m.data[(j + 1) * n..];
            eng.for_each_band(tail, rows_per * n, |bi, head| {
                let start = j + 1 + bi * rows_per;
                let rows_here = head.len() / n;
                for ri in 0..rows_here {
                    let i = start + ri;
                    let ci = colj_ref[i];
                    if ci == 0.0 {
                        continue;
                    }
                    let row = &mut head[ri * n..(ri + 1) * n];
                    for k in j + 1..=i {
                        row[k] -= ci * colj_ref[k];
                    }
                }
            });
        }
    }
    zero_upper(m);
    Ok(())
}

/// Unblocked factor of the `nb × nb` diagonal block at `(j0, j0)`
/// inside an `ld`-strided matrix — the seed column-sweep arithmetic
/// (scaled column copied to `colj`, then contiguous row downdates), so
/// `n ≤ NB` systems reproduce the seed factor bit-for-bit.
fn chol_unblocked(
    data: &mut [f64],
    ld: usize,
    j0: usize,
    nb: usize,
    colj: &mut Vec<f64>,
) -> Result<()> {
    colj.clear();
    colj.resize(nb, 0.0);
    for j in 0..nb {
        let pivot = data[(j0 + j) * ld + j0 + j];
        if pivot <= 0.0 || !pivot.is_finite() {
            let gj = j0 + j;
            bail!("matrix not positive definite at pivot {gj} (value {pivot:.3e})");
        }
        let pivot = pivot.sqrt();
        data[(j0 + j) * ld + j0 + j] = pivot;
        for i in j + 1..nb {
            let v = data[(j0 + i) * ld + j0 + j] / pivot;
            data[(j0 + i) * ld + j0 + j] = v;
            colj[i] = v;
        }
        for i in j + 1..nb {
            let ci = colj[i];
            if ci == 0.0 {
                continue;
            }
            let row = &mut data[(j0 + i) * ld + j0..(j0 + i) * ld + j0 + nb];
            for k in j + 1..=i {
                row[k] -= ci * colj[k];
            }
        }
    }
    Ok(())
}

/// Zero the (stale) upper triangle after a factorization.
fn zero_upper(m: &mut MatF64) {
    let n = m.rows;
    for i in 0..n {
        for j in i + 1..n {
            *m.at_mut(i, j) = 0.0;
        }
    }
}

/// Inverse of a lower-triangular matrix: blocked forward TRSM against
/// the identity, column-banded on the engine. The leading row blocks of
/// each column band are structurally zero and skipped, preserving the
/// n³/6 flop count of the seed column solver; off-diagonal blocks are
/// the packed GEMM kernel.
pub fn lower_tri_inverse(l: &MatF64) -> MatF64 {
    let n = l.rows;
    if kernel::naive_mode() || n < TRSM_MIN_S {
        return lower_tri_inverse_naive(l);
    }
    let mut inv = MatF64::zeros(n, n);
    let eng = crate::engine::global();
    let cols_per = eng.chunk(n);
    let n_bands = n.div_ceil(cols_per.max(1));
    let mut bands: Vec<Vec<f64>> = vec![Vec::new(); n_bands];
    let lv = View::row_major(&l.data, n);
    eng.for_each_band(&mut bands, 1, |bi, slot| {
        let c0 = bi * cols_per;
        let w = cols_per.min(n - c0);
        let mut buf = vec![0.0f64; n * w];
        for j in c0..c0 + w {
            buf[j * w + (j - c0)] = 1.0;
        }
        // rows above the band's first block stay zero for every column
        let blk0 = (c0 / NB) * NB;
        let mut rb = blk0;
        while rb < n {
            let nb = NB.min(n - rb);
            if rb > blk0 {
                // C_rb −= L[rb.., blk0..rb] · X[blk0..rb]
                let (above, below) = buf.split_at_mut(rb * w);
                let cslice = &mut below[..nb * w];
                let bview = View::row_major(&above[blk0 * w..], w);
                kf64::gemm_core_viewb(
                    cslice,
                    w,
                    0,
                    lv.offset(rb, blk0),
                    0,
                    nb,
                    rb - blk0,
                    blk0, // absolute chunk phase: chains independent of band width
                    bview,
                    w,
                    true,
                );
            }
            // forward substitution within the diagonal block
            for i in rb..rb + nb {
                let lrow = l.row(i);
                let (xa, xb) = buf.split_at_mut(i * w);
                let xi = &mut xb[..w];
                for t in rb..i {
                    let c = lrow[t];
                    if c == 0.0 {
                        continue;
                    }
                    let xt = &xa[t * w..(t + 1) * w];
                    for j in 0..w {
                        xi[j] -= c * xt[j];
                    }
                }
                let d = lrow[i];
                for v in xi.iter_mut() {
                    *v /= d;
                }
            }
            rb += nb;
        }
        slot[0] = buf;
    });
    for (bi, buf) in bands.iter().enumerate() {
        let c0 = bi * cols_per;
        let w = cols_per.min(n - c0);
        for j in 0..w {
            for i in c0 + j..n {
                *inv.at_mut(i, c0 + j) = buf[i * w + j];
            }
        }
    }
    inv
}

/// Seed column-parallel triangular inverse: column `j` of `L⁻¹` is the
/// forward-substitution solve of `L·x = e_j`, which only touches
/// indices `≥ j` (total n³/6 flops). Naive reference for
/// [`lower_tri_inverse`].
pub fn lower_tri_inverse_naive(l: &MatF64) -> MatF64 {
    let n = l.rows;
    let mut inv = MatF64::zeros(n, n);
    let eng = crate::engine::global();
    let cols_per = eng.chunk(n);
    let n_bands = n.div_ceil(cols_per.max(1));
    let mut bands: Vec<Vec<Vec<f64>>> = vec![Vec::new(); n_bands];
    eng.for_each_band(&mut bands, 1, |bi, slot| {
        let j0 = bi * cols_per;
        let jend = (j0 + cols_per).min(n);
        let mut cols = Vec::with_capacity(jend - j0);
        for j in j0..jend {
            let mut x = vec![0.0f64; n];
            x[j] = 1.0 / l.at(j, j);
            for i in j + 1..n {
                let li = l.row(i);
                let mut sum = 0.0;
                for (k, &xk) in x.iter().enumerate().take(i).skip(j) {
                    sum += li[k] * xk;
                }
                x[i] = -sum / li[i];
            }
            cols.push(x);
        }
        slot[0] = cols;
    });
    for (bi, cols) in bands.into_iter().enumerate() {
        let j0 = bi * cols_per;
        for (dj, col) in cols.into_iter().enumerate() {
            let j = j0 + dj;
            for i in j..n {
                *inv.at_mut(i, j) = col[i];
            }
        }
    }
    inv
}

/// Solve `U·X = RHS` for upper-triangular `U` (s×s) against an s×n
/// right-hand-side matrix: blocked TRSM, column-banded on the engine.
/// Row blocks are processed bottom-up — back-substitution sweeps inside
/// the diagonal block, packed GEMM updates for the already-solved
/// blocks below.
pub fn upper_tri_solve_many(u: &MatF64, rhs: &MatF64) -> MatF64 {
    let s = u.rows;
    assert_eq!(u.cols, s);
    assert_eq!(rhs.rows, s);
    if kernel::naive_mode() || s < TRSM_MIN_S {
        return upper_tri_solve_many_naive(u, rhs);
    }
    let n = rhs.cols;
    let mut x = MatF64::zeros(s, n);
    if n == 0 {
        return x;
    }
    let eng = crate::engine::global();
    let cols_per = eng.chunk(n);
    let n_bands = n.div_ceil(cols_per.max(1));
    let mut bands: Vec<Vec<f64>> = vec![Vec::new(); n_bands];
    let uv = View::row_major(&u.data, s);
    let n_blocks = s.div_ceil(NB);
    eng.for_each_band(&mut bands, 1, |bi, slot| {
        let c0 = bi * cols_per;
        let w = cols_per.min(n - c0);
        let mut buf = vec![0.0f64; s * w];
        for i in 0..s {
            buf[i * w..(i + 1) * w].copy_from_slice(&rhs.row(i)[c0..c0 + w]);
        }
        for blk in (0..n_blocks).rev() {
            let b0 = blk * NB;
            let b1 = (b0 + NB).min(s);
            if b1 < s {
                // C[b0..b1) −= U[b0..b1, b1..s] · X[b1..s, band]
                let (head, tail) = buf.split_at_mut(b1 * w);
                let cslice = &mut head[b0 * w..];
                let bview = View::row_major(tail, w);
                kf64::gemm_core_viewb(
                    cslice,
                    w,
                    0,
                    uv.offset(b0, b1),
                    0,
                    b1 - b0,
                    s - b1,
                    b1, // absolute chunk phase (same for every band)
                    bview,
                    w,
                    true,
                );
            }
            // back substitution within the diagonal block
            for i in (b0..b1).rev() {
                let urow = u.row(i);
                let (xa, xb) = buf.split_at_mut((i + 1) * w);
                let xi = &mut xa[i * w..];
                for t in i + 1..b1 {
                    let c = urow[t];
                    if c == 0.0 {
                        continue;
                    }
                    let xt = &xb[(t - (i + 1)) * w..(t - i) * w];
                    for j in 0..w {
                        xi[j] -= c * xt[j];
                    }
                }
                let d = urow[i];
                for v in xi.iter_mut() {
                    *v /= d;
                }
            }
        }
        slot[0] = buf;
    });
    for (bi, buf) in bands.iter().enumerate() {
        let c0 = bi * cols_per;
        let w = cols_per.min(n - c0);
        for i in 0..s {
            for j in 0..w {
                *x.at_mut(i, c0 + j) = buf[i * w + j];
            }
        }
    }
    x
}

/// Seed column-parallel back substitution: naive reference for
/// [`upper_tri_solve_many`].
pub fn upper_tri_solve_many_naive(u: &MatF64, rhs: &MatF64) -> MatF64 {
    let s = u.rows;
    assert_eq!(u.cols, s);
    assert_eq!(rhs.rows, s);
    let n = rhs.cols;
    let mut x = MatF64::zeros(s, n);
    let eng = crate::engine::global();
    let cols_per = eng.chunk(n);
    let n_bands = n.div_ceil(cols_per.max(1));
    let mut bands: Vec<Vec<Vec<f64>>> = vec![Vec::new(); n_bands];
    eng.for_each_band(&mut bands, 1, |bi, slot| {
        let j0 = bi * cols_per;
        let jend = (j0 + cols_per).min(n);
        let mut cols = Vec::with_capacity(jend - j0);
        for j in j0..jend {
            let mut col = vec![0.0f64; s];
            for i in (0..s).rev() {
                let urow = u.row(i);
                let mut sum = rhs.at(i, j);
                for (k, &ck) in col.iter().enumerate().skip(i + 1) {
                    sum -= urow[k] * ck;
                }
                col[i] = sum / urow[i];
            }
            cols.push(col);
        }
        slot[0] = cols;
    });
    for (bi, cols) in bands.into_iter().enumerate() {
        let j0 = bi * cols_per;
        for (dj, col) in cols.into_iter().enumerate() {
            for i in 0..s {
                *x.at_mut(i, j0 + dj) = col[i];
            }
        }
    }
    x
}

/// Upper-triangular `U` with `A⁻¹ = Uᵀ·U`, computed WITHOUT forming
/// `A⁻¹`: with `J` the index-reversal permutation and
/// `M = J·A·J = Lₘ·Lₘᵀ`, one has `A⁻¹ = J·Lₘ⁻ᵀ·Lₘ⁻¹·J = UᵀU` for
/// `U = J·Lₘ⁻¹·J` (upper triangular). Cost ≈ n³/3 (cholesky) + n³/6
/// (triangular inverse), vs ≈ 2.7·n³ for the naive
/// chol→full-inverse→chol chain — the §Perf-L3 optimization that makes
/// SparseGPT/Thanos feasible at OPT layer shapes on CPU.
pub fn inverse_factor_upper(a: &MatF64) -> Result<MatF64> {
    let n = a.rows;
    let m = MatF64::from_fn(n, n, |i, j| a.at(n - 1 - i, n - 1 - j));
    let lm = cholesky(&m)?;
    let linv = lower_tri_inverse(&lm);
    Ok(MatF64::from_fn(n, n, |i, j| linv.at(n - 1 - i, n - 1 - j)))
}

/// Solve `L·y = b` (forward substitution), `L` lower triangular.
pub fn solve_lower(l: &MatF64, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        let lrow = l.row(i);
        for k in 0..i {
            sum -= lrow[k] * y[k];
        }
        y[i] = sum / lrow[i];
    }
    y
}

/// Solve `Lᵀ·x = y` (backward substitution), `L` lower triangular.
pub fn solve_lower_t(l: &MatF64, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(y.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l.at(k, i) * x[k];
        }
        x[i] = sum / l.at(i, i);
    }
    x
}

/// Solve `A·x = b` given the Cholesky factor of `A`.
pub fn chol_solve(l: &MatF64, b: &[f64]) -> Vec<f64> {
    solve_lower_t(l, &solve_lower(l, b))
}

/// Allocation-free [`chol_solve`]: forward substitution into `y`, back
/// substitution into `x` (both resized in place). Exactly the same
/// arithmetic as [`solve_lower`] + [`solve_lower_t`], so results are
/// bit-identical — the buffer-reuse variant the per-row Thanos solves
/// use through `batched::RowSolveScratch`.
pub fn chol_solve_into(l: &MatF64, b: &[f64], y: &mut Vec<f64>, x: &mut Vec<f64>) {
    let n = l.rows;
    assert_eq!(b.len(), n);
    y.clear();
    y.resize(n, 0.0);
    for i in 0..n {
        let mut sum = b[i];
        let lrow = l.row(i);
        for k in 0..i {
            sum -= lrow[k] * y[k];
        }
        y[i] = sum / lrow[i];
    }
    x.clear();
    x.resize(n, 0.0);
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l.at(k, i) * x[k];
        }
        x[i] = sum / l.at(i, i);
    }
}

/// Full inverse of a symmetric PD matrix via Cholesky. The n identity
/// columns are independent solves, so they are fanned out across
/// threads (the dominant 2n³ of the ~2.3n³ total cost parallelizes).
pub fn chol_inverse(a: &MatF64) -> Result<MatF64> {
    let n = a.rows;
    let l = cholesky(a)?;
    let mut inv = MatF64::zeros(n, n);
    let eng = crate::engine::global();
    let cols_per = eng.chunk(n);
    let n_bands = n.div_ceil(cols_per.max(1));
    // collect per-band column groups, then transpose into `inv`
    let l_ref = &l;
    let mut bands: Vec<Vec<Vec<f64>>> = vec![Vec::new(); n_bands];
    eng.for_each_band(&mut bands, 1, |bi, slot| {
        let j0 = bi * cols_per;
        let jend = (j0 + cols_per).min(n);
        let mut cols = Vec::with_capacity(jend - j0);
        let mut e = vec![0.0f64; n];
        for j in j0..jend {
            e[j] = 1.0;
            cols.push(chol_solve(l_ref, &e));
            e[j] = 0.0;
        }
        slot[0] = cols;
    });
    for (bi, cols) in bands.into_iter().enumerate() {
        let j0 = bi * cols_per;
        for (dj, col) in cols.into_iter().enumerate() {
            let j = j0 + dj;
            for i in 0..n {
                *inv.at_mut(i, j) = col[i];
            }
        }
    }
    // symmetrize to remove round-off asymmetry — downstream code relies
    // on Hinv being exactly symmetric (principal submatrices → Cholesky).
    for i in 0..n {
        for j in 0..i {
            let v = 0.5 * (inv.at(i, j) + inv.at(j, i));
            *inv.at_mut(i, j) = v;
            *inv.at_mut(j, i) = v;
        }
    }
    Ok(inv)
}

/// General square solve `A·x = b` via LU with partial pivoting.
/// Used where symmetry is not guaranteed (padded batched systems of
/// §H.1 mix identity rows into `R̂′`).
pub fn lu_solve(a: &MatF64, b: &[f64]) -> Result<Vec<f64>> {
    let n = a.rows;
    assert_eq!(a.cols, n);
    assert_eq!(b.len(), n);
    let mut lu = a.clone();
    let mut x: Vec<f64> = b.to_vec();
    let mut piv: Vec<usize> = (0..n).collect();
    for k in 0..n {
        // pivot
        let mut pmax = lu.at(k, k).abs();
        let mut prow = k;
        for i in k + 1..n {
            let v = lu.at(i, k).abs();
            if v > pmax {
                pmax = v;
                prow = i;
            }
        }
        if pmax == 0.0 || !pmax.is_finite() {
            bail!("singular matrix in lu_solve at column {k}");
        }
        if prow != k {
            piv.swap(k, prow);
            for j in 0..n {
                let t = lu.at(k, j);
                *lu.at_mut(k, j) = lu.at(prow, j);
                *lu.at_mut(prow, j) = t;
            }
            x.swap(k, prow);
        }
        let pivot = lu.at(k, k);
        for i in k + 1..n {
            let f = lu.at(i, k) / pivot;
            *lu.at_mut(i, k) = f;
            if f != 0.0 {
                for j in k + 1..n {
                    let v = lu.at(k, j);
                    *lu.at_mut(i, j) -= f * v;
                }
                x[i] -= f * x[k];
            }
        }
    }
    // back substitution
    for i in (0..n).rev() {
        let mut sum = x[i];
        for j in i + 1..n {
            sum -= lu.at(i, j) * x[j];
        }
        x[i] = sum / lu.at(i, i);
    }
    Ok(x)
}

/// Add the standard SparseGPT-style damping `λ·I` with
/// `λ = percdamp · mean(diag(H))`, and replace zero diagonal entries
/// (dead input channels) with 1 so `H` stays invertible — mirroring the
/// reference implementations of SparseGPT/Wanda.
pub fn damp_hessian(h: &mut MatF64, percdamp: f64) {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    let mut trace = 0.0;
    for i in 0..n {
        trace += h.at(i, i);
    }
    let lambda = percdamp * (trace / n as f64).max(f64::MIN_POSITIVE);
    for i in 0..n {
        let d = h.at(i, i);
        if d == 0.0 {
            *h.at_mut(i, i) = 1.0;
        } else {
            *h.at_mut(i, i) = d + lambda;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_f64;
    use crate::linalg::Mat;
    use crate::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> MatF64 {
        let mut r = Rng::new(seed);
        let x = Mat::from_fn(n, n + 3, |_, _| r.normal_f32(0.0, 1.0));
        let mut h = crate::linalg::gemm::xxt_f64(&x);
        damp_hessian(&mut h, 0.01);
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(12, 1);
        let l = cholesky(&a).unwrap();
        let rec = matmul_f64(&l, &l.transpose());
        assert!(a.max_abs_diff(&rec) < 1e-9);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = MatF64::eye(3);
        *a.at_mut(2, 2) = -1.0;
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn blocked_cholesky_rejects_indefinite_large() {
        // indefiniteness deep in the trailing submatrix must surface
        // through the blocked path too
        let mut a = random_spd(200, 31);
        *a.at_mut(170, 170) = -5.0;
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn chol_solve_solves() {
        let a = random_spd(20, 2);
        let mut r = Rng::new(3);
        let b: Vec<f64> = (0..20).map(|_| r.normal()).collect();
        let l = cholesky(&a).unwrap();
        let x = chol_solve(&l, &b);
        // residual check
        for i in 0..20 {
            let ax: f64 = (0..20).map(|j| a.at(i, j) * x[j]).sum();
            assert!((ax - b[i]).abs() < 1e-8, "row {i}");
        }
    }

    #[test]
    fn chol_inverse_is_inverse() {
        let a = random_spd(15, 4);
        let inv = chol_inverse(&a).unwrap();
        let prod = matmul_f64(&a, &inv);
        let eye = MatF64::eye(15);
        assert!(prod.max_abs_diff(&eye) < 1e-8);
    }

    #[test]
    fn chol_inverse_symmetric() {
        let a = random_spd(10, 5);
        let inv = chol_inverse(&a).unwrap();
        assert!(inv.max_abs_diff(&inv.transpose()) == 0.0);
    }

    #[test]
    fn lower_tri_inverse_inverts() {
        let a = random_spd(20, 8);
        let l = cholesky(&a).unwrap();
        let linv = lower_tri_inverse(&l);
        let prod = matmul_f64(&l, &linv);
        assert!(prod.max_abs_diff(&MatF64::eye(20)) < 1e-9);
        // strictly lower triangular result
        for i in 0..20 {
            for j in i + 1..20 {
                assert_eq!(linv.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn blocked_lower_tri_inverse_matches_naive() {
        // large enough for the blocked TRSM path (n >= TRSM_MIN_S)
        let a = random_spd(150, 33);
        let l = cholesky(&a).unwrap();
        let blocked = lower_tri_inverse(&l);
        let naive = lower_tri_inverse_naive(&l);
        assert!(blocked.max_abs_diff(&naive) < 1e-9);
        for i in 0..150 {
            for j in i + 1..150 {
                assert_eq!(blocked.at(i, j), 0.0, "({i},{j})");
            }
        }
        let prod = matmul_f64(&l, &blocked);
        assert!(prod.max_abs_diff(&MatF64::eye(150)) < 1e-8);
    }

    #[test]
    fn blocked_upper_tri_solve_matches_naive() {
        let a = random_spd(140, 34);
        let u = inverse_factor_upper(&a).unwrap();
        let mut r = Rng::new(35);
        let rhs = MatF64::from_fn(140, 90, |_, _| r.normal());
        let blocked = upper_tri_solve_many(&u, &rhs);
        let naive = upper_tri_solve_many_naive(&u, &rhs);
        assert!(blocked.max_abs_diff(&naive) < 1e-8);
    }

    #[test]
    fn inverse_factor_upper_identity() {
        let a = random_spd(24, 9);
        let u = inverse_factor_upper(&a).unwrap();
        // upper triangular
        for i in 0..24 {
            for j in 0..i {
                assert_eq!(u.at(i, j), 0.0, "({i},{j})");
            }
        }
        // U^T U == A^{-1}  (check A · U^T U == I)
        let utu = matmul_f64(&u.transpose(), &u);
        let prod = matmul_f64(&a, &utu);
        assert!(prod.max_abs_diff(&MatF64::eye(24)) < 1e-8);
        // must agree with the naive chain
        let naive = cholesky(&chol_inverse(&a).unwrap()).unwrap().transpose();
        let utu2 = matmul_f64(&naive.transpose(), &naive);
        assert!(utu.max_abs_diff(&utu2) < 1e-8);
    }

    #[test]
    fn parallel_cholesky_matches_large() {
        // exercise the threaded blocked path (n > PAR_MIN)
        let a = random_spd(300, 10);
        let l = cholesky(&a).unwrap();
        let rec = matmul_f64(&l, &l.transpose());
        assert!(a.max_abs_diff(&rec) < 1e-7);
    }

    #[test]
    fn blocked_cholesky_matches_naive_reference() {
        let a = random_spd(220, 36);
        let l = cholesky(&a).unwrap();
        let mut m = a.clone();
        cholesky_naive_in_place(&mut m).unwrap();
        assert!(l.max_abs_diff(&m) < 1e-9, "blocked vs seed factor");
    }

    #[test]
    fn cholesky_in_place_matches_cholesky() {
        let a = random_spd(40, 21);
        let l = cholesky(&a).unwrap();
        let mut m = a.clone();
        cholesky_in_place(&mut m).unwrap();
        assert_eq!(l.data, m.data, "in-place factor must be bit-identical");
    }

    #[test]
    fn small_systems_keep_seed_arithmetic() {
        // n <= NB must reproduce the seed factor bit-for-bit: the
        // thousands of per-row Thanos systems rely on it
        let a = random_spd(64, 37);
        let mut blocked = a.clone();
        cholesky_in_place(&mut blocked).unwrap();
        let mut seed = a.clone();
        cholesky_naive_in_place(&mut seed).unwrap();
        assert_eq!(blocked.data, seed.data);
    }

    #[test]
    fn chol_solve_into_matches_chol_solve() {
        let a = random_spd(18, 22);
        let l = cholesky(&a).unwrap();
        let mut r = Rng::new(23);
        let b: Vec<f64> = (0..18).map(|_| r.normal()).collect();
        let direct = chol_solve(&l, &b);
        let mut y = Vec::new();
        let mut x = Vec::new();
        chol_solve_into(&l, &b, &mut y, &mut x);
        assert_eq!(direct, x, "scratch solve must be bit-identical");
    }

    #[test]
    fn lu_solve_matches_chol_solve_on_spd() {
        let a = random_spd(16, 6);
        let mut r = Rng::new(7);
        let b: Vec<f64> = (0..16).map(|_| r.normal()).collect();
        let l = cholesky(&a).unwrap();
        let x1 = chol_solve(&l, &b);
        let x2 = lu_solve(&a, &b).unwrap();
        for i in 0..16 {
            assert!((x1[i] - x2[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn lu_solve_handles_permutation_needs() {
        // leading zero pivot forces row exchange
        let a = MatF64::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let x = lu_solve(&a, &[3.0, 5.0]).unwrap();
        assert!((x[0] - 5.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn lu_solve_rejects_singular() {
        let a = MatF64::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(lu_solve(&a, &[1.0, 1.0]).is_err());
    }

    #[test]
    fn damp_hessian_fixes_dead_channels() {
        let mut h = MatF64::zeros(3, 3);
        *h.at_mut(0, 0) = 2.0;
        damp_hessian(&mut h, 0.01);
        assert!(h.at(1, 1) == 1.0 && h.at(2, 2) == 1.0);
        assert!(h.at(0, 0) > 2.0);
        assert!(cholesky(&h).is_ok());
    }
}
