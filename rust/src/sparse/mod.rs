//! Compressed weight formats + real sparse kernels — the subsystem
//! that turns pruning masks into *measured* storage and wall-clock
//! wins instead of the modeled figure the repo used to report
//! (DESIGN.md §Sparse, §Substitutions).
//!
//! Layers:
//!
//! * [`formats`] — [`NmPacked`] (n:m, bit-packed indices + dense
//!   outlier rows), [`Csr`] (unstructured), [`DenseCompact`]
//!   (structured column removal), each with **bitwise-exact**
//!   `from_dense`/`to_dense` round-trips and its own serialized form
//!   (checkpoint format v2, `model::ModelState::save_compressed`).
//! * [`kernels`] — sparse×dense matvec/GEMM per format, row-banded on
//!   the shared [`crate::engine`] pool with per-worker decode scratch,
//!   cross-validated against `linalg::gemm`.
//! * [`SparseModel`] — the per-layer compressed tensors of a pruned
//!   [`crate::model::ModelState`], chosen by the pruning
//!   [`Pattern`] (n:m → `NmPacked`, unstructured → `Csr`, structured →
//!   `DenseCompact`), emitted by the coordinator's
//!   [`crate::coordinator::PruneReport::sparse_model`].
//! * [`bench`] — the measured dense-vs-sparse sweep shared by the
//!   `sparse_matmul` bench binary and the `thanos sparse-bench` CLI.
//!
//! Byte accounting here is the single source of truth:
//! [`crate::pruning::nm::compressed_bytes`] delegates to [`nm_bytes`].

pub mod bench;
pub mod formats;
pub mod kernels;

pub use formats::{nm_tail_error, Csr, DenseCompact, NmPacked};

use crate::linalg::Mat;
use crate::model::ModelState;
use crate::pruning::Pattern;
use anyhow::{bail, ensure, Context, Result};

// ---------------------------------------------------------------------------
// byte accounting (single source of truth; `pruning::nm` delegates here)
// ---------------------------------------------------------------------------

/// Metadata bits per kept weight of an n:m group: the NVIDIA sparse
/// tensor-core layouts (2 bits for 2:4, 3 bits for 4:8 — Ampere
/// whitepaper, 2020) and `⌈log2 m⌉` positional bits in general, which
/// the NVIDIA cases are instances of.
pub fn nm_index_bits(n: usize, m: usize) -> usize {
    match (n, m) {
        (2, 4) => 2,
        (4, 8) => 3,
        _ => (usize::BITS - (m.max(1) - 1).leading_zeros()) as usize,
    }
}

/// Storage of an n:m compressed `c×b` layer in bytes: kept values at
/// `bytes_per_weight` each, [`nm_index_bits`] metadata bits per kept
/// value, plus `outlier_rows` dense rows (values + a u32 row id each).
pub fn nm_bytes(
    c: usize,
    b: usize,
    n: usize,
    m: usize,
    outlier_rows: usize,
    bytes_per_weight: usize,
) -> usize {
    let packed_rows = c - outlier_rows.min(c);
    let kept = packed_rows * (b / m.max(1)) * (m - n.min(m));
    kept * bytes_per_weight
        + (kept * nm_index_bits(n, m)).div_ceil(8)
        + outlier_rows.min(c) * (b * bytes_per_weight + 4)
}

/// Maximum elementwise |a − b| divided by max(1, ‖reference‖∞) — the
/// relative-error readout the kernel cross-validation uses.
pub fn max_rel_err(a: &Mat, reference: &Mat) -> f64 {
    assert_eq!((a.rows, a.cols), (reference.rows, reference.cols));
    let scale = reference
        .data
        .iter()
        .fold(1.0f32, |s, &v| s.max(v.abs())) as f64;
    a.data
        .iter()
        .zip(&reference.data)
        .map(|(&x, &y)| ((x as f64) - (y as f64)).abs())
        .fold(0.0, f64::max)
        / scale
}

// ---------------------------------------------------------------------------
// SparseTensor — the format union
// ---------------------------------------------------------------------------

/// One compressed layer in whichever format fits its sparsity pattern.
#[derive(Clone, Debug, PartialEq)]
pub enum SparseTensor {
    Nm(NmPacked),
    Csr(Csr),
    DenseCompact(DenseCompact),
}

impl SparseTensor {
    pub fn rows(&self) -> usize {
        match self {
            SparseTensor::Nm(t) => t.rows,
            SparseTensor::Csr(t) => t.rows,
            SparseTensor::DenseCompact(t) => t.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            SparseTensor::Nm(t) => t.cols,
            SparseTensor::Csr(t) => t.cols,
            SparseTensor::DenseCompact(t) => t.cols,
        }
    }

    /// Exact (bitwise) dense reconstruction.
    pub fn to_dense(&self) -> Mat {
        match self {
            SparseTensor::Nm(t) => t.to_dense(),
            SparseTensor::Csr(t) => t.to_dense(),
            SparseTensor::DenseCompact(t) => t.to_dense(),
        }
    }

    /// Actual compressed storage footprint in bytes.
    pub fn bytes(&self) -> usize {
        match self {
            SparseTensor::Nm(t) => t.bytes(),
            SparseTensor::Csr(t) => t.bytes(),
            SparseTensor::DenseCompact(t) => t.bytes(),
        }
    }

    /// Short human label, e.g. `nm(2:4)`, `csr`, `dense-compact`.
    pub fn label(&self) -> String {
        match self {
            SparseTensor::Nm(t) => format!("nm({}:{})", t.n, t.m),
            SparseTensor::Csr(_) => "csr".to_string(),
            SparseTensor::DenseCompact(_) => "dense-compact".to_string(),
        }
    }

    /// `out = self · x` through the format's kernel ([`kernels`]).
    pub fn matmul_into(&self, x: &Mat, out: &mut Mat) {
        kernels::matmul_into(self, x, out);
    }

    pub fn matmul(&self, x: &Mat) -> Mat {
        kernels::matmul(self, x)
    }

    /// Serialize (tag byte + format payload; checkpoint v2 segment).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            SparseTensor::Nm(t) => {
                out.push(1u8);
                t.write_bytes(&mut out);
            }
            SparseTensor::Csr(t) => {
                out.push(2u8);
                t.write_bytes(&mut out);
            }
            SparseTensor::DenseCompact(t) => {
                out.push(3u8);
                t.write_bytes(&mut out);
            }
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<SparseTensor> {
        let mut r = formats::ByteReader::new(b);
        let t = match r.u8()? {
            1 => SparseTensor::Nm(NmPacked::read_bytes(&mut r)?),
            2 => SparseTensor::Csr(Csr::read_bytes(&mut r)?),
            3 => SparseTensor::DenseCompact(DenseCompact::read_bytes(&mut r)?),
            tag => bail!("unknown sparse tensor tag {tag}"),
        };
        r.finish()?;
        Ok(t)
    }

    /// Decode a serialized tensor delivered as consecutive pieces (the
    /// streamed v3 checkpoint loader feeds CRC-verified section chunks).
    /// `total_len` is the declared blob length from the section table;
    /// a piece stream that doesn't reassemble to exactly that length is
    /// rejected before any decoding happens.
    pub fn from_chunks<'a>(
        chunks: impl IntoIterator<Item = &'a [u8]>,
        total_len: usize,
    ) -> Result<SparseTensor> {
        let mut blob = Vec::with_capacity(total_len);
        for piece in chunks {
            blob.extend_from_slice(piece);
            ensure!(
                blob.len() <= total_len,
                "sparse blob chunks overrun the declared {total_len} bytes"
            );
        }
        ensure!(
            blob.len() == total_len,
            "sparse blob chunks reassemble to {} of {total_len} declared bytes",
            blob.len()
        );
        SparseTensor::from_bytes(&blob)
    }
}

/// Compress one pruned weight matrix in the format its pruning pattern
/// targets: n:m → [`NmPacked`], unstructured → [`Csr`], structured →
/// [`DenseCompact`]. Rows that violate the n:m/structured pattern
/// (α>0 outlier rows) are detected from the data and stored dense.
pub fn compress_mat(w: &Mat, pattern: &Pattern) -> Result<SparseTensor> {
    Ok(match *pattern {
        Pattern::SemiStructured { n, m, .. } => SparseTensor::Nm(NmPacked::from_dense(w, n, m)?),
        Pattern::Unstructured { .. } => SparseTensor::Csr(Csr::from_dense(w)),
        Pattern::Structured { .. } => SparseTensor::DenseCompact(DenseCompact::from_dense(w)),
    })
}

// ---------------------------------------------------------------------------
// SparseModel — per-layer compressed tensors of a pruned model
// ---------------------------------------------------------------------------

/// One compressed prunable layer.
#[derive(Clone, Debug)]
pub struct SparseLayer {
    pub name: String,
    pub tensor: SparseTensor,
}

/// The compressed form of every prunable layer of a pruned model —
/// what checkpoint format v2 serializes and the sparse kernels serve.
#[derive(Clone, Debug, Default)]
pub struct SparseModel {
    pub layers: Vec<SparseLayer>,
}

impl SparseModel {
    /// Compress every prunable layer of `state` per `pattern`.
    pub fn compress_state(state: &ModelState, pattern: &Pattern) -> Result<SparseModel> {
        let mut layers = Vec::new();
        for l in 0..state.config.n_layers {
            for name in state.prunable_layers(l) {
                let w = state.get_mat(&name)?;
                let tensor = compress_mat(&w, pattern)
                    .with_context(|| format!("compressing layer {name}"))?;
                layers.push(SparseLayer { name, tensor });
            }
        }
        Ok(SparseModel { layers })
    }

    /// The `(input, output)` dimensions of chaining every layer in
    /// stored order (the serving forward pass). Errors if the model is
    /// empty or any consecutive pair of layers disagrees on its shared
    /// dimension — the validation gate both `Server::start` and the
    /// hot-reload path run before accepting a model.
    pub fn chain_dims(&self) -> Result<(usize, usize)> {
        let first = self.layers.first().context("sparse model has no layers")?;
        let mut rows = first.tensor.rows();
        for (prev, next) in self.layers.iter().zip(&self.layers[1..]) {
            ensure!(
                next.tensor.cols() == rows,
                "layer {} expects input dim {}, but {} produces {}",
                next.name,
                next.tensor.cols(),
                prev.name,
                rows
            );
            rows = next.tensor.rows();
        }
        Ok((first.tensor.cols(), rows))
    }

    /// Batched forward pass: chain each column of `x` through every
    /// layer in order via [`kernels::forward_chain`]. Allocating
    /// convenience for tests and oracles; the serving batcher holds a
    /// persistent [`kernels::ForwardScratch`] instead.
    pub fn forward_batch(&self, x: &Mat) -> Result<Mat> {
        let (d_in, d_out) = self.chain_dims()?;
        ensure!(
            x.rows == d_in,
            "forward_batch input dim {} != model input dim {d_in}",
            x.rows
        );
        if x.cols == 0 {
            return Ok(Mat::zeros(d_out, 0));
        }
        let layers: Vec<&SparseTensor> = self.layers.iter().map(|l| &l.tensor).collect();
        let mut scratch = kernels::ForwardScratch::new();
        Ok(kernels::forward_chain(&layers, x, &mut scratch).clone())
    }

    pub fn get(&self, name: &str) -> Option<&SparseTensor> {
        self.layers
            .iter()
            .find(|l| l.name == name)
            .map(|l| &l.tensor)
    }

    /// Dense f32 bytes of the covered layers.
    pub fn dense_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.tensor.rows() * l.tensor.cols() * 4)
            .sum()
    }

    /// Actual compressed bytes of the covered layers.
    pub fn compressed_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.tensor.bytes()).sum()
    }

    /// Check every compressed layer reconstructs the state's weights
    /// **bitwise** — the invariant checkpoint v2 relies on.
    pub fn verify_roundtrip(&self, state: &ModelState) -> Result<()> {
        for l in &self.layers {
            let w = state.get_mat(&l.name)?;
            ensure!(
                (l.tensor.rows(), l.tensor.cols()) == (w.rows, w.cols),
                "layer {}: compressed shape {}x{} vs dense {}x{}",
                l.name,
                l.tensor.rows(),
                l.tensor.cols(),
                w.rows,
                w.cols
            );
            let back = l.tensor.to_dense();
            let identical = back
                .data
                .iter()
                .zip(&w.data)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            ensure!(identical, "layer {}: round-trip not bit-identical", l.name);
        }
        Ok(())
    }

    /// One-line byte summary.
    pub fn summary(&self) -> String {
        let dense = self.dense_bytes();
        let comp = self.compressed_bytes();
        format!(
            "{} layers compressed: {:.2} MiB -> {:.2} MiB ({:.1}% of dense f32)",
            self.layers.len(),
            dense as f64 / (1 << 20) as f64,
            comp as f64 / (1 << 20) as f64,
            if dense > 0 { 100.0 * comp as f64 / dense as f64 } else { 0.0 },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn nm_index_bits_general_matches_nvidia_cases() {
        // the NVIDIA 2:4/4:8 metadata widths ARE ⌈log2 m⌉ positional bits
        assert_eq!(nm_index_bits(2, 4), 2);
        assert_eq!(nm_index_bits(4, 8), 3);
        assert_eq!(nm_index_bits(1, 4), 2);
        assert_eq!(nm_index_bits(3, 8), 3);
        assert_eq!(nm_index_bits(1, 2), 1);
        assert_eq!(nm_index_bits(0, 1), 0);
        assert_eq!(nm_index_bits(8, 16), 4);
    }

    #[test]
    fn nm_bytes_matches_packed_instance() {
        // the accounting formula must equal the real packer's footprint
        // at f32 width, outliers included
        let mut r = Rng::new(41);
        let (c, b) = (12, 24);
        let mut w = Mat::from_fn(c, b, |_, _| r.normal_f32(0.0, 1.0));
        for i in 0..c - 2 {
            for g in (0..b).step_by(4) {
                w.row_mut(i)[g] = 0.0;
                w.row_mut(i)[g + 3] = 0.0;
            }
        }
        let t = NmPacked::from_dense(&w, 2, 4).unwrap();
        assert_eq!(t.outlier_rows.len(), 2);
        assert_eq!(t.bytes(), nm_bytes(c, b, 2, 4, 2, 4));
    }

    #[test]
    fn tensor_bytes_roundtrip_through_serialization() {
        let mut r = Rng::new(42);
        let mut w = Mat::from_fn(6, 9, |_, _| r.normal_f32(0.0, 1.0));
        for (k, v) in w.data.iter_mut().enumerate() {
            if k % 2 == 0 {
                *v = 0.0;
            }
        }
        let t = SparseTensor::Csr(Csr::from_dense(&w));
        let back = SparseTensor::from_bytes(&t.to_bytes()).unwrap();
        assert_eq!(back, t);
        assert!(SparseTensor::from_bytes(&[9u8, 0, 0]).is_err());
    }

    #[test]
    fn compress_mat_picks_format_by_pattern() {
        let mut r = Rng::new(43);
        let w = Mat::from_fn(4, 8, |_, _| r.normal_f32(0.0, 1.0));
        let nm = compress_mat(
            &crate::pruning::magnitude::semi_structured(&w, 2, 4).w,
            &Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 },
        )
        .unwrap();
        assert!(matches!(nm, SparseTensor::Nm(_)));
        let csr = compress_mat(&w, &Pattern::Unstructured { p: 0.5 }).unwrap();
        assert!(matches!(csr, SparseTensor::Csr(_)));
        let dc = compress_mat(&w, &Pattern::Structured { p: 0.5, alpha: 0.0 }).unwrap();
        assert!(matches!(dc, SparseTensor::DenseCompact(_)));
    }

    #[test]
    fn chain_dims_validates_and_forward_batch_chains() {
        let mut r = Rng::new(44);
        let half_zero = |m: &mut Mat| {
            for (k, v) in m.data.iter_mut().enumerate() {
                if k % 2 == 0 {
                    *v = 0.0;
                }
            }
        };
        let mut wa = Mat::from_fn(6, 4, |_, _| r.normal_f32(0.0, 1.0));
        let mut wb = Mat::from_fn(4, 6, |_, _| r.normal_f32(0.0, 1.0));
        half_zero(&mut wa);
        half_zero(&mut wb);
        let sm = SparseModel {
            layers: vec![
                SparseLayer { name: "a".into(), tensor: SparseTensor::Csr(Csr::from_dense(&wa)) },
                SparseLayer { name: "b".into(), tensor: SparseTensor::Csr(Csr::from_dense(&wb)) },
            ],
        };
        assert_eq!(sm.chain_dims().unwrap(), (4, 4));
        let x = Mat::from_fn(4, 3, |_, _| r.normal_f32(0.0, 1.0));
        let y = sm.forward_batch(&x).unwrap();
        let want = sm.layers[1].tensor.matmul(&sm.layers[0].tensor.matmul(&x));
        assert_eq!(
            y.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // wrong input dim is an Err, not a panic
        assert!(sm.forward_batch(&Mat::zeros(5, 2)).is_err());
        // mis-chained layers are rejected up front (a: 4→6 twice)
        let bad = SparseModel { layers: vec![sm.layers[0].clone(), sm.layers[0].clone()] };
        assert!(bad.chain_dims().is_err());
        assert!(SparseModel::default().chain_dims().is_err());
    }

    #[test]
    fn max_rel_err_basics() {
        let a = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        assert_eq!(max_rel_err(&a, &b), 0.0);
        let c = Mat::from_vec(1, 2, vec![1.0, 2.2]);
        assert!((max_rel_err(&c, &b) - 0.1).abs() < 1e-6);
    }
}
