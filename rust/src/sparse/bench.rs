//! Measured dense-vs-sparse sweep shared by the `sparse_matmul` bench
//! binary and the `thanos sparse-bench` CLI path: one pruned layer per
//! (format, sparsity) case, timed against the dense GEMM on identical
//! inputs and cross-validated within 1e-5 relative error.

use super::{kernels, max_rel_err, Csr, DenseCompact, NmPacked, SparseTensor};
use crate::linalg::gemm::matmul_into;
use crate::linalg::Mat;
use crate::pruning::magnitude;
use crate::rng::Rng;
use anyhow::Result;

/// One measured case of the sweep.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub rows: usize,
    pub cols: usize,
    pub batch: usize,
    /// format + sparsity label, e.g. `csr@70%`, `nm(2:4)`
    pub case: String,
    /// exact zero fraction of the pruned dense matrix
    pub sparsity: f64,
    /// dense GEMM on the *unpruned* matrix (the serving baseline), ms
    pub dense_ms: f64,
    /// dense GEMM on the pruned matrix (zero-skipping), ms
    pub pruned_dense_ms: f64,
    /// compressed-format kernel, ms
    pub sparse_ms: f64,
    pub bytes_dense: usize,
    pub bytes_sparse: usize,
    /// kernel vs `linalg::gemm` cross-validation error
    pub max_rel_err: f64,
}

impl SweepRow {
    pub fn csv_header() -> &'static str {
        "rows,cols,batch,case,sparsity,dense_ms,pruned_dense_ms,sparse_ms,\
         speedup_vs_dense,bytes_dense,bytes_sparse,max_rel_err"
    }

    pub fn csv(&self) -> String {
        format!(
            "{},{},{},{},{:.3},{:.4},{:.4},{:.4},{:.2},{},{},{:.2e}",
            self.rows,
            self.cols,
            self.batch,
            self.case,
            self.sparsity,
            self.dense_ms,
            self.pruned_dense_ms,
            self.sparse_ms,
            self.speedup_vs_dense(),
            self.bytes_dense,
            self.bytes_sparse,
            self.max_rel_err,
        )
    }

    /// Measured speedup of the compressed kernel over the dense GEMM.
    pub fn speedup_vs_dense(&self) -> f64 {
        self.dense_ms / self.sparse_ms.max(1e-9)
    }

    pub fn pretty(&self) -> String {
        format!(
            "  {:<13} sparsity {:>5.1}%  dense {:>8.3}ms  pruned-dense {:>8.3}ms  \
             sparse {:>8.3}ms ({:>5.2}x)  bytes {:>5.1}%  err {:.1e}",
            self.case,
            self.sparsity * 100.0,
            self.dense_ms,
            self.pruned_dense_ms,
            self.sparse_ms,
            self.speedup_vs_dense(),
            100.0 * self.bytes_sparse as f64 / self.bytes_dense.max(1) as f64,
            self.max_rel_err,
        )
    }
}

/// Layer shapes the sweep drivers (`benches/sparse_matmul.rs` and
/// `thanos sparse-bench`) share, so the two entry points measure the
/// same thing.
pub fn default_shapes(quick: bool) -> &'static [(usize, usize)] {
    if quick {
        &[(256, 512)]
    } else {
        &[(768, 768), (1024, 1024), (2048, 2048)]
    }
}

/// Batch widths matching [`default_shapes`].
pub fn default_batches(quick: bool) -> &'static [usize] {
    if quick {
        &[1, 32]
    } else {
        &[1, 32, 256]
    }
}

/// Best-of-`reps` wall time of `f` after one warm-up call, seconds.
/// Shared by the sweep and by `eval::measured_format_speedup`.
pub fn best_of(reps: usize, mut f: impl FnMut()) -> f64 {
    f();
    (0..reps)
        .map(|_| {
            let t0 = crate::trace::clock::now_nanos();
            f();
            crate::trace::clock::secs_since(t0)
        })
        .fold(f64::INFINITY, f64::min)
}

fn measure_case(
    case: &str,
    w_pruned: &Mat,
    tensor: &SparseTensor,
    x: &Mat,
    dense_ms: f64,
) -> SweepRow {
    let (c, k) = (w_pruned.rows, x.cols);
    let mut out = Mat::zeros(c, k);
    let pruned_dense_ms = best_of(3, || matmul_into(w_pruned, x, &mut out)) * 1e3;
    let mut out_s = Mat::zeros(c, k);
    let sparse_ms = best_of(3, || kernels::matmul_into(tensor, x, &mut out_s)) * 1e3;
    // cross-validate against the naive-order reference: the sparse
    // kernels keep the scalar accumulation chains, while the packed
    // dense GEMM reorders sums (KC partials + FMA), so comparing
    // against `matmul_naive` keeps the 1e-5 gate a *format* check
    // rather than a summation-order check
    let out = crate::linalg::gemm::matmul_naive(w_pruned, x);
    SweepRow {
        rows: c,
        cols: w_pruned.cols,
        batch: k,
        case: case.to_string(),
        sparsity: w_pruned.sparsity(),
        dense_ms,
        pruned_dense_ms,
        sparse_ms,
        bytes_dense: c * w_pruned.cols * 4,
        bytes_sparse: tensor.bytes(),
        max_rel_err: max_rel_err(&out_s, &out),
    }
}

/// Run the full format sweep on one `c×b` layer at batch width `batch`:
/// CSR at 50/60/70% unstructured, `NmPacked` at 2:4 and 4:8 (when `b`
/// allows), and `DenseCompact` at 50/70% structured.
pub fn sweep(c: usize, b: usize, batch: usize, seed: u64) -> Result<Vec<SweepRow>> {
    let mut r = Rng::new(seed);
    let dense = Mat::from_fn(c, b, |_, _| r.normal_f32(0.0, 1.0));
    let x = Mat::from_fn(b, batch, |_, _| r.normal_f32(0.0, 1.0));
    let mut out = Mat::zeros(c, batch);
    let dense_ms = best_of(3, || matmul_into(&dense, &x, &mut out)) * 1e3;

    let mut rows = Vec::new();
    for &p in &[0.5, 0.6, 0.7] {
        let pruned = magnitude::unstructured(&dense, p).w;
        let t = SparseTensor::Csr(Csr::from_dense(&pruned));
        rows.push(measure_case(
            &format!("csr@{:.0}%", p * 100.0),
            &pruned,
            &t,
            &x,
            dense_ms,
        ));
    }
    for &(n, m) in &[(2usize, 4usize), (4, 8)] {
        if b % m != 0 {
            continue; // each n:m case only needs its own group size
        }
        let pruned = magnitude::semi_structured(&dense, n, m).w;
        let t = SparseTensor::Nm(NmPacked::from_dense(&pruned, n, m)?);
        rows.push(measure_case(&format!("nm({n}:{m})"), &pruned, &t, &x, dense_ms));
    }
    for &p in &[0.5, 0.7] {
        let pruned = magnitude::structured(&dense, p).w;
        let t = SparseTensor::DenseCompact(DenseCompact::from_dense(&pruned));
        rows.push(measure_case(
            &format!("struct@{:.0}%", p * 100.0),
            &pruned,
            &t,
            &x,
            dense_ms,
        ));
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_all_formats_and_validates() {
        let rows = sweep(24, 32, 4, 0xBEC).unwrap();
        let cases: Vec<&str> = rows.iter().map(|r| r.case.as_str()).collect();
        assert!(cases.iter().any(|c| c.starts_with("csr")));
        assert!(cases.iter().any(|c| c.starts_with("nm(2:4)")));
        assert!(cases.iter().any(|c| c.starts_with("nm(4:8)")));
        assert!(cases.iter().any(|c| c.starts_with("struct")));
        for row in &rows {
            assert!(row.max_rel_err <= 1e-5, "{}: err {}", row.case, row.max_rel_err);
            assert!(row.bytes_sparse > 0 && row.bytes_dense > 0);
            assert!(row.csv().split(',').count() == 12);
        }
        // n:m cases must actually shrink storage (50% values + indices)
        let nm = rows.iter().find(|r| r.case == "nm(2:4)").unwrap();
        assert!(nm.bytes_sparse < nm.bytes_dense);
    }
}
