//! Compressed weight-tensor formats with **exact** dense round-trips.
//!
//! Three layouts, one per sparsity pattern the pruning methods emit
//! (DESIGN.md §Sparse):
//!
//! * [`NmPacked`] — n:m semi-structured: kept values plus bit-packed
//!   in-group indices (the NVIDIA layout: 2 bits/kept for 2:4, 3 bits
//!   for 4:8 — i.e. ⌈log2 m⌉ bits in general), with dense *outlier
//!   rows* for the α>0 variants where the highest-loss rows are left
//!   unpruned.
//! * [`Csr`] — unstructured masks: classic compressed-sparse-row.
//! * [`DenseCompact`] — structured column removal: the kept columns as
//!   a compact dense matrix, again with dense outlier rows.
//!
//! Exactness contract: `to_dense(from_dense(w)) == w` **bitwise** for
//! every input. Entries are classified by `f32::to_bits() != 0`, so a
//! negative zero is treated as a kept value (and a row containing one
//! in a pruned position simply becomes an outlier row) rather than
//! being silently canonicalized — checkpoint v2 reloads depend on this.

use crate::linalg::Mat;
use anyhow::{bail, ensure, Context, Result};

/// The documented error for a column count that does not tile into
/// groups of `m` — shared verbatim by [`NmPacked::from_dense`] and
/// [`crate::pruning::nm::validate`] so the packer and the validator
/// reject tails consistently.
pub fn nm_tail_error(cols: usize, m: usize) -> String {
    format!("cols {cols} not divisible by m={m} (n:m formats do not support tail groups)")
}

// ---------------------------------------------------------------------------
// bit-stream helpers (little-endian, shared by pack / unpack / kernels)
// ---------------------------------------------------------------------------

/// Append-only little-endian bit stream writer.
pub(crate) struct BitWriter {
    buf: Vec<u8>,
    acc: u64,
    n: u32,
}

impl BitWriter {
    pub(crate) fn new() -> BitWriter {
        BitWriter { buf: Vec::new(), acc: 0, n: 0 }
    }

    pub(crate) fn push(&mut self, v: usize, bits: u32) {
        debug_assert!(bits <= 16 && (bits == 0 || (v as u64) < (1u64 << bits)));
        self.acc |= (v as u64) << self.n;
        self.n += bits;
        while self.n >= 8 {
            self.buf.push(self.acc as u8);
            self.acc >>= 8;
            self.n -= 8;
        }
    }

    pub(crate) fn finish(mut self) -> Vec<u8> {
        if self.n > 0 {
            self.buf.push(self.acc as u8);
        }
        self.buf
    }
}

/// Read `nbits ≤ 16` bits at bit offset `bit_off` from a little-endian
/// stream (a 24-bit window always covers `7 + 16` bits).
#[inline]
pub(crate) fn read_bits(buf: &[u8], bit_off: usize, nbits: u32) -> usize {
    debug_assert!(nbits <= 16);
    let byte = bit_off / 8;
    let shift = bit_off % 8;
    let mut window = 0u32;
    for k in 0..3 {
        if let Some(&b) = buf.get(byte + k) {
            window |= (b as u32) << (8 * k);
        }
    }
    ((window >> shift) & (((1u64 << nbits) - 1) as u32)) as usize
}

// ---------------------------------------------------------------------------
// byte-stream (de)serialization helpers for checkpoint v2
// ---------------------------------------------------------------------------

pub(crate) fn put_u32(out: &mut Vec<u8>, v: usize) {
    out.extend_from_slice(&(v as u32).to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: usize) {
    out.extend_from_slice(&(v as u64).to_le_bytes());
}

pub(crate) fn put_u32_slice(out: &mut Vec<u8>, s: &[u32]) {
    for &v in s {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

pub(crate) fn put_f32_slice(out: &mut Vec<u8>, s: &[f32]) {
    for &v in s {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Bounds-checked cursor over a serialized tensor blob.
pub(crate) struct ByteReader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> ByteReader<'a> {
    pub(crate) fn new(b: &'a [u8]) -> ByteReader<'a> {
        ByteReader { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // `n <= len - i` (never `i + n <= len`): corrupt length fields
        // may be near usize::MAX, and the sum would wrap in release
        ensure!(
            n <= self.b.len() - self.i,
            "truncated sparse tensor blob (need {n} bytes at offset {})",
            self.i
        );
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<usize> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]) as usize)
    }

    pub(crate) fn u64(&mut self) -> Result<usize> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]) as usize)
    }

    pub(crate) fn bytes(&mut self, n: usize) -> Result<Vec<u8>> {
        Ok(self.take(n)?.to_vec())
    }

    pub(crate) fn u32_vec(&mut self, n: usize) -> Result<Vec<u32>> {
        let nbytes = n.checked_mul(4).context("element count overflows")?;
        let s = self.take(nbytes)?;
        Ok(s.chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub(crate) fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let nbytes = n.checked_mul(4).context("element count overflows")?;
        let s = self.take(nbytes)?;
        Ok(s.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub(crate) fn finish(&self) -> Result<()> {
        ensure!(
            self.i == self.b.len(),
            "trailing bytes in sparse tensor blob ({} of {})",
            self.b.len() - self.i,
            self.b.len()
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// NmPacked
// ---------------------------------------------------------------------------

/// n:m semi-structured layer: per group of `m` consecutive weights in a
/// row, at most `m − n` are nonzero; the kept values are stored densely
/// and their in-group positions are bit-packed at
/// [`crate::sparse::nm_index_bits`] bits each. Rows that violate the
/// pattern (the α>0 outlier rows) are stored dense.
#[derive(Clone, Debug, PartialEq)]
pub struct NmPacked {
    pub rows: usize,
    pub cols: usize,
    /// zeros per group
    pub n: usize,
    /// group size
    pub m: usize,
    /// kept values: packed rows ascending × groups ascending × the
    /// `m − n` kept slots in ascending column order
    pub values: Vec<f32>,
    /// bit-packed in-group indices, one per kept value, little-endian
    pub indices: Vec<u8>,
    /// rows stored dense (ascending)
    pub outlier_rows: Vec<u32>,
    /// `outlier_rows.len() × cols` row-major dense data
    pub outlier_values: Vec<f32>,
}

impl NmPacked {
    /// Kept weights per group.
    #[inline]
    pub fn keep(&self) -> usize {
        self.m - self.n
    }

    /// Kept weights per packed row.
    #[inline]
    pub fn kept_per_row(&self) -> usize {
        (self.cols / self.m) * self.keep()
    }

    /// Metadata bits per kept weight (see [`crate::sparse::nm_index_bits`]).
    #[inline]
    pub fn index_bits(&self) -> u32 {
        super::nm_index_bits(self.n, self.m) as u32
    }

    /// Pack a dense matrix. Rows whose every `m`-group has at most
    /// `m − n` entries with nonzero bits are packed; the rest become
    /// dense outlier rows. Errors (documented, not panics): `m == 0`,
    /// `n > m`, `m > 65536`, and `cols % m != 0` ([`nm_tail_error`]).
    pub fn from_dense(w: &Mat, n: usize, m: usize) -> Result<NmPacked> {
        ensure!(m >= 1, "n:m needs m >= 1");
        ensure!(n <= m, "n:m needs n <= m (got {n}:{m})");
        ensure!(m <= 65536, "n:m group size {m} too large for 16-bit indices");
        if w.cols % m != 0 {
            bail!("{}", nm_tail_error(w.cols, m));
        }
        let keep = m - n;
        let groups = w.cols / m;
        let bits = super::nm_index_bits(n, m) as u32;

        let mut outlier_rows: Vec<u32> = Vec::new();
        let mut packed_rows: Vec<usize> = Vec::new();
        'rows: for i in 0..w.rows {
            let row = w.row(i);
            for g in 0..groups {
                let nz = row[g * m..(g + 1) * m]
                    .iter()
                    .filter(|v| v.to_bits() != 0)
                    .count();
                if nz > keep {
                    outlier_rows.push(i as u32);
                    continue 'rows;
                }
            }
            packed_rows.push(i);
        }

        let mut values = Vec::with_capacity(packed_rows.len() * groups * keep);
        let mut bw = BitWriter::new();
        let mut kept_idx: Vec<usize> = Vec::with_capacity(keep);
        for &i in &packed_rows {
            let row = w.row(i);
            for g in 0..groups {
                let grp = &row[g * m..(g + 1) * m];
                kept_idx.clear();
                kept_idx.extend((0..m).filter(|&t| grp[t].to_bits() != 0));
                // pad with zero-valued slots so every group stores
                // exactly `keep` entries (uniform per-row layout)
                for (t, v) in grp.iter().enumerate() {
                    if kept_idx.len() == keep {
                        break;
                    }
                    if v.to_bits() == 0 {
                        kept_idx.push(t);
                    }
                }
                kept_idx.sort_unstable();
                debug_assert_eq!(kept_idx.len(), keep);
                for &t in &kept_idx {
                    values.push(grp[t]);
                    bw.push(t, bits);
                }
            }
        }
        let mut outlier_values = Vec::with_capacity(outlier_rows.len() * w.cols);
        for &i in &outlier_rows {
            outlier_values.extend_from_slice(w.row(i as usize));
        }
        Ok(NmPacked {
            rows: w.rows,
            cols: w.cols,
            n,
            m,
            values,
            indices: bw.finish(),
            outlier_rows,
            outlier_values,
        })
    }

    /// Exact (bitwise) dense reconstruction.
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        let keep = self.keep();
        let kpr = self.kept_per_row();
        let bits = self.index_bits();
        let mut oi = 0usize;
        let mut p = 0usize;
        for i in 0..self.rows {
            if oi < self.outlier_rows.len() && self.outlier_rows[oi] as usize == i {
                out.row_mut(i)
                    .copy_from_slice(&self.outlier_values[oi * self.cols..(oi + 1) * self.cols]);
                oi += 1;
                continue;
            }
            let vals = &self.values[p * kpr..(p + 1) * kpr];
            let base = p * kpr * bits as usize;
            let row = out.row_mut(i);
            for (t, &v) in vals.iter().enumerate() {
                let idx = read_bits(&self.indices, base + t * bits as usize, bits);
                row[(t / keep) * self.m + idx] = v;
            }
            p += 1;
        }
        out
    }

    /// Actual storage footprint of this instance in bytes (f32 values).
    pub fn bytes(&self) -> usize {
        self.values.len() * 4
            + self.indices.len()
            + self.outlier_rows.len() * 4
            + self.outlier_values.len() * 4
    }

    pub(crate) fn write_bytes(&self, out: &mut Vec<u8>) {
        put_u32(out, self.rows);
        put_u32(out, self.cols);
        put_u32(out, self.n);
        put_u32(out, self.m);
        put_u64(out, self.values.len());
        put_f32_slice(out, &self.values);
        put_u64(out, self.indices.len());
        out.extend_from_slice(&self.indices);
        put_u32(out, self.outlier_rows.len());
        put_u32_slice(out, &self.outlier_rows);
        put_f32_slice(out, &self.outlier_values);
    }

    pub(crate) fn read_bytes(r: &mut ByteReader) -> Result<NmPacked> {
        let rows = r.u32()?;
        let cols = r.u32()?;
        let n = r.u32()?;
        let m = r.u32()?;
        ensure!(m >= 1 && n <= m, "corrupt n:m header ({n}:{m})");
        ensure!(m <= 65536, "corrupt n:m header (m {m} exceeds 16-bit indices)");
        ensure!(cols % m == 0, "corrupt n:m header (cols {cols}, m {m})");
        let nv = r.u64()?;
        let values = r.f32_vec(nv)?;
        let ni = r.u64()?;
        let indices = r.bytes(ni)?;
        let no = r.u32()?;
        ensure!(no <= rows, "corrupt n:m header (outliers {no} > rows {rows})");
        let outlier_rows = r.u32_vec(no)?;
        let outlier_values = r.f32_vec(no * cols)?;
        let t = NmPacked { rows, cols, n, m, values, indices, outlier_rows, outlier_values };
        ensure!(
            t.values.len() == (rows - no) * t.kept_per_row(),
            "n:m value count mismatch"
        );
        ensure!(
            t.indices.len() == (t.values.len() * t.index_bits() as usize).div_ceil(8),
            "n:m index bytes mismatch"
        );
        ensure!(
            t.outlier_rows.windows(2).all(|w| w[0] < w[1])
                && t.outlier_rows.iter().all(|&x| (x as usize) < rows),
            "n:m outlier rows not sorted/in range"
        );
        // validate the bit-packed index stream: every in-group index
        // must be < m and strictly increasing within its group (the
        // writer's invariant) — otherwise `to_dense` would index out of
        // bounds or silently collapse duplicate slots
        let keep = t.keep();
        let bits = t.index_bits();
        if keep > 0 {
            let mut prev = 0usize;
            for tt in 0..t.values.len() {
                let idx = read_bits(&t.indices, tt * bits as usize, bits);
                ensure!(idx < m, "n:m index {idx} out of range for m={m}");
                ensure!(
                    tt % keep == 0 || idx > prev,
                    "n:m indices not strictly increasing within a group"
                );
                prev = idx;
            }
        }
        Ok(t)
    }
}

// ---------------------------------------------------------------------------
// Csr
// ---------------------------------------------------------------------------

/// Compressed sparse row: the format for unstructured masks.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// `rows + 1` offsets into `col_idx` / `values`
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    /// Store every entry with nonzero bits (exact round-trip).
    pub fn from_dense(w: &Mat) -> Csr {
        assert!(w.cols <= u32::MAX as usize && w.data.len() <= u32::MAX as usize);
        let mut row_ptr = Vec::with_capacity(w.rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for i in 0..w.rows {
            for (j, &v) in w.row(i).iter().enumerate() {
                if v.to_bits() != 0 {
                    col_idx.push(j as u32);
                    values.push(v);
                }
            }
            row_ptr.push(values.len() as u32);
        }
        Csr { rows: w.rows, cols: w.cols, row_ptr, col_idx, values }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Exact (bitwise) dense reconstruction.
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let row = out.row_mut(i);
            for t in self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize {
                row[self.col_idx[t] as usize] = self.values[t];
            }
        }
        out
    }

    /// Actual storage footprint in bytes (f32 values, u32 indices).
    pub fn bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.col_idx.len() * 4 + self.values.len() * 4
    }

    pub(crate) fn write_bytes(&self, out: &mut Vec<u8>) {
        put_u32(out, self.rows);
        put_u32(out, self.cols);
        put_u32_slice(out, &self.row_ptr);
        put_u64(out, self.values.len());
        put_u32_slice(out, &self.col_idx);
        put_f32_slice(out, &self.values);
    }

    pub(crate) fn read_bytes(r: &mut ByteReader) -> Result<Csr> {
        let rows = r.u32()?;
        let cols = r.u32()?;
        let row_ptr = r.u32_vec(rows + 1)?;
        let nnz = r.u64()?;
        let col_idx = r.u32_vec(nnz)?;
        let values = r.f32_vec(nnz)?;
        ensure!(
            row_ptr.first() == Some(&0)
                && row_ptr.last() == Some(&(nnz as u32))
                && row_ptr.windows(2).all(|w| w[0] <= w[1]),
            "corrupt CSR row pointers"
        );
        ensure!(
            col_idx.iter().all(|&j| (j as usize) < cols),
            "CSR column index out of range"
        );
        Ok(Csr { rows, cols, row_ptr, col_idx, values })
    }
}

// ---------------------------------------------------------------------------
// DenseCompact
// ---------------------------------------------------------------------------

/// Structured column removal: the kept columns of the non-outlier rows
/// as one compact dense matrix, plus dense outlier rows (the α>0 rows
/// that keep the removed columns).
#[derive(Clone, Debug, PartialEq)]
pub struct DenseCompact {
    pub rows: usize,
    pub cols: usize,
    /// surviving original column indices (ascending)
    pub kept_cols: Vec<u32>,
    /// `(rows − outlier_rows.len()) × kept_cols.len()` row-major,
    /// packed rows in ascending original order
    pub data: Vec<f32>,
    /// rows stored dense (ascending)
    pub outlier_rows: Vec<u32>,
    /// `outlier_rows.len() × cols` row-major dense data
    pub outlier_values: Vec<f32>,
}

impl DenseCompact {
    /// Detect the shared removed-column set (the columns hitting the
    /// maximum per-column zero count) and the outlier rows that keep
    /// them. Total on every input; inputs without structured sparsity
    /// simply compress poorly (never lossily).
    pub fn from_dense(w: &Mat) -> DenseCompact {
        let (c, b) = (w.rows, w.cols);
        let mut zero_count = vec![0usize; b];
        for i in 0..c {
            for (j, v) in w.row(i).iter().enumerate() {
                if v.to_bits() == 0 {
                    zero_count[j] += 1;
                }
            }
        }
        let c_star = zero_count.iter().copied().max().unwrap_or(0);
        let removed: Vec<bool> = (0..b)
            .map(|j| c_star > 0 && zero_count[j] == c_star)
            .collect();
        let kept_cols: Vec<u32> = (0..b).filter(|&j| !removed[j]).map(|j| j as u32).collect();
        let mut outlier_rows: Vec<u32> = Vec::new();
        let mut packed: Vec<usize> = Vec::new();
        for i in 0..c {
            let keeps_removed = w
                .row(i)
                .iter()
                .enumerate()
                .any(|(j, v)| removed[j] && v.to_bits() != 0);
            if keeps_removed {
                outlier_rows.push(i as u32);
            } else {
                packed.push(i);
            }
        }
        let mut data = Vec::with_capacity(packed.len() * kept_cols.len());
        for &i in &packed {
            let row = w.row(i);
            for &j in &kept_cols {
                data.push(row[j as usize]);
            }
        }
        let mut outlier_values = Vec::with_capacity(outlier_rows.len() * b);
        for &i in &outlier_rows {
            outlier_values.extend_from_slice(w.row(i as usize));
        }
        DenseCompact { rows: c, cols: b, kept_cols, data, outlier_rows, outlier_values }
    }

    /// Exact (bitwise) dense reconstruction.
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        let kc = self.kept_cols.len();
        let mut oi = 0usize;
        let mut p = 0usize;
        for i in 0..self.rows {
            if oi < self.outlier_rows.len() && self.outlier_rows[oi] as usize == i {
                out.row_mut(i)
                    .copy_from_slice(&self.outlier_values[oi * self.cols..(oi + 1) * self.cols]);
                oi += 1;
                continue;
            }
            let src = &self.data[p * kc..(p + 1) * kc];
            let row = out.row_mut(i);
            for (t, &j) in self.kept_cols.iter().enumerate() {
                row[j as usize] = src[t];
            }
            p += 1;
        }
        out
    }

    /// Actual storage footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.kept_cols.len() * 4
            + self.data.len() * 4
            + self.outlier_rows.len() * 4
            + self.outlier_values.len() * 4
    }

    pub(crate) fn write_bytes(&self, out: &mut Vec<u8>) {
        put_u32(out, self.rows);
        put_u32(out, self.cols);
        put_u32(out, self.kept_cols.len());
        put_u32_slice(out, &self.kept_cols);
        put_u32(out, self.outlier_rows.len());
        put_u32_slice(out, &self.outlier_rows);
        put_f32_slice(out, &self.data);
        put_f32_slice(out, &self.outlier_values);
    }

    pub(crate) fn read_bytes(r: &mut ByteReader) -> Result<DenseCompact> {
        let rows = r.u32()?;
        let cols = r.u32()?;
        let nk = r.u32()?;
        let kept_cols = r.u32_vec(nk)?;
        let no = r.u32()?;
        ensure!(no <= rows, "corrupt DenseCompact header");
        let outlier_rows = r.u32_vec(no)?;
        let data = r.f32_vec((rows - no) * nk)?;
        let outlier_values = r.f32_vec(no * cols)?;
        ensure!(
            kept_cols.windows(2).all(|w| w[0] < w[1])
                && kept_cols.iter().all(|&j| (j as usize) < cols),
            "DenseCompact kept columns not sorted/in range"
        );
        ensure!(
            outlier_rows.windows(2).all(|w| w[0] < w[1])
                && outlier_rows.iter().all(|&x| (x as usize) < rows),
            "DenseCompact outlier rows not sorted/in range"
        );
        Ok(DenseCompact { rows, cols, kept_cols, data, outlier_rows, outlier_values })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn bits_of(m: &Mat) -> Vec<u32> {
        m.data.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn bit_stream_roundtrips() {
        for bits in [0u32, 1, 2, 3, 5, 7, 8, 11, 16] {
            let mask = if bits == 0 { 0 } else { (1usize << bits) - 1 };
            let vals: Vec<usize> = (0..37).map(|k| (k * 2654435761usize) & mask).collect();
            let mut bw = BitWriter::new();
            for &v in &vals {
                bw.push(v, bits);
            }
            let buf = bw.finish();
            for (k, &v) in vals.iter().enumerate() {
                assert_eq!(read_bits(&buf, k * bits as usize, bits), v, "bits={bits} k={k}");
            }
        }
    }

    #[test]
    fn nm_roundtrip_with_outliers_and_negative_zero() {
        let mut r = Rng::new(11);
        let (c, b, n, m) = (9, 16, 2usize, 4usize);
        let mut w = Mat::zeros(c, b);
        for i in 0..c {
            if i == 3 || i == 7 {
                // outlier rows: dense
                for v in w.row_mut(i) {
                    *v = r.normal_f32(0.0, 1.0);
                }
                continue;
            }
            for g in (0..b).step_by(m) {
                w.row_mut(i)[g] = r.normal_f32(0.0, 1.0);
                w.row_mut(i)[g + 2] = r.normal_f32(0.0, 1.0);
            }
        }
        // a kept negative zero must survive bitwise
        w.row_mut(0)[0] = -0.0;
        let t = NmPacked::from_dense(&w, n, m).unwrap();
        assert_eq!(t.outlier_rows, vec![3, 7]);
        assert_eq!(bits_of(&t.to_dense()), bits_of(&w));
        assert!(t.bytes() < w.data.len() * 4);
    }

    #[test]
    fn nm_rejects_tail_with_documented_error() {
        let w = Mat::zeros(2, 10);
        let err = NmPacked::from_dense(&w, 2, 4).unwrap_err().to_string();
        assert_eq!(err, nm_tail_error(10, 4));
    }

    #[test]
    fn csr_roundtrip_exact() {
        let mut r = Rng::new(12);
        let mut w = Mat::from_fn(13, 21, |_, _| r.normal_f32(0.0, 1.0));
        for (k, v) in w.data.iter_mut().enumerate() {
            if k % 3 == 0 {
                *v = 0.0;
            }
        }
        w.data[5] = -0.0;
        let t = Csr::from_dense(&w);
        assert_eq!(bits_of(&t.to_dense()), bits_of(&w));
        // -0.0 is kept as a value, not dropped
        assert_eq!(t.nnz(), w.data.iter().filter(|v| v.to_bits() != 0).count());
    }

    #[test]
    fn dense_compact_roundtrip_with_outliers() {
        let mut r = Rng::new(13);
        let mut w = Mat::from_fn(10, 12, |_, _| r.normal_f32(0.0, 1.0));
        // remove columns 2, 5, 9 from all rows except outlier row 4
        for i in 0..10 {
            if i == 4 {
                continue;
            }
            for &j in &[2usize, 5, 9] {
                w.row_mut(i)[j] = 0.0;
            }
        }
        let t = DenseCompact::from_dense(&w);
        assert_eq!(t.outlier_rows, vec![4]);
        assert_eq!(t.kept_cols.len(), 9);
        assert_eq!(bits_of(&t.to_dense()), bits_of(&w));
        assert!(t.bytes() < w.data.len() * 4 + 12 * 4);
    }

    #[test]
    fn dense_compact_total_on_unstructured_input() {
        // no shared zero columns: compresses poorly but stays exact
        let mut r = Rng::new(14);
        let w = Mat::from_fn(6, 8, |_, _| r.normal_f32(0.0, 1.0));
        let t = DenseCompact::from_dense(&w);
        assert_eq!(bits_of(&t.to_dense()), bits_of(&w));
    }

    #[test]
    fn serialization_roundtrips_all_formats() {
        let mut r = Rng::new(15);
        let mut w = Mat::from_fn(8, 16, |_, _| r.normal_f32(0.0, 1.0));
        for g in (0..16).step_by(4) {
            for i in 0..7 {
                w.row_mut(i)[g] = 0.0;
                w.row_mut(i)[g + 1] = 0.0;
            }
        }
        let nm = NmPacked::from_dense(&w, 2, 4).unwrap();
        let mut buf = Vec::new();
        nm.write_bytes(&mut buf);
        let mut rd = ByteReader::new(&buf);
        let back = NmPacked::read_bytes(&mut rd).unwrap();
        rd.finish().unwrap();
        assert_eq!(back, nm);

        let csr = Csr::from_dense(&w);
        let mut buf = Vec::new();
        csr.write_bytes(&mut buf);
        let mut rd = ByteReader::new(&buf);
        let back = Csr::read_bytes(&mut rd).unwrap();
        rd.finish().unwrap();
        assert_eq!(back, csr);

        let dc = DenseCompact::from_dense(&w);
        let mut buf = Vec::new();
        dc.write_bytes(&mut buf);
        let mut rd = ByteReader::new(&buf);
        let back = DenseCompact::read_bytes(&mut rd).unwrap();
        rd.finish().unwrap();
        assert_eq!(back, dc);
    }

    #[test]
    fn truncated_blob_is_rejected() {
        let w = Mat::zeros(3, 4);
        let csr = Csr::from_dense(&w);
        let mut buf = Vec::new();
        csr.write_bytes(&mut buf);
        buf.pop();
        let mut rd = ByteReader::new(&buf);
        assert!(Csr::read_bytes(&mut rd).is_err());
    }
}
