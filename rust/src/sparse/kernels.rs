//! Sparse × dense execution kernels — the measured counterpart of the
//! modeled n:m speedup (DESIGN.md §Sparse).
//!
//! Every kernel computes `out = W · X` (`W` compressed `c×b`, `X` dense
//! `b×k`) with f32 accumulation in ascending-column order per row —
//! the naive GEMM's operation order restricted to the nonzero entries,
//! so results stay within 1e-5 relative error of
//! [`crate::linalg::gemm::matmul_naive`] (pinned by the
//! cross-validation tests and the `sparse_matmul` bench's self-check;
//! the packed dense GEMM itself reorders sums, see DESIGN.md §Perf-L3).
//!
//! Parallelism: output rows are banded over the shared
//! [`crate::engine::PruneEngine`] pool (disjoint bands ⇒ bit-identical
//! results for any thread count, like every other kernel in the crate);
//! the n:m path decodes each row's bit-packed column indices into a
//! per-worker pooled scratch (the [`SpmvScratch`] analogue of
//! `linalg::batched::RowSolveScratch`) so the hot loop does no
//! allocation and no per-element bit arithmetic.
//!
//! The inner loops reuse the packed dense core's register-tiled row
//! kernels ([`crate::linalg::kernel::sparse_row_axpy`] /
//! [`dense_row_axpy`]): a j-block of the output row accumulates in
//! registers while the (decoded) column list streams past, instead of
//! read-modify-writing the output row once per nonzero. Per-element
//! chains keep the scalar loop's ascending-nonzero order; on FMA
//! targets the fused multiply-add rounds once per step, which may move
//! the lowest bits relative to the old two-rounding loop (well inside
//! the 1e-5 gate). Serial==parallel bit-identity is unaffected.

use super::formats::{read_bits, Csr, DenseCompact, NmPacked};
use super::SparseTensor;
use crate::engine;
use crate::linalg::kernel::{dense_row_axpy, sparse_row_axpy};
use crate::linalg::Mat;

/// Per-worker decode scratch for the n:m kernel: the current row's
/// absolute column indices, reused across rows, calls and layers.
pub struct SpmvScratch {
    cols: Vec<u32>,
}

impl SpmvScratch {
    fn new() -> SpmvScratch {
        SpmvScratch { cols: Vec::new() }
    }
}

thread_local! {
    static SPMV_SCRATCH: std::cell::RefCell<SpmvScratch> =
        std::cell::RefCell::new(SpmvScratch::new());
}

fn with_spmv_scratch<R>(f: impl FnOnce(&mut SpmvScratch) -> R) -> R {
    SPMV_SCRATCH.with(|cell| f(&mut cell.borrow_mut()))
}

/// `out = W · X` for a compressed tensor, row-banded on the engine.
pub fn matmul_into(t: &SparseTensor, x: &Mat, out: &mut Mat) {
    assert_eq!(t.cols(), x.rows, "sparse matmul inner-dim mismatch");
    assert_eq!(out.rows, t.rows(), "sparse matmul output rows");
    assert_eq!(out.cols, x.cols, "sparse matmul output cols");
    let (c, k, b) = (out.rows, out.cols, x.rows);
    if c == 0 || k == 0 {
        return;
    }
    let eng = engine::global();
    if c * k * b < 64 * 64 * 64 || eng.threads() == 1 {
        rows_body(t, x, 0, &mut out.data, k);
        return;
    }
    let rows_per = eng.chunk(c);
    eng.for_each_band(&mut out.data, rows_per * k, |bi, head| {
        rows_body(t, x, bi * rows_per, head, k);
    });
}

/// Allocating convenience wrapper.
pub fn matmul(t: &SparseTensor, x: &Mat) -> Mat {
    let mut out = Mat::zeros(t.rows(), x.cols);
    matmul_into(t, x, &mut out);
    out
}

/// Matrix–vector convenience (`k = 1`, the serving hot path).
pub fn matvec(t: &SparseTensor, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), t.cols(), "sparse matvec dim mismatch");
    let xm = Mat::from_vec(x.len(), 1, x.to_vec());
    matmul(t, &xm).data
}

/// Ping-pong output buffers for [`forward_chain`], reused across
/// batches so the serving batcher's steady state allocates nothing
/// (buffers grow to the largest layer×batch shape seen and stay there).
pub struct ForwardScratch {
    a: Mat,
    b: Mat,
}

impl Default for ForwardScratch {
    fn default() -> Self {
        ForwardScratch::new()
    }
}

impl ForwardScratch {
    pub fn new() -> ForwardScratch {
        ForwardScratch { a: Mat::zeros(0, 0), b: Mat::zeros(0, 0) }
    }
}

/// Reshape a scratch buffer in place; contents are overwritten by the
/// kernel (`rows_body` zeroes every band head), so no fill is needed.
fn reshape(m: &mut Mat, rows: usize, cols: usize) {
    m.rows = rows;
    m.cols = cols;
    m.data.resize(rows * cols, 0.0);
}

/// The batched serving entry point: chain a `b×k` batch (one request
/// per column) through `layers` in order, `out = W_L · … · W_1 · X`,
/// each step on the engine-banded sparse kernels above. Returns a
/// reference into `scratch` (valid until the next call).
///
/// Because every kernel accumulates each output column independently
/// (ascending-nonzero order per row, columns never interact), column
/// `j` of the result is **bitwise identical** to running request `j`
/// through the chain alone — batch composition can never change a
/// response (DESIGN.md §Serving; pinned by `forward_chain` tests).
pub fn forward_chain<'s>(
    layers: &[&SparseTensor],
    x: &Mat,
    scratch: &'s mut ForwardScratch,
) -> &'s Mat {
    assert!(!layers.is_empty(), "forward_chain needs at least one layer");
    assert_eq!(layers[0].cols(), x.rows, "forward_chain input dim mismatch");
    let k = x.cols;
    let ForwardScratch { a, b } = scratch;
    reshape(a, layers[0].rows(), k);
    matmul_into(layers[0], x, a);
    let (mut src, mut dst) = (&mut *a, &mut *b);
    for t in &layers[1..] {
        assert_eq!(t.cols(), src.rows, "forward_chain layer dim mismatch");
        reshape(dst, t.rows(), k);
        matmul_into(t, src, dst);
        std::mem::swap(&mut src, &mut dst);
    }
    src
}

/// Compute output rows `[r0, r0 + head.len()/k)` into `head`.
fn rows_body(t: &SparseTensor, x: &Mat, r0: usize, head: &mut [f32], k: usize) {
    head.iter_mut().for_each(|v| *v = 0.0);
    let rows_here = head.len() / k;
    match t {
        SparseTensor::Nm(p) => nm_rows(p, x, r0, rows_here, head, k),
        SparseTensor::Csr(c) => csr_rows(c, x, r0, rows_here, head, k),
        SparseTensor::DenseCompact(d) => dc_rows(d, x, r0, rows_here, head, k),
    }
}

/// `orow += v · X[col, :]` over a dense weight row with zero skipping
/// (the outlier-row path): the register-tiled decoded-panel kernel.
#[inline]
fn dense_row(wrow: &[f32], x: &Mat, orow: &mut [f32], k: usize) {
    debug_assert_eq!(orow.len(), k);
    dense_row_axpy(orow, wrow, &x.data, x.cols);
}

fn nm_rows(t: &NmPacked, x: &Mat, r0: usize, rows_here: usize, head: &mut [f32], k: usize) {
    let keep = t.keep();
    let kpr = t.kept_per_row();
    let bits = t.index_bits();
    let mut oi = t.outlier_rows.partition_point(|&r| (r as usize) < r0);
    let mut p = r0 - oi;
    with_spmv_scratch(|s| {
        for ri in 0..rows_here {
            let i = r0 + ri;
            let orow = &mut head[ri * k..(ri + 1) * k];
            if oi < t.outlier_rows.len() && t.outlier_rows[oi] as usize == i {
                dense_row(&t.outlier_values[oi * t.cols..(oi + 1) * t.cols], x, orow, k);
                oi += 1;
                continue;
            }
            let vals = &t.values[p * kpr..(p + 1) * kpr];
            // decode this row's in-group indices to absolute columns once
            let base = p * kpr * bits as usize;
            s.cols.clear();
            for tt in 0..kpr {
                let idx = read_bits(&t.indices, base + tt * bits as usize, bits);
                s.cols.push(((tt / keep) * t.m + idx) as u32);
            }
            // decoded-panel path: register-tiled row kernel (skips the
            // zero-padded kept slots like the scalar loop did)
            sparse_row_axpy(orow, &s.cols, vals, &x.data, x.cols);
            p += 1;
        }
    });
}

fn csr_rows(t: &Csr, x: &Mat, r0: usize, rows_here: usize, head: &mut [f32], k: usize) {
    for ri in 0..rows_here {
        let i = r0 + ri;
        let orow = &mut head[ri * k..(ri + 1) * k];
        let (lo, hi) = (t.row_ptr[i] as usize, t.row_ptr[i + 1] as usize);
        // register-tiled row kernel; skips stored -0.0 like the scalar loop
        sparse_row_axpy(orow, &t.col_idx[lo..hi], &t.values[lo..hi], &x.data, x.cols);
    }
}

fn dc_rows(t: &DenseCompact, x: &Mat, r0: usize, rows_here: usize, head: &mut [f32], k: usize) {
    let kc = t.kept_cols.len();
    let mut oi = t.outlier_rows.partition_point(|&r| (r as usize) < r0);
    let mut p = r0 - oi;
    for ri in 0..rows_here {
        let i = r0 + ri;
        let orow = &mut head[ri * k..(ri + 1) * k];
        if oi < t.outlier_rows.len() && t.outlier_rows[oi] as usize == i {
            dense_row(&t.outlier_values[oi * t.cols..(oi + 1) * t.cols], x, orow, k);
            oi += 1;
            continue;
        }
        let drow = &t.data[p * kc..(p + 1) * kc];
        sparse_row_axpy(orow, &t.kept_cols, drow, &x.data, x.cols);
        p += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm;
    use crate::rng::Rng;
    use crate::sparse::max_rel_err;

    fn pruned_nm(c: usize, b: usize, seed: u64) -> Mat {
        let mut r = Rng::new(seed);
        let w = Mat::from_fn(c, b, |_, _| r.normal_f32(0.0, 1.0));
        crate::pruning::magnitude::semi_structured(&w, 2, 4).w
    }

    #[test]
    fn nm_kernel_matches_gemm() {
        let w = pruned_nm(33, 48, 21);
        let mut r = Rng::new(22);
        let x = Mat::from_fn(48, 9, |_, _| r.normal_f32(0.0, 1.0));
        let t = SparseTensor::Nm(NmPacked::from_dense(&w, 2, 4).unwrap());
        let got = matmul(&t, &x);
        let want = gemm::matmul(&w, &x);
        assert!(max_rel_err(&got, &want) <= 1e-5, "err {}", max_rel_err(&got, &want));
    }

    #[test]
    fn nm_kernel_handles_outlier_rows() {
        let mut w = pruned_nm(16, 32, 23);
        let mut r = Rng::new(24);
        for &i in &[2usize, 9, 15] {
            for v in w.row_mut(i) {
                *v = r.normal_f32(0.0, 1.0);
            }
        }
        let x = Mat::from_fn(32, 5, |_, _| r.normal_f32(0.0, 1.0));
        let t = SparseTensor::Nm(NmPacked::from_dense(&w, 2, 4).unwrap());
        let got = matmul(&t, &x);
        let want = gemm::matmul(&w, &x);
        assert!(max_rel_err(&got, &want) <= 1e-5);
    }

    #[test]
    fn csr_kernel_matches_gemm() {
        let mut r = Rng::new(25);
        let mut w = Mat::from_fn(19, 27, |_, _| r.normal_f32(0.0, 1.0));
        for (k, v) in w.data.iter_mut().enumerate() {
            if k % 10 < 7 {
                *v = 0.0;
            }
        }
        let x = Mat::from_fn(27, 4, |_, _| r.normal_f32(0.0, 1.0));
        let t = SparseTensor::Csr(Csr::from_dense(&w));
        let got = matmul(&t, &x);
        let want = gemm::matmul(&w, &x);
        assert!(max_rel_err(&got, &want) <= 1e-5);
    }

    #[test]
    fn dense_compact_kernel_matches_gemm() {
        let mut r = Rng::new(26);
        let mut w = Mat::from_fn(14, 20, |_, _| r.normal_f32(0.0, 1.0));
        for i in 0..14 {
            if i == 6 {
                continue; // outlier row keeps every column
            }
            for &j in &[1usize, 4, 7, 13, 18] {
                w.row_mut(i)[j] = 0.0;
            }
        }
        let x = Mat::from_fn(20, 6, |_, _| r.normal_f32(0.0, 1.0));
        let t = SparseTensor::DenseCompact(DenseCompact::from_dense(&w));
        let got = matmul(&t, &x);
        let want = gemm::matmul(&w, &x);
        assert!(max_rel_err(&got, &want) <= 1e-5);
    }

    #[test]
    fn serial_and_parallel_bit_identical() {
        // a shape large enough to cross the banding threshold
        let w = pruned_nm(96, 128, 27);
        let mut r = Rng::new(28);
        let x = Mat::from_fn(128, 64, |_, _| r.normal_f32(0.0, 1.0));
        let t = SparseTensor::Nm(NmPacked::from_dense(&w, 2, 4).unwrap());
        let par = matmul(&t, &x);
        let ser = crate::engine::with_serial(|| matmul(&t, &x));
        let bits = |m: &Mat| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&par), bits(&ser));
    }

    #[test]
    fn forward_chain_matches_layerwise_matmul() {
        // wq(d×d) → w1(ff×d) → w2(d×ff): the block pipeline's dim chain
        let (d, ff, k) = (16, 40, 3);
        let mut r = Rng::new(31);
        let x = Mat::from_fn(d, k, |_, _| r.normal_f32(0.0, 1.0));
        let t0 = SparseTensor::Nm(NmPacked::from_dense(&pruned_nm(d, d, 32), 2, 4).unwrap());
        let t1 = SparseTensor::Nm(NmPacked::from_dense(&pruned_nm(ff, d, 33), 2, 4).unwrap());
        let t2 = SparseTensor::Nm(NmPacked::from_dense(&pruned_nm(d, ff, 34), 2, 4).unwrap());
        let mut s = ForwardScratch::new();
        let got = forward_chain(&[&t0, &t1, &t2], &x, &mut s).clone();
        let want = matmul(&t2, &matmul(&t1, &matmul(&t0, &x)));
        assert_eq!((got.rows, got.cols), (d, k));
        let bits = |m: &Mat| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&got), bits(&want));
    }

    #[test]
    fn forward_chain_is_batch_composition_independent() {
        // column j of a batched forward must be bitwise identical to
        // running request j alone — the serving determinism contract
        let (d, ff, k) = (24, 48, 5);
        let mut r = Rng::new(35);
        let x = Mat::from_fn(d, k, |_, _| r.normal_f32(0.0, 1.0));
        let t0 = SparseTensor::Nm(NmPacked::from_dense(&pruned_nm(ff, d, 36), 2, 4).unwrap());
        let t1 = SparseTensor::Nm(NmPacked::from_dense(&pruned_nm(d, ff, 37), 2, 4).unwrap());
        let layers = [&t0, &t1];
        let mut s = ForwardScratch::new();
        let batched = forward_chain(&layers, &x, &mut s).clone();
        for j in 0..k {
            let col: Vec<f32> = (0..d).map(|i| x.data[i * k + j]).collect();
            let solo = forward_chain(&layers, &Mat::from_vec(d, 1, col), &mut s).clone();
            for i in 0..d {
                assert_eq!(
                    batched.data[i * k + j].to_bits(),
                    solo.data[i].to_bits(),
                    "row {i} col {j} differs between batched and solo"
                );
            }
        }
    }

    #[test]
    fn matvec_matches_matmul_column() {
        let w = pruned_nm(24, 32, 29);
        let mut r = Rng::new(30);
        let xv: Vec<f32> = (0..32).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let t = SparseTensor::Nm(NmPacked::from_dense(&w, 2, 4).unwrap());
        let y = matvec(&t, &xv);
        let xm = Mat::from_vec(32, 1, xv);
        assert_eq!(y, matmul(&t, &xm).data);
    }
}
