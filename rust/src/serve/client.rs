//! Minimal blocking client for the serving protocol — used by the
//! robustness tests, the serving bench, and anyone scripting against a
//! local daemon.

use std::io::{self, BufReader, BufWriter};
use std::net::{TcpStream, ToSocketAddrs};

use super::protocol::{self, InferRequest, Response};

/// One connection to a serving daemon; requests are sequential per
/// connection (open several clients for concurrency).
pub struct ServeClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl ServeClient {
    pub fn connect(addr: impl ToSocketAddrs) -> io::Result<ServeClient> {
        let stream = TcpStream::connect(addr)?;
        let read_half = stream.try_clone()?;
        Ok(ServeClient {
            reader: BufReader::new(read_half),
            writer: BufWriter::new(stream),
        })
    }

    /// Send one inference request and block for its response.
    /// `deadline_ms == 0` selects the server's default deadline.
    pub fn infer(&mut self, input: &[f32], deadline_ms: u32) -> io::Result<Response> {
        let req = InferRequest { deadline_ms, input: input.to_vec() };
        protocol::write_request(&mut self.writer, &req)?;
        protocol::read_response(&mut self.reader)
    }
}
