//! Checkpoint hot reload: poll a watch directory for `*.thnck`
//! candidates, validate each through the full CRC-checked checkpoint
//! loader, swap atomically on success — and keep serving the old model
//! (with a logged, counted rejection) on any failure.
//!
//! State machine (DESIGN.md §Serving): IDLE → CANDIDATE (newest file
//! by mtime that is not the one already loaded or already rejected) →
//! VALIDATE (read with [`faults::with_retry`] over the `serve.reload`
//! fault site, decode via [`ModelState::from_bytes`], require a sparse
//! payload, a chainable layer sequence, and an unchanged input
//! dimension) → SWAP (publish a new [`LoadedModel`] generation) or
//! REJECT (remember the candidate's identity so a corrupt file is
//! logged once, not every poll tick).
//!
//! In-flight batches hold the [`Arc`] of the generation they started
//! with, so a swap never tears a response.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use super::server::{LoadedModel, Shared};
use crate::model::ModelState;
use crate::robust::faults::{self, RetryPolicy};

/// Identity of a candidate file; reused to skip files already loaded
/// or already rejected without re-reading them every tick.
#[derive(Clone, Debug, PartialEq, Eq)]
struct FileId {
    path: PathBuf,
    mtime_nanos: u128,
    len: u64,
}

fn file_id(path: &Path) -> Option<FileId> {
    let meta = std::fs::metadata(path).ok()?;
    let mtime = meta.modified().ok()?;
    let mtime_nanos = mtime
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    Some(FileId { path: path.to_path_buf(), mtime_nanos, len: meta.len() })
}

/// Newest `*.thnck` in `dir` by (mtime, name); `None` on an empty or
/// unreadable directory (both are normal between deployments).
fn newest_candidate(dir: &Path) -> Option<FileId> {
    let mut best: Option<FileId> = None;
    for entry in std::fs::read_dir(dir).ok()? {
        let path = entry.ok()?.path();
        if path.extension().and_then(|e| e.to_str()) != Some("thnck") {
            continue;
        }
        let Some(id) = file_id(&path) else { continue };
        let newer = match &best {
            None => true,
            Some(b) => (id.mtime_nanos, &id.path) > (b.mtime_nanos, &b.path),
        };
        if newer {
            best = Some(id);
        }
    }
    best
}

/// Read + validate one candidate; on success returns the next model
/// generation. Transient read errors (including injected `serve.reload`
/// faults) are absorbed by the shared retry/backoff policy before the
/// candidate is declared unreadable.
fn try_load(shared: &Shared, id: &FileId) -> crate::Result<LoadedModel> {
    let bytes = faults::with_retry(&RetryPolicy::default(), || {
        faults::point("serve.reload")?;
        std::fs::read(&id.path)
    })?;
    let (_, sparse) = ModelState::from_bytes(&bytes)?;
    let sparse = sparse.ok_or_else(|| {
        anyhow::anyhow!("candidate {} has no compressed payload", id.path.display())
    })?;
    let current = shared.current_model();
    let next = LoadedModel::new(
        sparse,
        current.version + 1,
        id.path.display().to_string(),
    )?;
    anyhow::ensure!(
        next.input_dim() == current.input_dim(),
        "candidate input dim {} != serving input dim {}",
        next.input_dim(),
        current.input_dim()
    );
    Ok(next)
}

fn watch_loop(shared: &Shared) {
    let dir = shared.opts.watch_dir.clone().expect("watcher spawned without watch_dir");
    let mut loaded: Option<FileId> = None;
    let mut rejected: Option<FileId> = None;
    while !shared.stopping() {
        thread::sleep(Duration::from_millis(shared.opts.poll_ms));
        let Some(id) = newest_candidate(&dir) else { continue };
        if loaded.as_ref() == Some(&id) || rejected.as_ref() == Some(&id) {
            continue;
        }
        // A panic during validation (e.g. an injected `serve.reload`
        // panic action) is a rejection, never a dead watcher.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            try_load(shared, &id)
        }))
        .unwrap_or_else(|_| Err(anyhow::anyhow!("candidate validation panicked")));
        match outcome {
            Ok(next) => {
                let version = next.version;
                shared.swap_model(next);
                shared
                    .counters
                    .reloads_ok
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                eprintln!(
                    "serve: hot-reloaded {} (model version {version})",
                    id.path.display()
                );
                loaded = Some(id);
                rejected = None;
            }
            Err(e) => {
                shared
                    .counters
                    .reloads_rejected
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                eprintln!(
                    "serve: rejected candidate {} (still serving model version {}): {e:#}",
                    id.path.display(),
                    shared.current_model().version
                );
                rejected = Some(id);
            }
        }
    }
}

/// Spawn the `serve-reload` watcher thread.
pub(crate) fn spawn_watcher(shared: Arc<Shared>) -> std::io::Result<thread::JoinHandle<()>> {
    thread::Builder::new().name("serve-reload".into()).spawn(move || watch_loop(&shared))
}
