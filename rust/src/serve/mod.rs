//! Fault-tolerant sparse serving: the long-running `thanos serve`
//! daemon (DESIGN.md §Serving).
//!
//! The daemon loads a compressed (v2/v3) checkpoint and answers
//! concurrent inference requests over the length-prefixed TCP protocol
//! in [`protocol`]. The robustness contract, exercised end-to-end by
//! `tests/serve_robustness.rs` and the CI `serve-smoke` chaos job:
//!
//! - **Bounded admission.** Requests enter a fixed-capacity queue; when
//!   it is full the request is *shed* with an explicit
//!   [`protocol::Status::Shed`] reason instead of queueing unboundedly.
//! - **Deadlines.** Every request carries a latency budget. Expired
//!   requests are cancelled cooperatively at batch-flush boundaries and
//!   answered with [`protocol::Status::DeadlineExceeded`] rather than
//!   occupying GEMM time.
//! - **Dynamic batching.** The batcher flushes when the queue reaches
//!   `max_batch` or the oldest request has waited `batch_window_ms`,
//!   then runs one engine-parallel [`crate::sparse::kernels::forward_chain`].
//!   Column independence of the kernels makes responses bitwise
//!   identical regardless of batch composition.
//! - **Panic containment.** A panic inside a batch (including the
//!   injected `serve.batch` fault) fails only that batch's requests
//!   with [`protocol::Status::BatchFailed`]; the daemon keeps serving.
//! - **Hot reload.** With `--serve_watch=DIR` the daemon polls for new
//!   checkpoint candidates, validates them through the full CRC v3
//!   loader plus [`crate::sparse::SparseModel::chain_dims`], and swaps
//!   atomically on success. A corrupt candidate is rejected and logged
//!   while the old model keeps answering.
//!
//! Fault sites on the serving path are listed in
//! [`crate::robust::faults::SERVE_SITES`].

pub mod client;
pub mod protocol;
mod reload;
mod server;

pub use client::ServeClient;
pub use protocol::{InferRequest, Response, Status};
pub use server::{ServeSnapshot, Server};

use std::path::PathBuf;

/// Tunables of one serving daemon; defaults mirror the CLI defaults in
/// [`crate::config::RunConfig`].
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Listen address; port 0 binds an ephemeral port (tests).
    pub addr: String,
    /// Admission-queue capacity; beyond it requests are shed.
    pub queue_cap: usize,
    /// Flush a batch as soon as this many requests are queued.
    pub max_batch: usize,
    /// ... or as soon as the oldest queued request has waited this long.
    pub batch_window_ms: u64,
    /// Deadline applied to requests that send `deadline_ms == 0`.
    pub default_deadline_ms: u32,
    /// Directory polled for replacement checkpoints (`*.thnck`).
    pub watch_dir: Option<PathBuf>,
    /// Poll interval of the hot-reload watcher.
    pub poll_ms: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:0".to_string(),
            queue_cap: 256,
            max_batch: 16,
            batch_window_ms: 5,
            default_deadline_ms: 1_000,
            watch_dir: None,
            poll_ms: 100,
        }
    }
}
