//! Wire protocol of the serving daemon: length-prefixed frames over a
//! byte stream (TCP in practice; anything `Read + Write` in tests).
//!
//! Every frame is `u32 len (LE)` followed by `len` payload bytes.
//! Request payload:
//!
//! ```text
//! u8  opcode        (1 = INFER)
//! u32 deadline_ms   (0 = use the server's default deadline)
//! u32 n
//! n × f32 (LE)      the input vector (must match the model input dim)
//! ```
//!
//! Response payload:
//!
//! ```text
//! u8 status         (see [`Status`])
//! status == Ok:     u32 n + n × f32 (LE)   — the output vector
//! otherwise:        u32 len + UTF-8 bytes  — the rejection reason
//! ```
//!
//! Malformed frames decode to `io::ErrorKind::InvalidData` with a
//! description, never a panic; oversized length prefixes are rejected
//! before any allocation ([`MAX_FRAME_BYTES`]), so a corrupt or hostile
//! peer cannot balloon server memory.

use std::io::{self, Read, Write};

/// Upper bound on one frame's payload (16 MiB — far above any real
/// request against the micro/tiny/small presets, far below harm).
pub const MAX_FRAME_BYTES: usize = 1 << 24;

/// Request opcode: run one inference.
pub const OP_INFER: u8 = 1;

/// Outcome class of one request, as carried on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    /// Computed; payload carries the output vector.
    Ok,
    /// Load-shed at admission (bounded queue full or server stopping).
    Shed,
    /// Deadline expired before the batch executed.
    DeadlineExceeded,
    /// The batch this request rode in failed (contained panic or
    /// injected/transient execution error); the request may be retried.
    BatchFailed,
    /// The request itself was unusable (wrong input dimension, bad
    /// frame semantics).
    BadRequest,
}

impl Status {
    pub fn as_u8(self) -> u8 {
        match self {
            Status::Ok => 0,
            Status::Shed => 1,
            Status::DeadlineExceeded => 2,
            Status::BatchFailed => 3,
            Status::BadRequest => 4,
        }
    }

    pub fn from_u8(v: u8) -> io::Result<Status> {
        Ok(match v {
            0 => Status::Ok,
            1 => Status::Shed,
            2 => Status::DeadlineExceeded,
            3 => Status::BatchFailed,
            4 => Status::BadRequest,
            other => return Err(bad(format!("unknown response status {other}"))),
        })
    }
}

/// One inference request.
#[derive(Clone, Debug, PartialEq)]
pub struct InferRequest {
    /// Per-request latency budget; 0 selects the server default.
    pub deadline_ms: u32,
    pub input: Vec<f32>,
}

/// One response: `Ok` carries the output, everything else a reason.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    pub status: Status,
    pub output: Vec<f32>,
    pub reason: String,
}

impl Response {
    pub fn ok(output: Vec<f32>) -> Response {
        Response { status: Status::Ok, output, reason: String::new() }
    }

    pub fn reject(status: Status, reason: impl Into<String>) -> Response {
        Response { status, output: Vec::new(), reason: reason.into() }
    }

    pub fn is_ok(&self) -> bool {
        self.status == Status::Ok
    }
}

fn bad(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

fn read_frame(r: &mut impl Read) -> io::Result<Vec<u8>> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(bad(format!("frame length {len} exceeds cap {MAX_FRAME_BYTES}")));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Cursor over a received payload with bounds-checked reads.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize, what: &str) -> io::Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| bad(format!("truncated frame reading {what}")))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> io::Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> io::Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f32s(&mut self, n: usize, what: &str) -> io::Result<Vec<f32>> {
        let b = self.take(n.checked_mul(4).ok_or_else(|| bad(format!("{what} overflow")))?, what)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn finish(&self, what: &str) -> io::Result<()> {
        if self.pos != self.buf.len() {
            return Err(bad(format!(
                "{} trailing bytes after {what}",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

pub fn write_request(w: &mut impl Write, req: &InferRequest) -> io::Result<()> {
    let mut p = Vec::with_capacity(9 + 4 * req.input.len());
    p.push(OP_INFER);
    p.extend_from_slice(&req.deadline_ms.to_le_bytes());
    p.extend_from_slice(&(req.input.len() as u32).to_le_bytes());
    for v in &req.input {
        p.extend_from_slice(&v.to_le_bytes());
    }
    write_frame(w, &p)
}

pub fn read_request(r: &mut impl Read) -> io::Result<InferRequest> {
    let frame = read_frame(r)?;
    let mut c = Cursor { buf: &frame, pos: 0 };
    let op = c.u8("opcode")?;
    if op != OP_INFER {
        return Err(bad(format!("unknown request opcode {op}")));
    }
    let deadline_ms = c.u32("deadline")?;
    let n = c.u32("input length")? as usize;
    let input = c.f32s(n, "input vector")?;
    c.finish("request")?;
    Ok(InferRequest { deadline_ms, input })
}

pub fn write_response(w: &mut impl Write, resp: &Response) -> io::Result<()> {
    let mut p = Vec::with_capacity(5 + 4 * resp.output.len() + resp.reason.len());
    p.push(resp.status.as_u8());
    if resp.status == Status::Ok {
        p.extend_from_slice(&(resp.output.len() as u32).to_le_bytes());
        for v in &resp.output {
            p.extend_from_slice(&v.to_le_bytes());
        }
    } else {
        p.extend_from_slice(&(resp.reason.len() as u32).to_le_bytes());
        p.extend_from_slice(resp.reason.as_bytes());
    }
    write_frame(w, &p)
}

pub fn read_response(r: &mut impl Read) -> io::Result<Response> {
    let frame = read_frame(r)?;
    let mut c = Cursor { buf: &frame, pos: 0 };
    let status = Status::from_u8(c.u8("status")?)?;
    let resp = if status == Status::Ok {
        let n = c.u32("output length")? as usize;
        Response::ok(c.f32s(n, "output vector")?)
    } else {
        let n = c.u32("reason length")? as usize;
        let bytes = c.take(n, "reason")?;
        let reason = String::from_utf8(bytes.to_vec())
            .map_err(|_| bad("rejection reason is not UTF-8".to_string()))?;
        Response::reject(status, reason)
    };
    c.finish("response")?;
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let req = InferRequest { deadline_ms: 250, input: vec![1.5, -2.0, 0.0, f32::MIN] };
        let mut buf = Vec::new();
        write_request(&mut buf, &req).unwrap();
        let back = read_request(&mut buf.as_slice()).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn response_roundtrips_all_statuses() {
        let cases = [
            Response::ok(vec![0.25, 7.75]),
            Response::reject(Status::Shed, "queue full (capacity 4)"),
            Response::reject(Status::DeadlineExceeded, "deadline exceeded"),
            Response::reject(Status::BatchFailed, "injected fault: panic at `serve.batch`"),
            Response::reject(Status::BadRequest, "input dim 3 != model dim 8"),
        ];
        for resp in cases {
            let mut buf = Vec::new();
            write_response(&mut buf, &resp).unwrap();
            let back = read_response(&mut buf.as_slice()).unwrap();
            assert_eq!(back, resp);
        }
    }

    #[test]
    fn corrupt_frames_error_instead_of_panicking() {
        let mut buf = Vec::new();
        write_request(&mut buf, &InferRequest { deadline_ms: 1, input: vec![1.0, 2.0] }).unwrap();
        // every truncation errors
        for len in 0..buf.len() {
            assert!(read_request(&mut &buf[..len]).is_err(), "truncation to {len} parsed");
        }
        // oversized length prefix is rejected before allocating
        let huge = (MAX_FRAME_BYTES as u32 + 1).to_le_bytes();
        assert!(read_request(&mut huge.as_slice()).is_err());
        // unknown opcode / status
        let mut bad_op = buf.clone();
        bad_op[4] = 99;
        assert!(read_request(&mut bad_op.as_slice()).is_err());
        let mut rbuf = Vec::new();
        write_response(&mut rbuf, &Response::ok(vec![1.0])).unwrap();
        rbuf[4] = 99;
        assert!(read_response(&mut rbuf.as_slice()).is_err());
        // trailing garbage is an error, not silently ignored
        let mut long = buf.clone();
        let n = long.len() as u32 - 4 + 3;
        long[..4].copy_from_slice(&n.to_le_bytes());
        long.extend_from_slice(&[0, 0, 0]);
        assert!(read_request(&mut long.as_slice()).is_err());
    }
}
