//! The serving daemon core: accept loop, bounded admission queue,
//! dynamic batcher with deadline enforcement and panic containment.
//!
//! Threading model (all lifecycle threads are dedicated OS threads,
//! never engine workers — the GEMM itself still runs on the shared
//! [`crate::engine`] pool via the batcher's submitting thread, which
//! always drains its own job inline, so serving batches make progress
//! even while every pooled worker is busy inside a prune job):
//!
//! - `serve-accept` — blocks in `TcpListener::accept`, probes the
//!   `serve.accept` fault site per connection, hands each stream to a
//!   detached `serve-conn` handler.
//! - `serve-conn` (one per connection) — decodes frames, validates the
//!   input dimension against the *current* model, admits into the
//!   bounded queue (or sheds), then blocks until the batcher answers.
//! - `serve-batcher` — flushes size-or-deadline windows into one
//!   engine-parallel [`kernels::forward_chain`] per batch, inside
//!   `catch_unwind` so a poisoned batch fails its own requests only.
//! - `serve-reload` (optional) — see [`super::reload`].

use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, ErrorKind};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread;
use std::time::Duration;

use anyhow::Context;

use super::protocol::{self, InferRequest, Response, Status};
use super::reload;
use super::ServeOptions;
use crate::linalg::Mat;
use crate::robust::faults;
use crate::sparse::{kernels, SparseModel, SparseTensor};
use crate::trace::{self, clock, hist::Histogram};

const NANOS_PER_MS: u64 = 1_000_000;

/// Lock that survives a poisoned mutex: every structure guarded here
/// (queue, model pointer, histogram) is valid at all times — writers
/// never leave them mid-update across a panic site — so serving must
/// keep going even if some thread died while holding the lock.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One checkpoint generation. Swapped atomically (behind a mutex, as a
/// pointer) by hot reload; in-flight batches keep the [`Arc`] they
/// started with, so a swap never changes an already-admitted answer.
pub(crate) struct LoadedModel {
    pub(crate) sparse: SparseModel,
    pub(crate) version: u64,
    pub(crate) source: String,
    d_in: usize,
}

impl LoadedModel {
    pub(crate) fn new(
        sparse: SparseModel,
        version: u64,
        source: String,
    ) -> crate::Result<LoadedModel> {
        let (d_in, _) = sparse
            .chain_dims()
            .with_context(|| format!("validating serve model from {source}"))?;
        Ok(LoadedModel { sparse, version, source, d_in })
    }

    pub(crate) fn input_dim(&self) -> usize {
        self.d_in
    }
}

/// One admitted, not-yet-answered request.
struct Pending {
    input: Vec<f32>,
    enqueued_nanos: u64,
    deadline_nanos: u64,
    tx: mpsc::Sender<Response>,
}

#[derive(Default)]
pub(crate) struct Counters {
    pub(crate) accepted: AtomicU64,
    pub(crate) completed: AtomicU64,
    pub(crate) shed: AtomicU64,
    pub(crate) deadline_dropped: AtomicU64,
    pub(crate) batch_failed: AtomicU64,
    pub(crate) bad_request: AtomicU64,
    pub(crate) batches: AtomicU64,
    pub(crate) reloads_ok: AtomicU64,
    pub(crate) reloads_rejected: AtomicU64,
    pub(crate) accept_faults: AtomicU64,
}

pub(crate) struct Shared {
    pub(crate) opts: ServeOptions,
    queue: Mutex<VecDeque<Pending>>,
    queue_cv: Condvar,
    stop: AtomicBool,
    model: Mutex<Arc<LoadedModel>>,
    pub(crate) counters: Counters,
    lat_us: Mutex<Histogram>,
}

impl Shared {
    pub(crate) fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    pub(crate) fn current_model(&self) -> Arc<LoadedModel> {
        Arc::clone(&lock(&self.model))
    }

    pub(crate) fn swap_model(&self, next: LoadedModel) {
        *lock(&self.model) = Arc::new(next);
    }

    /// Admit one request (or shed it) and block until it is answered.
    /// Runs on the connection handler's thread.
    fn submit(&self, req: InferRequest) -> Response {
        let model = self.current_model();
        if req.input.len() != model.input_dim() {
            self.counters.bad_request.fetch_add(1, Ordering::Relaxed);
            return Response::reject(
                Status::BadRequest,
                format!(
                    "input dim {} != model input dim {}",
                    req.input.len(),
                    model.input_dim()
                ),
            );
        }
        let now = clock::now_nanos();
        let budget_ms =
            if req.deadline_ms == 0 { self.opts.default_deadline_ms } else { req.deadline_ms };
        let (tx, rx) = mpsc::channel();
        {
            let mut q = lock(&self.queue);
            if self.stopping() {
                self.counters.shed.fetch_add(1, Ordering::Relaxed);
                return Response::reject(Status::Shed, "server stopping");
            }
            if q.len() >= self.opts.queue_cap {
                self.counters.shed.fetch_add(1, Ordering::Relaxed);
                return Response::reject(
                    Status::Shed,
                    format!("queue full (capacity {})", self.opts.queue_cap),
                );
            }
            q.push_back(Pending {
                input: req.input,
                enqueued_nanos: now,
                deadline_nanos: now + u64::from(budget_ms) * NANOS_PER_MS,
                tx,
            });
            self.counters.accepted.fetch_add(1, Ordering::Relaxed);
            self.queue_cv.notify_all();
        }
        rx.recv().unwrap_or_else(|_| {
            Response::reject(Status::BatchFailed, "server stopped before the batch ran")
        })
    }
}

/// Point-in-time view of the daemon's counters and latency profile.
#[derive(Clone, Debug)]
pub struct ServeSnapshot {
    pub accepted: u64,
    pub completed: u64,
    pub shed: u64,
    pub deadline_dropped: u64,
    pub batch_failed: u64,
    pub bad_request: u64,
    pub batches: u64,
    pub reloads_ok: u64,
    pub reloads_rejected: u64,
    pub accept_faults: u64,
    pub queue_depth: usize,
    pub engine_queue_depth: usize,
    pub model_version: u64,
    pub model_source: String,
    /// Admission-to-answer latency quantiles (ms); 0 until the first
    /// completed request.
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// A running serving daemon. Dropping it (or calling
/// [`Server::shutdown`]) stops the lifecycle threads after draining
/// already-admitted requests.
pub struct Server {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<thread::JoinHandle<()>>,
    batcher: Option<thread::JoinHandle<()>>,
    reload: Option<thread::JoinHandle<()>>,
}

impl Server {
    /// Validate `sparse` as a servable chain, bind the listener, and
    /// start the lifecycle threads. `source` labels the checkpoint in
    /// logs and snapshots.
    pub fn start(
        sparse: SparseModel,
        source: impl Into<String>,
        opts: ServeOptions,
    ) -> crate::Result<Server> {
        let model = LoadedModel::new(sparse, 1, source.into())?;
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding serve listener on {}", opts.addr))?;
        let addr = listener.local_addr().context("resolving serve listener address")?;
        let shared = Arc::new(Shared {
            opts,
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            stop: AtomicBool::new(false),
            model: Mutex::new(Arc::new(model)),
            counters: Counters::default(),
            lat_us: Mutex::new(Histogram::new()),
        });
        let b = Arc::clone(&shared);
        let batcher = thread::Builder::new()
            .name("serve-batcher".into())
            .spawn(move || batcher_loop(&b))
            .context("spawning serve batcher")?;
        let reload = if shared.opts.watch_dir.is_some() {
            Some(reload::spawn_watcher(Arc::clone(&shared)).context("spawning serve watcher")?)
        } else {
            None
        };
        let a = Arc::clone(&shared);
        let accept = thread::Builder::new()
            .name("serve-accept".into())
            .spawn(move || accept_loop(&listener, &a))
            .context("spawning serve acceptor")?;
        Ok(Server { shared, addr, accept: Some(accept), batcher: Some(batcher), reload })
    }

    /// The bound listen address (resolves port 0 to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn snapshot(&self) -> ServeSnapshot {
        let c = &self.shared.counters;
        let model = self.shared.current_model();
        let (p50_ms, p99_ms) = {
            let h = lock(&self.shared.lat_us);
            (
                h.p50().unwrap_or(0) as f64 / 1_000.0,
                h.p99().unwrap_or(0) as f64 / 1_000.0,
            )
        };
        ServeSnapshot {
            accepted: c.accepted.load(Ordering::Relaxed),
            completed: c.completed.load(Ordering::Relaxed),
            shed: c.shed.load(Ordering::Relaxed),
            deadline_dropped: c.deadline_dropped.load(Ordering::Relaxed),
            batch_failed: c.batch_failed.load(Ordering::Relaxed),
            bad_request: c.bad_request.load(Ordering::Relaxed),
            batches: c.batches.load(Ordering::Relaxed),
            reloads_ok: c.reloads_ok.load(Ordering::Relaxed),
            reloads_rejected: c.reloads_rejected.load(Ordering::Relaxed),
            accept_faults: c.accept_faults.load(Ordering::Relaxed),
            queue_depth: lock(&self.shared.queue).len(),
            engine_queue_depth: crate::engine::global().queue_depth(),
            model_version: model.version,
            model_source: model.source.clone(),
            p50_ms,
            p99_ms,
        }
    }

    /// A copy of the admission-to-answer latency histogram (µs).
    pub fn latency_histogram(&self) -> Histogram {
        lock(&self.shared.lat_us).clone()
    }

    /// Stop accepting, drain already-admitted requests, join the
    /// lifecycle threads. Idempotent.
    pub fn shutdown(&mut self) {
        if !self.shared.stop.swap(true, Ordering::SeqCst) {
            self.shared.queue_cv.notify_all();
            // Wake the acceptor out of its blocking accept.
            let _ = TcpStream::connect(self.addr);
        }
        for h in [self.accept.take(), self.batcher.take(), self.reload.take()].into_iter().flatten()
        {
            let _ = h.join();
        }
    }

    /// Block until the accept loop exits (daemon mode: forever, until
    /// the process is signalled or the listener breaks).
    pub fn wait(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: &TcpListener, shared: &Arc<Shared>) {
    for conn in listener.incoming() {
        if shared.stopping() {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        // Probe the accept fault site; any injected failure (error or
        // panic) costs exactly this connection, never the daemon.
        let probe = catch_unwind(|| faults::point("serve.accept"));
        let dropped = match probe {
            Ok(Ok(())) => None,
            Ok(Err(e)) => Some(e.to_string()),
            Err(_) => Some("injected panic".to_string()),
        };
        if let Some(why) = dropped {
            shared.counters.accept_faults.fetch_add(1, Ordering::Relaxed);
            eprintln!("serve: dropping connection: {why}");
            continue;
        }
        let c = Arc::clone(shared);
        let spawned = thread::Builder::new()
            .name("serve-conn".into())
            .spawn(move || conn_loop(stream, &c));
        if let Err(e) = spawned {
            // Thread exhaustion: shed this connection, keep accepting.
            eprintln!("serve: dropping connection (no handler thread): {e}");
        }
    }
}

fn conn_loop(stream: TcpStream, shared: &Arc<Shared>) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    loop {
        let req = match protocol::read_request(&mut reader) {
            Ok(r) => r,
            Err(e) if e.kind() == ErrorKind::UnexpectedEof => return,
            Err(e) => {
                // Framing is lost after a malformed request; answer
                // once, then close.
                shared.counters.bad_request.fetch_add(1, Ordering::Relaxed);
                let resp = Response::reject(Status::BadRequest, e.to_string());
                let _ = protocol::write_response(&mut writer, &resp);
                return;
            }
        };
        let resp = shared.submit(req);
        if protocol::write_response(&mut writer, &resp).is_err() {
            return;
        }
    }
}

fn batcher_loop(shared: &Arc<Shared>) {
    let mut scratch = kernels::ForwardScratch::new();
    while let Some(batch) = next_batch(shared) {
        run_batch(shared, batch, &mut scratch);
        trace::flush_local();
    }
    trace::flush_local();
}

/// Block until a batch is due: the queue holds `max_batch` requests,
/// the oldest has waited `batch_window_ms`, or the server is stopping
/// (drain). Returns `None` once stopped *and* drained.
fn next_batch(shared: &Shared) -> Option<Vec<Pending>> {
    let window_nanos = shared.opts.batch_window_ms * NANOS_PER_MS;
    let mut q = lock(&shared.queue);
    loop {
        let stopping = shared.stopping();
        let Some(front) = q.front() else {
            if stopping {
                return None;
            }
            let (guard, _) = shared
                .queue_cv
                .wait_timeout(q, Duration::from_millis(50))
                .unwrap_or_else(PoisonError::into_inner);
            q = guard;
            continue;
        };
        let age = clock::now_nanos().saturating_sub(front.enqueued_nanos);
        if stopping || q.len() >= shared.opts.max_batch || age >= window_nanos {
            let n = q.len().min(shared.opts.max_batch);
            return Some(q.drain(..n).collect());
        }
        let (guard, _) = shared
            .queue_cv
            .wait_timeout(q, Duration::from_nanos(window_nanos - age))
            .unwrap_or_else(PoisonError::into_inner);
        q = guard;
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Execute one flushed batch: enforce deadlines, run the chained
/// sparse GEMM under `catch_unwind`, answer every rider.
fn run_batch(shared: &Shared, batch: Vec<Pending>, scratch: &mut kernels::ForwardScratch) {
    let now = clock::now_nanos();
    let mut live = Vec::with_capacity(batch.len());
    for p in batch {
        if now >= p.deadline_nanos {
            shared.counters.deadline_dropped.fetch_add(1, Ordering::Relaxed);
            let waited_ms =
                now.saturating_sub(p.enqueued_nanos) as f64 / NANOS_PER_MS as f64;
            let _ = p.tx.send(Response::reject(
                Status::DeadlineExceeded,
                format!("deadline exceeded after {waited_ms:.1} ms in queue"),
            ));
        } else {
            live.push(p);
        }
    }
    if live.is_empty() {
        return;
    }
    let model = shared.current_model();
    let d_in = model.input_dim();
    let k = live.len();
    // One request per column; the kernels accumulate columns
    // independently, so each answer is bitwise the unbatched one.
    let mut x = Mat::zeros(d_in, k);
    for (j, p) in live.iter().enumerate() {
        for (i, v) in p.input.iter().enumerate() {
            x.data[i * k + j] = *v;
        }
    }
    shared.counters.batches.fetch_add(1, Ordering::Relaxed);
    let outcome = catch_unwind(AssertUnwindSafe(|| -> std::io::Result<Vec<Vec<f32>>> {
        faults::point("serve.batch")?;
        let _span = trace::span("serve.batch");
        let layers: Vec<&SparseTensor> = model.sparse.layers.iter().map(|l| &l.tensor).collect();
        let y = kernels::forward_chain(&layers, &x, scratch);
        let d_out = y.rows;
        Ok((0..k).map(|j| (0..d_out).map(|i| y.data[i * k + j]).collect()).collect())
    }));
    match outcome {
        Ok(Ok(cols)) => {
            let done = clock::now_nanos();
            let mut h = lock(&shared.lat_us);
            for (p, col) in live.iter().zip(cols) {
                h.record(done.saturating_sub(p.enqueued_nanos) / 1_000);
                shared.counters.completed.fetch_add(1, Ordering::Relaxed);
                let _ = p.tx.send(Response::ok(col));
            }
        }
        Ok(Err(e)) => fail_batch(shared, &live, &format!("batch execution failed: {e}")),
        Err(payload) => fail_batch(
            shared,
            &live,
            &format!("batch panicked: {}", panic_message(payload.as_ref())),
        ),
    }
}

fn fail_batch(shared: &Shared, live: &[Pending], reason: &str) {
    eprintln!("serve: {reason} ({} request(s) failed)", live.len());
    for p in live {
        shared.counters.batch_failed.fetch_add(1, Ordering::Relaxed);
        let _ = p.tx.send(Response::reject(Status::BatchFailed, reason));
    }
}
