//! Shared experiment harness for the examples and the paper-table
//! benches: checkpoint caching (train once, reuse everywhere),
//! method×pattern sweeps, and table formatting.

use crate::config::ModelConfig;
use crate::coordinator::{Backend, Coordinator, PruneReport, PruneSpec};
use crate::data::{Corpus, CorpusConfig};
use crate::eval;
use crate::model::ModelState;
use crate::pruning::{Method, Pattern, PruneOpts};
use crate::runtime::Runtime;
use crate::train::{LossPoint, Trainer};
use anyhow::{Context, Result};

/// Default corpus sized for the experiments (paper: 128 calibration
/// sequences).
pub fn experiment_corpus(cfg: &ModelConfig) -> Corpus {
    Corpus::build(&CorpusConfig {
        seq_len: cfg.seq_len,
        train_seqs: 2048,
        calib_seqs: 128,
        eval_seqs: 64,
        ..Default::default()
    })
}

/// Train (or load a cached) checkpoint: `checkpoints/<model>-s<steps>.thnck`.
/// Returns the state and the loss log (empty when loaded from cache).
pub fn ensure_trained(
    rt: &Runtime,
    model: &str,
    steps: usize,
    lr: f32,
    seed: u64,
) -> Result<(ModelState, Vec<LossPoint>)> {
    let path = format!("checkpoints/{model}-s{steps}.thnck");
    if std::path::Path::new(&path).exists() {
        let st = ModelState::load(&path)?;
        return Ok((st, Vec::new()));
    }
    let mm = rt.model(model)?;
    let corpus = experiment_corpus(&mm.config);
    let state = ModelState::init(mm, seed);
    let mut trainer = Trainer::new(rt, state, lr)?;
    let log = trainer
        .train(&corpus, steps, seed ^ 0x7EA1)
        .context("training checkpoint")?;
    trainer.state.save(&path)?;
    Ok((trainer.state, log))
}

/// Outcome of one (method, pattern) cell of a paper table.
#[derive(Clone, Debug)]
pub struct Cell {
    pub method: Method,
    pub pattern: Pattern,
    pub ppl: f64,
    pub zero_shot_avg: Option<f64>,
    pub sparsity: f64,
    pub prune_secs: f64,
}

/// Prune a fresh copy of `base` and evaluate perplexity (and optionally
/// the zero-shot suite).
#[allow(clippy::too_many_arguments)]
pub fn run_cell(
    rt: &Runtime,
    base: &ModelState,
    corpus: &Corpus,
    method: Method,
    pattern: Pattern,
    opts: &PruneOpts,
    backend: Backend,
    with_zero_shot: Option<usize>,
) -> Result<(Cell, PruneReport)> {
    let mut state = base.clone();
    let spec = PruneSpec { method, pattern, opts: *opts, backend };
    let report = Coordinator::new(rt).prune_model(&mut state, &corpus.calib, &spec)?;
    let ppl = eval::perplexity(rt, &state, &corpus.eval)?;
    let zero_shot_avg = match with_zero_shot {
        Some(n) => {
            let zs = eval::zero_shot_suite(rt, &state, &corpus.grammar, n, 1234)?;
            Some(eval::zero_shot_average(&zs))
        }
        None => None,
    };
    Ok((
        Cell {
            method,
            pattern,
            ppl,
            zero_shot_avg,
            sparsity: report.overall_sparsity(),
            prune_secs: report.prune_secs,
        },
        report,
    ))
}

/// Markdown-ish table of cells grouped by pattern (the Table 2 layout).
pub fn format_table(dense_ppl: f64, cells: &[Cell]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "  {:<12} {:<22} {:>10} {:>9} {:>8}\n",
        "Method", "Sparsity", "PPL", "ZeroShot", "secs"
    ));
    out.push_str(&format!(
        "  {:<12} {:<22} {:>10.3} {:>9} {:>8}\n",
        "Dense", "0%", dense_ppl, "-", "-"
    ));
    for c in cells {
        let zs = c
            .zero_shot_avg
            .map(|z| format!("{:.1}%", z * 100.0))
            .unwrap_or_else(|| "-".into());
        out.push_str(&format!(
            "  {:<12} {:<22} {:>10.3} {:>9} {:>8.2}\n",
            c.method.name(),
            c.pattern.label(),
            c.ppl,
            zs,
            c.prune_secs
        ));
    }
    out
}

/// Quick env-var override helper for example knobs
/// (`THANOS_STEPS=50 cargo run --example e2e_compress`).
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

pub fn env_str(name: &str, default: &str) -> String {
    std::env::var(name).unwrap_or_else(|_| default.to_string())
}
