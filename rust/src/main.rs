//! `thanos` — the launcher binary.
//!
//! Subcommands (hand-rolled CLI; no clap in the offline vendor set):
//!
//! ```text
//! thanos info   [--model small]                    # manifest + config summary
//! thanos train  [--model small --train_steps 400]  # train + save checkpoint
//! thanos prune  <method> <pattern> [--model ...]   # prune a checkpoint
//!               [--backend=rust --journal=p --resume=1 --faults=spec]
//!               [--mem_budget=256M]                # bounded-memory streaming
//! thanos eval   [--model ...]                      # ppl + zero-shot of a checkpoint
//! thanos e2e    [--model ...]                      # train → prune-all-methods → eval
//! thanos compress <pattern> [--model ...]          # pack a pruned checkpoint (v2)
//! thanos sparse-bench [quick]                      # measured sparse-kernel sweep
//! thanos serve  [ckpt] [--serve_addr=host:port]    # serving daemon on a compressed ckpt
//!               [--serve_queue=256 --serve_batch=16 --serve_window_ms=5]
//!               [--serve_deadline_ms=1000 --serve_watch=dir --serve_poll_ms=100]
//! ```
//!
//! `method` ∈ magnitude|wanda|sparsegpt|thanos; `pattern` ∈
//! unstructured:<p> | structured:<p>:<alpha> | nm:<n>:<m>[:<alpha>].
//!
//! `compress` and `sparse-bench` are artifact-free: they run entirely
//! on the Rust `sparse/` subsystem (no AOT executables needed).
//!
//! Tracing: `--trace=out.json` (any subcommand) or `THANOS_TRACE=out.json`
//! enables the per-worker span tracer and writes a Chrome trace-event
//! file on successful exit — load it in `chrome://tracing` or Perfetto.
//! The CLI flag wins when both are set. See DESIGN.md §Observability.
//!
//! Crash safety: `--backend=rust` routes `prune` through the journaled
//! pipeline; `--journal=path` (default `{ckpt_dir}/{model}-prune.journal`
//! when `--resume=1` is set) records per-layer progress, and `--resume=1`
//! replays it after a crash, skipping completed blocks. `--faults=spec`
//! (or `THANOS_FAULTS`) installs a deterministic fault-injection schedule
//! — see DESIGN.md §Robustness. `--mem_budget=256M` bounds calibration-
//! activation memory by streaming chunks through a CRC-verified spill
//! container (bitwise-identical output) — see DESIGN.md §Streaming.

use anyhow::{bail, Context, Result};
use thanos::config::RunConfig;
use thanos::coordinator::{Backend, Coordinator, PruneSpec, RobustOpts};
use thanos::data::{Corpus, CorpusConfig};
use thanos::eval;
use thanos::model::ModelState;
use thanos::pruning::{Method, Pattern, PruneOpts};
use thanos::runtime::Runtime;
use thanos::train::{format_loss_curve, Trainer};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_method(s: &str) -> Result<Method> {
    Ok(match s {
        "magnitude" => Method::Magnitude,
        "wanda" => Method::Wanda,
        "sparsegpt" => Method::SparseGpt,
        "thanos" => Method::Thanos,
        other => bail!("unknown method '{other}'"),
    })
}

fn parse_pattern(s: &str, default_alpha: f64) -> Result<Pattern> {
    let parts: Vec<&str> = s.split(':').collect();
    Ok(match parts[0] {
        "unstructured" => Pattern::Unstructured {
            p: parts.get(1).context("unstructured:<p>")?.parse()?,
        },
        "structured" => Pattern::Structured {
            p: parts.get(1).context("structured:<p>[:alpha]")?.parse()?,
            alpha: parts.get(2).map(|a| a.parse()).transpose()?.unwrap_or(default_alpha),
        },
        "nm" => Pattern::SemiStructured {
            n: parts.get(1).context("nm:<n>:<m>")?.parse()?,
            m: parts.get(2).context("nm:<n>:<m>")?.parse()?,
            alpha: parts.get(3).map(|a| a.parse()).transpose()?.unwrap_or(default_alpha),
        },
        other => bail!("unknown pattern '{other}'"),
    })
}

fn corpus_for(rc: &RunConfig) -> Corpus {
    Corpus::build(&CorpusConfig {
        seq_len: rc.model.seq_len,
        train_seqs: rc.train_seqs,
        calib_seqs: rc.calib_seqs,
        eval_seqs: rc.eval_seqs,
        ..Default::default()
    })
}

fn ckpt_path(rc: &RunConfig) -> String {
    format!("{}/{}.thnck", rc.ckpt_dir, rc.model.name)
}

fn run() -> Result<()> {
    let mut rc = RunConfig::default();
    let args = rc.parse_args(std::env::args().skip(1))?;
    let cmd = args.first().map(String::as_str).unwrap_or("info");
    thanos::trace::init(rc.trace.as_deref());

    let result = match cmd {
        "info" => {
            let rt = Runtime::load(&rc.artifacts_dir)?;
            println!("artifacts: {} executables", rt.manifest.executables.len());
            for (name, m) in &rt.manifest.models {
                println!(
                    "  model {name}: {} params, {} layers, d={} ff={}",
                    m.flat_size, m.config.n_layers, m.config.d_model, m.config.d_ff
                );
            }
            Ok(())
        }
        "train" => {
            let rt = Runtime::load(&rc.artifacts_dir)?;
            let mm = rt.model(&rc.model.name)?;
            let corpus = corpus_for(&rc);
            let state = ModelState::init(mm, rc.seed);
            let mut trainer = Trainer::new(&rt, state, rc.lr as f32)?;
            println!(
                "training {} ({} params) for {} steps…",
                rc.model.name, mm.flat_size, rc.train_steps
            );
            let log = trainer.train(&corpus, rc.train_steps, rc.seed ^ 0x7EA1)?;
            print!("{}", format_loss_curve(&log, rc.train_steps / 10));
            let path = ckpt_path(&rc);
            trainer.state.save(&path)?;
            println!("saved checkpoint to {path}");
            Ok(())
        }
        "prune" => {
            let method = parse_method(args.get(1).context("prune <method> <pattern>")?)?;
            let pattern =
                parse_pattern(args.get(2).context("prune <method> <pattern>")?, rc.alpha)?;
            // Fault schedule: CLI flag wins over THANOS_FAULTS.
            match &rc.faults {
                Some(spec) => thanos::robust::faults::install(
                    thanos::robust::faults::parse_schedule(spec)?,
                ),
                None => thanos::robust::faults::init_from_env()?,
            }
            let rt = Runtime::load(&rc.artifacts_dir)?;
            let corpus = corpus_for(&rc);
            let mut state =
                ModelState::load(ckpt_path(&rc)).context("run `thanos train` first")?;
            let ppl0 = eval::perplexity(&rt, &state, &corpus.eval)?;
            let spec = PruneSpec {
                method,
                pattern,
                opts: PruneOpts { block_size: rc.block_size, ..Default::default() },
                backend: if rc.backend == "rust" { Backend::Rust } else { Backend::Aot },
            };
            // `--resume` without an explicit journal uses the default
            // per-model path, so crash + rerun needs no extra flags.
            let journal = rc.journal.clone().map(std::path::PathBuf::from).or_else(|| {
                rc.resume.then(|| {
                    std::path::PathBuf::from(format!(
                        "{}/{}-prune.journal",
                        rc.ckpt_dir, rc.model.name
                    ))
                })
            });
            let robust = RobustOpts { journal, resume: rc.resume, mem_budget: rc.mem_budget };
            let coord = Coordinator::new(&rt);
            let report = coord.prune_model_robust(&mut state, &corpus.calib, &spec, &robust)?;
            println!("{}", report.summary());
            let ppl1 = eval::perplexity(&rt, &state, &corpus.eval)?;
            println!(
                "{} {}: ppl {:.3} -> {:.3}",
                method.name(),
                pattern.label(),
                ppl0,
                ppl1
            );
            let out = format!("{}/{}-pruned.thnck", rc.ckpt_dir, rc.model.name);
            state.save(&out)?;
            println!("saved pruned checkpoint to {out}");
            Ok(())
        }
        "eval" => {
            let rt = Runtime::load(&rc.artifacts_dir)?;
            let corpus = corpus_for(&rc);
            let state = ModelState::load(ckpt_path(&rc))?;
            let ppl = eval::perplexity(&rt, &state, &corpus.eval)?;
            println!(
                "perplexity: {ppl:.3}  (sparsity {:.1}%)",
                state.prunable_sparsity() * 100.0
            );
            let zs = eval::zero_shot_suite(&rt, &state, &corpus.grammar, 50, rc.seed)?;
            print!("{}", eval::format_zero_shot(&zs));
            Ok(())
        }
        "e2e" => {
            println!("run: cargo run --release --example e2e_compress");
            Ok(())
        }
        // pack a pruned checkpoint into compressed formats (checkpoint
        // v2) and print the measured compression report — artifact-free
        "compress" => {
            let pattern =
                parse_pattern(args.get(1).context("compress <pattern> [--model ...]")?, rc.alpha)?;
            let pruned_path = format!("{}/{}-pruned.thnck", rc.ckpt_dir, rc.model.name);
            let src = if std::path::Path::new(&pruned_path).exists() {
                pruned_path
            } else {
                ckpt_path(&rc)
            };
            let state = ModelState::load(&src)
                .context("run `thanos train` + `thanos prune` first")?;
            let sparsity = state.prunable_sparsity();
            // a dense checkpoint would "compress" every row as an
            // outlier and grow the file — refuse instead of misleading
            anyhow::ensure!(
                sparsity > 0.01,
                "checkpoint {src} is dense (sparsity {:.2}%) — run `thanos prune` first",
                sparsity * 100.0
            );
            println!(
                "compressing {} (sparsity {:.1}%) as {}…",
                src,
                sparsity * 100.0,
                pattern.label()
            );
            let sm = thanos::sparse::SparseModel::compress_state(&state, &pattern)?;
            print!("{}", eval::compression_report(&state, &sm)?);
            let out = format!("{}/{}-compressed.thnck", rc.ckpt_dir, rc.model.name);
            // save_compressed round-trip-verifies every layer bitwise
            state.save_compressed(&out, &sm)?;
            let (back, reloaded) = ModelState::load_with_sparse(&out)?;
            anyhow::ensure!(
                back.flat
                    .iter()
                    .zip(&state.flat)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "v2 reload not bit-identical"
            );
            anyhow::ensure!(reloaded.is_some(), "v2 checkpoint lost its sparse tensors");
            let metrics = thanos::metrics::Metrics::new();
            metrics.record_compression(
                "sparse.compress",
                sm.dense_bytes(),
                sm.compressed_bytes(),
            );
            print!("{}", metrics.report());
            println!("saved compressed checkpoint to {out} (reload verified bit-identical)");
            Ok(())
        }
        // measured dense-vs-sparse kernel sweep (the sparse_matmul bench
        // in-process; `quick` or THANOS_SPARSE_QUICK=1 for CI-size shapes)
        "sparse-bench" => {
            let quick = args.get(1).map(String::as_str) == Some("quick")
                || std::env::var("THANOS_SPARSE_QUICK").map(|v| v == "1").unwrap_or(false);
            // same shape/batch tables as benches/sparse_matmul.rs, so
            // the CLI and the bench binary measure the same sweep
            for &(c, b) in thanos::sparse::bench::default_shapes(quick) {
                for &batch in thanos::sparse::bench::default_batches(quick) {
                    println!("-- {c}x{b}, batch {batch} --");
                    for row in thanos::sparse::bench::sweep(c, b, batch, 0xBEC)? {
                        println!("{}", row.pretty());
                        anyhow::ensure!(
                            row.max_rel_err <= 1e-5,
                            "{}: kernel diverged from gemm ({:.2e})",
                            row.case,
                            row.max_rel_err
                        );
                    }
                }
            }
            println!("(dense = unpruned GEMM baseline; bytes = compressed/dense f32)");
            Ok(())
        }
        // perf tooling: time one AOT executable (compile once, then N
        // timed executions with synthetic inputs of the declared shapes)
        "exec-bench" => {
            let name = args.get(1).context("exec-bench <executable> [reps]")?;
            let reps: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(5);
            let rt = Runtime::load(&rc.artifacts_dir)?;
            let entry = rt
                .manifest
                .executables
                .get(name)
                .with_context(|| format!("unknown executable '{name}'"))?
                .clone();
            let mut rng = thanos::rng::Rng::new(7);
            let inputs: Vec<xla::Literal> = entry
                .args
                .iter()
                .map(|a| -> Result<xla::Literal> {
                    let n = a.numel();
                    match a.dtype {
                        thanos::runtime::Dtype::F32 => {
                            let mut v = vec![0.0f32; n];
                            rng.fill_normal(&mut v, 0.5);
                            // PSD-ify square f32 inputs named like Hessians is
                            // impossible generically; add diagonal dominance
                            if a.shape.len() == 2 && a.shape[0] == a.shape[1] {
                                let d = a.shape[0];
                                for i in 0..d {
                                    v[i * d + i] += d as f32;
                                }
                            }
                            thanos::runtime::lit_f32(&v, &a.shape)
                        }
                        thanos::runtime::Dtype::I32 => {
                            let v: Vec<i32> =
                                (0..n).map(|_| rng.below(64) as i32).collect();
                            thanos::runtime::lit_i32(&v, &a.shape)
                        }
                    }
                })
                .collect::<Result<_>>()?;
            let t0 = thanos::trace::clock::now_nanos();
            rt.exec(name, &inputs)?; // includes compile
            println!(
                "first call (incl. compile): {:.3}s",
                thanos::trace::clock::secs_since(t0)
            );
            let t1 = thanos::trace::clock::now_nanos();
            for _ in 0..reps {
                rt.exec(name, &inputs)?;
            }
            println!(
                "steady-state: {:.4}s/exec over {reps} reps",
                thanos::trace::clock::secs_since(t1) / reps as f64
            );
            Ok(())
        }
        // long-running serving daemon over a compressed checkpoint —
        // artifact-free (sparse kernels only); see DESIGN.md §Serving
        "serve" => {
            // Fault schedule: CLI flag wins over THANOS_FAULTS.
            match &rc.faults {
                Some(spec) => thanos::robust::faults::install(
                    thanos::robust::faults::parse_schedule(spec)?,
                ),
                None => thanos::robust::faults::init_from_env()?,
            }
            let ckpt = args
                .get(1)
                .cloned()
                .unwrap_or_else(|| format!("{}/{}-compressed.thnck", rc.ckpt_dir, rc.model.name));
            let (_, sparse) = ModelState::load_with_sparse(&ckpt)
                .context("run `thanos compress` first")?;
            let sparse = sparse.with_context(|| {
                format!("checkpoint {ckpt} has no compressed tensors — run `thanos compress`")
            })?;
            let (d_in, d_out) = sparse.chain_dims()?;
            let opts = thanos::serve::ServeOptions {
                addr: rc.serve_addr.clone(),
                queue_cap: rc.serve_queue,
                max_batch: rc.serve_batch,
                batch_window_ms: rc.serve_window_ms,
                default_deadline_ms: rc.serve_deadline_ms,
                watch_dir: rc.serve_watch.clone().map(std::path::PathBuf::from),
                poll_ms: rc.serve_poll_ms,
            };
            let mut server = thanos::serve::Server::start(sparse, ckpt.clone(), opts)?;
            // Parsed by tests/scripts; stdout is line-buffered, so this
            // flushes before the daemon blocks.
            println!(
                "serving {ckpt} ({d_in}->{d_out}) on {}",
                server.local_addr()
            );
            server.wait();
            Ok(())
        }
        other => bail!(
            "unknown command '{other}' (info|train|prune|eval|e2e|compress|sparse-bench|exec-bench|serve)"
        ),
    };
    if result.is_ok() {
        if let Some(path) = thanos::trace::export()? {
            println!("trace written to {}", path.display());
        }
    }
    result
}
