//! # Thanos: block-wise pruning for LLM compression
//!
//! Reproduction of *"Thanos: A Block-wise Pruning Algorithm for Efficient
//! Large Language Model Compression"* (Ilin & Richtárik, 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the compression-pipeline coordinator: the
//!   paper's generic block-by-block pruning loop (Algorithm 3), model
//!   state, checkpointing, the calibration-data pipeline, training and
//!   evaluation drivers, and a pure-Rust implementation of every pruning
//!   method (Magnitude, Wanda, SparseGPT, Thanos unstructured /
//!   structured / n:m).
//! * **L2/L1 (`python/compile/`)** — the JAX transformer + Pallas hot-spot
//!   kernels, AOT-lowered to HLO text at build time (`make artifacts`)
//!   and executed from Rust through the PJRT C API ([`runtime`]).
//!
//! Python never runs on the request path: after `make artifacts` the
//! `thanos` binary, the examples and the benches are self-contained.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`rng`] | deterministic xoshiro256** RNG, Gaussian/Zipf samplers |
//! | [`engine`] | the `PruneEngine`: persistent work-stealing thread pool with scoped job submission; all crate parallelism (layer-level and row-level) shares its thread budget |
//! | [`linalg`] | from-scratch dense LA over a packed register-tiled micro-kernel core: GEMM (density-probed), `XXᵀ` SYRK, blocked Cholesky/TRSM, permutations, padded batched systems — row-parallel through [`engine`] |
//! | [`jsonutil`] | hand-rolled JSON (artifact manifests, configs, reports) |
//! | [`config`] | model/run configuration + CLI override layer |
//! | [`data`] | synthetic hierarchical-Markov corpus (train/calib/eval splits) |
//! | [`pruning`] | the paper's algorithms 1, 2, 8 + all baselines, pure Rust |
//! | [`runtime`] | PJRT client, HLO artifact loading, executable cache |
//! | [`model`] | transformer parameter state + checkpoint IO |
//! | [`train`] | training driver over the AOT train-step executable |
//! | [`coordinator`] | Algorithm 3 pipeline: capture → Hessian → prune → re-forward |
//! | [`sparse`] | compressed weight formats (n:m packed, CSR, dense-compact) + real sparse×dense kernels + checkpoint-v2 tensors |
//! | [`eval`] | perplexity + synthetic zero-shot harness + measured/modeled compression report |
//! | [`proptest`] | mini property-testing framework used by the test suite |
//! | [`metrics`] | sharded counters/timers with interned `&'static str` keys |
//! | [`trace`] | per-worker span tracer: thread-local event shards, latency histograms, Chrome-trace export, and the crate's single wall-clock read point ([`trace::clock`]) |
//! | [`robust`] | crash-safety layer: atomic fsync-rename writes, CRC-64/XZ checksums, the prune journal, and deterministic site-keyed fault injection (`THANOS_FAULTS`) |
//! | [`serve`] | fault-tolerant serving daemon (`thanos serve`): length-prefixed TCP protocol, bounded admission with load-shedding, deadline-aware dynamic batching onto the sparse kernels, panic containment, checkpoint hot reload |
//! | [`harness`] | experiment harness shared by examples and paper-table benches |

// The workspace lint table ([workspace.lints] in the root Cargo.toml)
// already denies this; the attribute keeps the guarantee visible at the
// crate root and effective even under a bare `rustc` invocation. Unsafe
// itself is confined to the files audited by rule D4 (audit.toml).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod config;
pub mod coordinator;
pub mod engine;
pub mod harness;
pub mod data;
pub mod eval;
pub mod jsonutil;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod proptest;
pub mod pruning;
pub mod rng;
pub mod robust;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod trace;
pub mod train;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
