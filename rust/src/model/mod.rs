//! Model state: the flat parameter vector, named-layer access by
//! manifest layout, and checkpoint IO (own binary format — no external
//! serialization crates offline).
//!
//! Checkpoint format (`.thnck`):
//! ```text
//! magic "THNS" | u32 version | u64 json_len | json header | f32 data (LE)
//! ```
//! The JSON header carries the model config and the parameter layout so
//! a checkpoint is self-describing (loadable without the manifest).

use crate::config::ModelConfig;
use crate::jsonutil::{obj, Json};
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::runtime::{ModelManifest, ParamEntry};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"THNS";
const VERSION: u32 = 1;

/// Transformer parameter state over a single flat f32 vector.
#[derive(Clone)]
pub struct ModelState {
    pub config: ModelConfig,
    pub layout: Vec<ParamEntry>,
    pub block_flat_size: usize,
    pub flat: Vec<f32>,
}

impl ModelState {
    /// Fresh random init (GPT-2 style: N(0, 0.02), residual-path scaled,
    /// norms at 1) following the manifest layout.
    pub fn init(mm: &ModelManifest, seed: u64) -> ModelState {
        let mut rng = Rng::new(seed);
        let mut flat = vec![0.0f32; mm.flat_size];
        let resid_std = 0.02 / (2.0 * mm.config.n_layers as f32).sqrt();
        for e in &mm.layout {
            let dst = &mut flat[e.offset..e.offset + e.numel()];
            if e.name.ends_with("ln1") || e.name.ends_with("ln2") || e.name.ends_with("ln_f") {
                dst.iter_mut().for_each(|v| *v = 1.0);
            } else if e.name.ends_with("wo") || e.name.ends_with("w2") {
                rng.fill_normal(dst, resid_std);
            } else {
                rng.fill_normal(dst, 0.02);
            }
        }
        ModelState {
            config: mm.config.clone(),
            layout: mm.layout.clone(),
            block_flat_size: mm.block_flat_size,
            flat,
        }
    }

    pub fn entry(&self, name: &str) -> Result<&ParamEntry> {
        self.layout
            .iter()
            .find(|e| e.name == name)
            .with_context(|| format!("no param '{name}'"))
    }

    /// Extract a weight matrix by name (must be 2-D).
    pub fn get_mat(&self, name: &str) -> Result<Mat> {
        let e = self.entry(name)?;
        if e.shape.len() != 2 {
            bail!("param '{name}' is not a matrix: {:?}", e.shape);
        }
        Ok(Mat::from_vec(
            e.shape[0],
            e.shape[1],
            self.flat[e.offset..e.offset + e.numel()].to_vec(),
        ))
    }

    /// Write a weight matrix back into the flat vector.
    pub fn set_mat(&mut self, name: &str, m: &Mat) -> Result<()> {
        let e = self.entry(name)?.clone();
        if e.shape != [m.rows, m.cols] {
            bail!(
                "shape mismatch for '{name}': {:?} vs {}x{}",
                e.shape,
                m.rows,
                m.cols
            );
        }
        self.flat[e.offset..e.offset + e.numel()].copy_from_slice(&m.data);
        Ok(())
    }

    /// The contiguous flat slice of transformer block `l` (input to the
    /// `block_capture` executable).
    pub fn block_slice(&self, l: usize) -> Result<&[f32]> {
        let first = self.entry(&format!("blocks.{l}.ln1"))?;
        let off = first.offset;
        Ok(&self.flat[off..off + self.block_flat_size])
    }

    /// Overwrite block `l` from a flat slice.
    pub fn set_block(&mut self, l: usize, data: &[f32]) -> Result<()> {
        let first = self.entry(&format!("blocks.{l}.ln1"))?.offset;
        if data.len() != self.block_flat_size {
            bail!("block slice size mismatch");
        }
        self.flat[first..first + self.block_flat_size].copy_from_slice(data);
        Ok(())
    }

    /// Names of the prunable layers of block `l`, pipeline order.
    pub fn prunable_layers(&self, l: usize) -> Vec<String> {
        ["wq", "wk", "wv", "wo", "w1", "w2"]
            .iter()
            .map(|s| format!("blocks.{l}.{s}"))
            .collect()
    }

    /// Overall sparsity of the prunable layers.
    pub fn prunable_sparsity(&self) -> f64 {
        let mut zeros = 0usize;
        let mut total = 0usize;
        for l in 0..self.config.n_layers {
            for name in self.prunable_layers(l) {
                let e = self.entry(&name).unwrap();
                let s = &self.flat[e.offset..e.offset + e.numel()];
                zeros += s.iter().filter(|&&v| v == 0.0).count();
                total += s.len();
            }
        }
        zeros as f64 / total as f64
    }

    // -- checkpoint IO ---------------------------------------------------

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let header = obj(vec![
            ("config", self.config.to_json()),
            ("block_flat_size", Json::Num(self.block_flat_size as f64)),
            (
                "layout",
                Json::Arr(
                    self.layout
                        .iter()
                        .map(|e| {
                            obj(vec![
                                ("name", Json::Str(e.name.clone())),
                                ("offset", Json::Num(e.offset as f64)),
                                (
                                    "shape",
                                    crate::jsonutil::arr_usize(&e.shape),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
        .to_string_compact();
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for v in &self.flat {
            f.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<ModelState> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(&path)
                .with_context(|| format!("opening checkpoint {}", path.as_ref().display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a thanos checkpoint (bad magic)");
        }
        let mut v4 = [0u8; 4];
        f.read_exact(&mut v4)?;
        let version = u32::from_le_bytes(v4);
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let mut l8 = [0u8; 8];
        f.read_exact(&mut l8)?;
        let hlen = u64::from_le_bytes(l8) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)?;
        let config = ModelConfig::from_json(header.get("config")?)?;
        let layout: Vec<ParamEntry> = header
            .get("layout")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(ParamEntry {
                    name: e.get("name")?.as_str()?.to_string(),
                    offset: e.get("offset")?.as_usize()?,
                    shape: e
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<_>>()?;
        let flat_size: usize = layout.iter().map(|e| e.numel()).sum();
        let mut data = Vec::new();
        f.read_to_end(&mut data)?;
        if data.len() != flat_size * 4 {
            bail!(
                "checkpoint data length {} != expected {}",
                data.len(),
                flat_size * 4
            );
        }
        let flat: Vec<f32> = data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(ModelState {
            config,
            layout,
            block_flat_size: header.get("block_flat_size")?.as_usize()?,
            flat,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest() -> ModelManifest {
        // layout mirroring the python param_specs for a micro config
        let cfg = ModelConfig {
            name: "micro".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 16,
            seq_len: 4,
        };
        let mut layout = Vec::new();
        let mut off = 0usize;
        let push = |layout: &mut Vec<ParamEntry>, name: &str, shape: Vec<usize>, off: &mut usize| {
            let numel: usize = shape.iter().product();
            layout.push(ParamEntry { name: name.into(), offset: *off, shape });
            *off += numel;
        };
        push(&mut layout, "emb", vec![16, 8], &mut off);
        push(&mut layout, "pos", vec![4, 8], &mut off);
        let mut block_flat = 0;
        for l in 0..2 {
            let before = off;
            push(&mut layout, &format!("blocks.{l}.ln1"), vec![8], &mut off);
            for w in ["wq", "wk", "wv", "wo"] {
                push(&mut layout, &format!("blocks.{l}.{w}"), vec![8, 8], &mut off);
            }
            push(&mut layout, &format!("blocks.{l}.ln2"), vec![8], &mut off);
            push(&mut layout, &format!("blocks.{l}.w1"), vec![16, 8], &mut off);
            push(&mut layout, &format!("blocks.{l}.w2"), vec![8, 16], &mut off);
            block_flat = off - before;
        }
        push(&mut layout, "ln_f", vec![8], &mut off);
        ModelManifest { config: cfg, flat_size: off, block_flat_size: block_flat, layout }
    }

    #[test]
    fn init_layout_and_access() {
        let mm = fake_manifest();
        let st = ModelState::init(&mm, 42);
        assert_eq!(st.flat.len(), mm.flat_size);
        // norms at 1
        let e = st.entry("blocks.0.ln1").unwrap();
        assert!(st.flat[e.offset..e.offset + 8].iter().all(|&v| v == 1.0));
        // matrices non-trivial
        let wq = st.get_mat("blocks.0.wq").unwrap();
        assert!(wq.frob_norm_sq() > 0.0);
    }

    #[test]
    fn set_get_roundtrip() {
        let mm = fake_manifest();
        let mut st = ModelState::init(&mm, 1);
        let mut w = st.get_mat("blocks.1.w1").unwrap();
        w.data[3] = 99.0;
        st.set_mat("blocks.1.w1", &w).unwrap();
        assert_eq!(st.get_mat("blocks.1.w1").unwrap().data[3], 99.0);
        // wrong shape rejected
        let bad = Mat::zeros(3, 3);
        assert!(st.set_mat("blocks.1.w1", &bad).is_err());
    }

    #[test]
    fn block_slice_contains_block_params() {
        let mm = fake_manifest();
        let st = ModelState::init(&mm, 2);
        let b1 = st.block_slice(1).unwrap();
        assert_eq!(b1.len(), mm.block_flat_size);
        // w2 of block 1 is at the end of the slice
        let e = st.entry("blocks.1.w2").unwrap();
        let rel = e.offset - st.entry("blocks.1.ln1").unwrap().offset;
        assert_eq!(&b1[rel..rel + 4], &st.flat[e.offset..e.offset + 4]);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let mm = fake_manifest();
        let mut st = ModelState::init(&mm, 3);
        st.flat[7] = -1.25;
        let dir = std::env::temp_dir().join("thanos_test_ckpt");
        let path = dir.join("m.thnck");
        st.save(&path).unwrap();
        let back = ModelState::load(&path).unwrap();
        assert_eq!(back.flat, st.flat);
        assert_eq!(back.config, st.config);
        assert_eq!(back.block_flat_size, st.block_flat_size);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sparsity_accounting() {
        let mm = fake_manifest();
        let mut st = ModelState::init(&mm, 4);
        assert_eq!(st.prunable_sparsity(), 0.0);
        let mut w = st.get_mat("blocks.0.wq").unwrap();
        w.data.iter_mut().for_each(|v| *v = 0.0);
        st.set_mat("blocks.0.wq", &w).unwrap();
        let total: usize = (0..2)
            .flat_map(|l| st.prunable_layers(l))
            .map(|n| st.entry(&n).unwrap().numel())
            .sum();
        assert!((st.prunable_sparsity() - 64.0 / total as f64).abs() < 1e-12);
    }
}
