//! Model state: the flat parameter vector, named-layer access by
//! manifest layout, and checkpoint IO (own binary format — no external
//! serialization crates offline).
//!
//! Checkpoint formats (`.thnck`):
//! ```text
//! v1 (dense):      magic "THNS" | u32 1 | u64 json_len | json header | f32 data (LE)
//! v2 (compressed): magic "THNS" | u32 2 | u64 json_len | json header
//!                  | f32 data of the non-compressed params (layout order, LE)
//!                  | serialized sparse tensors (header `sparse` order)
//! v3 (sectioned):  magic "THNS" | u32 3 | u32 n_sections
//!                  | n_sections x (u64 len | u64 crc64)   -- section table
//!                  | section bytes, concatenated
//!                  section 0 = json header, section 1 = dense f32 payload,
//!                  sections 2.. = sparse tensor blobs (header `sparse` order)
//! ```
//! The JSON header carries the model config and the parameter layout so
//! a checkpoint is self-describing (loadable without the manifest); a
//! compressed header additionally lists `sparse: [{name, len}]` — the
//! layers stored as [`crate::sparse::SparseTensor`] blobs instead of
//! dense f32. [`ModelState::load`] reads all three versions; compressed
//! layers reconstruct **bit-identically** (pinned by the round-trip
//! tests). Writers emit v3 through [`crate::robust::atomic`] (temp file
//! + fsync + rename, CRC-64/XZ per section), so a crash never leaves a
//! torn checkpoint and every truncation or bit-flip of a v3 file is
//! detected at load with a descriptive error. `save_v1`/`save_v2` keep
//! the legacy formats writable for back-compat tests and tooling.

use crate::config::ModelConfig;
use crate::jsonutil::{obj, Json};
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::runtime::{ModelManifest, ParamEntry};
use crate::sparse::{SparseLayer, SparseModel, SparseTensor};
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashSet;
use std::io::Write;
use std::path::Path;

const MAGIC: &[u8; 4] = b"THNS";
/// v1: the whole flat vector as dense f32.
const VERSION_DENSE: u32 = 1;
/// v2: compressed prunable layers + dense remainder.
const VERSION_SPARSE: u32 = 2;
/// v3: CRC-64 checksummed sections (header | dense | sparse blobs).
const VERSION_SECTIONED: u32 = 3;
/// Sanity cap on the v3 section count (header + dense + sparse layers).
const MAX_SECTIONS: usize = 4096;

/// Transformer parameter state over a single flat f32 vector.
#[derive(Clone)]
pub struct ModelState {
    pub config: ModelConfig,
    pub layout: Vec<ParamEntry>,
    pub block_flat_size: usize,
    pub flat: Vec<f32>,
}

impl ModelState {
    /// Fresh random init (GPT-2 style: N(0, 0.02), residual-path scaled,
    /// norms at 1) following the manifest layout.
    pub fn init(mm: &ModelManifest, seed: u64) -> ModelState {
        let mut rng = Rng::new(seed);
        let mut flat = vec![0.0f32; mm.flat_size];
        let resid_std = 0.02 / (2.0 * mm.config.n_layers as f32).sqrt();
        for e in &mm.layout {
            let dst = &mut flat[e.offset..e.offset + e.numel()];
            if e.name.ends_with("ln1") || e.name.ends_with("ln2") || e.name.ends_with("ln_f") {
                dst.iter_mut().for_each(|v| *v = 1.0);
            } else if e.name.ends_with("wo") || e.name.ends_with("w2") {
                rng.fill_normal(dst, resid_std);
            } else {
                rng.fill_normal(dst, 0.02);
            }
        }
        ModelState {
            config: mm.config.clone(),
            layout: mm.layout.clone(),
            block_flat_size: mm.block_flat_size,
            flat,
        }
    }

    pub fn entry(&self, name: &str) -> Result<&ParamEntry> {
        self.layout
            .iter()
            .find(|e| e.name == name)
            .with_context(|| format!("no param '{name}'"))
    }

    /// Extract a weight matrix by name (must be 2-D).
    pub fn get_mat(&self, name: &str) -> Result<Mat> {
        let e = self.entry(name)?;
        if e.shape.len() != 2 {
            bail!("param '{name}' is not a matrix: {:?}", e.shape);
        }
        Ok(Mat::from_vec(
            e.shape[0],
            e.shape[1],
            self.flat[e.offset..e.offset + e.numel()].to_vec(),
        ))
    }

    /// Write a weight matrix back into the flat vector.
    pub fn set_mat(&mut self, name: &str, m: &Mat) -> Result<()> {
        let e = self.entry(name)?.clone();
        if e.shape != [m.rows, m.cols] {
            bail!(
                "shape mismatch for '{name}': {:?} vs {}x{}",
                e.shape,
                m.rows,
                m.cols
            );
        }
        self.flat[e.offset..e.offset + e.numel()].copy_from_slice(&m.data);
        Ok(())
    }

    /// The contiguous flat slice of transformer block `l` (input to the
    /// `block_capture` executable).
    pub fn block_slice(&self, l: usize) -> Result<&[f32]> {
        let first = self.entry(&format!("blocks.{l}.ln1"))?;
        let off = first.offset;
        Ok(&self.flat[off..off + self.block_flat_size])
    }

    /// Overwrite block `l` from a flat slice.
    pub fn set_block(&mut self, l: usize, data: &[f32]) -> Result<()> {
        let first = self.entry(&format!("blocks.{l}.ln1"))?.offset;
        if data.len() != self.block_flat_size {
            bail!("block slice size mismatch");
        }
        self.flat[first..first + self.block_flat_size].copy_from_slice(data);
        Ok(())
    }

    /// Names of the prunable layers of block `l`, pipeline order.
    pub fn prunable_layers(&self, l: usize) -> Vec<String> {
        ["wq", "wk", "wv", "wo", "w1", "w2"]
            .iter()
            .map(|s| format!("blocks.{l}.{s}"))
            .collect()
    }

    /// Overall sparsity of the prunable layers.
    pub fn prunable_sparsity(&self) -> f64 {
        let mut zeros = 0usize;
        let mut total = 0usize;
        for l in 0..self.config.n_layers {
            for name in self.prunable_layers(l) {
                let e = self.entry(&name).unwrap();
                let s = &self.flat[e.offset..e.offset + e.numel()];
                zeros += s.iter().filter(|&&v| v == 0.0).count();
                total += s.len();
            }
        }
        zeros as f64 / total as f64
    }

    // -- checkpoint IO ---------------------------------------------------

    /// The shared v1/v2 JSON header; v2 appends the `sparse` segment
    /// list.
    fn header_json(&self, sparse: Option<Json>) -> String {
        let mut pairs = vec![
            ("config", self.config.to_json()),
            ("block_flat_size", Json::Num(self.block_flat_size as f64)),
            (
                "layout",
                Json::Arr(
                    self.layout
                        .iter()
                        .map(|e| {
                            obj(vec![
                                ("name", Json::Str(e.name.clone())),
                                ("offset", Json::Num(e.offset as f64)),
                                (
                                    "shape",
                                    crate::jsonutil::arr_usize(&e.shape),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if let Some(s) = sparse {
            pairs.push(("sparse", s));
        }
        obj(pairs).to_string_compact()
    }

    /// The dense f32 payload (little-endian, layout order), skipping the
    /// layers in `skip`.
    fn dense_payload(&self, skip: &HashSet<&str>) -> Vec<u8> {
        let mut out = Vec::new();
        for e in &self.layout {
            if skip.contains(e.name.as_str()) {
                continue;
            }
            for v in &self.flat[e.offset..e.offset + e.numel()] {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Serialize (and verify) the compressed layers: the header `sparse`
    /// list plus the tensor blobs, in a stable order.
    fn sparse_segments(&self, sparse: &SparseModel) -> Result<(Json, Vec<(String, Vec<u8>)>)> {
        sparse.verify_roundtrip(self)?;
        let segs: Vec<(String, Vec<u8>)> = sparse
            .layers
            .iter()
            .map(|l| (l.name.clone(), l.tensor.to_bytes()))
            .collect();
        let names: HashSet<&str> = segs.iter().map(|(n, _)| n.as_str()).collect();
        ensure!(names.len() == segs.len(), "duplicate layer in sparse model");
        let sparse_json = Json::Arr(
            segs.iter()
                .map(|(name, bytes)| {
                    obj(vec![
                        ("name", Json::Str(name.clone())),
                        ("len", Json::Num(bytes.len() as f64)),
                    ])
                })
                .collect(),
        );
        Ok((sparse_json, segs))
    }

    /// Write a v3 file: section table (lengths + CRC-64s) then sections,
    /// through the atomic temp-file + fsync + rename path.
    fn write_sectioned(path: &Path, sections: &[&[u8]]) -> Result<()> {
        let mut f = crate::robust::AtomicFile::create(path)?;
        f.write_all(MAGIC)?;
        f.write_all(&VERSION_SECTIONED.to_le_bytes())?;
        f.write_all(&(sections.len() as u32).to_le_bytes())?;
        for s in sections {
            f.write_all(&(s.len() as u64).to_le_bytes())?;
            f.write_all(&crate::robust::crc64(s).to_le_bytes())?;
        }
        for s in sections {
            f.write_all(s)?;
        }
        f.commit()?;
        Ok(())
    }

    /// Save a dense checkpoint (v3: checksummed sections, atomic write).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let header = self.header_json(None);
        let dense = self.dense_payload(&HashSet::new());
        Self::write_sectioned(path.as_ref(), &[header.as_bytes(), &dense])
    }

    /// Save a compressed checkpoint (v3): the layers covered by `sparse`
    /// are stored as one tensor-blob section each, everything else in
    /// the dense section. Verifies first that every compressed layer
    /// reproduces the current weights bitwise, so a reload is guaranteed
    /// bit-identical.
    pub fn save_compressed(&self, path: impl AsRef<Path>, sparse: &SparseModel) -> Result<()> {
        let (sparse_json, segs) = self.sparse_segments(sparse)?;
        let skip: HashSet<&str> = segs.iter().map(|(n, _)| n.as_str()).collect();
        let header = self.header_json(Some(sparse_json));
        let dense = self.dense_payload(&skip);
        let mut sections: Vec<&[u8]> = vec![header.as_bytes(), &dense];
        sections.extend(segs.iter().map(|(_, b)| b.as_slice()));
        Self::write_sectioned(path.as_ref(), &sections)
    }

    /// Save a legacy v1 (fully dense, unchecksummed) checkpoint. Kept
    /// for back-compat coverage and tooling; still written atomically.
    pub fn save_v1(&self, path: impl AsRef<Path>) -> Result<()> {
        let header = self.header_json(None);
        let mut out = Vec::with_capacity(16 + header.len() + self.flat.len() * 4);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION_DENSE.to_le_bytes());
        out.extend_from_slice(&(header.len() as u64).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.append(&mut self.dense_payload(&HashSet::new()));
        crate::robust::write_atomic(path, &out)?;
        Ok(())
    }

    /// Save a legacy v2 (compressed, unchecksummed) checkpoint.
    pub fn save_v2(&self, path: impl AsRef<Path>, sparse: &SparseModel) -> Result<()> {
        let (sparse_json, segs) = self.sparse_segments(sparse)?;
        let skip: HashSet<&str> = segs.iter().map(|(n, _)| n.as_str()).collect();
        let header = self.header_json(Some(sparse_json));
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION_SPARSE.to_le_bytes());
        out.extend_from_slice(&(header.len() as u64).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.append(&mut self.dense_payload(&skip));
        for (_, bytes) in &segs {
            out.extend_from_slice(bytes);
        }
        crate::robust::write_atomic(path, &out)?;
        Ok(())
    }

    /// Load a checkpoint of any supported version (the sparse tensors of
    /// a compressed file are decompressed and dropped; use
    /// [`Self::load_with_sparse`] to keep them).
    pub fn load(path: impl AsRef<Path>) -> Result<ModelState> {
        Ok(Self::load_with_sparse(path)?.0)
    }

    /// Load a checkpoint; for compressed files additionally returns the
    /// tensors ready for [`crate::sparse::kernels`].
    pub fn load_with_sparse(
        path: impl AsRef<Path>,
    ) -> Result<(ModelState, Option<SparseModel>)> {
        let bytes = std::fs::read(&path)
            .with_context(|| format!("opening checkpoint {}", path.as_ref().display()))?;
        Self::from_bytes(&bytes)
            .with_context(|| format!("loading checkpoint {}", path.as_ref().display()))
    }

    /// Load a v3 checkpoint incrementally through
    /// [`crate::robust::stream::SectionedReader`]: the dense section
    /// and sparse blobs stream chunk-at-a-time with rolling CRC-64
    /// verification, so peak memory is the decoded model plus one
    /// stream chunk instead of model + whole serialized file. The
    /// result is bitwise-identical to [`Self::load_with_sparse`] on
    /// every valid v3 file; corrupt input errs before a caller can
    /// observe a complete-but-wrong model. Legacy v1/v2 files (no
    /// section CRCs to stream against) are refused — use
    /// [`Self::load_with_sparse`] for those.
    pub fn load_streamed(path: impl AsRef<Path>) -> Result<(ModelState, Option<SparseModel>)> {
        const STREAM_CHUNK: usize = 1 << 20;
        let path = path.as_ref();
        let mut r = crate::robust::stream::SectionedReader::open(path)?;
        let n = r.n_sections();
        let header_bytes = r
            .read_section(0)
            .with_context(|| format!("loading checkpoint {}", path.display()))?;
        let mut hdr = Header::parse(&header_bytes, false)?;
        let sparse_list = hdr.sparse.take();
        let compressed: HashSet<&str> = sparse_list
            .iter()
            .flatten()
            .map(|(nm, _)| nm.as_str())
            .collect();

        // Dense section: stream into `flat` in layout order, carrying
        // f32s split across chunk boundaries (≤ 3 leftover bytes).
        let entries: Vec<(usize, usize)> = hdr
            .layout
            .iter()
            .filter(|e| !compressed.contains(e.name.as_str()))
            .map(|e| (e.offset, e.numel()))
            .collect();
        let expected: u64 = entries.iter().map(|&(_, numel)| numel as u64 * 4).sum();
        ensure!(
            expected == r.section_len(1),
            "dense section of {} holds {} bytes but the layout needs {expected}",
            path.display(),
            r.section_len(1)
        );
        let mut flat = vec![0.0f32; hdr.flat_size];
        let mut entry = 0usize;
        let mut within = 0usize;
        let mut carry = [0u8; 4];
        let mut carry_len = 0usize;
        r.for_each_chunk(1, STREAM_CHUNK, |mut piece| {
            while !piece.is_empty() {
                let take = (4 - carry_len).min(piece.len());
                carry[carry_len..carry_len + take].copy_from_slice(&piece[..take]);
                carry_len += take;
                piece = &piece[take..];
                if carry_len < 4 {
                    break;
                }
                while entry < entries.len() && within == entries[entry].1 {
                    entry += 1;
                    within = 0;
                }
                // unreachable given the exact length check above
                ensure!(entry < entries.len(), "dense payload overruns the layout");
                flat[entries[entry].0 + within] = f32::from_le_bytes(carry);
                within += 1;
                carry_len = 0;
            }
            Ok(())
        })
        .with_context(|| format!("loading checkpoint {}", path.display()))?;

        let sparse = match &sparse_list {
            None => {
                ensure!(
                    n == 2,
                    "v3 checkpoint has {n} sections but no sparse list in its header"
                );
                None
            }
            Some(list) => {
                ensure!(
                    list.len() == n - 2,
                    "v3 header lists {} sparse layers but the file has {} blob sections",
                    list.len(),
                    n - 2
                );
                ensure!(compressed.len() == list.len(), "duplicate layer in sparse list");
                let mut layers = Vec::with_capacity(list.len());
                for (i, (name, len)) in list.iter().enumerate() {
                    let sec = 2 + i;
                    ensure!(
                        *len as u64 == r.section_len(sec),
                        "sparse layer '{name}': header says {len} bytes, \
                         section {sec} carries {}",
                        r.section_len(sec)
                    );
                    let mut pieces: Vec<Vec<u8>> = Vec::new();
                    r.for_each_chunk(sec, STREAM_CHUNK, |piece| {
                        pieces.push(piece.to_vec());
                        Ok(())
                    })?;
                    let tensor =
                        SparseTensor::from_chunks(pieces.iter().map(|p| p.as_slice()), *len)
                            .with_context(|| format!("decoding compressed layer '{name}'"))?;
                    layers.push(place_sparse_layer(&hdr.layout, &mut flat, name, tensor)?);
                }
                Some(SparseModel { layers })
            }
        };
        Ok((
            ModelState {
                config: hdr.config,
                layout: hdr.layout,
                block_flat_size: hdr.block_flat_size,
                flat,
            },
            sparse,
        ))
    }

    /// Decode a checkpoint image of any supported version. Every length,
    /// offset and (for v3) checksum is validated with overflow-safe
    /// arithmetic: corrupt input yields a descriptive `Err`, never a
    /// panic.
    pub fn from_bytes(bytes: &[u8]) -> Result<(ModelState, Option<SparseModel>)> {
        ensure!(bytes.len() >= 8, "checkpoint too short: {} bytes", bytes.len());
        ensure!(&bytes[..4] == MAGIC, "not a thanos checkpoint (bad magic)");
        let version = u32::from_le_bytes(bytes[4..8].try_into().expect("4-byte slice"));
        match version {
            VERSION_DENSE | VERSION_SPARSE => Self::decode_v12(version, &bytes[8..]),
            VERSION_SECTIONED => Self::decode_v3(&bytes[8..]),
            v => bail!("unsupported checkpoint version {v}"),
        }
    }

    /// Decode the legacy v1/v2 body (everything after magic + version).
    fn decode_v12(version: u32, rest: &[u8]) -> Result<(ModelState, Option<SparseModel>)> {
        ensure!(rest.len() >= 8, "truncated checkpoint: missing header length");
        let hlen = u64::from_le_bytes(rest[..8].try_into().expect("8-byte slice"));
        ensure!(
            hlen <= (rest.len() - 8) as u64,
            "header length {hlen} exceeds the file's remaining {} bytes",
            rest.len() - 8
        );
        let hlen = hlen as usize;
        let mut hdr = Header::parse(&rest[8..8 + hlen], version == VERSION_SPARSE)?;
        let data = &rest[8 + hlen..];

        if version == VERSION_DENSE {
            let flat = decode_dense_exact(data, &hdr.layout, &HashSet::new(), hdr.flat_size)?;
            return Ok((
                ModelState {
                    config: hdr.config,
                    layout: hdr.layout,
                    block_flat_size: hdr.block_flat_size,
                    flat,
                },
                None,
            ));
        }

        // v2: dense remainder in layout order, then the sparse segments
        let sparse_list = hdr.sparse.take().expect("v2 header carries a sparse list");
        let compressed: HashSet<&str> = sparse_list.iter().map(|(n, _)| n.as_str()).collect();
        let mut flat = vec![0.0f32; hdr.flat_size];
        let mut off = 0usize;
        for e in &hdr.layout {
            if compressed.contains(e.name.as_str()) {
                continue;
            }
            let nbytes = e.numel() * 4;
            // `nbytes <= len - off` (not `off + nbytes <= len`): a
            // corrupt header could make the sum wrap in release builds
            ensure!(
                nbytes <= data.len() - off,
                "truncated dense section at param '{}'",
                e.name
            );
            for (dst, c) in flat[e.offset..e.offset + e.numel()]
                .iter_mut()
                .zip(data[off..off + nbytes].chunks_exact(4))
            {
                *dst = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            off += nbytes;
        }
        let mut layers = Vec::with_capacity(sparse_list.len());
        for (name, len) in &sparse_list {
            ensure!(*len <= data.len() - off, "truncated sparse segment '{name}'");
            layers.push(decode_sparse_layer(
                &hdr.layout,
                &mut flat,
                name,
                &data[off..off + len],
            )?);
            off += len;
        }
        ensure!(off == data.len(), "trailing bytes in v2 checkpoint");
        Ok((
            ModelState {
                config: hdr.config,
                layout: hdr.layout,
                block_flat_size: hdr.block_flat_size,
                flat,
            },
            Some(SparseModel { layers }),
        ))
    }

    /// Decode the v3 sectioned body (everything after magic + version).
    fn decode_v3(rest: &[u8]) -> Result<(ModelState, Option<SparseModel>)> {
        ensure!(rest.len() >= 4, "truncated v3 checkpoint: missing section count");
        let n = u32::from_le_bytes(rest[..4].try_into().expect("4-byte slice")) as usize;
        ensure!(
            (2..=MAX_SECTIONS).contains(&n),
            "v3 checkpoint declares {n} sections (expected 2..={MAX_SECTIONS})"
        );
        let table_len = n * 16;
        ensure!(table_len <= rest.len() - 4, "truncated v3 section table");
        let body = &rest[4 + table_len..];
        let mut table = Vec::with_capacity(n);
        let mut total: u64 = 0;
        for i in 0..n {
            let base = 4 + i * 16;
            let len = u64::from_le_bytes(rest[base..base + 8].try_into().expect("8-byte slice"));
            let crc = u64::from_le_bytes(
                rest[base + 8..base + 16].try_into().expect("8-byte slice"),
            );
            total = total
                .checked_add(len)
                .context("v3 section lengths overflow")?;
            table.push((len, crc));
        }
        ensure!(
            total == body.len() as u64,
            "v3 sections total {total} bytes but {} payload bytes are present \
             (truncated or corrupt section table)",
            body.len()
        );
        let mut sections: Vec<&[u8]> = Vec::with_capacity(n);
        let mut off = 0usize;
        for (i, (len, crc)) in table.iter().enumerate() {
            let len = *len as usize;
            let sec = &body[off..off + len];
            let got = crate::robust::crc64(sec);
            ensure!(
                got == *crc,
                "checkpoint section {i} fails its CRC-64 \
                 (stored {crc:016x}, computed {got:016x}): the file is corrupt"
            );
            sections.push(sec);
            off += len;
        }
        let mut hdr = Header::parse(sections[0], false)?;
        match hdr.sparse.take() {
            None => {
                ensure!(
                    n == 2,
                    "v3 checkpoint has {n} sections but no sparse list in its header"
                );
                let flat =
                    decode_dense_exact(sections[1], &hdr.layout, &HashSet::new(), hdr.flat_size)?;
                Ok((
                    ModelState {
                        config: hdr.config,
                        layout: hdr.layout,
                        block_flat_size: hdr.block_flat_size,
                        flat,
                    },
                    None,
                ))
            }
            Some(list) => {
                ensure!(
                    list.len() == n - 2,
                    "v3 header lists {} sparse layers but the file has {} blob sections",
                    list.len(),
                    n - 2
                );
                let compressed: HashSet<&str> = list.iter().map(|(nm, _)| nm.as_str()).collect();
                ensure!(compressed.len() == list.len(), "duplicate layer in sparse list");
                let mut flat =
                    decode_dense_exact(sections[1], &hdr.layout, &compressed, hdr.flat_size)?;
                let mut layers = Vec::with_capacity(list.len());
                for (i, (name, len)) in list.iter().enumerate() {
                    let blob = sections[2 + i];
                    ensure!(
                        *len == blob.len(),
                        "sparse layer '{name}': header says {len} bytes, \
                         section {} carries {}",
                        2 + i,
                        blob.len()
                    );
                    layers.push(decode_sparse_layer(&hdr.layout, &mut flat, name, blob)?);
                }
                Ok((
                    ModelState {
                        config: hdr.config,
                        layout: hdr.layout,
                        block_flat_size: hdr.block_flat_size,
                        flat,
                    },
                    Some(SparseModel { layers }),
                ))
            }
        }
    }
}

/// Parsed and validated checkpoint header.
struct Header {
    config: ModelConfig,
    layout: Vec<ParamEntry>,
    block_flat_size: usize,
    flat_size: usize,
    sparse: Option<Vec<(String, usize)>>,
}

impl Header {
    /// Parse and validate a checkpoint header. Offsets and shapes are
    /// checked against the derived flat size with overflow-safe
    /// arithmetic, so a corrupt header produces an error rather than a
    /// panic or an absurd allocation downstream.
    fn parse(bytes: &[u8], require_sparse: bool) -> Result<Header> {
        let text = std::str::from_utf8(bytes).context("checkpoint header is not UTF-8")?;
        let header = Json::parse(text)?;
        let config = ModelConfig::from_json(header.get("config")?)?;
        let mut layout: Vec<ParamEntry> = Vec::new();
        let mut flat_size = 0usize;
        for e in header.get("layout")?.as_arr()? {
            let name = e.get("name")?.as_str()?.to_string();
            let offset = e.get("offset")?.as_usize()?;
            let shape: Vec<usize> = e
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?;
            let numel = shape
                .iter()
                .try_fold(1usize, |a, &d| a.checked_mul(d))
                .with_context(|| format!("param '{name}': shape {shape:?} overflows"))?;
            flat_size = flat_size
                .checked_add(numel)
                .with_context(|| format!("layout sizes overflow at param '{name}'"))?;
            layout.push(ParamEntry { name, offset, shape });
        }
        ensure!(
            flat_size.checked_mul(4).is_some(),
            "flat size {flat_size} is implausibly large"
        );
        for e in &layout {
            let numel = e.numel(); // safe: checked-multiplied above
            ensure!(
                numel <= flat_size && e.offset <= flat_size - numel,
                "param '{}' at offset {} with {} elements exceeds the flat size {}",
                e.name,
                e.offset,
                numel,
                flat_size
            );
        }
        let block_flat_size = header.get("block_flat_size")?.as_usize()?;
        ensure!(
            block_flat_size <= flat_size,
            "block_flat_size {block_flat_size} exceeds flat size {flat_size}"
        );
        let sparse = match header.get_opt("sparse") {
            Some(s) => Some(
                s.as_arr()?
                    .iter()
                    .map(|e| {
                        Ok((
                            e.get("name")?.as_str()?.to_string(),
                            e.get("len")?.as_usize()?,
                        ))
                    })
                    .collect::<Result<Vec<_>>>()?,
            ),
            None => None,
        };
        ensure!(
            !require_sparse || sparse.is_some(),
            "v2 checkpoint header lacks a `sparse` list"
        );
        Ok(Header { config, layout, block_flat_size, flat_size, sparse })
    }
}

/// Decode a dense f32 payload (layout order, skipping `skip`) that must
/// account for every byte of `data`.
fn decode_dense_exact(
    data: &[u8],
    layout: &[ParamEntry],
    skip: &HashSet<&str>,
    flat_size: usize,
) -> Result<Vec<f32>> {
    let mut flat = vec![0.0f32; flat_size];
    let mut off = 0usize;
    for e in layout {
        if skip.contains(e.name.as_str()) {
            continue;
        }
        let nbytes = e.numel() * 4;
        ensure!(
            nbytes <= data.len() - off,
            "truncated dense payload at param '{}'",
            e.name
        );
        for (dst, c) in flat[e.offset..e.offset + e.numel()]
            .iter_mut()
            .zip(data[off..off + nbytes].chunks_exact(4))
        {
            *dst = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        off += nbytes;
    }
    ensure!(
        off == data.len(),
        "dense payload carries {} unexpected trailing bytes",
        data.len() - off
    );
    Ok(flat)
}

/// Decode one compressed layer blob, write it densely into `flat`, and
/// return the kept tensor.
fn decode_sparse_layer(
    layout: &[ParamEntry],
    flat: &mut [f32],
    name: &str,
    blob: &[u8],
) -> Result<SparseLayer> {
    let tensor = SparseTensor::from_bytes(blob)
        .with_context(|| format!("decoding compressed layer '{name}'"))?;
    place_sparse_layer(layout, flat, name, tensor)
}

/// Validate a decoded tensor against the layout, write it densely into
/// `flat`, and return the kept tensor (shared by the whole-image and
/// streamed v3 loaders).
fn place_sparse_layer(
    layout: &[ParamEntry],
    flat: &mut [f32],
    name: &str,
    tensor: SparseTensor,
) -> Result<SparseLayer> {
    let e = layout
        .iter()
        .find(|e| e.name == name)
        .with_context(|| format!("compressed layer '{name}' not in layout"))?;
    ensure!(
        e.shape == [tensor.rows(), tensor.cols()],
        "compressed layer '{name}': shape {:?} vs {}x{}",
        e.shape,
        tensor.rows(),
        tensor.cols()
    );
    let dense = tensor.to_dense();
    flat[e.offset..e.offset + e.numel()].copy_from_slice(&dense.data);
    Ok(SparseLayer { name: name.to_string(), tensor })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest() -> ModelManifest {
        // layout mirroring the python param_specs for a micro config
        let cfg = ModelConfig {
            name: "micro".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 16,
            seq_len: 4,
        };
        let mut layout = Vec::new();
        let mut off = 0usize;
        let push = |layout: &mut Vec<ParamEntry>, name: &str, shape: Vec<usize>, off: &mut usize| {
            let numel: usize = shape.iter().product();
            layout.push(ParamEntry { name: name.into(), offset: *off, shape });
            *off += numel;
        };
        push(&mut layout, "emb", vec![16, 8], &mut off);
        push(&mut layout, "pos", vec![4, 8], &mut off);
        let mut block_flat = 0;
        for l in 0..2 {
            let before = off;
            push(&mut layout, &format!("blocks.{l}.ln1"), vec![8], &mut off);
            for w in ["wq", "wk", "wv", "wo"] {
                push(&mut layout, &format!("blocks.{l}.{w}"), vec![8, 8], &mut off);
            }
            push(&mut layout, &format!("blocks.{l}.ln2"), vec![8], &mut off);
            push(&mut layout, &format!("blocks.{l}.w1"), vec![16, 8], &mut off);
            push(&mut layout, &format!("blocks.{l}.w2"), vec![8, 16], &mut off);
            block_flat = off - before;
        }
        push(&mut layout, "ln_f", vec![8], &mut off);
        ModelManifest { config: cfg, flat_size: off, block_flat_size: block_flat, layout }
    }

    #[test]
    fn init_layout_and_access() {
        let mm = fake_manifest();
        let st = ModelState::init(&mm, 42);
        assert_eq!(st.flat.len(), mm.flat_size);
        // norms at 1
        let e = st.entry("blocks.0.ln1").unwrap();
        assert!(st.flat[e.offset..e.offset + 8].iter().all(|&v| v == 1.0));
        // matrices non-trivial
        let wq = st.get_mat("blocks.0.wq").unwrap();
        assert!(wq.frob_norm_sq() > 0.0);
    }

    #[test]
    fn set_get_roundtrip() {
        let mm = fake_manifest();
        let mut st = ModelState::init(&mm, 1);
        let mut w = st.get_mat("blocks.1.w1").unwrap();
        w.data[3] = 99.0;
        st.set_mat("blocks.1.w1", &w).unwrap();
        assert_eq!(st.get_mat("blocks.1.w1").unwrap().data[3], 99.0);
        // wrong shape rejected
        let bad = Mat::zeros(3, 3);
        assert!(st.set_mat("blocks.1.w1", &bad).is_err());
    }

    #[test]
    fn block_slice_contains_block_params() {
        let mm = fake_manifest();
        let st = ModelState::init(&mm, 2);
        let b1 = st.block_slice(1).unwrap();
        assert_eq!(b1.len(), mm.block_flat_size);
        // w2 of block 1 is at the end of the slice
        let e = st.entry("blocks.1.w2").unwrap();
        let rel = e.offset - st.entry("blocks.1.ln1").unwrap().offset;
        assert_eq!(&b1[rel..rel + 4], &st.flat[e.offset..e.offset + 4]);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let mm = fake_manifest();
        let mut st = ModelState::init(&mm, 3);
        st.flat[7] = -1.25;
        let dir = std::env::temp_dir().join("thanos_test_ckpt");
        let path = dir.join("m.thnck");
        st.save(&path).unwrap();
        let back = ModelState::load(&path).unwrap();
        assert_eq!(back.flat, st.flat);
        assert_eq!(back.config, st.config);
        assert_eq!(back.block_flat_size, st.block_flat_size);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_roundtrips_across_versions() {
        let mm = fake_manifest();
        let mut st = ModelState::init(&mm, 7);
        // prune every prunable layer to 2:4, then compress
        for l in 0..2 {
            for name in st.prunable_layers(l) {
                let w = st.get_mat(&name).unwrap();
                let pruned = crate::pruning::magnitude::semi_structured(&w, 2, 4).w;
                st.set_mat(&name, &pruned).unwrap();
            }
        }
        let pattern = crate::pruning::Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 };
        let sm = SparseModel::compress_state(&st, &pattern).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let dir = std::env::temp_dir().join("thanos_test_ckpt_v2");
        // v3 sectioned (what the writers emit today)
        let p3 = dir.join("m3.thnck");
        st.save_compressed(&p3, &sm).unwrap();
        let (back, sparse) = ModelState::load_with_sparse(&p3).unwrap();
        assert_eq!(bits(&back.flat), bits(&st.flat), "v3 reload must be bit-identical");
        assert_eq!(sparse.unwrap().layers.len(), 12);
        // legacy v2 still loads through the same entry points
        let p2 = dir.join("m2.thnck");
        st.save_v2(&p2, &sm).unwrap();
        let (back2, sparse2) = ModelState::load_with_sparse(&p2).unwrap();
        assert_eq!(bits(&back2.flat), bits(&st.flat), "v2 reload must be bit-identical");
        assert_eq!(sparse2.unwrap().layers.len(), 12);
        // legacy v1 too
        let p1 = dir.join("m1.thnck");
        st.save_v1(&p1).unwrap();
        let (b1, none) = ModelState::load_with_sparse(&p1).unwrap();
        assert!(none.is_none());
        assert_eq!(bits(&b1.flat), bits(&st.flat));
        assert_eq!(bits(&ModelState::load(&p3).unwrap().flat), bits(&st.flat));
        // compressed layers shrink the file despite header + section table
        let s1 = std::fs::metadata(&p1).unwrap().len();
        let s3 = std::fs::metadata(&p3).unwrap().len();
        assert!(s3 < s1, "v3 {s3} bytes !< v1 {s1} bytes");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streamed_load_is_bitwise_identical() {
        let mm = fake_manifest();
        let mut st = ModelState::init(&mm, 21);
        for l in 0..2 {
            for name in st.prunable_layers(l) {
                let w = st.get_mat(&name).unwrap();
                let pruned = crate::pruning::magnitude::semi_structured(&w, 2, 4).w;
                st.set_mat(&name, &pruned).unwrap();
            }
        }
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let dir = std::env::temp_dir().join("thanos_test_ckpt_streamed");
        // dense v3
        let pd = dir.join("dense.thnck");
        st.save(&pd).unwrap();
        let (sd, none) = ModelState::load_streamed(&pd).unwrap();
        assert!(none.is_none());
        assert_eq!(bits(&sd.flat), bits(&ModelState::load(&pd).unwrap().flat));
        // compressed v3: streamed == whole-image load, sparse tensors kept
        let pattern = crate::pruning::Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 };
        let sm = SparseModel::compress_state(&st, &pattern).unwrap();
        let pc = dir.join("compressed.thnck");
        st.save_compressed(&pc, &sm).unwrap();
        let (whole, wsp) = ModelState::load_with_sparse(&pc).unwrap();
        let (streamed, ssp) = ModelState::load_streamed(&pc).unwrap();
        assert_eq!(bits(&streamed.flat), bits(&whole.flat));
        assert_eq!(ssp.unwrap().layers.len(), wsp.unwrap().layers.len());
        // a payload bit flip is rejected by the rolling section CRC
        let img = std::fs::read(&pc).unwrap();
        let mut bad = img.clone();
        let mid = img.len() / 2;
        bad[mid] ^= 0x40;
        std::fs::write(&pc, &bad).unwrap();
        assert!(ModelState::load_streamed(&pc).is_err());
        // legacy versions are refused descriptively, not misread
        let p1 = dir.join("legacy.thnck");
        st.save_v1(&p1).unwrap();
        let err = ModelState::load_streamed(&p1).unwrap_err();
        assert!(format!("{err:#}").contains("v3"), "unexpected error: {err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v3_detects_corruption() {
        let mm = fake_manifest();
        let st = ModelState::init(&mm, 9);
        let dir = std::env::temp_dir().join("thanos_test_ckpt_v3corrupt");
        let p = dir.join("m.thnck");
        st.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // a single payload bit-flip fails the section CRC
        let mut flipped = bytes.clone();
        let last = flipped.len() - 3;
        flipped[last] ^= 0x10;
        let err = ModelState::from_bytes(&flipped).unwrap_err();
        assert!(format!("{err:#}").contains("CRC-64"), "unexpected error: {err:#}");
        // any truncation is caught by the section-table total
        assert!(ModelState::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sparsity_accounting() {
        let mm = fake_manifest();
        let mut st = ModelState::init(&mm, 4);
        assert_eq!(st.prunable_sparsity(), 0.0);
        let mut w = st.get_mat("blocks.0.wq").unwrap();
        w.data.iter_mut().for_each(|v| *v = 0.0);
        st.set_mat("blocks.0.wq", &w).unwrap();
        let total: usize = (0..2)
            .flat_map(|l| st.prunable_layers(l))
            .map(|n| st.entry(&n).unwrap().numel())
            .sum();
        assert!((st.prunable_sparsity() - 64.0 / total as f64).abs() < 1e-12);
    }
}
