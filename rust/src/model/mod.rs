//! Model state: the flat parameter vector, named-layer access by
//! manifest layout, and checkpoint IO (own binary format — no external
//! serialization crates offline).
//!
//! Checkpoint formats (`.thnck`):
//! ```text
//! v1 (dense):      magic "THNS" | u32 1 | u64 json_len | json header | f32 data (LE)
//! v2 (compressed): magic "THNS" | u32 2 | u64 json_len | json header
//!                  | f32 data of the non-compressed params (layout order, LE)
//!                  | serialized sparse tensors (header `sparse` order)
//! ```
//! The JSON header carries the model config and the parameter layout so
//! a checkpoint is self-describing (loadable without the manifest); a
//! v2 header additionally lists `sparse: [{name, len}]` — the layers
//! stored as [`crate::sparse::SparseTensor`] blobs instead of dense
//! f32. [`ModelState::load`] reads both versions; compressed layers
//! reconstruct **bit-identically** (pinned by the round-trip tests).

use crate::config::ModelConfig;
use crate::jsonutil::{obj, Json};
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::runtime::{ModelManifest, ParamEntry};
use crate::sparse::{SparseLayer, SparseModel, SparseTensor};
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"THNS";
/// v1: the whole flat vector as dense f32.
const VERSION_DENSE: u32 = 1;
/// v2: compressed prunable layers + dense remainder.
const VERSION_SPARSE: u32 = 2;

/// Transformer parameter state over a single flat f32 vector.
#[derive(Clone)]
pub struct ModelState {
    pub config: ModelConfig,
    pub layout: Vec<ParamEntry>,
    pub block_flat_size: usize,
    pub flat: Vec<f32>,
}

impl ModelState {
    /// Fresh random init (GPT-2 style: N(0, 0.02), residual-path scaled,
    /// norms at 1) following the manifest layout.
    pub fn init(mm: &ModelManifest, seed: u64) -> ModelState {
        let mut rng = Rng::new(seed);
        let mut flat = vec![0.0f32; mm.flat_size];
        let resid_std = 0.02 / (2.0 * mm.config.n_layers as f32).sqrt();
        for e in &mm.layout {
            let dst = &mut flat[e.offset..e.offset + e.numel()];
            if e.name.ends_with("ln1") || e.name.ends_with("ln2") || e.name.ends_with("ln_f") {
                dst.iter_mut().for_each(|v| *v = 1.0);
            } else if e.name.ends_with("wo") || e.name.ends_with("w2") {
                rng.fill_normal(dst, resid_std);
            } else {
                rng.fill_normal(dst, 0.02);
            }
        }
        ModelState {
            config: mm.config.clone(),
            layout: mm.layout.clone(),
            block_flat_size: mm.block_flat_size,
            flat,
        }
    }

    pub fn entry(&self, name: &str) -> Result<&ParamEntry> {
        self.layout
            .iter()
            .find(|e| e.name == name)
            .with_context(|| format!("no param '{name}'"))
    }

    /// Extract a weight matrix by name (must be 2-D).
    pub fn get_mat(&self, name: &str) -> Result<Mat> {
        let e = self.entry(name)?;
        if e.shape.len() != 2 {
            bail!("param '{name}' is not a matrix: {:?}", e.shape);
        }
        Ok(Mat::from_vec(
            e.shape[0],
            e.shape[1],
            self.flat[e.offset..e.offset + e.numel()].to_vec(),
        ))
    }

    /// Write a weight matrix back into the flat vector.
    pub fn set_mat(&mut self, name: &str, m: &Mat) -> Result<()> {
        let e = self.entry(name)?.clone();
        if e.shape != [m.rows, m.cols] {
            bail!(
                "shape mismatch for '{name}': {:?} vs {}x{}",
                e.shape,
                m.rows,
                m.cols
            );
        }
        self.flat[e.offset..e.offset + e.numel()].copy_from_slice(&m.data);
        Ok(())
    }

    /// The contiguous flat slice of transformer block `l` (input to the
    /// `block_capture` executable).
    pub fn block_slice(&self, l: usize) -> Result<&[f32]> {
        let first = self.entry(&format!("blocks.{l}.ln1"))?;
        let off = first.offset;
        Ok(&self.flat[off..off + self.block_flat_size])
    }

    /// Overwrite block `l` from a flat slice.
    pub fn set_block(&mut self, l: usize, data: &[f32]) -> Result<()> {
        let first = self.entry(&format!("blocks.{l}.ln1"))?.offset;
        if data.len() != self.block_flat_size {
            bail!("block slice size mismatch");
        }
        self.flat[first..first + self.block_flat_size].copy_from_slice(data);
        Ok(())
    }

    /// Names of the prunable layers of block `l`, pipeline order.
    pub fn prunable_layers(&self, l: usize) -> Vec<String> {
        ["wq", "wk", "wv", "wo", "w1", "w2"]
            .iter()
            .map(|s| format!("blocks.{l}.{s}"))
            .collect()
    }

    /// Overall sparsity of the prunable layers.
    pub fn prunable_sparsity(&self) -> f64 {
        let mut zeros = 0usize;
        let mut total = 0usize;
        for l in 0..self.config.n_layers {
            for name in self.prunable_layers(l) {
                let e = self.entry(&name).unwrap();
                let s = &self.flat[e.offset..e.offset + e.numel()];
                zeros += s.iter().filter(|&&v| v == 0.0).count();
                total += s.len();
            }
        }
        zeros as f64 / total as f64
    }

    // -- checkpoint IO ---------------------------------------------------

    /// The shared v1/v2 JSON header; v2 appends the `sparse` segment
    /// list.
    fn header_json(&self, sparse: Option<Json>) -> String {
        let mut pairs = vec![
            ("config", self.config.to_json()),
            ("block_flat_size", Json::Num(self.block_flat_size as f64)),
            (
                "layout",
                Json::Arr(
                    self.layout
                        .iter()
                        .map(|e| {
                            obj(vec![
                                ("name", Json::Str(e.name.clone())),
                                ("offset", Json::Num(e.offset as f64)),
                                (
                                    "shape",
                                    crate::jsonutil::arr_usize(&e.shape),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if let Some(s) = sparse {
            pairs.push(("sparse", s));
        }
        obj(pairs).to_string_compact()
    }

    fn open_writer(path: impl AsRef<Path>) -> Result<std::io::BufWriter<std::fs::File>> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        Ok(std::io::BufWriter::new(std::fs::File::create(&path)?))
    }

    /// Save a v1 (fully dense) checkpoint.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let header = self.header_json(None);
        let mut f = Self::open_writer(path)?;
        f.write_all(MAGIC)?;
        f.write_all(&VERSION_DENSE.to_le_bytes())?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for v in &self.flat {
            f.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    /// Save a v2 checkpoint: the layers covered by `sparse` are stored
    /// as compressed tensors, everything else as dense f32. Verifies
    /// first that every compressed layer reproduces the current weights
    /// bitwise, so a reload is guaranteed bit-identical.
    pub fn save_compressed(&self, path: impl AsRef<Path>, sparse: &SparseModel) -> Result<()> {
        sparse.verify_roundtrip(self)?;
        let segs: Vec<(String, Vec<u8>)> = sparse
            .layers
            .iter()
            .map(|l| (l.name.clone(), l.tensor.to_bytes()))
            .collect();
        let compressed: std::collections::HashSet<&str> =
            segs.iter().map(|(n, _)| n.as_str()).collect();
        ensure!(
            compressed.len() == segs.len(),
            "duplicate layer in sparse model"
        );
        let sparse_json = Json::Arr(
            segs.iter()
                .map(|(name, bytes)| {
                    obj(vec![
                        ("name", Json::Str(name.clone())),
                        ("len", Json::Num(bytes.len() as f64)),
                    ])
                })
                .collect(),
        );
        let header = self.header_json(Some(sparse_json));
        let mut f = Self::open_writer(path)?;
        f.write_all(MAGIC)?;
        f.write_all(&VERSION_SPARSE.to_le_bytes())?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for e in &self.layout {
            if compressed.contains(e.name.as_str()) {
                continue;
            }
            for v in &self.flat[e.offset..e.offset + e.numel()] {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        for (_, bytes) in &segs {
            f.write_all(bytes)?;
        }
        Ok(())
    }

    /// Load a checkpoint of either version (the sparse tensors of a v2
    /// file are decompressed and dropped; use [`Self::load_with_sparse`]
    /// to keep them).
    pub fn load(path: impl AsRef<Path>) -> Result<ModelState> {
        Ok(Self::load_with_sparse(path)?.0)
    }

    /// Load a checkpoint; for v2 files additionally returns the
    /// compressed tensors ready for [`crate::sparse::kernels`].
    pub fn load_with_sparse(
        path: impl AsRef<Path>,
    ) -> Result<(ModelState, Option<SparseModel>)> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(&path)
                .with_context(|| format!("opening checkpoint {}", path.as_ref().display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a thanos checkpoint (bad magic)");
        }
        let mut v4 = [0u8; 4];
        f.read_exact(&mut v4)?;
        let version = u32::from_le_bytes(v4);
        if version != VERSION_DENSE && version != VERSION_SPARSE {
            bail!("unsupported checkpoint version {version}");
        }
        let mut l8 = [0u8; 8];
        f.read_exact(&mut l8)?;
        let hlen = u64::from_le_bytes(l8) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)?;
        let config = ModelConfig::from_json(header.get("config")?)?;
        let layout: Vec<ParamEntry> = header
            .get("layout")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(ParamEntry {
                    name: e.get("name")?.as_str()?.to_string(),
                    offset: e.get("offset")?.as_usize()?,
                    shape: e
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<_>>()?;
        let flat_size: usize = layout.iter().map(|e| e.numel()).sum();
        let block_flat_size = header.get("block_flat_size")?.as_usize()?;
        let mut data = Vec::new();
        f.read_to_end(&mut data)?;

        if version == VERSION_DENSE {
            if data.len() != flat_size * 4 {
                bail!(
                    "checkpoint data length {} != expected {}",
                    data.len(),
                    flat_size * 4
                );
            }
            let flat: Vec<f32> = data
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            return Ok((ModelState { config, layout, block_flat_size, flat }, None));
        }

        // v2: dense remainder in layout order, then the sparse segments
        let sparse_list: Vec<(String, usize)> = header
            .get("sparse")?
            .as_arr()?
            .iter()
            .map(|e| Ok((e.get("name")?.as_str()?.to_string(), e.get("len")?.as_usize()?)))
            .collect::<Result<_>>()?;
        let compressed: std::collections::HashSet<&str> =
            sparse_list.iter().map(|(n, _)| n.as_str()).collect();
        let mut flat = vec![0.0f32; flat_size];
        let mut off = 0usize;
        for e in &layout {
            if compressed.contains(e.name.as_str()) {
                continue;
            }
            let nbytes = e.numel() * 4;
            // `nbytes <= len - off` (not `off + nbytes <= len`): a
            // corrupt header could make the sum wrap in release builds
            ensure!(
                nbytes <= data.len() - off,
                "truncated dense section at param '{}'",
                e.name
            );
            for (dst, c) in flat[e.offset..e.offset + e.numel()]
                .iter_mut()
                .zip(data[off..off + nbytes].chunks_exact(4))
            {
                *dst = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            off += nbytes;
        }
        let mut layers = Vec::with_capacity(sparse_list.len());
        for (name, len) in sparse_list {
            ensure!(
                len <= data.len() - off,
                "truncated sparse segment '{name}'"
            );
            let tensor = SparseTensor::from_bytes(&data[off..off + len])
                .with_context(|| format!("decoding compressed layer '{name}'"))?;
            off += len;
            let e = layout
                .iter()
                .find(|e| e.name == name)
                .with_context(|| format!("compressed layer '{name}' not in layout"))?;
            ensure!(
                e.shape == [tensor.rows(), tensor.cols()],
                "compressed layer '{name}': shape {:?} vs {}x{}",
                e.shape,
                tensor.rows(),
                tensor.cols()
            );
            let dense = tensor.to_dense();
            flat[e.offset..e.offset + e.numel()].copy_from_slice(&dense.data);
            layers.push(SparseLayer { name, tensor });
        }
        ensure!(off == data.len(), "trailing bytes in v2 checkpoint");
        Ok((
            ModelState { config, layout, block_flat_size, flat },
            Some(SparseModel { layers }),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest() -> ModelManifest {
        // layout mirroring the python param_specs for a micro config
        let cfg = ModelConfig {
            name: "micro".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 16,
            seq_len: 4,
        };
        let mut layout = Vec::new();
        let mut off = 0usize;
        let push = |layout: &mut Vec<ParamEntry>, name: &str, shape: Vec<usize>, off: &mut usize| {
            let numel: usize = shape.iter().product();
            layout.push(ParamEntry { name: name.into(), offset: *off, shape });
            *off += numel;
        };
        push(&mut layout, "emb", vec![16, 8], &mut off);
        push(&mut layout, "pos", vec![4, 8], &mut off);
        let mut block_flat = 0;
        for l in 0..2 {
            let before = off;
            push(&mut layout, &format!("blocks.{l}.ln1"), vec![8], &mut off);
            for w in ["wq", "wk", "wv", "wo"] {
                push(&mut layout, &format!("blocks.{l}.{w}"), vec![8, 8], &mut off);
            }
            push(&mut layout, &format!("blocks.{l}.ln2"), vec![8], &mut off);
            push(&mut layout, &format!("blocks.{l}.w1"), vec![16, 8], &mut off);
            push(&mut layout, &format!("blocks.{l}.w2"), vec![8, 16], &mut off);
            block_flat = off - before;
        }
        push(&mut layout, "ln_f", vec![8], &mut off);
        ModelManifest { config: cfg, flat_size: off, block_flat_size: block_flat, layout }
    }

    #[test]
    fn init_layout_and_access() {
        let mm = fake_manifest();
        let st = ModelState::init(&mm, 42);
        assert_eq!(st.flat.len(), mm.flat_size);
        // norms at 1
        let e = st.entry("blocks.0.ln1").unwrap();
        assert!(st.flat[e.offset..e.offset + 8].iter().all(|&v| v == 1.0));
        // matrices non-trivial
        let wq = st.get_mat("blocks.0.wq").unwrap();
        assert!(wq.frob_norm_sq() > 0.0);
    }

    #[test]
    fn set_get_roundtrip() {
        let mm = fake_manifest();
        let mut st = ModelState::init(&mm, 1);
        let mut w = st.get_mat("blocks.1.w1").unwrap();
        w.data[3] = 99.0;
        st.set_mat("blocks.1.w1", &w).unwrap();
        assert_eq!(st.get_mat("blocks.1.w1").unwrap().data[3], 99.0);
        // wrong shape rejected
        let bad = Mat::zeros(3, 3);
        assert!(st.set_mat("blocks.1.w1", &bad).is_err());
    }

    #[test]
    fn block_slice_contains_block_params() {
        let mm = fake_manifest();
        let st = ModelState::init(&mm, 2);
        let b1 = st.block_slice(1).unwrap();
        assert_eq!(b1.len(), mm.block_flat_size);
        // w2 of block 1 is at the end of the slice
        let e = st.entry("blocks.1.w2").unwrap();
        let rel = e.offset - st.entry("blocks.1.ln1").unwrap().offset;
        assert_eq!(&b1[rel..rel + 4], &st.flat[e.offset..e.offset + 4]);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let mm = fake_manifest();
        let mut st = ModelState::init(&mm, 3);
        st.flat[7] = -1.25;
        let dir = std::env::temp_dir().join("thanos_test_ckpt");
        let path = dir.join("m.thnck");
        st.save(&path).unwrap();
        let back = ModelState::load(&path).unwrap();
        assert_eq!(back.flat, st.flat);
        assert_eq!(back.config, st.config);
        assert_eq!(back.block_flat_size, st.block_flat_size);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_v2_roundtrip_and_v1_back_compat() {
        let mm = fake_manifest();
        let mut st = ModelState::init(&mm, 7);
        // prune every prunable layer to 2:4, then compress
        for l in 0..2 {
            for name in st.prunable_layers(l) {
                let w = st.get_mat(&name).unwrap();
                let pruned = crate::pruning::magnitude::semi_structured(&w, 2, 4).w;
                st.set_mat(&name, &pruned).unwrap();
            }
        }
        let pattern = crate::pruning::Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 };
        let sm = SparseModel::compress_state(&st, &pattern).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let dir = std::env::temp_dir().join("thanos_test_ckpt_v2");
        let p2 = dir.join("m2.thnck");
        st.save_compressed(&p2, &sm).unwrap();
        let (back, sparse) = ModelState::load_with_sparse(&p2).unwrap();
        assert_eq!(bits(&back.flat), bits(&st.flat), "v2 reload must be bit-identical");
        assert_eq!(sparse.unwrap().layers.len(), 12);
        // v1 files still load through the same entry points
        let p1 = dir.join("m1.thnck");
        st.save(&p1).unwrap();
        let (b1, none) = ModelState::load_with_sparse(&p1).unwrap();
        assert!(none.is_none());
        assert_eq!(bits(&b1.flat), bits(&st.flat));
        assert_eq!(bits(&ModelState::load(&p2).unwrap().flat), bits(&st.flat));
        // compressed layers shrink the file despite the longer header
        let s1 = std::fs::metadata(&p1).unwrap().len();
        let s2 = std::fs::metadata(&p2).unwrap().len();
        assert!(s2 < s1, "v2 {s2} bytes !< v1 {s1} bytes");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sparsity_accounting() {
        let mm = fake_manifest();
        let mut st = ModelState::init(&mm, 4);
        assert_eq!(st.prunable_sparsity(), 0.0);
        let mut w = st.get_mat("blocks.0.wq").unwrap();
        w.data.iter_mut().for_each(|v| *v = 0.0);
        st.set_mat("blocks.0.wq", &w).unwrap();
        let total: usize = (0..2)
            .flat_map(|l| st.prunable_layers(l))
            .map(|n| st.entry(&n).unwrap().numel())
            .sum();
        assert!((st.prunable_sparsity() - 64.0 / total as f64).abs() < 1e-12);
    }
}
