//! Lightweight metrics: named counters and wall-clock stage timers.
//!
//! The coordinator and the benches both report through this module so
//! that pipeline-stage timing (capture / hessian / prune / re-forward)
//! is visible without external tracing crates.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A set of named counters + accumulated stage durations. Thread-safe.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    timers: BTreeMap<String, Duration>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn add_time(&self, name: &str, d: Duration) {
        let mut g = self.inner.lock().unwrap();
        *g.timers.entry(name.to_string()).or_insert(Duration::ZERO) += d;
    }

    /// Time a closure under a named stage.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add_time(name, t0.elapsed());
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    pub fn timer_secs(&self, name: &str) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .timers
            .get(name)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0)
    }

    /// Human-readable multi-line report.
    pub fn report(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &g.counters {
            out.push_str(&format!("  {k:<40} {v}\n"));
        }
        for (k, d) in &g.timers {
            out.push_str(&format!("  {k:<40} {:.3}s\n", d.as_secs_f64()));
        }
        out
    }
}

/// Simple stopwatch for benches.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("layers_pruned", 3);
        m.incr("layers_pruned", 2);
        assert_eq!(m.counter("layers_pruned"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timers_accumulate() {
        let m = Metrics::new();
        m.add_time("stage", Duration::from_millis(30));
        m.add_time("stage", Duration::from_millis(20));
        assert!((m.timer_secs("stage") - 0.05).abs() < 1e-9);
        let v = m.time("stage2", || 7);
        assert_eq!(v, 7);
        assert!(m.timer_secs("stage2") >= 0.0);
    }

    #[test]
    fn report_lists_everything() {
        let m = Metrics::new();
        m.incr("a", 1);
        m.add_time("b", Duration::from_millis(5));
        let r = m.report();
        assert!(r.contains('a') && r.contains('b'));
    }
}
