//! Lightweight metrics: named counters, gauges and wall-clock stage
//! timers.
//!
//! The coordinator and the benches both report through this module so
//! that pipeline-stage timing (capture / hessian / prune / re-forward)
//! and the [`crate::engine`] pool's queue/occupancy counters are
//! visible without external tracing crates.
//!
//! Keys are interned `&'static str`s and the counter/timer stores are
//! sharded by thread: the hot-path entry points ([`Metrics::incr_static`],
//! [`Metrics::add_time_static`], [`Metrics::time_static`]) take one
//! uncontended per-shard lock and allocate nothing. The `&str`
//! convenience API is unchanged — it interns (allocating only the
//! first time a key is ever seen process-wide) and forwards to the
//! static path. Hot callers (the runtime's per-executable `exec.*`
//! keys, the engine gauges) pre-intern their keys once and stay
//! allocation-free per call. Reads sum across shards, so totals are
//! exact regardless of which threads recorded.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::trace::clock;

/// Global leaky key interner: each distinct metric name is boxed and
/// leaked exactly once, so the set of live allocations is bounded by
/// the set of distinct keys (dozens in practice). Interning makes keys
/// `Copy` and lets the sharded stores use pointer-sized map keys.
pub fn intern(name: &str) -> &'static str {
    static INTERNED: Mutex<BTreeSet<&'static str>> = Mutex::new(BTreeSet::new());
    let mut set = INTERNED.lock().unwrap();
    if let Some(&s) = set.get(name) {
        return s;
    }
    let leaked: &'static str = Box::leak(name.to_string().into_boxed_str());
    set.insert(leaked);
    leaked
}

const N_SHARDS: usize = 8;

/// The calling thread's shard index — assigned round-robin on first
/// use, so concurrent recorders spread across the shard locks.
fn shard_index() -> usize {
    static NEXT_SHARD: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SHARD: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
    }
    SHARD.with(|s| {
        let v = s.get();
        if v != usize::MAX {
            return v;
        }
        let v = NEXT_SHARD.fetch_add(1, Ordering::Relaxed) % N_SHARDS;
        s.set(v);
        v
    })
}

#[derive(Default)]
struct Shard {
    counters: Mutex<BTreeMap<&'static str, u64>>,
    timer_nanos: Mutex<BTreeMap<&'static str, u64>>,
}

/// A set of named counters + gauges + accumulated stage durations.
/// Thread-safe; counters and timers are sharded by recording thread.
pub struct Metrics {
    shards: Vec<Shard>,
    gauges: Mutex<BTreeMap<&'static str, f64>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            shards: (0..N_SHARDS).map(|_| Shard::default()).collect(),
            gauges: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn incr(&self, name: &str, by: u64) {
        self.incr_static(intern(name), by);
    }

    /// Allocation-free counter increment for a pre-interned key.
    pub fn incr_static(&self, name: &'static str, by: u64) {
        let mut c = self.shards[shard_index()].counters.lock().unwrap();
        *c.entry(name).or_insert(0) += by;
    }

    pub fn add_time(&self, name: &str, d: Duration) {
        self.add_time_static(intern(name), d);
    }

    /// Allocation-free timer accumulation for a pre-interned key.
    pub fn add_time_static(&self, name: &'static str, d: Duration) {
        let mut t = self.shards[shard_index()].timer_nanos.lock().unwrap();
        *t.entry(name).or_insert(0) += d.as_nanos() as u64;
    }

    /// Time a closure under a named stage.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        self.time_static(intern(name), f)
    }

    /// [`Metrics::time`] for a pre-interned key: no lock or allocation
    /// beyond the single per-shard timer update.
    pub fn time_static<T>(&self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = clock::now_nanos();
        let out = f();
        let dt = clock::now_nanos().saturating_sub(t0);
        self.add_time_static(name, Duration::from_nanos(dt));
        out
    }

    /// Set a point-in-time gauge (last write wins).
    pub fn set_gauge(&self, name: &str, value: f64) {
        self.gauges.lock().unwrap().insert(intern(name), value);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).copied()
    }

    /// Record a [`crate::engine::EngineStats`] snapshot as gauges under
    /// `<prefix>.*` — the per-engine queue/occupancy readout the
    /// coordinator report and the fig9 bench surface. `wall_secs` is
    /// the observation window used for the occupancy estimate.
    pub fn record_engine(&self, prefix: &str, stats: &crate::engine::EngineStats, wall_secs: f64) {
        self.set_gauge(&format!("{prefix}.threads"), stats.threads as f64);
        self.set_gauge(&format!("{prefix}.jobs_submitted"), stats.jobs_submitted as f64);
        self.set_gauge(&format!("{prefix}.jobs_inline"), stats.jobs_inline as f64);
        self.set_gauge(&format!("{prefix}.tasks_executed"), stats.tasks_executed as f64);
        self.set_gauge(&format!("{prefix}.queue_peak"), stats.queue_peak as f64);
        self.set_gauge(&format!("{prefix}.busy_secs"), stats.busy_secs);
        self.set_gauge(&format!("{prefix}.occupancy"), stats.occupancy(wall_secs));
    }

    /// Record a compression outcome as gauges under `<prefix>.*`:
    /// dense bytes, compressed bytes, and the compressed/dense ratio —
    /// the readout `thanos compress` and the sparse bench surface.
    pub fn record_compression(&self, prefix: &str, dense_bytes: usize, compressed_bytes: usize) {
        self.set_gauge(&format!("{prefix}.dense_bytes"), dense_bytes as f64);
        self.set_gauge(
            &format!("{prefix}.compressed_bytes"),
            compressed_bytes as f64,
        );
        let ratio = if dense_bytes > 0 {
            compressed_bytes as f64 / dense_bytes as f64
        } else {
            0.0
        };
        self.set_gauge(&format!("{prefix}.ratio"), ratio);
    }

    /// Record a [`crate::serve::ServeSnapshot`] as gauges under
    /// `<prefix>.*` — the serving daemon's counter/latency readout the
    /// serving bench and the `serve-smoke` CI job surface.
    pub fn record_serve(&self, prefix: &str, snap: &crate::serve::ServeSnapshot) {
        self.set_gauge(&format!("{prefix}.accepted"), snap.accepted as f64);
        self.set_gauge(&format!("{prefix}.completed"), snap.completed as f64);
        self.set_gauge(&format!("{prefix}.shed"), snap.shed as f64);
        self.set_gauge(
            &format!("{prefix}.deadline_dropped"),
            snap.deadline_dropped as f64,
        );
        self.set_gauge(&format!("{prefix}.batch_failed"), snap.batch_failed as f64);
        self.set_gauge(&format!("{prefix}.batches"), snap.batches as f64);
        self.set_gauge(&format!("{prefix}.reloads_ok"), snap.reloads_ok as f64);
        self.set_gauge(
            &format!("{prefix}.reloads_rejected"),
            snap.reloads_rejected as f64,
        );
        self.set_gauge(&format!("{prefix}.queue_depth"), snap.queue_depth as f64);
        self.set_gauge(
            &format!("{prefix}.engine_queue_depth"),
            snap.engine_queue_depth as f64,
        );
        self.set_gauge(&format!("{prefix}.model_version"), snap.model_version as f64);
        self.set_gauge(&format!("{prefix}.p50_ms"), snap.p50_ms);
        self.set_gauge(&format!("{prefix}.p99_ms"), snap.p99_ms);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.shards
            .iter()
            .map(|s| s.counters.lock().unwrap().get(name).copied().unwrap_or(0))
            .sum()
    }

    pub fn timer_secs(&self, name: &str) -> f64 {
        let nanos: u64 = self
            .shards
            .iter()
            .map(|s| s.timer_nanos.lock().unwrap().get(name).copied().unwrap_or(0))
            .sum();
        nanos as f64 * 1e-9
    }

    /// Human-readable multi-line report (counters, gauges, timers —
    /// each merged across shards, sorted by key).
    pub fn report(&self) -> String {
        let mut counters: BTreeMap<&'static str, u64> = BTreeMap::new();
        let mut timers: BTreeMap<&'static str, u64> = BTreeMap::new();
        for s in &self.shards {
            for (&k, &v) in s.counters.lock().unwrap().iter() {
                *counters.entry(k).or_insert(0) += v;
            }
            for (&k, &v) in s.timer_nanos.lock().unwrap().iter() {
                *timers.entry(k).or_insert(0) += v;
            }
        }
        let mut out = String::new();
        for (k, v) in &counters {
            out.push_str(&format!("  {k:<40} {v}\n"));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("  {k:<40} {v:.3}\n"));
        }
        for (k, nanos) in &timers {
            out.push_str(&format!("  {k:<40} {:.3}s\n", *nanos as f64 * 1e-9));
        }
        out
    }
}

/// Simple stopwatch for benches (reads [`crate::trace::clock`], the
/// crate's single wall-clock source).
pub struct Stopwatch(u64);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(clock::now_nanos())
    }
    pub fn secs(&self) -> f64 {
        clock::secs_since(self.0)
    }
    pub fn millis(&self) -> f64 {
        clock::secs_since(self.0) * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("layers_pruned", 3);
        m.incr("layers_pruned", 2);
        assert_eq!(m.counter("layers_pruned"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timers_accumulate() {
        let m = Metrics::new();
        m.add_time("stage", Duration::from_millis(30));
        m.add_time("stage", Duration::from_millis(20));
        assert!((m.timer_secs("stage") - 0.05).abs() < 1e-9);
        let v = m.time("stage2", || 7);
        assert_eq!(v, 7);
        assert!(m.timer_secs("stage2") >= 0.0);
    }

    #[test]
    fn report_lists_everything() {
        let m = Metrics::new();
        m.incr("a", 1);
        m.add_time("b", Duration::from_millis(5));
        m.set_gauge("g", 0.5);
        let r = m.report();
        assert!(r.contains('a') && r.contains('b') && r.contains('g'));
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        assert_eq!(m.gauge("x"), None);
        m.set_gauge("x", 1.0);
        m.set_gauge("x", 2.5);
        assert_eq!(m.gauge("x"), Some(2.5));
    }

    #[test]
    fn compression_snapshot_lands_as_gauges() {
        let m = Metrics::new();
        m.record_compression("sparse.compress", 1000, 560);
        assert_eq!(m.gauge("sparse.compress.dense_bytes"), Some(1000.0));
        assert_eq!(m.gauge("sparse.compress.compressed_bytes"), Some(560.0));
        assert_eq!(m.gauge("sparse.compress.ratio"), Some(0.56));
        m.record_compression("empty", 0, 0);
        assert_eq!(m.gauge("empty.ratio"), Some(0.0));
    }

    #[test]
    fn engine_snapshot_lands_as_gauges() {
        let m = Metrics::new();
        let stats = crate::engine::EngineStats {
            threads: 4,
            jobs_submitted: 10,
            jobs_inline: 2,
            tasks_executed: 80,
            queue_peak: 3,
            busy_secs: 2.0,
        };
        m.record_engine("engine", &stats, 1.0);
        assert_eq!(m.gauge("engine.threads"), Some(4.0));
        assert_eq!(m.gauge("engine.jobs_submitted"), Some(10.0));
        assert_eq!(m.gauge("engine.queue_peak"), Some(3.0));
        assert_eq!(m.gauge("engine.occupancy"), Some(0.5));
    }

    #[test]
    fn serve_snapshot_lands_as_gauges() {
        let m = Metrics::new();
        let snap = crate::serve::ServeSnapshot {
            accepted: 10,
            completed: 7,
            shed: 2,
            deadline_dropped: 1,
            batch_failed: 0,
            bad_request: 0,
            batches: 3,
            reloads_ok: 1,
            reloads_rejected: 1,
            accept_faults: 0,
            queue_depth: 0,
            engine_queue_depth: 0,
            model_version: 2,
            model_source: "test.thnck".to_string(),
            p50_ms: 1.5,
            p99_ms: 4.0,
        };
        m.record_serve("serve", &snap);
        assert_eq!(m.gauge("serve.accepted"), Some(10.0));
        assert_eq!(m.gauge("serve.shed"), Some(2.0));
        assert_eq!(m.gauge("serve.reloads_rejected"), Some(1.0));
        assert_eq!(m.gauge("serve.model_version"), Some(2.0));
        assert_eq!(m.gauge("serve.p99_ms"), Some(4.0));
    }

    #[test]
    fn interned_keys_are_stable_and_shared() {
        let a = intern("metrics.test.key");
        let b = intern("metrics.test.key");
        assert!(std::ptr::eq(a, b), "same key must intern to one allocation");
        assert_eq!(a, "metrics.test.key");
    }

    #[test]
    fn static_and_interned_paths_share_totals() {
        let m = Metrics::new();
        let k = intern("metrics.test.static");
        m.incr_static(k, 2);
        m.incr("metrics.test.static", 3);
        assert_eq!(m.counter("metrics.test.static"), 5);
        m.add_time_static(k, Duration::from_millis(10));
        m.add_time("metrics.test.static", Duration::from_millis(5));
        assert!((m.timer_secs("metrics.test.static") - 0.015).abs() < 1e-9);
        let v = m.time_static(k, || 11);
        assert_eq!(v, 11);
    }
}
