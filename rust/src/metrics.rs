//! Lightweight metrics: named counters, gauges and wall-clock stage
//! timers.
//!
//! The coordinator and the benches both report through this module so
//! that pipeline-stage timing (capture / hessian / prune / re-forward)
//! and the [`crate::engine`] pool's queue/occupancy counters are
//! visible without external tracing crates.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A set of named counters + gauges + accumulated stage durations.
/// Thread-safe.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    timers: BTreeMap<String, Duration>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn add_time(&self, name: &str, d: Duration) {
        let mut g = self.inner.lock().unwrap();
        *g.timers.entry(name.to_string()).or_insert(Duration::ZERO) += d;
    }

    /// Time a closure under a named stage.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add_time(name, t0.elapsed());
        out
    }

    /// Set a point-in-time gauge (last write wins).
    pub fn set_gauge(&self, name: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.gauges.insert(name.to_string(), value);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    /// Record a [`crate::engine::EngineStats`] snapshot as gauges under
    /// `<prefix>.*` — the per-engine queue/occupancy readout the
    /// coordinator report and the fig9 bench surface. `wall_secs` is
    /// the observation window used for the occupancy estimate.
    pub fn record_engine(&self, prefix: &str, stats: &crate::engine::EngineStats, wall_secs: f64) {
        self.set_gauge(&format!("{prefix}.threads"), stats.threads as f64);
        self.set_gauge(&format!("{prefix}.jobs_submitted"), stats.jobs_submitted as f64);
        self.set_gauge(&format!("{prefix}.jobs_inline"), stats.jobs_inline as f64);
        self.set_gauge(&format!("{prefix}.tasks_executed"), stats.tasks_executed as f64);
        self.set_gauge(&format!("{prefix}.queue_peak"), stats.queue_peak as f64);
        self.set_gauge(&format!("{prefix}.busy_secs"), stats.busy_secs);
        self.set_gauge(&format!("{prefix}.occupancy"), stats.occupancy(wall_secs));
    }

    /// Record a compression outcome as gauges under `<prefix>.*`:
    /// dense bytes, compressed bytes, and the compressed/dense ratio —
    /// the readout `thanos compress` and the sparse bench surface.
    pub fn record_compression(&self, prefix: &str, dense_bytes: usize, compressed_bytes: usize) {
        self.set_gauge(&format!("{prefix}.dense_bytes"), dense_bytes as f64);
        self.set_gauge(
            &format!("{prefix}.compressed_bytes"),
            compressed_bytes as f64,
        );
        let ratio = if dense_bytes > 0 {
            compressed_bytes as f64 / dense_bytes as f64
        } else {
            0.0
        };
        self.set_gauge(&format!("{prefix}.ratio"), ratio);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    pub fn timer_secs(&self, name: &str) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .timers
            .get(name)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0)
    }

    /// Human-readable multi-line report.
    pub fn report(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &g.counters {
            out.push_str(&format!("  {k:<40} {v}\n"));
        }
        for (k, v) in &g.gauges {
            out.push_str(&format!("  {k:<40} {v:.3}\n"));
        }
        for (k, d) in &g.timers {
            out.push_str(&format!("  {k:<40} {:.3}s\n", d.as_secs_f64()));
        }
        out
    }
}

/// Simple stopwatch for benches.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("layers_pruned", 3);
        m.incr("layers_pruned", 2);
        assert_eq!(m.counter("layers_pruned"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timers_accumulate() {
        let m = Metrics::new();
        m.add_time("stage", Duration::from_millis(30));
        m.add_time("stage", Duration::from_millis(20));
        assert!((m.timer_secs("stage") - 0.05).abs() < 1e-9);
        let v = m.time("stage2", || 7);
        assert_eq!(v, 7);
        assert!(m.timer_secs("stage2") >= 0.0);
    }

    #[test]
    fn report_lists_everything() {
        let m = Metrics::new();
        m.incr("a", 1);
        m.add_time("b", Duration::from_millis(5));
        m.set_gauge("g", 0.5);
        let r = m.report();
        assert!(r.contains('a') && r.contains('b') && r.contains('g'));
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        assert_eq!(m.gauge("x"), None);
        m.set_gauge("x", 1.0);
        m.set_gauge("x", 2.5);
        assert_eq!(m.gauge("x"), Some(2.5));
    }

    #[test]
    fn compression_snapshot_lands_as_gauges() {
        let m = Metrics::new();
        m.record_compression("sparse.compress", 1000, 560);
        assert_eq!(m.gauge("sparse.compress.dense_bytes"), Some(1000.0));
        assert_eq!(m.gauge("sparse.compress.compressed_bytes"), Some(560.0));
        assert_eq!(m.gauge("sparse.compress.ratio"), Some(0.56));
        m.record_compression("empty", 0, 0);
        assert_eq!(m.gauge("empty.ratio"), Some(0.0));
    }

    #[test]
    fn engine_snapshot_lands_as_gauges() {
        let m = Metrics::new();
        let stats = crate::engine::EngineStats {
            threads: 4,
            jobs_submitted: 10,
            jobs_inline: 2,
            tasks_executed: 80,
            queue_peak: 3,
            busy_secs: 2.0,
        };
        m.record_engine("engine", &stats, 1.0);
        assert_eq!(m.gauge("engine.threads"), Some(4.0));
        assert_eq!(m.gauge("engine.jobs_submitted"), Some(10.0));
        assert_eq!(m.gauge("engine.queue_peak"), Some(3.0));
        assert_eq!(m.gauge("engine.occupancy"), Some(0.5));
    }
}
