//! The `PruneEngine` — a persistent, work-stealing thread pool shared
//! by every parallel kernel in the crate.
//!
//! The seed implementation spawned fresh `std::thread::scope` workers
//! inside every GEMM / Cholesky / row-update call, which (a) pays the
//! spawn+join cost on every hot-loop iteration and (b) makes two-level
//! parallelism (layer-parallel outer loop × row-parallel inner kernels)
//! oversubscribe the machine. The engine replaces all of that with ONE
//! pool sized to the hardware (or to `THANOS_THREADS`):
//!
//! * **Scoped job submission** — [`PruneEngine::run`] submits a batch
//!   of `n_tasks` index-addressed tasks and blocks until all of them
//!   finished, so jobs may borrow stack data (same contract as
//!   `std::thread::scope`, without the per-call spawns).
//! * **Work stealing via an atomic claim counter** — workers (and the
//!   submitting thread itself) claim task indices with a `fetch_add`,
//!   so fast workers automatically steal the tail of slow workers'
//!   ranges and concurrent jobs interleave on the same pool.
//! * **No oversubscription by construction** — nested submissions
//!   (a layer-parallel task whose inner GEMM submits row-parallel
//!   tasks) land on the same fixed-size pool; the submitter always
//!   drains its own job, so nesting cannot deadlock and the two levels
//!   share one thread budget instead of multiplying.
//! * **Determinism** — every task computes an independent output range,
//!   so results are bit-identical for any thread count. `THANOS_THREADS=1`
//!   (or [`with_serial`]) forces fully inline execution; the test suite
//!   pins serial == parallel bit-equality for all pruning methods.
//! * **Counters** — jobs / tasks / queue depth / busy time are exported
//!   through [`EngineStats`] and surfaced in the coordinator report and
//!   the `fig9_pruning_time` bench.

pub mod model;
pub mod pipeline;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::trace::{self, clock};

/// Environment variable fixing the pool size (`>= 1`). Unset or invalid
/// values fall back to `std::thread::available_parallelism()`.
pub const THREADS_ENV: &str = "THANOS_THREADS";

/// Oversubscription factor for [`PruneEngine::chunk`]: splitting work
/// into a few more tasks than threads lets the claim counter balance
/// load when several jobs share the pool.
const TASKS_PER_THREAD: usize = 4;

static GLOBAL: OnceLock<PruneEngine> = OnceLock::new();

thread_local! {
    static SERIAL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// The process-wide engine, created on first use. Pool size comes from
/// [`THREADS_ENV`] or the hardware parallelism.
pub fn global() -> &'static PruneEngine {
    GLOBAL.get_or_init(|| PruneEngine::with_threads(configured_threads()))
}

fn configured_threads() -> usize {
    std::env::var(THREADS_ENV)
        .ok()
        .and_then(|v| parse_threads(&v))
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

/// Parse a `THANOS_THREADS` value; `None` for anything that is not a
/// positive integer.
pub fn parse_threads(v: &str) -> Option<usize> {
    v.trim().parse::<usize>().ok().filter(|&n| n >= 1)
}

/// Run `f` with every engine submission on this thread forced inline
/// (exactly the execution `THANOS_THREADS=1` would produce), restoring
/// the previous mode afterwards — the in-process hook the determinism
/// tests use to compare serial vs parallel results bit-for-bit.
pub fn with_serial<R>(f: impl FnOnce() -> R) -> R {
    struct Guard(bool);
    impl Drop for Guard {
        fn drop(&mut self) {
            SERIAL.with(|s| s.set(self.0));
        }
    }
    let prev = SERIAL.with(|s| s.replace(true));
    let _guard = Guard(prev);
    f()
}

/// Cumulative engine activity counters (monotone since engine start).
/// Use [`EngineStats::delta_since`] to scope them to one pipeline run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EngineStats {
    /// pool size (including the submitting thread as a participant)
    pub threads: usize,
    /// jobs that went through the shared queue
    pub jobs_submitted: u64,
    /// jobs executed inline (serial mode, single-thread pool, or 1 task)
    pub jobs_inline: u64,
    /// individual tasks executed (queued + inline)
    pub tasks_executed: u64,
    /// deepest queue depth observed since engine start
    pub queue_peak: usize,
    /// summed wall time spent inside task bodies, across all workers
    pub busy_secs: f64,
}

impl EngineStats {
    /// Counters accumulated since `earlier` (same engine). `queue_peak`
    /// stays the engine-lifetime peak — a high-water mark, not a rate.
    pub fn delta_since(&self, earlier: &EngineStats) -> EngineStats {
        EngineStats {
            threads: self.threads,
            jobs_submitted: self.jobs_submitted - earlier.jobs_submitted,
            jobs_inline: self.jobs_inline - earlier.jobs_inline,
            tasks_executed: self.tasks_executed - earlier.tasks_executed,
            queue_peak: self.queue_peak,
            busy_secs: self.busy_secs - earlier.busy_secs,
        }
    }

    /// Approximate pool occupancy over a wall-clock window: busy time
    /// divided by `threads × wall`. Nested jobs can double-count the
    /// submitting thread, so the value is clamped to `[0, 1]`.
    pub fn occupancy(&self, wall_secs: f64) -> f64 {
        if wall_secs <= 0.0 || self.threads == 0 {
            return 0.0;
        }
        (self.busy_secs / (wall_secs * self.threads as f64)).clamp(0.0, 1.0)
    }
}

struct Shared {
    queue: Mutex<VecDeque<Arc<Job>>>,
    work_cv: Condvar,
    /// Read with `Relaxed` everywhere: the flag itself carries no data —
    /// the queue mutex orders it. It is only stored while holding
    /// `queue` (see `Drop`) and only read by workers holding `queue`, so
    /// mutex release/acquire provides the happens-before edge; the
    /// atomic type just keeps it out of the `VecDeque` payload.
    shutdown: AtomicBool,
    // The counters below are monotone observability gauges: written with
    // `Relaxed` RMWs (atomicity without ordering) and read only through
    // `stats()` snapshots for reports and benches. No control flow or
    // weight arithmetic ever depends on them, and cross-thread *data*
    // visibility is carried by the queue mutex and each job's completion
    // latch — so stronger orderings here would buy nothing but fences.
    // The audited exception ledger (audit.toml, rule D1/D6) points here.
    jobs_submitted: AtomicU64,
    jobs_inline: AtomicU64,
    tasks_executed: AtomicU64,
    queue_peak: AtomicUsize,
    busy_nanos: AtomicU64,
}

impl Shared {
    fn new() -> Shared {
        Shared {
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            jobs_submitted: AtomicU64::new(0),
            jobs_inline: AtomicU64::new(0),
            tasks_executed: AtomicU64::new(0),
            queue_peak: AtomicUsize::new(0),
            busy_nanos: AtomicU64::new(0),
        }
    }

    /// Claim-and-execute tasks of `job` until its counter is exhausted.
    fn execute(&self, job: &Job) {
        while let Some(i) = job.claim() {
            let task_span = trace::span("engine.task");
            let t0 = clock::now_nanos();
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                // SAFETY: `run_dyn` keeps the closure alive until every
                // claimed task has completed (it blocks on the latch),
                // and tasks only run between claim and complete.
                let f = unsafe { &*job.f };
                f(i);
            }));
            let dt = clock::now_nanos().saturating_sub(t0);
            drop(task_span);
            self.busy_nanos.fetch_add(dt, Ordering::Relaxed);
            self.tasks_executed.fetch_add(1, Ordering::Relaxed);
            if let Err(payload) = result {
                let mut slot = job.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            job.complete_one();
        }
    }
}

/// One submitted batch: `n_tasks` index-addressed calls into a
/// lifetime-erased closure, with an atomic claim counter and a
/// mutex/condvar completion latch.
struct Job {
    n_tasks: usize,
    next: AtomicUsize,
    /// Raw (lifetime-erased) pointer to the submitter's closure; only
    /// dereferenced between claim and completion, which `run_dyn`
    /// brackets inside the closure's real lifetime.
    f: *const (dyn Fn(usize) + Sync),
    remaining: Mutex<usize>,
    done_cv: Condvar,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

// SAFETY: the raw closure pointer is only dereferenced while the
// submitting call frame is alive (see `run_dyn`); all other fields are
// standard thread-safe primitives.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// `Relaxed` is sufficient: `fetch_add` is atomic regardless of
    /// ordering, so indices are handed out exactly once; visibility of
    /// the closure and its captures is established by the queue mutex
    /// (push/pop) before any claim, and completion is published through
    /// the `remaining` mutex — the claim counter orders nothing itself.
    fn claim(&self) -> Option<usize> {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        if i < self.n_tasks {
            Some(i)
        } else {
            None
        }
    }

    fn complete_one(&self) {
        let mut remaining = self.remaining.lock().unwrap();
        *remaining -= 1;
        if *remaining == 0 {
            self.done_cv.notify_all();
        }
    }
}

/// The pool. One lives for the whole process ([`global`]); tests may
/// build private instances, which join their workers on drop.
pub struct PruneEngine {
    shared: Arc<Shared>,
    threads: usize,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl PruneEngine {
    /// Build a pool of `threads` total participants: `threads - 1`
    /// persistent workers plus the submitting thread itself.
    pub fn with_threads(threads: usize) -> PruneEngine {
        let threads = threads.max(1);
        let shared = Arc::new(Shared::new());
        let mut handles = Vec::new();
        for i in 0..threads - 1 {
            let sh = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("prune-engine-{i}"))
                .spawn(move || worker_loop(&sh))
                .expect("spawning engine worker");
            handles.push(handle);
        }
        PruneEngine { shared, threads, handles: Mutex::new(handles) }
    }

    /// Total participants (workers + submitter).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Suggested items-per-task for splitting `items` units of row-like
    /// work: a few tasks per thread so concurrent jobs balance.
    pub fn chunk(&self, items: usize) -> usize {
        if items == 0 {
            return 1;
        }
        let target = (self.threads * TASKS_PER_THREAD).clamp(1, items);
        items.div_ceil(target)
    }

    /// [`chunk`](Self::chunk) rounded up to a multiple of `align`, so
    /// tile-granular kernels (the packed GEMM's `MR`-row panels) never
    /// split a tile across two bands. The result still depends only on
    /// `items`, `align` and the pool size — never on runtime timing —
    /// so band decomposition stays deterministic.
    pub fn chunk_aligned(&self, items: usize, align: usize) -> usize {
        let align = align.max(1);
        self.chunk(items).div_ceil(align) * align
    }

    /// Instantaneous depth of the shared job queue — the gauge the
    /// serving daemon exports next to its own admission-queue depth.
    /// Purely observational: no control flow anywhere keys off it.
    ///
    /// Fairness note for mixed workloads (serving batches sharing the
    /// pool with prune jobs): a submitter always drains its own job
    /// inline (see [`run`](Self::run)), so a serving batch makes
    /// progress on the submitting thread even while every pooled
    /// worker is busy inside a long prune job — neither workload can
    /// starve the other into deadlock or unbounded wait. The
    /// `concurrent_submitters_interleave` test pins that liveness.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Snapshot of the cumulative activity counters.
    pub fn stats(&self) -> EngineStats {
        let s = &self.shared;
        EngineStats {
            threads: self.threads,
            jobs_submitted: s.jobs_submitted.load(Ordering::Relaxed),
            jobs_inline: s.jobs_inline.load(Ordering::Relaxed),
            tasks_executed: s.tasks_executed.load(Ordering::Relaxed),
            queue_peak: s.queue_peak.load(Ordering::Relaxed),
            busy_secs: s.busy_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        }
    }

    /// Run `f(0..n_tasks)` across the pool and block until every task
    /// completed. Tasks may borrow the caller's stack (the call does not
    /// return before the last task finishes). Panics in tasks are
    /// re-raised here after the batch drains, like `std::thread::scope`.
    pub fn run<F: Fn(usize) + Sync>(&self, n_tasks: usize, f: F) {
        self.run_dyn(n_tasks, &f);
    }

    fn run_dyn(&self, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        let serial = SERIAL.with(|s| s.get());
        if serial || self.threads == 1 || n_tasks == 1 {
            self.shared.jobs_inline.fetch_add(1, Ordering::Relaxed);
            let t0 = clock::now_nanos();
            for i in 0..n_tasks {
                let _task_span = trace::span("engine.task");
                f(i);
            }
            self.shared
                .busy_nanos
                .fetch_add(clock::now_nanos().saturating_sub(t0), Ordering::Relaxed);
            self.shared
                .tasks_executed
                .fetch_add(n_tasks as u64, Ordering::Relaxed);
            trace::flush_local();
            return;
        }

        // SAFETY: erase the closure's lifetime so workers can hold it
        // through the shared queue. Sound because this frame blocks on
        // the completion latch below: the closure outlives every call.
        let f_erased: *const (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        let job = Arc::new(Job {
            n_tasks,
            next: AtomicUsize::new(0),
            f: f_erased,
            remaining: Mutex::new(n_tasks),
            done_cv: Condvar::new(),
            panic: Mutex::new(None),
        });
        {
            let mut queue = self.shared.queue.lock().unwrap();
            queue.push_back(Arc::clone(&job));
            let depth = queue.len();
            self.shared.queue_peak.fetch_max(depth, Ordering::Relaxed);
        }
        self.shared.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        self.shared.work_cv.notify_all();

        // The submitter helps with its own job first (this is what makes
        // nested submission deadlock-free), then waits for stragglers.
        self.shared.execute(&job);
        {
            let mut remaining = job.remaining.lock().unwrap();
            while *remaining > 0 {
                remaining = job.done_cv.wait(remaining).unwrap();
            }
        }
        // Job boundary: publish this thread's span events so a drain
        // right after `run` returns sees the whole batch.
        trace::flush_local();
        if let Some(payload) = job.panic.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
    }

    /// Split `data` into contiguous bands of `band_len` elements (the
    /// last may be shorter) and run `f(band_index, band)` for each, in
    /// parallel. Bands are disjoint, so no synchronization is needed in
    /// `f`. This is the engine-backed replacement for the repeated
    /// `split_at_mut` + `thread::scope` pattern of the seed kernels.
    pub fn for_each_band<T, F>(&self, data: &mut [T], band_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let n = data.len();
        if n == 0 {
            return;
        }
        let band_len = band_len.max(1);
        let n_bands = n.div_ceil(band_len);
        let base = SendPtr(data.as_mut_ptr());
        self.run(n_bands, move |i| {
            let start = i * band_len;
            let len = band_len.min(n - start);
            // SAFETY: bands are disjoint sub-ranges of `data`, which
            // outlives `run` (it blocks until all tasks finish).
            let band = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), len) };
            f(i, band);
        });
    }

    /// Two-slice variant of [`for_each_band`](Self::for_each_band): both
    /// slices are banded with the same band *count* (`band_a` elements
    /// of `a` / `band_b` elements of `b` per band) and `f` receives the
    /// matching pair. Used where a weight band and its mask band must be
    /// updated together.
    pub fn for_each_band2<T, U, F>(
        &self,
        a: &mut [T],
        b: &mut [U],
        band_a: usize,
        band_b: usize,
        f: F,
    ) where
        T: Send,
        U: Send,
        F: Fn(usize, &mut [T], &mut [U]) + Sync,
    {
        let (na, nb) = (a.len(), b.len());
        if na == 0 && nb == 0 {
            return;
        }
        let band_a = band_a.max(1);
        let band_b = band_b.max(1);
        let n_bands = na.div_ceil(band_a);
        assert_eq!(
            n_bands,
            nb.div_ceil(band_b),
            "for_each_band2 slices disagree on band count"
        );
        let pa = SendPtr(a.as_mut_ptr());
        let pb = SendPtr(b.as_mut_ptr());
        self.run(n_bands, move |i| {
            let sa = i * band_a;
            let sb = i * band_b;
            let la = band_a.min(na - sa);
            let lb = band_b.min(nb - sb);
            // SAFETY: disjoint bands of two distinct live slices.
            let (ba, bb) = unsafe {
                (
                    std::slice::from_raw_parts_mut(pa.0.add(sa), la),
                    std::slice::from_raw_parts_mut(pb.0.add(sb), lb),
                )
            };
            f(i, ba, bb);
        });
    }
}

impl Drop for PruneEngine {
    fn drop(&mut self) {
        // The store MUST happen while holding the queue mutex. A worker
        // that has checked `shutdown` (false) and found the queue empty
        // holds the mutex until `wait()` releases it; an unlocked store
        // plus notify in that window would be consumed before the worker
        // sleeps — a lost wakeup, and `join` below hangs forever. With
        // the store under the lock, the worker either sees the flag at
        // its check or is already parked when the notify lands. The
        // exhaustive interleaving model in `engine::model` checks both
        // protocols: the unlocked variant reaches the stuck state, this
        // one cannot (`tests/engine_model.rs`).
        {
            let _queue = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Relaxed);
        }
        self.shared.work_cv.notify_all();
        for handle in self.handles.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
    }
}

/// Raw pointer wrapper so band base addresses can cross threads.
struct SendPtr<T>(*mut T);

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
// SAFETY: the pointer is only used to derive disjoint sub-slices of a
// slice that outlives the parallel region.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

fn worker_loop(shared: &Shared) {
    loop {
        // span covers queue wait + wakeup; it closes on the shutdown
        // return too (guard drop), keeping every shard stream balanced
        let wait_span = trace::span("engine.wait");
        let job = {
            let mut queue = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::Relaxed) {
                    return;
                }
                // Drop jobs whose every task has been claimed; their
                // latches complete without further queue involvement.
                queue.retain(|j| j.next.load(Ordering::Relaxed) < j.n_tasks);
                if let Some(j) = queue.front() {
                    break Arc::clone(j);
                }
                queue = shared.work_cv.wait(queue).unwrap();
            }
        };
        drop(wait_span);
        shared.execute(&job);
        // job boundary: publish this worker's events while it idles
        trace::flush_local();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_visits_every_index_exactly_once() {
        let eng = PruneEngine::with_threads(4);
        let hits: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
        eng.run(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn drop_joins_idle_workers_repeatedly() {
        // Regression smoke test for the shutdown lost-wakeup fix: drop
        // engines whose workers are idle-parked many times in a row.
        // The exhaustive proof is `engine::model` (tests/engine_model.rs);
        // this catches a reintroduced hang quickly (test harness timeout)
        // rather than deterministically.
        for _ in 0..64 {
            let eng = PruneEngine::with_threads(4);
            eng.run(8, |_| {});
        }
    }

    #[test]
    fn nested_jobs_complete_without_deadlock() {
        let eng = PruneEngine::with_threads(3);
        let total = AtomicUsize::new(0);
        let inner = &eng;
        eng.run(5, |_| {
            inner.run(7, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 35);
    }

    #[test]
    fn for_each_band_bands_are_disjoint_and_complete() {
        let eng = PruneEngine::with_threads(4);
        let mut data = vec![usize::MAX; 1003];
        eng.for_each_band(&mut data, 13, |bi, band| {
            for v in band.iter_mut() {
                *v = bi;
            }
        });
        for (k, &v) in data.iter().enumerate() {
            assert_eq!(v, k / 13, "element {k}");
        }
    }

    #[test]
    fn for_each_band2_pairs_match() {
        let eng = PruneEngine::with_threads(3);
        let mut a = vec![0u32; 60];
        let mut b = vec![false; 30];
        eng.for_each_band2(&mut a, &mut b, 8, 4, |bi, ba, bb| {
            for v in ba.iter_mut() {
                *v = bi as u32;
            }
            for v in bb.iter_mut() {
                *v = true;
            }
        });
        assert!(b.iter().all(|&m| m));
        for (k, &v) in a.iter().enumerate() {
            assert_eq!(v as usize, k / 8);
        }
    }

    #[test]
    fn concurrent_submitters_interleave() {
        // Two submitter threads sharing one pool: both jobs complete
        // (submitters self-drain, so neither can be starved by the
        // other holding all the workers) and the queue drains to zero.
        let eng = std::sync::Arc::new(PruneEngine::with_threads(2));
        let done = std::sync::Arc::new(AtomicUsize::new(0));
        let mut joins = Vec::new();
        for _ in 0..2 {
            let (e, d) = (std::sync::Arc::clone(&eng), std::sync::Arc::clone(&done));
            joins.push(std::thread::spawn(move || {
                for _ in 0..16 {
                    e.run(32, |_| {
                        d.fetch_add(1, Ordering::Relaxed);
                    });
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(done.load(Ordering::Relaxed), 2 * 16 * 32);
        assert_eq!(eng.queue_depth(), 0);
    }

    #[test]
    fn serial_mode_forces_inline_execution() {
        let eng = PruneEngine::with_threads(4);
        let before = eng.stats();
        let out = with_serial(|| {
            let count = AtomicUsize::new(0);
            eng.run(16, |_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
            count.load(Ordering::Relaxed)
        });
        assert_eq!(out, 16);
        let after = eng.stats().delta_since(&before);
        assert_eq!(after.jobs_submitted, 0, "serial mode must not queue");
        assert_eq!(after.jobs_inline, 1);
        assert_eq!(after.tasks_executed, 16);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let eng = PruneEngine::with_threads(1);
        let count = AtomicUsize::new(0);
        eng.run(9, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 9);
        assert_eq!(eng.stats().jobs_submitted, 0);
    }

    #[test]
    fn task_panic_propagates_and_engine_survives() {
        let eng = PruneEngine::with_threads(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            eng.run(8, |i| {
                if i == 3 {
                    panic!("task boom");
                }
            });
        }));
        assert!(caught.is_err(), "panic must propagate to the submitter");
        let count = AtomicUsize::new(0);
        eng.run(4, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 4, "engine usable after panic");
    }

    #[test]
    fn parse_threads_accepts_positive_integers_only() {
        assert_eq!(parse_threads("1"), Some(1));
        assert_eq!(parse_threads(" 8 "), Some(8));
        assert_eq!(parse_threads("0"), None);
        assert_eq!(parse_threads("-2"), None);
        assert_eq!(parse_threads("many"), None);
        assert_eq!(parse_threads(""), None);
    }

    #[test]
    fn chunk_aligned_rounds_up_to_tile_multiples() {
        let eng = PruneEngine::with_threads(4);
        let c = eng.chunk_aligned(1000, 8);
        assert_eq!(c % 8, 0);
        assert!(c >= eng.chunk(1000));
        // tiny inputs still produce a usable (aligned) band size
        assert_eq!(eng.chunk_aligned(3, 8), 8);
        assert_eq!(eng.chunk_aligned(0, 8), 8);
    }

    #[test]
    fn chunk_targets_a_few_tasks_per_thread() {
        let eng = PruneEngine::with_threads(4);
        assert_eq!(eng.chunk(0), 1);
        assert_eq!(eng.chunk(1), 1);
        let c = eng.chunk(1000);
        let tasks = 1000usize.div_ceil(c);
        assert!((4..=4 * TASKS_PER_THREAD).contains(&tasks), "{tasks} tasks");
    }

    #[test]
    fn occupancy_is_bounded() {
        let s = EngineStats { threads: 4, busy_secs: 100.0, ..Default::default() };
        assert!(s.occupancy(1.0) <= 1.0);
        assert_eq!(s.occupancy(0.0), 0.0);
    }

    #[test]
    fn global_engine_is_usable() {
        let eng = global();
        assert!(eng.threads() >= 1);
        let count = AtomicUsize::new(0);
        eng.run(3, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 3);
    }
}
