//! Two-stage bounded pipeline: a prefetch stage feeding a compute stage
//! through a capacity-limited queue (DESIGN.md §Streaming).
//!
//! [`run_pipeline`] runs `producer(i)` for `i in 0..n` on one helper
//! thread and `consumer(i, item)` on the calling thread, overlapped.
//! The determinism argument is structural, not a tuning property:
//!
//! * items are produced index-ascending by a single producer,
//! * the queue is FIFO, and
//! * the consumer applies items **strictly in index order** on one
//!   thread,
//!
//! so overlap changes *when* work happens but never *what order* state
//! is mutated in — the pipelined run is bitwise identical to the inline
//! serial loop (`for i { consumer(i, producer(i)?)? }`), which is
//! exactly what executes under `THANOS_THREADS=1` /
//! [`super::with_serial`].
//!
//! **Backpressure**: at most `capacity` items sit produced-but-unconsumed;
//! the producer blocks (applying backpressure to prefetch IO) rather
//! than buffering unboundedly. The coordinator derives `capacity` from
//! the [`crate::robust::stream::MemoryGovernor`] byte budget.
//!
//! **Watchdog**: each stage watches the *other* stage's progress
//! counter while blocked on the queue. The blocked side wakes on a
//! heartbeat (`Condvar::wait_timeout` — the one sanctioned way to pace
//! wakeups without reading a clock; no wall-clock value ever enters the
//! decision) and counts consecutive heartbeats in which the peer's
//! counter did not move. After `watchdog_beats` such beats the run
//! fails, naming the stuck stage, instead of hanging a multi-hour prune.
//! The decision is purely counter-based (D6-clean): beats elapsed ×
//! counter unchanged, never a timestamp comparison.
//!
//! A producer error or panic is forwarded through the queue in index
//! position and re-raised on the calling thread at the point the
//! consumer reaches that index — again identical to where the inline
//! loop would have failed.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::trace;

/// Tuning for [`run_pipeline`]. `capacity` bounds the queue (≥ 1);
/// `watchdog_beats == 0` disables stall detection; `beat_millis` paces
/// the heartbeat wakeups of a blocked stage. The stage names appear in
/// stall errors ("naming the stuck stage") and nowhere else.
#[derive(Clone, Copy, Debug)]
pub struct PipelineOpts {
    pub capacity: usize,
    pub watchdog_beats: u64,
    pub beat_millis: u64,
    pub prefetch_stage: &'static str,
    pub compute_stage: &'static str,
}

impl Default for PipelineOpts {
    fn default() -> Self {
        // ~2 minutes of silence before a stage is declared stuck.
        PipelineOpts {
            capacity: 2,
            watchdog_beats: 2400,
            beat_millis: 50,
            prefetch_stage: "prefetch",
            compute_stage: "compute",
        }
    }
}

/// Counters observed by one [`run_pipeline`] call.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PipelineStats {
    pub produced: u64,
    pub consumed: u64,
    /// High-water mark of produced-but-unconsumed items (≤ capacity).
    pub max_queued: usize,
    /// False when the run executed inline (serial engine mode).
    pub overlapped: bool,
}

enum Item<T> {
    Val(T),
    Err(anyhow::Error),
    Panic(Box<dyn std::any::Any + Send>),
}

struct Queue<T> {
    items: VecDeque<(usize, Item<T>)>,
    produced: u64,
    consumed: u64,
    max_queued: usize,
    done_producing: bool,
    closed: bool,
    /// Stall verdict from the producer-side watchdog (the consumer
    /// reports its own verdict directly from its pop loop).
    stall: Option<String>,
}

struct Shared<T> {
    q: Mutex<Queue<T>>,
    /// Signaled when queue space appears (or the pipeline closes).
    cv_push: Condvar,
    /// Signaled when an item appears (or the pipeline closes).
    cv_pop: Condvar,
}

fn stall_error(stage: &str, beats: u64) -> String {
    format!("pipeline stalled: stage `{stage}` made no progress across {beats} heartbeats")
}

/// Run the two-stage pipeline. See the module docs for the determinism
/// and watchdog contracts. Falls back to the inline serial loop when the
/// engine is in serial mode ([`super::with_serial`] / one thread).
pub fn run_pipeline<T, P, C>(
    n: usize,
    opts: &PipelineOpts,
    mut producer: P,
    mut consumer: C,
) -> Result<PipelineStats>
where
    T: Send,
    P: FnMut(usize) -> Result<T> + Send,
    C: FnMut(usize, T) -> Result<()>,
{
    let serial = super::SERIAL.with(|s| s.get()) || super::global().threads() == 1;
    if serial || n == 0 {
        for i in 0..n {
            let item = producer(i)?;
            consumer(i, item)?;
        }
        return Ok(PipelineStats {
            produced: n as u64,
            consumed: n as u64,
            max_queued: 0,
            overlapped: false,
        });
    }

    let capacity = opts.capacity.max(1);
    let sh = Shared {
        q: Mutex::new(Queue {
            items: VecDeque::with_capacity(capacity.min(n)),
            produced: 0,
            consumed: 0,
            max_queued: 0,
            done_producing: false,
            closed: false,
            stall: None,
        }),
        cv_push: Condvar::new(),
        cv_pop: Condvar::new(),
    };
    let beat = Duration::from_millis(opts.beat_millis.max(1));

    let mut panic_payload: Option<Box<dyn std::any::Any + Send>> = None;
    let result = std::thread::scope(|s| -> Result<PipelineStats> {
        let shr = &sh;
        s.spawn(move || {
            for i in 0..n {
                let item = match catch_unwind(AssertUnwindSafe(|| producer(i))) {
                    Ok(Ok(v)) => Item::Val(v),
                    Ok(Err(e)) => Item::Err(e),
                    Err(p) => Item::Panic(p),
                };
                let terminal = !matches!(item, Item::Val(_));
                let mut q = shr.q.lock().expect("pipeline queue poisoned");
                let mut last_consumed = q.consumed;
                let mut beats = 0u64;
                loop {
                    if q.closed {
                        return;
                    }
                    if q.items.len() < capacity {
                        q.items.push_back((i, item));
                        q.produced += 1;
                        q.max_queued = q.max_queued.max(q.items.len());
                        if terminal {
                            q.done_producing = true;
                        }
                        shr.cv_pop.notify_one();
                        break;
                    }
                    // Queue full: the compute stage owns every queued item,
                    // so no movement in `consumed` means it is stuck.
                    let (guard, timeout) = shr
                        .cv_push
                        .wait_timeout(q, beat)
                        .expect("pipeline queue poisoned");
                    q = guard;
                    if !timeout.timed_out() || q.consumed != last_consumed {
                        last_consumed = q.consumed;
                        beats = 0;
                        continue;
                    }
                    beats += 1;
                    if opts.watchdog_beats > 0 && beats >= opts.watchdog_beats {
                        q.stall = Some(stall_error(opts.compute_stage, beats));
                        q.closed = true;
                        shr.cv_pop.notify_all();
                        return;
                    }
                }
                if terminal {
                    return;
                }
            }
            let mut q = shr.q.lock().expect("pipeline queue poisoned");
            q.done_producing = true;
            shr.cv_pop.notify_all();
        });

        for i in 0..n {
            let (idx, item) = {
                let mut q = sh.q.lock().expect("pipeline queue poisoned");
                let mut last_produced = q.produced;
                let mut beats = 0u64;
                loop {
                    if let Some(msg) = q.stall.take() {
                        q.closed = true;
                        sh.cv_push.notify_all();
                        bail!("{msg}");
                    }
                    if let Some(front) = q.items.pop_front() {
                        q.consumed += 1;
                        sh.cv_push.notify_one();
                        break front;
                    }
                    if q.done_producing {
                        // Terminal error items are delivered in index
                        // position, so an exhausted producer with an empty
                        // queue before index n-1 cannot happen; fail loudly
                        // rather than wait forever if it ever does.
                        q.closed = true;
                        sh.cv_push.notify_all();
                        bail!("pipeline produced {} of {n} items", q.produced);
                    }
                    let guard = {
                        let _wait = trace::span("pipeline.wait");
                        let (guard, timeout) = sh
                            .cv_pop
                            .wait_timeout(q, beat)
                            .expect("pipeline queue poisoned");
                        if !timeout.timed_out() || guard.produced != last_produced {
                            last_produced = guard.produced;
                            beats = 0;
                        } else if !guard.done_producing {
                            beats += 1;
                        }
                        guard
                    };
                    q = guard;
                    if opts.watchdog_beats > 0 && beats >= opts.watchdog_beats {
                        q.closed = true;
                        sh.cv_push.notify_all();
                        bail!("{}", stall_error(opts.prefetch_stage, beats));
                    }
                }
            };
            debug_assert_eq!(idx, i, "pipeline items must arrive index-ascending");
            let close = |err: Option<anyhow::Error>| -> Result<()> {
                let mut q = sh.q.lock().expect("pipeline queue poisoned");
                q.closed = true;
                sh.cv_push.notify_all();
                match err {
                    Some(e) => Err(e),
                    None => Ok(()),
                }
            };
            match item {
                Item::Val(v) => {
                    if let Err(e) = consumer(i, v) {
                        close(Some(e))?;
                    }
                }
                Item::Err(e) => close(Some(e))?,
                Item::Panic(p) => {
                    panic_payload = Some(p);
                    close(None)?;
                    break;
                }
            }
        }
        let q = sh.q.lock().expect("pipeline queue poisoned");
        Ok(PipelineStats {
            produced: q.produced,
            consumed: q.consumed,
            max_queued: q.max_queued,
            overlapped: true,
        })
    });
    if let Some(p) = panic_payload {
        // Re-raise the producer's panic on the calling thread only after
        // the scope joined cleanly — exactly where the inline loop would
        // have panicked, with the helper thread already gone.
        resume_unwind(p);
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn opts(capacity: usize, watchdog_beats: u64, beat_millis: u64) -> PipelineOpts {
        PipelineOpts {
            capacity,
            watchdog_beats,
            beat_millis,
            prefetch_stage: "test.prefetch",
            compute_stage: "test.compute",
        }
    }

    fn collect(n: usize, o: &PipelineOpts) -> (Vec<usize>, PipelineStats) {
        let mut seen = Vec::new();
        let stats = run_pipeline(
            n,
            o,
            |i| Ok(i * 3),
            |i, v| {
                assert_eq!(v, i * 3);
                seen.push(v);
                Ok(())
            },
        )
        .unwrap();
        (seen, stats)
    }

    #[test]
    fn pipelined_matches_serial_in_order() {
        let o = opts(3, 0, 5);
        let (par, par_stats) = collect(37, &o);
        let (ser, ser_stats) = crate::engine::with_serial(|| collect(37, &o));
        assert_eq!(par, ser);
        assert_eq!(par, (0..37).map(|i| i * 3).collect::<Vec<_>>());
        assert!(!ser_stats.overlapped);
        if crate::engine::global().threads() > 1 {
            assert!(par_stats.overlapped);
            assert_eq!(par_stats.produced, 37);
            assert_eq!(par_stats.consumed, 37);
        }
    }

    #[test]
    fn empty_pipeline_is_fine() {
        let stats = run_pipeline(0, &opts(2, 0, 5), |_| Ok(()), |_, _| Ok(())).unwrap();
        assert_eq!(stats.produced, 0);
    }

    #[test]
    fn capacity_bounds_queue_depth() {
        if crate::engine::global().threads() == 1 {
            return; // inline path has no queue
        }
        let o = opts(2, 0, 5);
        let stats = run_pipeline(
            64,
            &o,
            |i| Ok(vec![i as u8; 16]),
            |_, _| {
                std::thread::sleep(Duration::from_micros(200));
                Ok(())
            },
        )
        .unwrap();
        assert!(stats.max_queued <= 2, "queue grew past capacity: {}", stats.max_queued);
        assert_eq!(stats.consumed, 64);
    }

    #[test]
    fn producer_error_arrives_in_index_order() {
        let consumed = AtomicUsize::new(0);
        let err = run_pipeline(
            10,
            &opts(4, 0, 5),
            |i| {
                if i == 3 {
                    anyhow::bail!("prefetch failed at {i}")
                } else {
                    Ok(i)
                }
            },
            |_, _| {
                consumed.fetch_add(1, Ordering::SeqCst);
                Ok(())
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("prefetch failed at 3"), "got: {err:#}");
        assert_eq!(consumed.load(Ordering::SeqCst), 3, "items before the error must land");
    }

    #[test]
    fn consumer_error_stops_the_producer() {
        let produced_past = AtomicUsize::new(0);
        let err = run_pipeline(
            1000,
            &opts(2, 0, 5),
            |i| {
                if i > 10 {
                    produced_past.fetch_add(1, Ordering::SeqCst);
                }
                Ok(i)
            },
            |i, _| {
                if i == 2 {
                    anyhow::bail!("compute rejected item {i}")
                } else {
                    Ok(())
                }
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("compute rejected item 2"), "got: {err:#}");
        // backpressure + close: the producer cannot have run far ahead
        assert!(
            produced_past.load(Ordering::SeqCst) < 16,
            "producer kept running after the consumer failed"
        );
    }

    #[test]
    fn producer_panic_reraises_on_caller_thread() {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let _ = run_pipeline(
                8,
                &opts(2, 0, 5),
                |i| {
                    if i == 1 {
                        panic!("injected fault: panic at `stream.prefetch`");
                    }
                    Ok(i)
                },
                |_, _: usize| Ok(()),
            );
        }));
        let p = caught.expect_err("panic must propagate");
        let msg = p
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| p.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("stream.prefetch"), "got: {msg}");
    }

    #[test]
    fn watchdog_names_a_stuck_prefetch_stage() {
        if crate::engine::global().threads() == 1 {
            return; // watchdog only exists on the overlapped path
        }
        // Cooperative stall: the producer blocks on a gate a rescuer
        // thread opens only well after the watchdog window has elapsed
        // (the scope still joins the producer before run_pipeline returns).
        let gate = std::sync::Arc::new((Mutex::new(false), Condvar::new()));
        let rescuer = {
            let gate = std::sync::Arc::clone(&gate);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(400));
                let (m, cv) = &*gate;
                *m.lock().unwrap() = true;
                cv.notify_all();
            })
        };
        let err = run_pipeline(
            4,
            &opts(2, 3, 10),
            |i| {
                if i == 1 {
                    let (m, cv) = &*gate;
                    let mut open = m.lock().unwrap();
                    while !*open {
                        open = cv.wait(open).unwrap();
                    }
                }
                Ok(i)
            },
            |_, _| Ok(()),
        )
        .unwrap_err();
        assert!(
            err.to_string().contains("stalled") && err.to_string().contains("test.prefetch"),
            "got: {err:#}"
        );
        rescuer.join().unwrap();
    }

    #[test]
    fn watchdog_names_a_stuck_compute_stage() {
        if crate::engine::global().threads() == 1 {
            return;
        }
        let gate = std::sync::Arc::new((Mutex::new(false), Condvar::new()));
        let rescuer = {
            let gate = std::sync::Arc::clone(&gate);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(400));
                let (m, cv) = &*gate;
                *m.lock().unwrap() = true;
                cv.notify_all();
            })
        };
        let err = run_pipeline(
            8,
            &opts(1, 3, 10),
            |i| Ok(i),
            |i, _| {
                if i == 0 {
                    let (m, cv) = &*gate;
                    let mut open = m.lock().unwrap();
                    while !*open {
                        open = cv.wait(open).unwrap();
                    }
                }
                Ok(())
            },
        )
        .unwrap_err();
        assert!(
            err.to_string().contains("stalled") && err.to_string().contains("test.compute"),
            "got: {err:#}"
        );
        rescuer.join().unwrap();
    }
}
