//! Exhaustive-interleaving model of the engine's push / claim /
//! terminate protocol — a loom-style checker over a hand-written
//! abstraction, built in-tree because the offline vendor set has no
//! model-checking crate.
//!
//! Each thread is a small program-counter machine over the shared state
//! that matters to the protocol: the queue mutex, the `shutdown` flag,
//! the queued job's claim counter and completion latch, and the
//! `work_cv` wait set. [`explore`] runs a depth-first search over every
//! interleaving of enabled transitions, memoizing visited states in a
//! `BTreeSet` (order-stable — determinism rule D2 holds here too).
//!
//! Two modeling choices make the check *conservative*:
//!
//! - **No spurious wakeups.** A parked thread is enabled only after a
//!   `notify`. Real condvars may wake spuriously and would eventually
//!   paper over a lost wakeup, but `std` guarantees nothing — a
//!   protocol that deadlocks here is wrong even if it usually limps
//!   through in practice.
//! - **Coarse atomic steps.** Lock-acquire+update+release sequences
//!   whose intermediate states no other thread can observe are fused
//!   into one transition; the shutdown-store step is the exception and
//!   is split exactly as the code under test splits it, because that
//!   window *is* the bug.
//!
//! Checked properties:
//!
//! 1. **No stuck state**: every non-final state has an enabled
//!    transition (deadlock-freedom).
//! 2. **Exactly-once execution**: every terminal state has all tasks
//!    claimed and the completion latch at zero.
//!
//! [`Config::locked_shutdown`] selects between the shipped `Drop`
//! protocol (store under the queue mutex) and the pre-fix variant
//! (unlocked store). The checker finds the lost-wakeup deadlock in the
//! latter — a worker that passed its shutdown check but has not yet
//! parked consumes no notify, then sleeps forever — and proves the
//! former clean; `tests/engine_model.rs` pins both outcomes as the
//! regression test for `PruneEngine::drop`.

use std::collections::BTreeSet;

/// Worker program counter values.
const W_ACQ: u8 = 0; // wants the queue lock
const W_CHK: u8 = 1; // holds lock, about to check `shutdown`
const W_SCAN: u8 = 2; // holds lock, scanning/retaining the queue
const W_WAITING: u8 = 3; // holds lock, committed to waiting
const W_EXEC: u8 = 4; // lock released, claim-executing the job
const W_WAKE: u8 = 5; // notified, wants the lock back
const W_PARKED: u8 = 8; // parked on `work_cv` (lock released)
const W_DONE: u8 = 9; // exited the worker loop

/// Submitter program counter values (the thread that runs `run_dyn`
/// once and then drops the engine).
const S_PUSH: u8 = 0;
const S_NOTIFY: u8 = 1;
const S_HELP: u8 = 2;
const S_LATCH: u8 = 3;
const S_STORE: u8 = 4;
const S_NOTIFY2: u8 = 5;
const S_JOIN: u8 = 6;
const S_DONE: u8 = 7;

/// No thread holds the queue mutex (holders are worker ids; submitter
/// lock sections are fused into single transitions, so it never holds
/// the lock across a visible state).
const LOCK_FREE: i8 = -1;

/// Model parameters.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// number of pool workers (the submitter is modeled separately)
    pub workers: usize,
    /// tasks in the single submitted job
    pub tasks: u8,
    /// `true` models the shipped `Drop` (shutdown stored under the
    /// queue mutex); `false` models the pre-fix unlocked store
    pub locked_shutdown: bool,
}

/// One interleaving state. `Ord` so the visited set can be a `BTreeSet`.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct State {
    shutdown: bool,
    job_present: bool,
    next: u8,
    remaining: u8,
    lock: i8,
    wpcs: Vec<u8>,
    spc: u8,
}

impl State {
    fn initial(cfg: &Config) -> State {
        State {
            shutdown: false,
            job_present: false,
            next: 0,
            remaining: cfg.tasks,
            lock: LOCK_FREE,
            wpcs: vec![W_ACQ; cfg.workers],
            spc: S_PUSH,
        }
    }

    fn is_final(&self) -> bool {
        self.spc == S_DONE && self.wpcs.iter().all(|&p| p == W_DONE)
    }

    /// `notify_all(work_cv)`: every parked thread becomes runnable (it
    /// still has to reacquire the lock before rechecking).
    fn notify_all(&mut self) {
        for p in &mut self.wpcs {
            if *p == W_PARKED {
                *p = W_WAKE;
            }
        }
    }
}

/// Result of exploring the full interleaving space.
#[derive(Clone, Debug)]
pub enum Outcome {
    /// no deadlock, and every terminal state executed all tasks
    Clean { states: usize, terminals: usize },
    /// a reachable non-final state with no enabled transition
    Stuck { states: usize, trace: Vec<String> },
    /// a terminal state with unexecuted tasks or a nonzero latch
    BadTerminal { states: usize, trace: Vec<String> },
}

fn worker_steps(cfg: &Config, st: &State, succ: &mut Vec<(String, State)>) {
    for (i, &pc) in st.wpcs.iter().enumerate() {
        let mut push = |label: String, f: &dyn Fn(&mut State)| {
            let mut n = st.clone();
            f(&mut n);
            succ.push((label, n));
        };
        match pc {
            W_ACQ if st.lock == LOCK_FREE => push(format!("w{i} acquires queue lock"), &|n| {
                n.lock = i as i8;
                n.wpcs[i] = W_CHK;
            }),
            W_CHK if st.shutdown => push(format!("w{i} sees shutdown, exits"), &|n| {
                n.lock = LOCK_FREE;
                n.wpcs[i] = W_DONE;
            }),
            W_CHK => push(format!("w{i} shutdown clear"), &|n| n.wpcs[i] = W_SCAN),
            W_SCAN if st.job_present && st.next < cfg.tasks => {
                push(format!("w{i} takes job, releases lock"), &|n| {
                    n.lock = LOCK_FREE;
                    n.wpcs[i] = W_EXEC;
                })
            }
            W_SCAN => push(format!("w{i} retains: queue empty, will wait"), &|n| {
                n.job_present = false; // fully-claimed job dropped
                n.wpcs[i] = W_WAITING;
            }),
            W_WAITING => push(format!("w{i} parks on work_cv"), &|n| {
                n.lock = LOCK_FREE;
                n.wpcs[i] = W_PARKED;
            }),
            W_WAKE if st.lock == LOCK_FREE => {
                push(format!("w{i} reacquires lock after wake"), &|n| {
                    n.lock = i as i8;
                    n.wpcs[i] = W_CHK;
                })
            }
            W_EXEC if st.next < cfg.tasks => {
                push(format!("w{i} claims+runs task {}", st.next), &|n| {
                    n.next += 1;
                    n.remaining -= 1;
                })
            }
            W_EXEC => push(format!("w{i} job drained, rechecks queue"), &|n| {
                n.wpcs[i] = W_ACQ;
            }),
            _ => {}
        }
    }
}

fn submitter_steps(cfg: &Config, st: &State, succ: &mut Vec<(String, State)>) {
    let mut push = |label: &str, f: &dyn Fn(&mut State)| {
        let mut n = st.clone();
        f(&mut n);
        succ.push((label.to_string(), n));
    };
    match st.spc {
        S_PUSH if st.lock == LOCK_FREE => push("sub pushes job (under lock)", &|n| {
            n.job_present = true;
            n.spc = S_NOTIFY;
        }),
        S_NOTIFY => push("sub notifies work_cv", &|n| {
            n.notify_all();
            n.spc = S_HELP;
        }),
        S_HELP if st.next < cfg.tasks => push("sub claims+runs a task", &|n| {
            n.next += 1;
            n.remaining -= 1;
        }),
        S_HELP => push("sub drained its job", &|n| n.spc = S_LATCH),
        S_LATCH if st.remaining == 0 => push("sub latch open (remaining==0)", &|n| {
            n.spc = S_STORE;
        }),
        S_STORE if cfg.locked_shutdown => {
            if st.lock == LOCK_FREE {
                push("sub stores shutdown under queue lock", &|n| {
                    n.shutdown = true;
                    n.spc = S_NOTIFY2;
                });
            }
        }
        S_STORE => push("sub stores shutdown (no lock)", &|n| {
            n.shutdown = true;
            n.spc = S_NOTIFY2;
        }),
        S_NOTIFY2 => push("sub notifies work_cv for shutdown", &|n| {
            n.notify_all();
            n.spc = S_JOIN;
        }),
        S_JOIN if st.wpcs.iter().all(|&p| p == W_DONE) => push("sub joins workers", &|n| {
            n.spc = S_DONE;
        }),
        _ => {}
    }
}

/// DFS over every interleaving reachable from the initial state.
pub fn explore(cfg: &Config) -> Outcome {
    let mut seen: BTreeSet<State> = BTreeSet::new();
    let mut stack: Vec<(State, Vec<String>)> = vec![(State::initial(cfg), Vec::new())];
    let mut terminals = 0usize;
    let mut stuck: Option<Vec<String>> = None;
    let mut bad: Option<Vec<String>> = None;
    while let Some((st, path)) = stack.pop() {
        if !seen.insert(st.clone()) {
            continue;
        }
        let mut succ: Vec<(String, State)> = Vec::new();
        worker_steps(cfg, &st, &mut succ);
        submitter_steps(cfg, &st, &mut succ);
        if succ.is_empty() {
            if st.is_final() {
                terminals += 1;
                if (st.next != cfg.tasks || st.remaining != 0) && bad.is_none() {
                    let mut t = path.clone();
                    t.push(format!("terminal with next={} remaining={}", st.next, st.remaining));
                    bad = Some(t);
                }
            } else if stuck.is_none() {
                let mut t = path.clone();
                t.push(format!("STUCK: {st:?}"));
                stuck = Some(t);
            }
            continue;
        }
        for (label, nst) in succ {
            if !seen.contains(&nst) {
                let mut npath = path.clone();
                npath.push(label);
                stack.push((nst, npath));
            }
        }
    }
    let states = seen.len();
    if let Some(trace) = stuck {
        Outcome::Stuck { states, trace }
    } else if let Some(trace) = bad {
        Outcome::BadTerminal { states, trace }
    } else {
        Outcome::Clean { states, terminals }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlocked_shutdown_store_has_a_lost_wakeup_deadlock() {
        let out = explore(&Config { workers: 2, tasks: 2, locked_shutdown: false });
        match out {
            Outcome::Stuck { trace, .. } => {
                let joined = trace.join("\n");
                assert!(joined.contains("parks on work_cv"), "{joined}");
                assert!(joined.contains("no lock"), "{joined}");
            }
            other => panic!("expected a deadlock, got {other:?}"),
        }
    }

    #[test]
    fn locked_shutdown_store_is_deadlock_free_and_exactly_once() {
        let out = explore(&Config { workers: 2, tasks: 2, locked_shutdown: true });
        match out {
            Outcome::Clean { states, terminals } => {
                assert!(states > 100, "suspiciously small space: {states}");
                assert!(terminals > 0);
            }
            other => panic!("expected clean, got {other:?}"),
        }
    }
}
