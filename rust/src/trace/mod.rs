//! Per-worker span tracer with Chrome trace-event export.
//!
//! The observability layer the perf PRs (L3–L5) hand-rolled with
//! scattered `Instant` pairs, rebuilt as a subsystem with the same
//! determinism discipline the audit enforces:
//!
//! - **Recording** is per-thread and lock-free: each thread owns a
//!   thread-local event buffer (a shard, keyed by a lazily-assigned
//!   worker id), and [`span`] pushes a begin/end event pair of raw
//!   [`clock`] ticks into it. The hot path takes no lock, performs no
//!   atomic RMW, allocates no `String` — D1-clean inside engine
//!   closures — and when tracing is disabled it is a single relaxed
//!   flag load plus a branch.
//! - **Draining** happens at engine job boundaries: workers flush
//!   their local buffer into a global registry after each job (and on
//!   thread exit), so the submitter can snapshot a consistent,
//!   per-shard-ordered event stream without ever stopping the pool.
//! - **Export** turns the registry into Chrome trace-event JSON
//!   (`chrome://tracing` / Perfetto `B`/`E` phase events, one `tid`
//!   per shard) via `THANOS_TRACE=out.json` or the `--trace` CLI
//!   flag, and [`aggregate`] folds the same stream into per-stage
//!   counts, totals and latency [`Histogram`]s for
//!   `PruneReport::summary()` and the BENCH JSON stage rows.
//!
//! Spans never perturb results: they carry no data into the compute
//! chain, and the serial==parallel bitwise-identity tests run with
//! tracing enabled (`rust/tests/trace_observability.rs`). All
//! wall-clock reads live in [`clock`], the audit's single D6 ledger
//! entry.
//!
//! Balance guarantee: an `End` is recorded iff its `Begin` was (the
//! span guard arms only on a successful `Begin`, and capacity limits
//! gate `Begin` only), and guards record their `End` on `Drop` — so
//! every flushed shard stream is balanced and properly nested even
//! across panics propagated out of engine tasks.

pub mod clock;
pub mod hist;

pub use hist::Histogram;

use crate::jsonutil::{obj, Json};
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

/// Environment variable naming the Chrome-trace output path; the
/// `--trace` CLI flag takes precedence. Setting either enables
/// tracing for the whole run.
pub const TRACE_ENV: &str = "THANOS_TRACE";

/// Per-thread event budget between flushes. Begins beyond the cap are
/// dropped (and counted); ends always land so streams stay balanced.
const LOCAL_CAP: usize = 1 << 16;
/// Global registry budget across all shards (~96 MB worst case).
const REGISTRY_CAP: usize = 1 << 22;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_SHARD: AtomicU32 = AtomicU32::new(0);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static REGISTRY: Mutex<BTreeMap<u32, Vec<Event>>> = Mutex::new(BTreeMap::new());
static OUT_PATH: Mutex<Option<PathBuf>> = Mutex::new(None);

/// Begin/end marker of one span event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Begin,
    End,
}

/// One recorded event: phase, interned stage name, epoch-relative
/// tick. 24 bytes, `Copy` — the unit of the thread-local buffers.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub phase: Phase,
    pub name: &'static str,
    pub t_nanos: u64,
}

struct LocalBuf {
    shard: Option<u32>,
    events: Vec<Event>,
}

impl LocalBuf {
    const fn new() -> LocalBuf {
        LocalBuf { shard: None, events: Vec::new() }
    }

    fn shard_id(&mut self) -> u32 {
        *self.shard.get_or_insert_with(|| NEXT_SHARD.fetch_add(1, Ordering::Relaxed))
    }

    /// Move the buffered events into the global registry (order
    /// preserved per shard). Whole batches beyond [`REGISTRY_CAP`]
    /// are dropped and counted rather than silently truncated.
    fn spill(&mut self) {
        if self.events.is_empty() {
            return;
        }
        let id = self.shard_id();
        let mut reg = registry();
        let held: usize = reg.values().map(Vec::len).sum();
        if held + self.events.len() > REGISTRY_CAP {
            DROPPED.fetch_add(self.events.len() as u64, Ordering::Relaxed);
            self.events.clear();
            return;
        }
        reg.entry(id).or_default().append(&mut self.events);
    }
}

impl Drop for LocalBuf {
    fn drop(&mut self) {
        // thread exit: whatever the last flush missed lands here
        self.spill();
    }
}

thread_local! {
    static LOCAL: RefCell<LocalBuf> = const { RefCell::new(LocalBuf::new()) };
}

fn registry() -> MutexGuard<'static, BTreeMap<u32, Vec<Event>>> {
    // tolerate poisoning: the registry holds plain event data and a
    // panicking engine task must still be able to flush on unwind
    REGISTRY.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Whether span recording is on (relaxed load — the disabled hot-path
/// cost of [`span`]).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on or off (tests and [`init`]).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Count of events dropped at capacity limits so far.
pub fn dropped_events() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Record one event into the calling thread's shard. Returns whether
/// the event landed; `Begin` respects [`LOCAL_CAP`], `End` always
/// lands (its `Begin` did, so balance requires it).
fn record(phase: Phase, name: &'static str) -> bool {
    let t_nanos = clock::now_nanos();
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        if phase == Phase::Begin && l.events.len() >= LOCAL_CAP {
            DROPPED.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        l.events.push(Event { phase, name, t_nanos });
        true
    })
}

/// RAII span guard returned by [`span`]: records `End` on drop, so
/// spans close on every exit path — early `return`, `?`, and panic
/// unwinding through engine tasks alike.
pub struct Span {
    name: &'static str,
    armed: bool,
}

impl Drop for Span {
    #[inline]
    fn drop(&mut self) {
        if self.armed {
            record(Phase::End, self.name);
        }
    }
}

/// Open a named span over the enclosing scope. Inert (one relaxed
/// load) when tracing is disabled; otherwise pushes a `Begin` into
/// the thread-local shard and an `End` when the guard drops. `name`
/// must be a `'static` literal — the interning that keeps events at
/// 24 bytes with no allocation.
#[inline]
pub fn span(name: &'static str) -> Span {
    if !enabled() {
        return Span { name, armed: false };
    }
    let armed = record(Phase::Begin, name);
    Span { name, armed }
}

/// Run `f` under a span and return `(result, wall_secs)`. The seconds
/// are always measured (coordinator stage accounting must survive
/// tracing being off); only the span events are gated on [`enabled`].
#[inline]
pub fn timed<T>(name: &'static str, f: impl FnOnce() -> T) -> (T, f64) {
    let _span = span(name);
    let t0 = clock::now_nanos();
    let out = f();
    (out, clock::secs_since(t0))
}

/// Flush the calling thread's buffered events into the global
/// registry. The engine calls this at job boundaries (after each
/// worker job, and when a submitter's `run` returns); long-lived
/// non-engine threads may call it whenever a consistent snapshot is
/// wanted. Cheap no-op when the buffer is empty.
pub fn flush_local() {
    LOCAL.with(|l| l.borrow_mut().spill());
}

/// Enable tracing and set the export path from the `--trace` CLI flag
/// (preferred) or the [`TRACE_ENV`] environment variable. No-op when
/// neither is set.
pub fn init(cli_path: Option<&str>) {
    let path = cli_path
        .map(str::to_string)
        .or_else(|| std::env::var(TRACE_ENV).ok())
        .filter(|p| !p.is_empty());
    if let Some(p) = path {
        *OUT_PATH.lock().unwrap_or_else(std::sync::PoisonError::into_inner) =
            Some(PathBuf::from(p));
        set_enabled(true);
    }
}

/// [`init`] from the environment only (benches, which have no CLI).
pub fn init_from_env() {
    init(None);
}

/// The configured export path, if tracing was initialized with one.
pub fn output_path() -> Option<PathBuf> {
    OUT_PATH.lock().unwrap_or_else(std::sync::PoisonError::into_inner).clone()
}

/// Snapshot all shards: flush the calling thread, then clone the
/// registry. Non-destructive — export and aggregation can both run,
/// in any order, and partial snapshots mid-run are valid (balanced
/// per shard up to any still-open spans on other threads).
fn snapshot() -> BTreeMap<u32, Vec<Event>> {
    flush_local();
    registry().clone()
}

/// Export the recorded spans as Chrome trace-event JSON to the path
/// from [`init`]. Returns `Ok(None)` when tracing is off or no path
/// is configured.
pub fn export() -> Result<Option<PathBuf>> {
    if !enabled() {
        return Ok(None);
    }
    match output_path() {
        Some(path) => {
            export_to(&path)?;
            Ok(Some(path))
        }
        None => Ok(None),
    }
}

/// Export the recorded spans as Chrome trace-event JSON to `path`.
/// The write is atomic (temp file + fsync + rename), so a crash during
/// export never leaves a truncated trace behind (audit rule D7).
pub fn export_to(path: &Path) -> Result<()> {
    let shards = snapshot();
    let doc = chrome_trace_json(&shards);
    let mut text = doc.to_string_compact();
    text.push('\n');
    crate::robust::write_atomic(path, text.as_bytes())
        .with_context(|| format!("writing Chrome trace to {}", path.display()))
}

/// Build the Chrome trace-event document: `B`/`E` duration events
/// with microsecond `ts`, `pid` 1, one `tid` per shard, plus
/// `thread_name` metadata rows and a `dropped_events` side channel.
fn chrome_trace_json(shards: &BTreeMap<u32, Vec<Event>>) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for (&tid, evs) in shards {
        events.push(obj(vec![
            ("name", Json::Str("thread_name".to_string())),
            ("ph", Json::Str("M".to_string())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(f64::from(tid))),
            ("args", obj(vec![("name", Json::Str(format!("shard-{tid}")))])),
        ]));
        for ev in evs {
            let ph = match ev.phase {
                Phase::Begin => "B",
                Phase::End => "E",
            };
            events.push(obj(vec![
                ("name", Json::Str(ev.name.to_string())),
                ("ph", Json::Str(ph.to_string())),
                ("ts", Json::Num(ev.t_nanos as f64 / 1e3)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(f64::from(tid))),
            ]));
        }
    }
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("otherData", obj(vec![("dropped_events", Json::Num(dropped_events() as f64))])),
    ])
}

/// Per-stage aggregate over all recorded spans of one name.
#[derive(Clone, Debug)]
pub struct StageAgg {
    pub name: &'static str,
    /// completed spans
    pub count: u64,
    /// summed span durations (overlapping spans on different workers
    /// each count fully, so totals can exceed wall time)
    pub total_nanos: u64,
    /// span-duration distribution in nanoseconds
    pub hist: Histogram,
}

impl StageAgg {
    pub fn total_secs(&self) -> f64 {
        self.total_nanos as f64 * 1e-9
    }
}

/// Pair begin/end events per shard and fold the resulting durations
/// by stage name. Non-destructive; spans still open on other threads
/// are skipped (their `End` has not been flushed yet).
pub fn aggregate() -> Vec<StageAgg> {
    aggregate_shards(&snapshot())
}

fn aggregate_shards(shards: &BTreeMap<u32, Vec<Event>>) -> Vec<StageAgg> {
    let mut by_name: BTreeMap<&'static str, StageAgg> = BTreeMap::new();
    for evs in shards.values() {
        let mut open: Vec<(&'static str, u64)> = Vec::new();
        for ev in evs {
            match ev.phase {
                Phase::Begin => open.push((ev.name, ev.t_nanos)),
                Phase::End => {
                    // spans are LIFO per thread, but a capped Begin
                    // drops its End too, so match by name from the top
                    if let Some(pos) = open.iter().rposition(|&(n, _)| n == ev.name) {
                        let (_, t0) = open.remove(pos);
                        let dur = ev.t_nanos.saturating_sub(t0);
                        let agg = by_name.entry(ev.name).or_insert_with(|| StageAgg {
                            name: ev.name,
                            count: 0,
                            total_nanos: 0,
                            hist: Histogram::new(),
                        });
                        agg.count += 1;
                        agg.total_nanos += dur;
                        agg.hist.record(dur);
                    }
                }
            }
        }
    }
    by_name.into_values().collect()
}

/// One row of a per-run stage breakdown (`PruneReport::stages`).
#[derive(Clone, Debug)]
pub struct StageLine {
    pub name: &'static str,
    pub count: u64,
    pub secs: f64,
}

/// Current per-stage `(count, total_nanos)` totals — take one before
/// a run and feed it to [`stage_delta`] after to scope the breakdown
/// to that run.
pub fn stage_totals() -> BTreeMap<&'static str, (u64, u64)> {
    aggregate().into_iter().map(|a| (a.name, (a.count, a.total_nanos))).collect()
}

/// Stage breakdown since an earlier [`stage_totals`] snapshot.
pub fn stage_delta(before: &BTreeMap<&'static str, (u64, u64)>) -> Vec<StageLine> {
    stage_totals()
        .into_iter()
        .map(|(name, (count, nanos))| {
            let (c0, n0) = before.get(name).copied().unwrap_or((0, 0));
            StageLine {
                name,
                count: count.saturating_sub(c0),
                secs: nanos.saturating_sub(n0) as f64 * 1e-9,
            }
        })
        .filter(|l| l.count > 0)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Pure-function tests only: the global enable flag and registry
    // are process-wide, and the lib test binary runs tests in
    // parallel — every scenario that toggles or drains global state
    // lives in rust/tests/trace_observability.rs (its own process).

    fn ev(phase: Phase, name: &'static str, t_nanos: u64) -> Event {
        Event { phase, name, t_nanos }
    }

    #[test]
    fn aggregation_pairs_nested_and_skips_open_spans() {
        let mut shards: BTreeMap<u32, Vec<Event>> = BTreeMap::new();
        shards.insert(
            0,
            vec![
                ev(Phase::Begin, "outer", 100),
                ev(Phase::Begin, "inner", 200),
                ev(Phase::End, "inner", 350),
                ev(Phase::End, "outer", 600),
                ev(Phase::Begin, "open", 700), // never closed: skipped
            ],
        );
        shards.insert(
            1,
            vec![ev(Phase::Begin, "inner", 1000), ev(Phase::End, "inner", 1400)],
        );
        let aggs = aggregate_shards(&shards);
        let get = |n: &str| aggs.iter().find(|a| a.name == n);
        let inner = get("inner").unwrap();
        assert_eq!(inner.count, 2);
        assert_eq!(inner.total_nanos, 150 + 400);
        assert_eq!(inner.hist.count(), 2);
        assert_eq!(inner.hist.max(), Some(400));
        let outer = get("outer").unwrap();
        assert_eq!((outer.count, outer.total_nanos), (1, 500));
        assert!(get("open").is_none());
    }

    #[test]
    fn chrome_json_is_valid_and_balanced() {
        let mut shards: BTreeMap<u32, Vec<Event>> = BTreeMap::new();
        shards.insert(
            3,
            vec![
                ev(Phase::Begin, "walk.solve", 1_000),
                ev(Phase::End, "walk.solve", 2_500),
            ],
        );
        let doc = chrome_trace_json(&shards);
        // round-trips through the parser
        let parsed = Json::parse(&doc.to_string_compact()).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 3); // metadata + B + E
        let meta = &evs[0];
        assert_eq!(meta.get("ph").unwrap().as_str().unwrap(), "M");
        let b = &evs[1];
        assert_eq!(b.get("ph").unwrap().as_str().unwrap(), "B");
        assert_eq!(b.get("name").unwrap().as_str().unwrap(), "walk.solve");
        assert_eq!(b.get("tid").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(b.get("ts").unwrap().as_f64().unwrap(), 1.0); // µs
        let e = &evs[2];
        assert_eq!(e.get("ph").unwrap().as_str().unwrap(), "E");
        assert_eq!(e.get("ts").unwrap().as_f64().unwrap(), 2.5);
    }

    #[test]
    fn stage_delta_subtracts_prior_totals() {
        let mut before: BTreeMap<&'static str, (u64, u64)> = BTreeMap::new();
        before.insert("walk.solve", (5, 1_000_000_000));
        // synthesize "after" by going through the public math directly
        let after: Vec<StageLine> = [("walk.solve", (7u64, 1_500_000_000u64))]
            .into_iter()
            .map(|(name, (count, nanos))| {
                let (c0, n0) = before.get(name).copied().unwrap_or((0, 0));
                StageLine {
                    name,
                    count: count - c0,
                    secs: (nanos - n0) as f64 * 1e-9,
                }
            })
            .collect();
        assert_eq!(after.len(), 1);
        assert_eq!(after[0].count, 2);
        assert!((after[0].secs - 0.5).abs() < 1e-12);
    }
}
