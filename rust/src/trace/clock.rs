//! The crate's single wall-clock read point.
//!
//! Every timing in the tree — engine busy gauges, coordinator stage
//! seconds, per-layer prune times, bench harness reps, span ticks —
//! derives from [`now_nanos`], which reads one process-wide monotonic
//! epoch lazily pinned at the first call. Confining the `Instant::`
//! access to this module is what lets the determinism audit (rule D6,
//! DESIGN.md §Determinism-contract) carry exactly ONE wall-clock
//! ledger entry instead of one per instrumented subsystem: the
//! analyzer treats `rust/src/trace` as a compute path, flags the
//! single site below, and `audit.toml` pins it at count 1.
//!
//! Ticks are epoch-relative `u64` nanoseconds, so they are `Copy`,
//! totally ordered across threads (the epoch is shared), directly
//! usable as Chrome trace-event timestamps, and cheap to stash in the
//! tracer's thread-local event buffers without carrying an `Instant`
//! around.

use std::sync::OnceLock;
use std::time::Instant;

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Nanoseconds elapsed since the process trace epoch (pinned at the
/// first call from any thread). Monotone non-decreasing per thread and
/// comparable across threads.
#[inline]
pub fn now_nanos() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Seconds elapsed since a tick previously obtained from
/// [`now_nanos`]. Saturates at zero if `t0_nanos` is in the future
/// (cannot happen for ticks taken on the same thread).
#[inline]
pub fn secs_since(t0_nanos: u64) -> f64 {
    now_nanos().saturating_sub(t0_nanos) as f64 * 1e-9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticks_are_monotone_and_secs_nonneg() {
        let a = now_nanos();
        let b = now_nanos();
        assert!(b >= a);
        assert!(secs_since(a) >= 0.0);
        // a tick "from the future" saturates instead of wrapping
        assert_eq!(secs_since(u64::MAX), 0.0);
    }
}
