//! Log-bucketed latency histogram.
//!
//! HdrHistogram-style layout: values below 32 get exact unit buckets;
//! above that, each power-of-two block is split into 32 sub-buckets
//! (5 mantissa bits), so a bucket's width is at most `1/32` of its
//! lower bound. Quantile estimates therefore carry a guaranteed
//! relative error ≤ 1/32 ≈ 3.1% — pinned against an exact sort-based
//! oracle by the tests below. The whole `u64` range maps to 1920
//! buckets; counts live in a lazily-grown heap `Vec` so an idle
//! histogram costs a few dozen bytes.
//!
//! Recording is a handful of integer ops and touches no locks — the
//! tracer records raw span events on the hot path and only builds
//! histograms at drain time ([`crate::trace::aggregate`]), but the
//! type is also fit for direct per-request recording in a serving
//! loop.

/// Mantissa bits per power-of-two block.
const SUB_BITS: u32 = 5;
const SUB: u64 = 1 << SUB_BITS;

/// Log-bucketed `u64` histogram with bounded-relative-error quantiles.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    n: u64,
    min: u64,
    max: u64,
    sum: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// Bucket index for a value: exact below `SUB`, log-bucketed above.
fn bucket_index(v: u64) -> usize {
    if v < SUB {
        return v as usize;
    }
    let e = 63 - v.leading_zeros();
    let mant = ((v >> (e - SUB_BITS)) - SUB) as usize;
    (((e - SUB_BITS + 1) as usize) << SUB_BITS) + mant
}

/// Smallest value that maps to bucket `idx` (the reported quantile
/// estimate — a lower bound on every value in the bucket).
fn bucket_low(idx: usize) -> u64 {
    if idx < SUB as usize {
        return idx as u64;
    }
    let block = (idx >> SUB_BITS) as u32;
    let mant = (idx & (SUB as usize - 1)) as u64;
    (SUB + mant) << (block - 1)
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { counts: Vec::new(), n: 0, min: u64::MAX, max: 0, sum: 0 }
    }

    /// Record one value.
    pub fn record(&mut self, v: u64) {
        let idx = bucket_index(v);
        if idx >= self.counts.len() {
            self.counts.resize(idx + 1, 0);
        }
        self.counts[idx] += 1;
        self.n += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        if other.counts.len() > self.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (dst, &src) in self.counts.iter_mut().zip(&other.counts) {
            *dst += src;
        }
        self.n += other.n;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Exact minimum recorded value (`None` when empty).
    pub fn min(&self) -> Option<u64> {
        (self.n > 0).then_some(self.min)
    }

    /// Exact maximum recorded value (`None` when empty).
    pub fn max(&self) -> Option<u64> {
        (self.n > 0).then_some(self.max)
    }

    /// Mean of all recorded values (`None` when empty).
    pub fn mean(&self) -> Option<f64> {
        (self.n > 0).then(|| self.sum as f64 / self.n as f64)
    }

    /// Nearest-rank quantile estimate: the bucket lower bound of the
    /// value at rank `⌈q·n⌉` (clamped to `[1, n]`). The estimate never
    /// exceeds the exact order statistic and undershoots it by at most
    /// a factor of 1/32; the top rank returns the exact maximum.
    pub fn quantile(&self, q: f64) -> Option<u64> {
        if self.n == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * self.n as f64).ceil() as u64).clamp(1, self.n);
        if rank == self.n {
            return Some(self.max);
        }
        let mut cum = 0u64;
        for (idx, &cnt) in self.counts.iter().enumerate() {
            cum += cnt;
            if cum >= rank {
                return Some(bucket_low(idx));
            }
        }
        Some(self.max)
    }

    pub fn p50(&self) -> Option<u64> {
        self.quantile(0.5)
    }

    pub fn p90(&self) -> Option<u64> {
        self.quantile(0.9)
    }

    pub fn p99(&self) -> Option<u64> {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exact oracle: sort and take the same nearest rank the histogram
    /// uses, then check the bounded-relative-error contract.
    fn check_against_oracle(name: &str, values: &[u64]) {
        let mut h = Histogram::new();
        for &v in values {
            h.record(v);
        }
        assert_eq!(h.count(), values.len() as u64);
        let mut sorted = values.to_vec();
        sorted.sort_unstable();
        for &q in &[0.0, 0.1, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let est = h.quantile(q);
            if values.is_empty() {
                assert_eq!(est, None, "{name}: empty histogram must yield None");
                continue;
            }
            let n = values.len() as u64;
            let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
            let exact = sorted[(rank - 1) as usize];
            let est = est.unwrap();
            assert!(est <= exact, "{name} q={q}: est {est} > exact {exact}");
            let err = (exact - est) as f64;
            assert!(
                err * 32.0 <= exact as f64,
                "{name} q={q}: est {est} misses exact {exact} by more than 1/32"
            );
        }
        if !values.is_empty() {
            assert_eq!(h.min(), Some(sorted[0]));
            assert_eq!(h.max(), Some(*sorted.last().unwrap()));
            assert_eq!(h.quantile(1.0), Some(*sorted.last().unwrap()));
        }
    }

    #[test]
    fn bucket_layout_is_monotone_with_tight_lower_bounds() {
        let mut last = 0usize;
        let probe: Vec<u64> = (0..4096)
            .chain((5..63).flat_map(|k| [(1u64 << k) - 1, 1 << k, (1 << k) + 1]))
            .chain([u64::MAX - 1, u64::MAX])
            .collect();
        let mut sorted = probe;
        sorted.sort_unstable();
        for v in sorted {
            let idx = bucket_index(v);
            assert!(idx >= last, "bucket index not monotone at {v}");
            last = idx;
            assert!(bucket_low(idx) <= v, "low({idx}) > {v}");
            if idx + 1 < 1920 {
                // v sits strictly below the next bucket's lower bound
                assert!(v < bucket_low(idx + 1), "v {v} >= low({})", idx + 1);
            }
        }
        assert_eq!(bucket_index(u64::MAX), 1919);
    }

    #[test]
    fn quantiles_match_exact_oracle_across_distributions() {
        check_against_oracle("empty", &[]);
        check_against_oracle("single", &[1_234_567]);
        check_against_oracle("all-zero", &[0; 100]);
        let mut ties = vec![1000u64; 500];
        ties.extend(vec![2000u64; 500]);
        ties.extend([1u64; 3]);
        check_against_oracle("heavy-ties", &ties);
        // deterministic heavy tail: v_i = 1e6 / (i+1)^1.3
        let power_law: Vec<u64> =
            (0..20_000).map(|i| (1.0e6 / f64::from(i + 1).powf(1.3)) as u64).collect();
        check_against_oracle("power-law", &power_law);
        // LCG uniform draws over a wide range
        let mut state = 0x2545F491_4F6CDD1Du64;
        let uniform: Vec<u64> = (0..9999)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                state >> 24
            })
            .collect();
        check_against_oracle("uniform", &uniform);
    }

    #[test]
    fn merge_equals_single_stream() {
        let a: Vec<u64> = (0..500).map(|i| i * 37 % 100_000).collect();
        let b: Vec<u64> = (0..700).map(|i| i * i + 5).collect();
        let mut ha = Histogram::new();
        let mut hb = Histogram::new();
        let mut hall = Histogram::new();
        for &v in &a {
            ha.record(v);
            hall.record(v);
        }
        for &v in &b {
            hb.record(v);
            hall.record(v);
        }
        ha.merge(&hb);
        assert_eq!(ha.count(), hall.count());
        assert_eq!(ha.min(), hall.min());
        assert_eq!(ha.max(), hall.max());
        for &q in &[0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(ha.quantile(q), hall.quantile(q));
        }
        assert_eq!(ha.mean(), hall.mean());
    }
}
