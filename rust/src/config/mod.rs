//! Configuration system: model presets, run configuration, CLI
//! overrides, JSON (de)serialization.
//!
//! The launcher (`thanos` binary) resolves configuration in layers:
//! built-in preset → optional JSON config file → `--key=value` CLI
//! overrides, in that order — the usual framework pattern (MaxText-
//! style) without external crates.

use crate::jsonutil::{obj, Json};
use anyhow::{bail, Context, Result};

/// Transformer architecture configuration (decoder-only LM).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
}

impl ModelConfig {
    /// Built-in presets. Sizes are chosen so pruning-quality deltas are
    /// measurable on CPU in minutes (DESIGN.md §Substitutions).
    pub fn preset(name: &str) -> Result<ModelConfig> {
        Ok(match name {
            // ~1.1M params — TinyLlama-analogue for Table 5 sweeps
            "tiny" => ModelConfig {
                name: "tiny".into(),
                vocab: 512,
                d_model: 128,
                n_layers: 2,
                n_heads: 4,
                d_ff: 512,
                seq_len: 128,
            },
            // ~4.9M params — the Table 2/3 workhorse
            "small" => ModelConfig {
                name: "small".into(),
                vocab: 512,
                d_model: 256,
                n_layers: 4,
                n_heads: 4,
                d_ff: 1024,
                seq_len: 128,
            },
            // ~13M params — the "larger model" column
            "med" => ModelConfig {
                name: "med".into(),
                vocab: 512,
                d_model: 384,
                n_layers: 6,
                n_heads: 6,
                d_ff: 1536,
                seq_len: 128,
            },
            other => bail!("unknown model preset '{other}' (tiny|small|med)"),
        })
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count (embeddings + blocks + final norm; the
    /// unembedding is tied to the embedding).
    pub fn n_params(&self) -> usize {
        let emb = self.vocab * self.d_model;
        let per_block = 4 * self.d_model * self.d_model          // q,k,v,o
            + 2 * self.d_model * self.d_ff                        // ff1, ff2
            + 2 * self.d_model;                                   // 2 norms
        emb + self.n_layers * per_block + self.d_model
    }

    /// The distinct prunable layer shapes (c×b) of one block, in
    /// pipeline order: q/k/v/o projections and the two FF matrices.
    /// Layout is `y = W·x` with `W ∈ ℝ^{out×in}` (c=out, b=in).
    pub fn layer_shapes(&self) -> Vec<(String, usize, usize)> {
        vec![
            ("wq".into(), self.d_model, self.d_model),
            ("wk".into(), self.d_model, self.d_model),
            ("wv".into(), self.d_model, self.d_model),
            ("wo".into(), self.d_model, self.d_model),
            ("w1".into(), self.d_ff, self.d_model),
            ("w2".into(), self.d_model, self.d_ff),
        ]
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("vocab", Json::Num(self.vocab as f64)),
            ("d_model", Json::Num(self.d_model as f64)),
            ("n_layers", Json::Num(self.n_layers as f64)),
            ("n_heads", Json::Num(self.n_heads as f64)),
            ("d_ff", Json::Num(self.d_ff as f64)),
            ("seq_len", Json::Num(self.seq_len as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: j.get("name")?.as_str()?.to_string(),
            vocab: j.get("vocab")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
        })
    }
}

/// Full run configuration for the launcher.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: ModelConfig,
    pub seed: u64,
    /// artifacts directory (HLO + manifest)
    pub artifacts_dir: String,
    /// checkpoint directory
    pub ckpt_dir: String,
    // training
    pub train_steps: usize,
    pub batch_size: usize,
    pub lr: f64,
    // data
    pub train_seqs: usize,
    pub calib_seqs: usize,
    pub eval_seqs: usize,
    // pruning
    pub block_size: usize,
    pub alpha: f64,
    /// Pruning backend (`--backend=aot|rust`); the journaled crash-safe
    /// path requires `rust`.
    pub backend: String,
    /// Chrome-trace output path (`--trace=out.json`); `None` falls back
    /// to the `THANOS_TRACE` environment variable.
    pub trace: Option<String>,
    // robustness (DESIGN.md §Robustness)
    /// Prune-journal path (`--journal=path`); defaults to
    /// `{ckpt_dir}/{model}-prune.journal` when `--resume` is set.
    pub journal: Option<String>,
    /// Resume an interrupted prune run from its journal (`--resume=1`).
    pub resume: bool,
    /// Deterministic fault-injection schedule (`--faults=site:n=action;…`);
    /// `None` falls back to the `THANOS_FAULTS` environment variable.
    pub faults: Option<String>,
    /// Byte budget for in-flight calibration activations during pruning
    /// (`--mem_budget=256M`; accepts bare bytes or a K/M/G suffix).
    /// `None` keeps the all-in-RAM behavior (DESIGN.md §Streaming).
    pub mem_budget: Option<u64>,
    // serving (DESIGN.md §Serving)
    /// `thanos serve` listen address (`--serve_addr=host:port`; port 0
    /// binds an ephemeral port).
    pub serve_addr: String,
    /// Admission-queue capacity before requests are shed.
    pub serve_queue: usize,
    /// Maximum requests per batch flush.
    pub serve_batch: usize,
    /// Batching window: flush once the oldest queued request has
    /// waited this long (ms).
    pub serve_window_ms: u64,
    /// Default per-request deadline (ms) for requests that send 0.
    pub serve_deadline_ms: u32,
    /// Hot-reload watch directory (`--serve_watch=dir`); `None`
    /// disables hot reload.
    pub serve_watch: Option<String>,
    /// Hot-reload poll interval (ms).
    pub serve_poll_ms: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: ModelConfig::preset("small").unwrap(),
            seed: 1234,
            artifacts_dir: "artifacts".into(),
            ckpt_dir: "checkpoints".into(),
            train_steps: 400,
            batch_size: 8,
            lr: 1e-3,
            train_seqs: 2048,
            calib_seqs: 128,
            eval_seqs: 64,
            block_size: 128,
            alpha: 0.1,
            backend: "aot".into(),
            trace: None,
            journal: None,
            resume: false,
            faults: None,
            mem_budget: None,
            serve_addr: "127.0.0.1:7077".into(),
            serve_queue: 256,
            serve_batch: 16,
            serve_window_ms: 5,
            serve_deadline_ms: 1_000,
            serve_watch: None,
            serve_poll_ms: 100,
        }
    }
}

/// Parse a byte count with an optional K/M/G (binary, case-insensitive)
/// suffix: `"1536"`, `"64K"`, `"256M"`, `"2G"`.
pub fn parse_bytes(s: &str) -> Result<u64> {
    let t = s.trim();
    let (digits, shift) = match t.as_bytes().last() {
        Some(b'k' | b'K') => (&t[..t.len() - 1], 10),
        Some(b'm' | b'M') => (&t[..t.len() - 1], 20),
        Some(b'g' | b'G') => (&t[..t.len() - 1], 30),
        _ => (t, 0),
    };
    let n: u64 = digits
        .trim()
        .parse()
        .with_context(|| format!("byte count '{s}' (expected e.g. 1536, 64K, 256M, 2G)"))?;
    n.checked_shl(shift)
        .filter(|v| v >> shift == n)
        .with_context(|| format!("byte count '{s}' overflows u64"))
}

impl RunConfig {
    /// Apply `--key=value` style overrides.
    pub fn apply_override(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "model" => self.model = ModelConfig::preset(value)?,
            "seed" => self.seed = value.parse().context("seed")?,
            "artifacts_dir" => self.artifacts_dir = value.into(),
            "ckpt_dir" => self.ckpt_dir = value.into(),
            "train_steps" => self.train_steps = value.parse().context("train_steps")?,
            "batch_size" => self.batch_size = value.parse().context("batch_size")?,
            "lr" => self.lr = value.parse().context("lr")?,
            "train_seqs" => self.train_seqs = value.parse().context("train_seqs")?,
            "calib_seqs" => self.calib_seqs = value.parse().context("calib_seqs")?,
            "eval_seqs" => self.eval_seqs = value.parse().context("eval_seqs")?,
            "block_size" => self.block_size = value.parse().context("block_size")?,
            "alpha" => self.alpha = value.parse().context("alpha")?,
            "backend" => match value {
                "aot" | "rust" => self.backend = value.into(),
                other => bail!("unknown backend '{other}' (aot|rust)"),
            },
            "trace" => self.trace = Some(value.into()),
            "journal" => self.journal = Some(value.into()),
            "resume" => {
                self.resume = match value {
                    "1" | "true" => true,
                    "0" | "false" => false,
                    other => bail!("resume takes 1|0|true|false, got '{other}'"),
                }
            }
            "faults" => self.faults = Some(value.into()),
            "mem_budget" => self.mem_budget = Some(parse_bytes(value).context("mem_budget")?),
            "serve_addr" => self.serve_addr = value.into(),
            "serve_queue" => self.serve_queue = value.parse().context("serve_queue")?,
            "serve_batch" => self.serve_batch = value.parse().context("serve_batch")?,
            "serve_window_ms" => {
                self.serve_window_ms = value.parse().context("serve_window_ms")?
            }
            "serve_deadline_ms" => {
                self.serve_deadline_ms = value.parse().context("serve_deadline_ms")?
            }
            "serve_watch" => self.serve_watch = Some(value.into()),
            "serve_poll_ms" => self.serve_poll_ms = value.parse().context("serve_poll_ms")?,
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Parse `args` of the form `--key=value` / `--key value`, applying
    /// overrides in order. Returns positional (non-flag) arguments.
    pub fn parse_args<I: Iterator<Item = String>>(&mut self, args: I) -> Result<Vec<String>> {
        let mut positional = Vec::new();
        let mut it = args.peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    self.apply_override(k, v)?;
                } else {
                    let v = it
                        .next()
                        .with_context(|| format!("flag --{stripped} needs a value"))?;
                    self.apply_override(stripped, &v)?;
                }
            } else {
                positional.push(a);
            }
        }
        Ok(positional)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for name in ["tiny", "small", "med"] {
            let m = ModelConfig::preset(name).unwrap();
            assert_eq!(m.name, name);
            assert_eq!(m.d_model % m.n_heads, 0);
        }
        assert!(ModelConfig::preset("huge").is_err());
    }

    #[test]
    fn param_counts_in_expected_band() {
        assert!(ModelConfig::preset("tiny").unwrap().n_params() < 2_000_000);
        let small = ModelConfig::preset("small").unwrap().n_params();
        assert!((3_000_000..8_000_000).contains(&small), "{small}");
        assert!(ModelConfig::preset("med").unwrap().n_params() > 10_000_000);
    }

    #[test]
    fn model_json_roundtrip() {
        let m = ModelConfig::preset("small").unwrap();
        let j = m.to_json();
        let back = ModelConfig::from_json(&j).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn overrides_and_positional() {
        let mut rc = RunConfig::default();
        let rest = rc
            .parse_args(
                [
                    "prune",
                    "--model=tiny",
                    "--train_steps",
                    "7",
                    "--alpha=0.2",
                    "--trace=t.json",
                    "--backend=rust",
                    "--resume=1",
                    "--journal=j.jnl",
                    "--faults=atomic.sync:1=err",
                    "--mem_budget=256M",
                    "--serve_addr=127.0.0.1:0",
                    "--serve_queue=8",
                    "--serve_batch=4",
                    "--serve_window_ms=2",
                    "--serve_deadline_ms=250",
                    "--serve_watch=wdir",
                    "--serve_poll_ms=20",
                ]
                .iter()
                .map(|s| s.to_string()),
            )
            .unwrap();
        assert_eq!(rest, vec!["prune"]);
        assert_eq!(rc.model.name, "tiny");
        assert_eq!(rc.train_steps, 7);
        assert_eq!(rc.alpha, 0.2);
        assert_eq!(rc.trace.as_deref(), Some("t.json"));
        assert_eq!(rc.backend, "rust");
        assert!(rc.resume);
        assert_eq!(rc.journal.as_deref(), Some("j.jnl"));
        assert_eq!(rc.faults.as_deref(), Some("atomic.sync:1=err"));
        assert_eq!(rc.mem_budget, Some(256 << 20));
        assert_eq!(rc.serve_addr, "127.0.0.1:0");
        assert_eq!(rc.serve_queue, 8);
        assert_eq!(rc.serve_batch, 4);
        assert_eq!(rc.serve_window_ms, 2);
        assert_eq!(rc.serve_deadline_ms, 250);
        assert_eq!(rc.serve_watch.as_deref(), Some("wdir"));
        assert_eq!(rc.serve_poll_ms, 20);
        assert!(rc.parse_args(["--backend=cuda".to_string()].into_iter()).is_err());
        assert!(rc.parse_args(["--serve_queue=lots".to_string()].into_iter()).is_err());
        assert!(rc.parse_args(["--resume=maybe".to_string()].into_iter()).is_err());
        assert!(rc.parse_args(["--mem_budget=big".to_string()].into_iter()).is_err());
        assert!(rc
            .parse_args(["--bogus=1".to_string()].into_iter())
            .is_err());
    }

    #[test]
    fn byte_suffixes_parse() {
        assert_eq!(parse_bytes("1536").unwrap(), 1536);
        assert_eq!(parse_bytes("64K").unwrap(), 64 << 10);
        assert_eq!(parse_bytes("64k").unwrap(), 64 << 10);
        assert_eq!(parse_bytes("256M").unwrap(), 256 << 20);
        assert_eq!(parse_bytes("2G").unwrap(), 2 << 30);
        assert_eq!(parse_bytes(" 8 M ").unwrap(), 8 << 20);
        assert!(parse_bytes("").is_err());
        assert!(parse_bytes("G").is_err());
        assert!(parse_bytes("-1").is_err());
        assert!(parse_bytes("99999999999999999999G").is_err());
        // bits shifted off the top are an error, not a silent wrap
        assert!(parse_bytes(&format!("{}G", u64::MAX >> 10)).is_err());
    }

    #[test]
    fn layer_shapes_cover_block() {
        let m = ModelConfig::preset("small").unwrap();
        let shapes = m.layer_shapes();
        assert_eq!(shapes.len(), 6);
        assert_eq!(shapes[4], ("w1".into(), 1024, 256));
        assert_eq!(shapes[5], ("w2".into(), 256, 1024));
    }
}
