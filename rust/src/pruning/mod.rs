//! The paper's pruning algorithms and every baseline, pure Rust.
//!
//! | method | paper ref | module |
//! |---|---|---|
//! | Magnitude | Alg. 4 (Han et al. 2015) | [`magnitude`] |
//! | Wanda | Alg. 6 (Sun et al. 2023) | [`wanda`] |
//! | SparseGPT | Alg. 5 (Frantar & Alistarh 2023) | [`sparsegpt`] |
//! | Thanos unstructured | Alg. 1 / Alg. 9 | [`thanos`] |
//! | Thanos structured + outlier rows | Alg. 2 / Alg. 7 | [`thanos`] |
//! | Thanos semi-structured n:m | Alg. 8 | [`thanos`] |
//!
//! Every method consumes the same [`CalibStats`] (accumulated Hessian
//! `H = (2/d)·Σ XˡXˡᵀ` and calibration row norms `‖X_{j:}‖₂²`), so the
//! coordinator computes calibration statistics once per layer and fans
//! out to whichever method the run requests.
//!
//! This pure-Rust path is (a) the baseline implementations the paper
//! compares against, (b) the oracle the AOT (JAX/Pallas → HLO) path is
//! cross-validated against, and (c) the engine of the Fig. 9
//! pruning-time benchmark where per-shape AOT artifacts would explode.

pub mod magnitude;
pub mod metric;
pub mod nm;
pub mod obs;
pub mod select;
pub mod sparsegpt;
pub mod thanos;
pub mod wanda;

use crate::linalg::chol::damp_hessian;
use crate::linalg::gemm::xxt_f64;
use crate::linalg::{row_norms_sq, Mat, MatF64};

/// Default Hessian damping (fraction of mean diagonal), the standard
/// `percdamp` of the SparseGPT reference implementation.
pub const PERCDAMP: f64 = 0.01;

/// Calibration statistics for one linear layer with input dim `b`:
/// everything any method needs, accumulated over calibration batches.
#[derive(Clone, Debug)]
pub struct CalibStats {
    /// running sum of `2·XXᵀ` over calibration chunks (undamped)
    pub h_sum: MatF64,
    /// running sum of squared row norms of X (`‖X_{j:}‖₂²` over the
    /// whole calibration set — the Wanda/OBD metric term)
    pub xnorm_sq: Vec<f64>,
    /// number of accumulated chunks (columns of X seen, for averaging)
    pub n_cols: usize,
}

impl CalibStats {
    pub fn new(b: usize) -> Self {
        CalibStats { h_sum: MatF64::zeros(b, b), xnorm_sq: vec![0.0; b], n_cols: 0 }
    }

    /// Accumulate one calibration chunk `X ∈ ℝ^{b×a}`.
    pub fn accumulate(&mut self, x: &Mat) {
        assert_eq!(x.rows, self.h_sum.rows, "input dim mismatch");
        let g = xxt_f64(x);
        for (acc, v) in self.h_sum.data.iter_mut().zip(&g.data) {
            *acc += 2.0 * v;
        }
        for (acc, v) in self.xnorm_sq.iter_mut().zip(row_norms_sq(x)) {
            *acc += v;
        }
        self.n_cols += x.cols;
    }

    /// Accumulate one captured activation chunk in the coordinator's
    /// wire layout: `xt` is row-major `[a, b]` (tokens × features), the
    /// transpose of the `X ∈ ℝ^{b×a}` calibration matrix. Exactly the
    /// transpose-then-[`Self::accumulate`] sequence — the single shared
    /// idiom of the in-RAM and streamed capture paths, so both
    /// accumulate bitwise-identically chunk-by-chunk.
    pub fn accumulate_chunk_xt(&mut self, xt: &[f32], a: usize) -> anyhow::Result<()> {
        let b = self.b();
        anyhow::ensure!(
            xt.len() == a * b,
            "activation chunk holds {} values, expected {a}×{b}",
            xt.len()
        );
        let xmat = Mat::from_vec(a, b, xt.to_vec()).transpose();
        self.accumulate(&xmat);
        Ok(())
    }

    /// Convenience constructor from a single calibration matrix.
    pub fn from_x(x: &Mat) -> Self {
        let mut s = CalibStats::new(x.rows);
        s.accumulate(x);
        s
    }

    pub fn b(&self) -> usize {
        self.h_sum.rows
    }

    /// Damped Hessian (average over accumulated columns, then
    /// `λ = percdamp·mean(diag)` added). Methods clone from here.
    pub fn hessian(&self, percdamp: f64) -> MatF64 {
        let mut h = self.h_sum.clone();
        if self.n_cols > 0 {
            let inv = 1.0 / self.n_cols as f64;
            for v in h.data.iter_mut() {
                *v *= inv;
            }
        }
        damp_hessian(&mut h, percdamp);
        h
    }

    /// `‖X_{j:}‖₂` (not squared) — the metric term as the paper writes it.
    pub fn xnorm(&self, j: usize) -> f64 {
        self.xnorm_sq[j].sqrt()
    }
}

/// Sparsity-pattern request shared by all methods.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Pattern {
    /// remove ⌊p·c·b⌋ weights anywhere
    Unstructured { p: f64 },
    /// remove whole columns for total sparsity `p`, keeping the `alpha`
    /// fraction of highest-loss rows untouched (paper §4.7.1)
    Structured { p: f64, alpha: f64 },
    /// n of every m consecutive weights per row are zero; `alpha`
    /// outlier rows are skipped (sparsity drops accordingly — §5.1)
    SemiStructured { n: usize, m: usize, alpha: f64 },
}

impl Pattern {
    pub fn label(&self) -> String {
        match self {
            Pattern::Unstructured { p } => format!("unstructured {:.0}%", p * 100.0),
            Pattern::Structured { p, alpha } => {
                format!("structured {:.0}% (α={alpha})", p * 100.0)
            }
            Pattern::SemiStructured { n, m, alpha } => format!("{n}:{m} (α={alpha})"),
        }
    }
}

/// Which pruning algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Magnitude,
    Wanda,
    SparseGpt,
    Thanos,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Magnitude => "Magnitude",
            Method::Wanda => "Wanda",
            Method::SparseGpt => "SparseGPT",
            Method::Thanos => "Thanos",
        }
    }

    pub const ALL: [Method; 4] =
        [Method::Magnitude, Method::Wanda, Method::SparseGpt, Method::Thanos];
}

/// Hyper-parameters that only some methods read.
#[derive(Clone, Copy, Debug)]
pub struct PruneOpts {
    /// Thanos block size B (Alg. 1); also SparseGPT's mask block Bs
    pub block_size: usize,
    /// Hessian damping
    pub percdamp: f64,
    /// Recompute + invert the residual Hessian per block exactly as
    /// Alg. 1 line 17 prescribes — the paper's O(b⁴/B) complexity
    /// (Table 1). Off by default: the suffix-factor identity
    /// `(H[j:, j:])⁻¹ = U[j:, j:]ᵀ U[j:, j:]` (with `H⁻¹ = UᵀU`)
    /// yields bit-equal math from ONE O(b³) factorization per layer
    /// (see EXPERIMENTS.md §Perf-L3; equality pinned by tests).
    pub paper_faithful_inverse: bool,
    /// Apply each block's joint updates as Λ-panel algebra — the §H.1
    /// padded batched row solves plus ONE mixed-precision packed GEMM
    /// per engine band (DESIGN.md §Perf-L4) — instead of the per-row
    /// scalar solve + axpy chains. On by default; the per-row path is
    /// the cross-check reference (`benches/prune_e2e.rs`) and is also
    /// forced process-wide by `THANOS_LINALG_NAIVE=1`, which overrides
    /// this flag.
    pub panel_apply: bool,
}

impl Default for PruneOpts {
    fn default() -> Self {
        PruneOpts {
            block_size: 128,
            percdamp: PERCDAMP,
            paper_faithful_inverse: false,
            panel_apply: true,
        }
    }
}

/// Result of pruning one layer.
#[derive(Clone, Debug)]
pub struct Pruned {
    pub w: Mat,
    /// per-entry removal mask (true = weight was removed)
    pub mask: Vec<bool>,
}

impl Pruned {
    pub fn from_w(w: Mat, original: &Mat) -> Pruned {
        let mask = w
            .data
            .iter()
            .zip(&original.data)
            .map(|(&new, &old)| new == 0.0 && old != 0.0)
            .collect();
        Pruned { w, mask }
    }

    pub fn sparsity(&self) -> f64 {
        self.w.sparsity()
    }
}

/// Prune several **independent** layers concurrently through the
/// shared [`crate::engine`] pool — the BESA-style observation that the
/// block-wise objective decouples the layers of one transformer block,
/// so layer-level parallelism is free accuracy-wise. Each layer task
/// runs the ordinary [`prune`] dispatch (whose inner kernels submit
/// row-parallel work to the *same* pool, so the two levels share one
/// thread budget instead of oversubscribing).
///
/// Returns one `(Pruned, secs)` result per input layer, in input order;
/// `secs` is that layer's own wall time (layers overlap, so the sum can
/// exceed the batch wall time). Results are bit-identical to calling
/// [`prune`] sequentially — pinned by the determinism tests.
///
/// A panicking layer does **not** abort the batch: the panic is caught
/// inside the layer's own task and surfaces as that slot's `Err`, so
/// the surviving layers' results are still returned (the coordinator
/// applies them before failing the run with every error). Each layer
/// also probes the fault site `prune.layer.<i>` — keyed by slot index,
/// not by a shared hit counter, so which layer faults under a
/// `THANOS_FAULTS` schedule never depends on thread scheduling.
pub fn prune_many(
    layers: &[(&Mat, &CalibStats)],
    method: Method,
    pattern: Pattern,
    opts: &PruneOpts,
) -> Vec<anyhow::Result<(Pruned, f64)>> {
    let mut slots: Vec<Option<anyhow::Result<(Pruned, f64)>>> = Vec::with_capacity(layers.len());
    slots.resize_with(layers.len(), || None);
    for i in 0..layers.len() {
        crate::robust::faults::register_site(&format!("prune.layer.{i}"));
    }
    crate::engine::global().for_each_band(&mut slots, 1, |i, slot| {
        let _layer_span = crate::trace::span("prune.layer");
        let (w, stats) = layers[i];
        let t0 = crate::trace::clock::now_nanos();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            crate::robust::faults::point(&format!("prune.layer.{i}"))?;
            prune(method, w, stats, pattern, opts)
        }));
        slot[0] = Some(match res {
            Ok(r) => r.map(|p| (p, crate::trace::clock::secs_since(t0))),
            Err(payload) => Err(anyhow::anyhow!(
                "layer task {i} panicked: {}",
                panic_message(&payload)
            )),
        });
    });
    slots
        .into_iter()
        .map(|s| s.expect("prune_many: every layer slot is filled"))
        .collect()
}

/// Best-effort text of a caught panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

/// Dispatch: prune `w` with `method` under `pattern`.
pub fn prune(
    method: Method,
    w: &Mat,
    stats: &CalibStats,
    pattern: Pattern,
    opts: &PruneOpts,
) -> anyhow::Result<Pruned> {
    match (method, pattern) {
        (Method::Magnitude, Pattern::Unstructured { p }) => Ok(magnitude::unstructured(w, p)),
        (Method::Magnitude, Pattern::SemiStructured { n, m, .. }) => {
            Ok(magnitude::semi_structured(w, n, m))
        }
        (Method::Magnitude, Pattern::Structured { p, .. }) => Ok(magnitude::structured(w, p)),
        (Method::Wanda, Pattern::Unstructured { p }) => Ok(wanda::unstructured(w, stats, p)),
        (Method::Wanda, Pattern::SemiStructured { n, m, .. }) => {
            Ok(wanda::semi_structured(w, stats, n, m))
        }
        (Method::Wanda, Pattern::Structured { p, .. }) => Ok(wanda::structured(w, stats, p)),
        (Method::SparseGpt, Pattern::Unstructured { p }) => {
            sparsegpt::unstructured(w, stats, p, opts)
        }
        (Method::SparseGpt, Pattern::SemiStructured { n, m, .. }) => {
            sparsegpt::semi_structured(w, stats, n, m, opts)
        }
        (Method::SparseGpt, Pattern::Structured { p, .. }) => {
            sparsegpt::structured(w, stats, p, opts)
        }
        (Method::Thanos, Pattern::Unstructured { p }) => thanos::unstructured(w, stats, p, opts),
        (Method::Thanos, Pattern::SemiStructured { n, m, alpha }) => {
            thanos::semi_structured(w, stats, n, m, alpha, opts)
        }
        (Method::Thanos, Pattern::Structured { p, alpha }) => {
            thanos::structured(w, stats, p, alpha, opts)
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::rng::Rng;

    /// A correlated calibration matrix: mixes a few latent factors so
    /// H is anisotropic (the regime where update-based methods win).
    pub fn correlated_x(b: usize, a: usize, seed: u64) -> Mat {
        let mut r = Rng::new(seed);
        let k = (b / 4).max(2);
        let factors = Mat::from_fn(k, a, |_, _| r.normal_f32(0.0, 1.0));
        let loading = Mat::from_fn(b, k, |_, _| r.normal_f32(0.0, 1.0));
        let mut x = crate::linalg::gemm::matmul(&loading, &factors);
        for v in x.data.iter_mut() {
            *v += r.normal_f32(0.0, 0.3);
        }
        x
    }

    pub fn random_w(c: usize, b: usize, seed: u64) -> Mat {
        let mut r = Rng::new(seed);
        Mat::from_fn(c, b, |_, _| {
            // avoid exact zeros so sparsity accounting is unambiguous
            let v = r.normal_f32(0.0, 1.0);
            if v == 0.0 {
                1e-3
            } else {
                v
            }
        })
    }

    pub fn setup(c: usize, b: usize, a: usize, seed: u64) -> (Mat, CalibStats, Mat) {
        let w = random_w(c, b, seed);
        let x = correlated_x(b, a, seed ^ 0xDEAD);
        let stats = CalibStats::from_x(&x);
        (w, stats, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calib_stats_accumulation_matches_concat() {
        use crate::linalg::Mat;
        use crate::rng::Rng;
        let mut r = Rng::new(3);
        let x1 = Mat::from_fn(6, 9, |_, _| r.normal_f32(0.0, 1.0));
        let x2 = Mat::from_fn(6, 5, |_, _| r.normal_f32(0.0, 1.0));
        // concatenated
        let mut xc = Mat::zeros(6, 14);
        for i in 0..6 {
            xc.row_mut(i)[..9].copy_from_slice(x1.row(i));
            xc.row_mut(i)[9..].copy_from_slice(x2.row(i));
        }
        let mut s_inc = CalibStats::new(6);
        s_inc.accumulate(&x1);
        s_inc.accumulate(&x2);
        let s_all = CalibStats::from_x(&xc);
        assert!(s_inc.h_sum.max_abs_diff(&s_all.h_sum) < 1e-9);
        for j in 0..6 {
            assert!((s_inc.xnorm_sq[j] - s_all.xnorm_sq[j]).abs() < 1e-9);
        }
        assert_eq!(s_inc.n_cols, 14);
    }

    #[test]
    fn chunk_xt_accumulation_is_bitwise_the_transpose_path() {
        use crate::linalg::Mat;
        use crate::rng::Rng;
        let (b, a) = (6, 9);
        let mut r = Rng::new(11);
        // wire layout: row-major [a, b]
        let xt: Vec<f32> = (0..a * b).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let mut s_chunk = CalibStats::new(b);
        s_chunk.accumulate_chunk_xt(&xt, a).unwrap();
        let mut s_ref = CalibStats::new(b);
        s_ref.accumulate(&Mat::from_vec(a, b, xt.clone()).transpose());
        assert_eq!(
            s_chunk.h_sum.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            s_ref.h_sum.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            s_chunk.xnorm_sq.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            s_ref.xnorm_sq.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(s_chunk.n_cols, a);
        assert!(s_chunk.accumulate_chunk_xt(&xt[..a * b - 1], a).is_err());
    }

    #[test]
    fn hessian_is_damped_and_pd() {
        let (_, stats, _) = testutil::setup(4, 8, 20, 1);
        let h = stats.hessian(PERCDAMP);
        assert!(crate::linalg::chol::cholesky(&h).is_ok());
    }

    #[test]
    fn pattern_labels() {
        assert_eq!(Pattern::Unstructured { p: 0.5 }.label(), "unstructured 50%");
        assert_eq!(Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 }.label(), "2:4 (α=0)");
    }
}
