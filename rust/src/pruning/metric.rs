//! Mask-selection primitives: the `ψ_X` mapping (eq. 11) and the `φ`
//! index extraction (eq. 12), plus the top-r selection machinery they
//! share.

use crate::linalg::Mat;
use crate::pruning::CalibStats;

/// Boolean mask over a `c×rest` metric matrix: true at the positions of
/// the `r` smallest metric values (the `ψ` of eq. 11, applied to an
/// arbitrary score matrix). Ties are broken by index for determinism.
pub fn smallest_r_mask(metric: &[f64], r: usize) -> Vec<bool> {
    let mut mask = Vec::new();
    smallest_r_mask_into(metric, r, &mut mask);
    mask
}

/// [`smallest_r_mask`] writing into a reused buffer (cleared and
/// resized in place) — the hot-loop variant the block-wise walks use so
/// the `c×rest` mask is not reallocated per block.
pub fn smallest_r_mask_into(metric: &[f64], r: usize, mask: &mut Vec<bool>) {
    let mut idx = Vec::new();
    smallest_r_mask_into_with_idx(metric, r, mask, &mut idx);
}

/// [`smallest_r_mask_into`] with a caller-provided index scratch: the
/// `(0..n)` index array used to cost an `O(c·rest)` allocation per
/// block on the oracle/reference walks — threading a per-call buffer
/// through (like the mask buffer itself) removes it. Identical
/// selection arithmetic; this remains the oracle the §Perf-L5
/// threshold engine ([`crate::pruning::select`]) is pinned against.
pub fn smallest_r_mask_into_with_idx(
    metric: &[f64],
    r: usize,
    mask: &mut Vec<bool>,
    idx: &mut Vec<u32>,
) {
    let n = metric.len();
    let r = r.min(n);
    mask.clear();
    mask.resize(n, false);
    if r == 0 {
        return;
    }
    if r == n {
        mask.iter_mut().for_each(|m| *m = true);
        return;
    }
    idx.clear();
    idx.extend(0..n as u32);
    idx.select_nth_unstable_by(r - 1, |&a, &b| {
        metric[a as usize]
            .partial_cmp(&metric[b as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    for &i in &idx[..r] {
        mask[i as usize] = true;
    }
}

/// The Wanda/OBD saliency `|W_ij|·‖X_{j:}‖₂` over a column window
/// `[c0, c1)` of `w`, flattened row-major into a `c×(c1-c0)` score
/// buffer. `xnorm_sq[j]` indexes the *original* column space.
pub fn wanda_metric_window(w: &Mat, stats: &CalibStats, c0: usize, c1: usize) -> Vec<f64> {
    let mut out = Vec::new();
    wanda_metric_window_into(w, stats, c0, c1, &mut out);
    out
}

/// [`wanda_metric_window`] writing into a reused buffer — the per-call
/// scratch the Thanos block walk threads through every block instead of
/// reallocating the full `c×rest` metric each iteration.
pub fn wanda_metric_window_into(
    w: &Mat,
    stats: &CalibStats,
    c0: usize,
    c1: usize,
    out: &mut Vec<f64>,
) {
    wanda_metric_window_rows_into(w, w.rows, stats, c0, c1, out);
}

/// Same, restricted to the first `rows` rows of `w` (the n:m walk
/// scores only non-outlier rows; passing `rows` here avoids cloning a
/// row-slice of `W` per block).
///
/// Row-banded on the shared engine (§Perf-L5): every output cell is a
/// pure per-cell function of `w` and the hoisted column norms, so the
/// fill is bit-identical for any thread count — and the metric stage
/// stops being a serial fraction of the engine-parallel walk.
pub fn wanda_metric_window_rows_into(
    w: &Mat,
    rows: usize,
    stats: &CalibStats,
    c0: usize,
    c1: usize,
    out: &mut Vec<f64>,
) {
    assert!(c0 <= c1 && c1 <= w.cols);
    assert!(rows <= w.rows);
    let width = c1 - c0;
    out.clear();
    out.resize(rows * width, 0.0);
    if rows == 0 || width == 0 {
        return;
    }
    // hoist the per-column ‖X_j‖ terms out of the row loop
    let col_norm: Vec<f64> = (c0..c1).map(|j| stats.xnorm_sq[j].sqrt()).collect();
    let eng = crate::engine::global();
    let rows_per = eng.chunk(rows);
    eng.for_each_band(&mut out[..], rows_per * width, |bi, band| {
        let row0 = bi * rows_per;
        for (ri, dst) in band.chunks_mut(width).enumerate() {
            let row = w.row(row0 + ri);
            for (k, j) in (c0..c1).enumerate() {
                dst[k] = (row[j].abs() as f64) * col_norm[k];
            }
        }
    });
}

/// `ψ_X(W_window, r)` — the global-residual-mask construction of
/// Alg. 1 line 6: mask of the `r` smallest Wanda-metric entries over
/// the residual window `[c0, b)`, returned as a `c×(b-c0)` row-major
/// boolean buffer.
pub fn psi_mask(w: &Mat, stats: &CalibStats, c0: usize, r: usize) -> Vec<bool> {
    let metric = wanda_metric_window(w, stats, c0, w.cols);
    smallest_r_mask(&metric, r)
}

/// `φ(mask_row)` — indices of the set entries (eq. 12). Offsets are
/// relative to the window the mask was built over.
pub fn phi(mask_row: &[bool]) -> Vec<usize> {
    mask_row
        .iter()
        .enumerate()
        .filter_map(|(i, &m)| if m { Some(i) } else { None })
        .collect()
}

/// Per-row top-k-smallest selection within each row of a score matrix
/// (Wanda's row-sparsity constraint, Alg. 6 line 4). Returns the same
/// layout of booleans.
pub fn per_row_smallest(metric: &[f64], rows: usize, cols: usize, k: usize) -> Vec<bool> {
    assert_eq!(metric.len(), rows * cols);
    let mut mask = vec![false; rows * cols];
    for i in 0..rows {
        let row = &metric[i * cols..(i + 1) * cols];
        let rm = smallest_r_mask(row, k);
        mask[i * cols..(i + 1) * cols].copy_from_slice(&rm);
    }
    mask
}

/// Per-group n-smallest within every group of `m` consecutive entries
/// of each row — the n:m mask (Alg. 8 line 10). `cols % m == 0`.
pub fn nm_mask(metric: &[f64], rows: usize, cols: usize, n: usize, m: usize) -> Vec<bool> {
    assert_eq!(cols % m, 0, "n:m needs cols divisible by m");
    assert!(n <= m);
    let mut mask = vec![false; rows * cols];
    for i in 0..rows {
        for g in (0..cols).step_by(m) {
            let grp = &metric[i * cols + g..i * cols + g + m];
            let gm = smallest_r_mask(grp, n);
            mask[i * cols + g..i * cols + g + m].copy_from_slice(&gm);
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::testutil;

    #[test]
    fn smallest_r_mask_selects_smallest() {
        let metric = vec![5.0, 1.0, 4.0, 0.5, 3.0];
        let m = smallest_r_mask(&metric, 2);
        assert_eq!(m, vec![false, true, false, true, false]);
        assert_eq!(smallest_r_mask(&metric, 0), vec![false; 5]);
        assert_eq!(smallest_r_mask(&metric, 5), vec![true; 5]);
        // r beyond len saturates
        assert_eq!(smallest_r_mask(&metric, 9), vec![true; 5]);
    }

    #[test]
    fn smallest_r_mask_tie_break_deterministic() {
        let metric = vec![1.0; 6];
        let m = smallest_r_mask(&metric, 3);
        assert_eq!(m.iter().filter(|&&x| x).count(), 3);
        let m2 = smallest_r_mask(&metric, 3);
        assert_eq!(m, m2);
    }

    #[test]
    fn phi_matches_paper_examples() {
        // paper §4.5: φ((1,0,0,1,1)) = (1,4,5) in 1-based = (0,3,4) 0-based
        assert_eq!(phi(&[true, false, false, true, true]), vec![0, 3, 4]);
        assert_eq!(phi(&[false, false, true, true, false]), vec![2, 3]);
        assert_eq!(phi(&[false; 4]), Vec::<usize>::new());
    }

    #[test]
    fn wanda_metric_window_matches_definition() {
        let (w, stats, _) = testutil::setup(3, 6, 12, 2);
        let metric = wanda_metric_window(&w, &stats, 2, 5);
        for i in 0..3 {
            for (k, j) in (2..5).enumerate() {
                let expect = (w.at(i, j).abs() as f64) * stats.xnorm_sq[j].sqrt();
                assert!((metric[i * 3 + k] - expect).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn into_variants_match_and_reset_reused_buffers() {
        let (w, stats, _) = testutil::setup(5, 12, 24, 11);
        let full = wanda_metric_window(&w, &stats, 3, 10);
        let mut buf = vec![9.0f64; 3]; // wrong size + stale values
        wanda_metric_window_into(&w, &stats, 3, 10, &mut buf);
        assert_eq!(full, buf);
        let mut rows_buf = Vec::new();
        wanda_metric_window_rows_into(&w, 3, &stats, 3, 10, &mut rows_buf);
        assert_eq!(&full[..3 * 7], &rows_buf[..]);
        let mut mask = vec![true; 99];
        smallest_r_mask_into(&full, 10, &mut mask);
        assert_eq!(mask, smallest_r_mask(&full, 10));
    }

    #[test]
    fn psi_mask_counts() {
        let (w, stats, _) = testutil::setup(4, 8, 16, 3);
        let mask = psi_mask(&w, &stats, 0, 13);
        assert_eq!(mask.iter().filter(|&&m| m).count(), 13);
        let mask = psi_mask(&w, &stats, 3, 7);
        assert_eq!(mask.len(), 4 * 5);
        assert_eq!(mask.iter().filter(|&&m| m).count(), 7);
    }

    #[test]
    fn per_row_smallest_counts_per_row() {
        let metric: Vec<f64> = (0..12).map(|i| (i % 4) as f64).collect();
        let mask = per_row_smallest(&metric, 3, 4, 2);
        for i in 0..3 {
            let cnt = mask[i * 4..(i + 1) * 4].iter().filter(|&&m| m).count();
            assert_eq!(cnt, 2);
        }
    }

    #[test]
    fn nm_mask_exactly_n_per_group() {
        let (w, stats, _) = testutil::setup(5, 8, 16, 4);
        let metric = wanda_metric_window(&w, &stats, 0, 8);
        let mask = nm_mask(&metric, 5, 8, 2, 4);
        for i in 0..5 {
            for g in (0..8).step_by(4) {
                let cnt = mask[i * 8 + g..i * 8 + g + 4].iter().filter(|&&m| m).count();
                assert_eq!(cnt, 2, "row {i} group {g}");
            }
        }
    }
}
