//! Wanda (Sun et al., 2023) — saliency `|W_ij|·‖X_{j:}‖₂`, no weight
//! update (paper Alg. 6 + n:m and structured extensions).
//!
//! The paper shows (App. G.3) this metric is the *optimal* choice when
//! exactly one weight is removed and nothing is adjusted; Wanda applies
//! it with a per-row sparsity constraint in a single shot.

use crate::linalg::Mat;
use crate::pruning::metric::{nm_mask, per_row_smallest, smallest_r_mask, wanda_metric_window};
use crate::pruning::{CalibStats, Pruned};

/// Unstructured Wanda: each row loses its ⌊p·b⌋ smallest-metric weights.
pub fn unstructured(w: &Mat, stats: &CalibStats, p: f64) -> Pruned {
    assert!((0.0..1.0).contains(&p));
    let metric = wanda_metric_window(w, stats, 0, w.cols);
    let k = (p * w.cols as f64).floor() as usize;
    let mask = per_row_smallest(&metric, w.rows, w.cols, k);
    apply(w, &mask)
}

/// n:m Wanda: n smallest-metric weights per group of m.
pub fn semi_structured(w: &Mat, stats: &CalibStats, n: usize, m: usize) -> Pruned {
    let metric = wanda_metric_window(w, stats, 0, w.cols);
    let mask = nm_mask(&metric, w.rows, w.cols, n, m);
    apply(w, &mask)
}

/// Structured Wanda: remove the ⌈p·b⌉ columns with the smallest total
/// saliency `‖W_{:j}‖₂²·‖X_{j:}‖₂²` (the paper's column loss eq. 15
/// with α = 0), no weight update.
pub fn structured(w: &Mat, stats: &CalibStats, p: f64) -> Pruned {
    assert!((0.0..1.0).contains(&p));
    let s = ((p * w.cols as f64).ceil() as usize).min(w.cols);
    let col_loss: Vec<f64> = (0..w.cols)
        .map(|j| {
            let wnorm: f64 = (0..w.rows).map(|i| (w.at(i, j) as f64).powi(2)).sum();
            wnorm * stats.xnorm_sq[j]
        })
        .collect();
    let col_mask = smallest_r_mask(&col_loss, s);
    let mut mask = vec![false; w.rows * w.cols];
    for i in 0..w.rows {
        for j in 0..w.cols {
            mask[i * w.cols + j] = col_mask[j];
        }
    }
    apply(w, &mask)
}

fn apply(w: &Mat, mask: &[bool]) -> Pruned {
    let mut out = w.clone();
    for (v, &m) in out.data.iter_mut().zip(mask) {
        if m {
            *v = 0.0;
        }
    }
    Pruned { w: out, mask: mask.to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::recon_loss;
    use crate::pruning::testutil::setup;

    #[test]
    fn per_row_sparsity_exact() {
        let (w, stats, _) = setup(12, 16, 32, 5);
        let pruned = unstructured(&w, &stats, 0.5);
        for i in 0..12 {
            let zeros = pruned.w.row(i).iter().filter(|&&v| v == 0.0).count();
            assert_eq!(zeros, 8, "row {i}");
        }
    }

    #[test]
    fn beats_magnitude_on_anisotropic_input() {
        // With correlated calibration data the activation-aware metric
        // must produce lower reconstruction loss than magnitude — the
        // core claim of the Wanda paper replicated as a test.
        let mut wins = 0;
        for seed in 0..5 {
            let (w, stats, x) = setup(24, 32, 64, 100 + seed);
            let wa = unstructured(&w, &stats, 0.5);
            let mg = crate::pruning::magnitude::unstructured(&w, 0.5);
            let lw = recon_loss(&wa.w, &w, &x);
            let lm = recon_loss(&mg.w, &w, &x);
            if lw < lm {
                wins += 1;
            }
        }
        assert!(wins >= 4, "wanda won only {wins}/5");
    }

    #[test]
    fn metric_prefers_low_activation_columns() {
        // if one input channel is always (near) zero, its weights prune first
        let (w, _, mut x) = setup(6, 8, 20, 6);
        for j in 0..20 {
            *x.at_mut(3, j) = 1e-6;
        }
        let stats = CalibStats::from_x(&x);
        let pruned = unstructured(&w, &stats, 0.2);
        for i in 0..6 {
            assert_eq!(pruned.w.at(i, 3), 0.0, "dead channel should prune, row {i}");
        }
    }

    #[test]
    fn nm_format_valid() {
        let (w, stats, _) = setup(6, 16, 24, 7);
        let pruned = semi_structured(&w, &stats, 4, 8);
        for i in 0..6 {
            for g in (0..16).step_by(8) {
                let zeros = pruned.w.row(i)[g..g + 8].iter().filter(|&&v| v == 0.0).count();
                assert_eq!(zeros, 4);
            }
        }
    }

    #[test]
    fn structured_columns_and_count() {
        let (w, stats, _) = setup(10, 12, 30, 8);
        let pruned = structured(&w, &stats, 0.25);
        let removed: Vec<usize> = (0..12)
            .filter(|&j| (0..10).all(|i| pruned.w.at(i, j) == 0.0))
            .collect();
        assert_eq!(removed.len(), 3); // ceil(0.25*12)
    }

    #[test]
    fn no_update_outside_mask() {
        let (w, stats, _) = setup(5, 10, 20, 9);
        let pruned = unstructured(&w, &stats, 0.4);
        for (k, (&nv, &ov)) in pruned.w.data.iter().zip(&w.data).enumerate() {
            if !pruned.mask[k] {
                assert_eq!(nv, ov, "Wanda must not modify kept weights");
            }
        }
    }
}
