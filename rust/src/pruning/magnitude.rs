//! Magnitude pruning (Han et al., 2015) — the data-free baseline
//! (paper Alg. 4 + structured/semi-structured extensions used in
//! Tables 2–3).

use crate::linalg::Mat;
use crate::pruning::metric::{nm_mask, smallest_r_mask};
use crate::pruning::Pruned;

fn abs_metric(w: &Mat) -> Vec<f64> {
    w.data.iter().map(|&v| v.abs() as f64).collect()
}

/// Remove the ⌊p·c·b⌋ smallest-|w| weights anywhere in the layer.
pub fn unstructured(w: &Mat, p: f64) -> Pruned {
    assert!((0.0..1.0).contains(&p));
    let r = (p * (w.rows * w.cols) as f64).floor() as usize;
    let mask = smallest_r_mask(&abs_metric(w), r);
    apply(w, &mask)
}

/// n:m magnitude: n smallest-|w| per group of m consecutive weights.
pub fn semi_structured(w: &Mat, n: usize, m: usize) -> Pruned {
    let mask = nm_mask(&abs_metric(w), w.rows, w.cols, n, m);
    apply(w, &mask)
}

/// Structured magnitude: remove the ⌈p·b⌉ columns with the smallest
/// ℓ² norm (data-free column saliency).
pub fn structured(w: &Mat, p: f64) -> Pruned {
    assert!((0.0..1.0).contains(&p));
    let s = ((p * w.cols as f64).ceil() as usize).min(w.cols);
    let col_norms: Vec<f64> = (0..w.cols)
        .map(|j| (0..w.rows).map(|i| (w.at(i, j) as f64).powi(2)).sum())
        .collect();
    let col_mask = smallest_r_mask(&col_norms, s);
    let mut mask = vec![false; w.rows * w.cols];
    for i in 0..w.rows {
        for j in 0..w.cols {
            mask[i * w.cols + j] = col_mask[j];
        }
    }
    apply(w, &mask)
}

fn apply(w: &Mat, mask: &[bool]) -> Pruned {
    let mut out = w.clone();
    for (v, &m) in out.data.iter_mut().zip(mask) {
        if m {
            *v = 0.0;
        }
    }
    Pruned { w: out, mask: mask.to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::testutil::random_w;

    #[test]
    fn unstructured_hits_exact_sparsity() {
        let w = random_w(16, 24, 1);
        for &p in &[0.1, 0.25, 0.5, 0.75] {
            let pruned = unstructured(&w, p);
            let want = (p * (16.0 * 24.0)).floor() as usize;
            let zeros = pruned.w.data.iter().filter(|&&v| v == 0.0).count();
            assert_eq!(zeros, want, "p={p}");
        }
    }

    #[test]
    fn unstructured_removes_smallest() {
        let w = Mat::from_vec(1, 4, vec![0.1, -5.0, 0.2, 3.0]);
        let pruned = unstructured(&w, 0.5);
        assert_eq!(pruned.w.data, vec![0.0, -5.0, 0.0, 3.0]);
    }

    #[test]
    fn semi_structured_format_valid() {
        let w = random_w(8, 16, 2);
        let pruned = semi_structured(&w, 2, 4);
        for i in 0..8 {
            for g in (0..16).step_by(4) {
                let zeros = pruned.w.row(i)[g..g + 4].iter().filter(|&&v| v == 0.0).count();
                assert_eq!(zeros, 2);
            }
        }
    }

    #[test]
    fn structured_removes_whole_columns() {
        let w = random_w(6, 10, 3);
        let pruned = structured(&w, 0.3);
        let mut removed_cols = 0;
        for j in 0..10 {
            let all_zero = (0..6).all(|i| pruned.w.at(i, j) == 0.0);
            let none_zero = (0..6).all(|i| pruned.w.at(i, j) != 0.0);
            assert!(all_zero || none_zero, "column {j} partially pruned");
            if all_zero {
                removed_cols += 1;
            }
        }
        assert_eq!(removed_cols, 3); // ceil(0.3*10)
    }

    #[test]
    fn mask_matches_zeros() {
        let w = random_w(4, 6, 4);
        let pruned = unstructured(&w, 0.5);
        for (k, &m) in pruned.mask.iter().enumerate() {
            assert_eq!(m, pruned.w.data[k] == 0.0);
        }
    }
}
