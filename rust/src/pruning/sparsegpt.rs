//! SparseGPT (Frantar & Alistarh, 2023) — column-sequential OBS pruning
//! (paper Alg. 5, App. F.3), the strongest prior method Thanos is
//! benchmarked against.
//!
//! Implementation follows the reference trick: take the upper Cholesky
//! factor `U` of `H⁻¹` (`H⁻¹ = UᵀU`). After eliminating columns
//! `< j`, the downdated inverse restricted to the remaining columns is
//! `U[j:, j:]ᵀ·U[j:, j:]`, so row `j` of `U` directly provides both the
//! OBS metric denominator (`U_jj²  = [H⁻¹_cur]_jj`) and the update
//! direction (`U[j, j:]/U_jj = H⁻¹_cur[j, j:]/[H⁻¹_cur]_jj`) — no
//! per-column Hessian downdates needed, which is what makes the method
//! O(b³) instead of O(b⁴).

use crate::linalg::batched::{forward_subst_upper_gather, with_panel_scratch};
use crate::linalg::chol::inverse_factor_upper;
use crate::linalg::kernel::{self, kf64, kmix, View};
use crate::linalg::{Mat, MatF64};
use crate::pruning::metric::{smallest_r_mask, smallest_r_mask_into_with_idx};
use crate::pruning::select::{smallest_r_mask_threshold_into, SelectScratch};
use crate::pruning::{CalibStats, PruneOpts, Pruned};
use anyhow::Result;

thread_local! {
    /// Per-worker forward-substitution buffers for the panel path
    /// (`q` / `rhs` / `e`), reused across bands, blocks and layers —
    /// the same no-hot-path-allocations convention as the solve
    /// scratches in `linalg::batched`.
    static FS_SCRATCH: std::cell::RefCell<(Vec<usize>, Vec<f64>, Vec<f64>)> =
        const { std::cell::RefCell::new((Vec::new(), Vec::new(), Vec::new())) };
}

/// Upper Cholesky factor `U` (row-major) with `H⁻¹ = UᵀU`, via the
/// reversal-trick factorization (no full inverse is ever formed —
/// §Perf-L3).
fn inverse_cholesky_upper(stats: &CalibStats, percdamp: f64) -> Result<MatF64> {
    let h = stats.hessian(percdamp);
    inverse_factor_upper(&h)
}

/// Unstructured SparseGPT at sparsity `p`, adaptive mask per column
/// block of `opts.block_size` (the `Bs` of Alg. 5).
pub fn unstructured(w: &Mat, stats: &CalibStats, p: f64, opts: &PruneOpts) -> Result<Pruned> {
    assert!((0.0..1.0).contains(&p));
    let u = inverse_cholesky_upper(stats, opts.percdamp)?;
    let (c, b) = (w.rows, w.cols);
    let bs = opts.block_size.clamp(1, b);
    let mut wk = w.clone();
    let mut mask = vec![false; c * b];
    // per-call selection scratch (§Perf-L5): the panel walk routes the
    // block mask through the engine-parallel threshold select (bitwise
    // identical to the oracle); reference walks keep the select_nth
    // oracle with the shared index scratch. Metric/mask buffers are
    // reused across blocks like the Thanos walk's.
    let mut sel = SelectScratch::new();
    let mut metric: Vec<f64> = Vec::new();
    let mut bm: Vec<bool> = Vec::new();
    let threshold_select = opts.panel_apply && !kernel::naive_mode();
    let mut j1 = 0;
    while j1 < b {
        let j2 = (j1 + bs).min(b);
        let width = j2 - j1;
        // block mask: r smallest of w²/U_jj² within the c×width block
        metric.clear();
        metric.resize(c * width, 0.0);
        for i in 0..c {
            let row = wk.row(i);
            for (k, j) in (j1..j2).enumerate() {
                let d = u.at(j, j);
                metric[i * width + k] = (row[j] as f64).powi(2) / (d * d);
            }
        }
        let r = (p * (c * width) as f64).floor() as usize;
        if threshold_select {
            smallest_r_mask_threshold_into(&metric, r, &mut bm, &mut sel);
        } else {
            smallest_r_mask_into_with_idx(&metric, r, &mut bm, &mut sel.idx);
        }
        for i in 0..c {
            for k in 0..width {
                mask[i * b + j1 + k] = bm[i * width + k];
            }
        }
        update_rows(&mut wk, &mask, &u, j1, j2, opts);
        j1 = j2;
    }
    Ok(Pruned { w: wk, mask })
}

/// n:m SparseGPT: the mask for each group of `m` columns is chosen when
/// the column walk reaches the group (metric uses current weights), so
/// the adaptive-mask property is preserved (`Bs = m` in Alg. 5).
///
/// Panel path (§Perf-L5): groups are tiny (`m ≤ 8`), so the per-column
/// OBS chain is replaced by a **per-group fused micro-kernel** — the
/// group's `n` errors come from one forward substitution through the
/// gathered `U[q][:, q]` ([`forward_subst_upper_gather`], the same
/// collapse the Λ-panel paths use), and the row suffix is updated in
/// ONE register-blocked pass with f64 accumulation
/// ([`fused_group_apply`]) instead of `n` separate f32 axpy sweeps.
/// Groups stay column-sequential per row (the adaptive-mask property),
/// rows stay band-parallel; per-row chains are row-local, so results
/// are bit-identical for any thread count. The seed per-column chain
/// remains the reference (`panel_apply = false` or
/// `THANOS_LINALG_NAIVE=1`).
pub fn semi_structured(
    w: &Mat,
    stats: &CalibStats,
    n: usize,
    m: usize,
    opts: &PruneOpts,
) -> Result<Pruned> {
    assert!(w.cols % m == 0, "n:m needs b divisible by m");
    assert!(n <= m);
    let u = inverse_cholesky_upper(stats, opts.percdamp)?;
    let (c, b) = (w.rows, w.cols);
    let mut wk = w.clone();
    let mut mask = vec![false; c * b];
    // per-row independent: row bands on the shared engine pool
    let u_ref = &u;
    let panel = opts.panel_apply && !kernel::naive_mode();
    let eng = crate::engine::global();
    let rows_per = eng.chunk(c);
    let band = rows_per * b;
    eng.for_each_band2(&mut wk.data, &mut mask, band, band, |_bi, whead, mhead| {
        let rows_here = whead.len() / b;
        // group-metric scratch reused across this band's rows
        let mut metric = vec![0.0f64; m];
        let mut gm = Vec::new();
        let mut gidx: Vec<u32> = Vec::new();
        let mut q: Vec<usize> = Vec::new();
        let mut rhs: Vec<f64> = Vec::new();
        let mut e: Vec<f64> = Vec::new();
        for ri in 0..rows_here {
            let row = &mut whead[ri * b..(ri + 1) * b];
            let rmask = &mut mhead[ri * b..(ri + 1) * b];
            for g in (0..b).step_by(m) {
                // choose n smallest metric within the group
                for (k, j) in (g..g + m).enumerate() {
                    let d = u_ref.at(j, j);
                    metric[k] = (row[j] as f64).powi(2) / (d * d);
                }
                smallest_r_mask_into_with_idx(&metric, n, &mut gm, &mut gidx);
                if panel {
                    // fused micro-kernel: batch the group's solves,
                    // apply the suffix once
                    q.clear();
                    rhs.clear();
                    for (k, j) in (g..g + m).enumerate() {
                        if gm[k] {
                            rmask[j] = true;
                            q.push(j);
                            rhs.push(row[j] as f64);
                        }
                    }
                    if q.is_empty() {
                        continue;
                    }
                    forward_subst_upper_gather(u_ref, &q, &rhs, &mut e);
                    fused_group_apply(row, g, u_ref, &q, &e);
                    for &j in &q {
                        row[j] = 0.0;
                    }
                } else {
                    // reference: OBS updates column by column
                    for (k, j) in (g..g + m).enumerate() {
                        if !gm[k] {
                            continue;
                        }
                        rmask[j] = true;
                        let d = u_ref.at(j, j);
                        let err = row[j] as f64 / d;
                        let urow = u_ref.row(j);
                        for t in j..b {
                            row[t] -= (err * urow[t]) as f32;
                        }
                        row[j] = 0.0;
                    }
                }
            }
        }
    });
    Ok(Pruned { w: wk, mask })
}

/// Register-blocked width of [`fused_group_apply`]'s suffix pass (f64
/// accumulator lanes held across the group's support).
const GROUP_BLOCK: usize = 32;

/// §Perf-L5 per-group fused apply: `row[g..] -= Σ_t e_t · U[q_t, g..]`
/// in ONE pass over the row suffix — a `GROUP_BLOCK`-wide f64
/// accumulator walks the suffix, the `n ≤ m ≤ 8` support rows of `U`
/// stream through it (ascending `t` per element, a fixed row-local
/// chain), and each output cell rounds to f32 exactly once. Columns
/// left of a support index contribute exact zeros (`U` is upper
/// triangular), matching the per-column reference's no-touch there.
fn fused_group_apply(row: &mut [f32], g: usize, u: &MatF64, q: &[usize], e: &[f64]) {
    debug_assert_eq!(q.len(), e.len());
    let b = row.len();
    let mut j0 = g;
    while j0 < b {
        let wlen = GROUP_BLOCK.min(b - j0);
        let mut acc = [0.0f64; GROUP_BLOCK];
        for (&qt, &et) in q.iter().zip(e) {
            let urow = &u.row(qt)[j0..j0 + wlen];
            for (a, &uv) in acc[..wlen].iter_mut().zip(urow) {
                *a = kf64::fmadd(et, uv, *a);
            }
        }
        for (dst, &a) in row[j0..j0 + wlen].iter_mut().zip(&acc[..wlen]) {
            *dst -= a as f32;
        }
        j0 += wlen;
    }
}

/// Structured SparseGPT baseline: the ⌈p·b⌉ columns with the smallest
/// aggregated OBS saliency `Σ_i w_ij²/[H⁻¹]_jj` are masked up front,
/// then pruned by the standard left-to-right column walk — each pruned
/// column's OBS update compensates only into columns *to its right*
/// (everything left of the walk is frozen, the defining property of
/// Alg. 5). This is exactly "SparseGPT run with a column-uniform mask";
/// the cumulative interaction between the removed columns is
/// approximated by the sum of rightward single-column corrections —
/// the approximation the paper identifies as Thanos' opening (§5.2,
/// App. A.1).
pub fn structured(w: &Mat, stats: &CalibStats, p: f64, opts: &PruneOpts) -> Result<Pruned> {
    assert!((0.0..1.0).contains(&p));
    let (c, b) = (w.rows, w.cols);
    let s = ((p * b as f64).ceil() as usize).min(b);
    let h = stats.hessian(opts.percdamp);
    let u = inverse_factor_upper(&h)?;
    // diag(H⁻¹)_j = Σ_k U[k, j]² (no full inverse needed)
    let hinv_diag: Vec<f64> = (0..b)
        .map(|j| (0..=j).map(|k| u.at(k, j) * u.at(k, j)).sum())
        .collect();
    // one-shot column selection by aggregated OBS saliency (eq. 45)
    let scores: Vec<f64> = (0..b)
        .map(|j| {
            let col: f64 = (0..c).map(|i| (w.at(i, j) as f64).powi(2)).sum();
            col / hinv_diag[j]
        })
        .collect();
    let col_mask = smallest_r_mask(&scores, s);
    let mut mask = vec![false; c * b];
    for i in 0..c {
        for j in 0..b {
            mask[i * b + j] = col_mask[j];
        }
    }
    let mut wk = w.clone();
    update_rows(&mut wk, &mask, &u, 0, b, opts);
    Ok(Pruned { w: wk, mask })
}

/// Apply per-column OBS updates for the masked entries in `[j1, j2)`,
/// row bands in parallel on the shared engine (rows are independent
/// once `U` is fixed).
///
/// Panel path (§Perf-L4): the column-sequential error chain of one row
/// is a forward substitution through the gathered upper-triangular
/// `U[q][:, q]` ([`forward_subst_upper_gather`]), so the whole row
/// update collapses to `row[j1:] -= e·U[q, j1:]` — and since `U`'s row
/// `j` vanishes left of `j`, scattering `e` into a rows×width panel
/// makes the band apply ONE mixed-precision packed GEMM against
/// `U[j1:j2, j1:]` packed once per block. The seed per-column loop
/// stays as the reference (forced by `THANOS_LINALG_NAIVE=1`).
fn update_rows(wk: &mut Mat, mask: &[bool], u: &MatF64, j1: usize, j2: usize, opts: &PruneOpts) {
    let (c, b) = (wk.rows, wk.cols);
    let width = j2 - j1;
    if c == 0 || width == 0 {
        return;
    }
    let panel = opts.panel_apply && !kernel::naive_mode();
    let eng = crate::engine::global();
    let rows_per = eng.chunk(c);
    // U[j1..j2, j1..b] packed once per block, shared across bands (an
    // offset view of the layer-global factor — no submatrix copy).
    let u_packed =
        panel.then(|| kf64::pack_b(View::row_major(&u.data, b).offset(j1, j1), width, b - j1));
    eng.for_each_band(&mut wk.data, rows_per * b, |bi, whead| {
        let row0 = bi * rows_per;
        let rows_here = whead.len() / b;
        let mask_ref = &mask[row0 * b..(row0 + rows_here) * b];
        if let Some(bp) = &u_packed {
            with_panel_scratch(|ps| {
                ps.begin(rows_here, width);
                FS_SCRATCH.with(|cell| {
                    let (q, rhs, e) = &mut *cell.borrow_mut();
                    for ri in 0..rows_here {
                        let row = &whead[ri * b..(ri + 1) * b];
                        let rmask = &mask_ref[ri * b..(ri + 1) * b];
                        q.clear();
                        rhs.clear();
                        for j in j1..j2 {
                            if rmask[j] {
                                q.push(j);
                                rhs.push(row[j] as f64);
                            }
                        }
                        forward_subst_upper_gather(u, q, rhs, e);
                        for (&j, &ev) in q.iter().zip(&*e) {
                            // the panel holds the already-solved errors
                            ps.push_support(j - j1);
                            ps.lam[ri * width + (j - j1)] = ev;
                        }
                        ps.end_row();
                    }
                });
                // apply the band as one mixed-precision GEMM, clamp
                let lam_view = View::row_major(&ps.lam, width);
                kmix::gemm_core(whead, b, j1, lam_view, 0, rows_here, bp, b - j1, true);
                for ri in 0..rows_here {
                    for &k in ps.row_support(ri) {
                        whead[ri * b + j1 + k] = 0.0;
                    }
                }
            });
            return;
        }
        for ri in 0..rows_here {
            let row = &mut whead[ri * b..(ri + 1) * b];
            let rmask = &mask_ref[ri * b..(ri + 1) * b];
            for j in j1..j2 {
                if !rmask[j] {
                    continue;
                }
                let d = u.at(j, j);
                let err = row[j] as f64 / d;
                let urow = u.row(j);
                for t in j..b {
                    row[t] -= (err * urow[t]) as f32;
                }
                row[j] = 0.0;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::recon_loss;
    use crate::pruning::testutil::setup;
    use crate::pruning::PruneOpts;

    fn opts() -> PruneOpts {
        PruneOpts { block_size: 8, percdamp: 0.01, ..Default::default() }
    }

    #[test]
    fn unstructured_sparsity_close_to_target() {
        let (w, stats, _) = setup(16, 32, 64, 20);
        let pruned = unstructured(&w, &stats, 0.5, &opts()).unwrap();
        // per-block exact counts; global = sum of per-block floors
        let zeros = pruned.w.data.iter().filter(|&&v| v == 0.0).count();
        assert_eq!(zeros, 16 * 32 / 2);
    }

    #[test]
    fn beats_wanda_on_reconstruction() {
        // the weight-update step must reduce loss vs mask-only pruning
        let mut wins = 0;
        for seed in 0..5 {
            let (w, stats, x) = setup(24, 32, 96, 200 + seed);
            let sg = unstructured(&w, &stats, 0.5, &opts()).unwrap();
            let wa = crate::pruning::wanda::unstructured(&w, &stats, 0.5);
            if recon_loss(&sg.w, &w, &x) < recon_loss(&wa.w, &w, &x) {
                wins += 1;
            }
        }
        assert!(wins >= 4, "sparsegpt won {wins}/5");
    }

    #[test]
    fn pruned_positions_are_exactly_zero_and_kept_change() {
        let (w, stats, _) = setup(8, 16, 32, 21);
        let pruned = unstructured(&w, &stats, 0.4, &opts()).unwrap();
        let mut kept_changed = 0;
        for (k, &m) in pruned.mask.iter().enumerate() {
            if m {
                assert_eq!(pruned.w.data[k], 0.0);
            } else if (pruned.w.data[k] - w.data[k]).abs() > 1e-7 {
                kept_changed += 1;
            }
        }
        assert!(kept_changed > 0, "OBS update should adjust surviving weights");
    }

    #[test]
    fn nm_format_valid_and_better_than_wanda_nm() {
        let (w, stats, x) = setup(16, 32, 64, 22);
        let sg = semi_structured(&w, &stats, 2, 4, &opts()).unwrap();
        for i in 0..16 {
            for g in (0..32).step_by(4) {
                let zeros = sg.w.row(i)[g..g + 4].iter().filter(|&&v| v == 0.0).count();
                assert_eq!(zeros, 2);
            }
        }
        let wa = crate::pruning::wanda::semi_structured(&w, &stats, 2, 4);
        assert!(recon_loss(&sg.w, &w, &x) < recon_loss(&wa.w, &w, &x));
    }

    #[test]
    fn structured_removes_exactly_s_columns() {
        let (w, stats, _) = setup(12, 16, 48, 23);
        let pruned = structured(&w, &stats, 0.25, &opts()).unwrap();
        let removed: Vec<usize> = (0..16)
            .filter(|&j| (0..12).all(|i| pruned.w.at(i, j) == 0.0))
            .collect();
        assert_eq!(removed.len(), 4);
    }

    #[test]
    fn structured_beats_wanda_structured() {
        let mut wins = 0;
        for seed in 0..5 {
            let (w, stats, x) = setup(16, 20, 60, 300 + seed);
            let sg = structured(&w, &stats, 0.3, &opts()).unwrap();
            let wa = crate::pruning::wanda::structured(&w, &stats, 0.3);
            if recon_loss(&sg.w, &w, &x) < recon_loss(&wa.w, &w, &x) {
                wins += 1;
            }
        }
        assert!(wins >= 4, "sparsegpt-struct won {wins}/5");
    }

    #[test]
    fn blocksize_one_equals_most_adaptive_mask() {
        // Bs=1 is pure column-by-column OBS; must run and hit sparsity
        let (w, stats, _) = setup(6, 12, 24, 24);
        let o = PruneOpts { block_size: 1, percdamp: 0.01, ..Default::default() };
        let pruned = unstructured(&w, &stats, 0.5, &o).unwrap();
        let zeros = pruned.w.data.iter().filter(|&&v| v == 0.0).count();
        assert_eq!(zeros, 6 * 12 / 2);
    }
}
