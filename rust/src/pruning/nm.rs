//! n:m sparse-format utilities: validation, storage accounting, and
//! the modeled sparse-tensor-core speedup figure.
//!
//! Since the `sparse/` subsystem landed, the *measured* story lives
//! there: [`crate::sparse::NmPacked`] materializes the format and
//! [`crate::sparse::kernels`] executes it on CPU (DESIGN.md §Sparse).
//! This module keeps the format validator, delegates byte accounting
//! to [`crate::sparse::nm_bytes`] (the single source of truth), and
//! retains [`modeled_speedup`] as the labeled secondary GPU figure
//! (DESIGN.md §Substitutions — no sparse tensor cores on this testbed).

use crate::linalg::Mat;

/// Pre-built row set for [`validate`]'s `skip_rows` argument. Callers
/// validating many layers against the same outlier set build it once
/// instead of paying a set construction per call.
///
/// Backed by a sorted, deduplicated `Vec` rather than a `HashSet`:
/// iteration order is deterministic (determinism contract rule D2 — no
/// hash containers in compute modules), membership is `binary_search`,
/// and for the few-dozen outlier rows a layer carries the flat layout
/// is also the faster one.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RowSet {
    rows: Vec<usize>,
}

impl RowSet {
    /// Empty set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Membership test. Takes `&usize` to match the `HashSet` call
    /// shape this type replaced.
    pub fn contains(&self, row: &usize) -> bool {
        self.rows.binary_search(row).is_ok()
    }

    /// Number of rows in the set.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Whether the set is empty.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Rows in ascending order.
    pub fn iter(&self) -> std::slice::Iter<'_, usize> {
        self.rows.iter()
    }
}

impl FromIterator<usize> for RowSet {
    fn from_iter<I: IntoIterator<Item = usize>>(it: I) -> Self {
        let mut rows: Vec<usize> = it.into_iter().collect();
        rows.sort_unstable();
        rows.dedup();
        Self { rows }
    }
}

/// Build a [`RowSet`] from a slice of row indices.
pub fn row_set(rows: &[usize]) -> RowSet {
    rows.iter().copied().collect()
}

/// Check that every group of `m` consecutive weights in every row
/// contains at least `n` zeros. `skip_rows` lists rows excluded from
/// the constraint (outlier rows under α > 0).
///
/// Documented errors (never panics): a column count with a tail group
/// (`cols % m != 0`) is rejected with the same error as the packer
/// ([`crate::sparse::nm_tail_error`]), and the first violating group is
/// reported with its row/group coordinates.
pub fn validate(w: &Mat, n: usize, m: usize, skip_rows: &RowSet) -> Result<(), String> {
    if m == 0 {
        return Err("n:m needs m >= 1".to_string());
    }
    if w.cols % m != 0 {
        return Err(crate::sparse::nm_tail_error(w.cols, m));
    }
    for i in 0..w.rows {
        if skip_rows.contains(&i) {
            continue;
        }
        for g in (0..w.cols).step_by(m) {
            let zeros = w.row(i)[g..g + m].iter().filter(|&&v| v == 0.0).count();
            if zeros < n {
                return Err(format!(
                    "row {i} group {g}: {zeros} zeros, need ≥ {n} for {n}:{m}"
                ));
            }
        }
    }
    Ok(())
}

/// Storage of an n:m compressed layer in bytes: kept values (f32/f16
/// width configurable) + `⌈log2 m⌉` positional index bits per kept
/// weight — which *is* the NVIDIA layout (2 bits per kept weight for
/// 2:4, 3 bits for 4:8; Ampere whitepaper, 2020). Delegates to
/// [`crate::sparse::nm_bytes`], the byte accounting the real packer
/// ([`crate::sparse::NmPacked::bytes`]) is pinned against; this entry
/// point is the zero-outlier-row case.
pub fn compressed_bytes(c: usize, b: usize, n: usize, m: usize, bytes_per_weight: usize) -> usize {
    crate::sparse::nm_bytes(c, b, n, m, 0, bytes_per_weight)
}

/// Dense storage in bytes.
pub fn dense_bytes(c: usize, b: usize, bytes_per_weight: usize) -> usize {
    c * b * bytes_per_weight
}

/// Modeled matmul speedup of an n:m layer vs dense on sparse tensor
/// cores. NVIDIA's 2:4 path doubles MAC throughput (NVIDIA Ampere
/// whitepaper, 2020); we model throughput gain as m/(m−n) discounted
/// by a fixed metadata/issue overhead. Reports label this figure as
/// modeled; the measured CPU figure comes from the `sparse_matmul`
/// bench and [`crate::eval::compression_report`].
pub fn modeled_speedup(n: usize, m: usize) -> f64 {
    const OVERHEAD: f64 = 0.12; // decode + operand-select overhead
    let ideal = m as f64 / (m - n) as f64;
    1.0 + (ideal - 1.0) * (1.0 - OVERHEAD)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::testutil::setup;

    #[test]
    fn validate_accepts_valid_format() {
        let (w, stats, _) = setup(8, 16, 32, 40);
        let p = crate::pruning::thanos::semi_structured(
            &w,
            &stats,
            2,
            4,
            0.0,
            &crate::pruning::PruneOpts::default(),
        )
        .unwrap();
        assert!(validate(&p.w, 2, 4, &RowSet::new()).is_ok());
    }

    #[test]
    fn validate_rejects_dense_matrix() {
        let (w, _, _) = setup(4, 8, 16, 41);
        assert!(validate(&w, 2, 4, &RowSet::new()).is_err());
    }

    #[test]
    fn validate_rejects_tail_like_the_packer() {
        let w = Mat::zeros(2, 10);
        assert_eq!(
            validate(&w, 2, 4, &RowSet::new()),
            Err(crate::sparse::nm_tail_error(10, 4))
        );
        assert_eq!(
            crate::sparse::NmPacked::from_dense(&w, 2, 4)
                .unwrap_err()
                .to_string(),
            crate::sparse::nm_tail_error(10, 4)
        );
    }

    #[test]
    fn validate_respects_skip_rows() {
        let (w, _, _) = setup(4, 8, 16, 42);
        let mut wp = w.clone();
        // make rows 1..4 valid 2:4, leave row 0 dense
        for i in 1..4 {
            for g in (0..8).step_by(4) {
                wp.row_mut(i)[g] = 0.0;
                wp.row_mut(i)[g + 1] = 0.0;
            }
        }
        assert!(validate(&wp, 2, 4, &RowSet::new()).is_err());
        assert!(validate(&wp, 2, 4, &row_set(&[0])).is_ok());
    }

    #[test]
    fn row_set_sorts_dedups_and_answers_membership() {
        let s = row_set(&[7, 3, 3, 11, 7]);
        assert_eq!(s.len(), 3);
        assert_eq!(s.iter().copied().collect::<Vec<_>>(), [3, 7, 11]);
        assert!(s.contains(&7) && s.contains(&3) && s.contains(&11));
        assert!(!s.contains(&5));
        assert!(RowSet::new().is_empty());
        // deterministic iteration order regardless of insertion order
        let t: RowSet = [11usize, 7, 3].into_iter().collect();
        assert_eq!(s, t);
    }

    #[test]
    fn compression_ratio_sane() {
        // 2:4 with f16 weights: 50% values + 2-bit indices → ~56% of dense f16
        let dense = dense_bytes(1024, 1024, 2);
        let comp = compressed_bytes(1024, 1024, 2, 4, 2);
        let ratio = comp as f64 / dense as f64;
        assert!(ratio > 0.5 && ratio < 0.65, "ratio {ratio}");
    }

    #[test]
    fn compressed_bytes_is_sparse_accounting_without_outliers() {
        for &(n, m) in &[(2usize, 4usize), (4, 8), (1, 2), (3, 4)] {
            assert_eq!(
                compressed_bytes(64, 8 * m, n, m, 2),
                crate::sparse::nm_bytes(64, 8 * m, n, m, 0, 2),
            );
        }
    }

    #[test]
    fn speedup_monotone_in_sparsity() {
        assert!(modeled_speedup(2, 4) > 1.5);
        assert!(modeled_speedup(2, 4) < 2.0);
        assert!(modeled_speedup(4, 8) > modeled_speedup(2, 4) * 0.99 - 0.01);
    }
}
