//! n:m sparse-format utilities: validation, storage accounting, and a
//! sparse-matmul cost model standing in for the Ampere 2:4 hardware
//! path (see DESIGN.md §Substitutions — no sparse tensor cores exist
//! on this testbed, so the *format* is verified exactly and the
//! speedup is modeled).

use crate::linalg::Mat;

/// Check that every group of `m` consecutive weights in every row
/// contains at least `n` zeros. `skip_rows` lists rows excluded from
/// the constraint (outlier rows under α > 0).
pub fn validate(w: &Mat, n: usize, m: usize, skip_rows: &[usize]) -> Result<(), String> {
    if w.cols % m != 0 {
        return Err(format!("cols {} not divisible by m={m}", w.cols));
    }
    let skip: std::collections::HashSet<usize> = skip_rows.iter().copied().collect();
    for i in 0..w.rows {
        if skip.contains(&i) {
            continue;
        }
        for g in (0..w.cols).step_by(m) {
            let zeros = w.row(i)[g..g + m].iter().filter(|&&v| v == 0.0).count();
            if zeros < n {
                return Err(format!(
                    "row {i} group {g}: {zeros} zeros, need ≥ {n} for {n}:{m}"
                ));
            }
        }
    }
    Ok(())
}

/// Storage of an n:m compressed layer in bytes: kept values (f32/f16
/// width configurable) + per-group index metadata (2-bit indices for
/// 2:4, ⌈log2(m choose n)⌉ in general — we use the NVIDIA layout of
/// 2 bits per kept weight for 2:4 and 3 bits for 4:8).
pub fn compressed_bytes(c: usize, b: usize, n: usize, m: usize, bytes_per_weight: usize) -> usize {
    let groups = c * b / m;
    let kept = groups * (m - n);
    let index_bits_per_kept = match (n, m) {
        (2, 4) => 2,
        (4, 8) => 3,
        _ => (usize::BITS - (m - 1).leading_zeros()) as usize,
    };
    kept * bytes_per_weight + (kept * index_bits_per_kept).div_ceil(8)
}

/// Dense storage in bytes.
pub fn dense_bytes(c: usize, b: usize, bytes_per_weight: usize) -> usize {
    c * b * bytes_per_weight
}

/// Modeled matmul speedup of an n:m layer vs dense on sparse tensor
/// cores. NVIDIA's 2:4 path doubles MAC throughput (NVIDIA Ampere
/// whitepaper, 2020); we model throughput gain as m/(m−n) discounted
/// by a fixed metadata/issue overhead.
pub fn modeled_speedup(n: usize, m: usize) -> f64 {
    const OVERHEAD: f64 = 0.12; // decode + operand-select overhead
    let ideal = m as f64 / (m - n) as f64;
    1.0 + (ideal - 1.0) * (1.0 - OVERHEAD)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::testutil::setup;

    #[test]
    fn validate_accepts_valid_format() {
        let (w, stats, _) = setup(8, 16, 32, 40);
        let p = crate::pruning::thanos::semi_structured(
            &w,
            &stats,
            2,
            4,
            0.0,
            &crate::pruning::PruneOpts::default(),
        )
        .unwrap();
        assert!(validate(&p.w, 2, 4, &[]).is_ok());
    }

    #[test]
    fn validate_rejects_dense_matrix() {
        let (w, _, _) = setup(4, 8, 16, 41);
        assert!(validate(&w, 2, 4, &[]).is_err());
    }

    #[test]
    fn validate_respects_skip_rows() {
        let (w, _, _) = setup(4, 8, 16, 42);
        let mut wp = w.clone();
        // make rows 1..4 valid 2:4, leave row 0 dense
        for i in 1..4 {
            for g in (0..8).step_by(4) {
                wp.row_mut(i)[g] = 0.0;
                wp.row_mut(i)[g + 1] = 0.0;
            }
        }
        assert!(validate(&wp, 2, 4, &[]).is_err());
        assert!(validate(&wp, 2, 4, &[0]).is_ok());
    }

    #[test]
    fn compression_ratio_sane() {
        // 2:4 with f16 weights: 50% values + 2-bit indices → ~56% of dense f16
        let dense = dense_bytes(1024, 1024, 2);
        let comp = compressed_bytes(1024, 1024, 2, 4, 2);
        let ratio = comp as f64 / dense as f64;
        assert!(ratio > 0.5 && ratio < 0.65, "ratio {ratio}");
    }

    #[test]
    fn speedup_monotone_in_sparsity() {
        assert!(modeled_speedup(2, 4) > 1.5);
        assert!(modeled_speedup(2, 4) < 2.0);
        assert!(modeled_speedup(4, 8) > modeled_speedup(2, 4) * 0.99 - 0.01);
    }
}
