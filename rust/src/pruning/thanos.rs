//! Thanos — the paper's contribution. Three variants:
//!
//! * [`unstructured`] — Alg. 1/9: block-wise walk with a *global
//!   residual mask* (`ψ_X` over everything not yet pruned, eq. 69–71)
//!   and the joint multi-weight update `w ← w − u·R̂⁻¹·R` (eq. 10) per
//!   row per block.
//! * [`structured`] — Alg. 2/7: outlier-row detection (eq. 14), row and
//!   column permutations (§G.4.4), the closed-form column-block update
//!   (eq. 13), inverse permutations.
//! * [`semi_structured`] — Alg. 8: n:m masks per group, uniform per-row
//!   system sizes, outlier rows skipped.
//!
//! The key difference from SparseGPT: all weights of a row selected
//! within a block are removed by *one* joint least-squares solve, so
//! the cumulative interaction between simultaneous removals is
//! accounted for exactly (the effect the paper credits for its
//! structured-pruning wins — §5.2, App. A.1).

use crate::linalg::batched::{
    apply_row_update, solve_band_padded_into_panel, solve_row_in_scratch, with_panel_scratch,
    with_row_solve_scratch,
};
use crate::linalg::chol::chol_inverse;
use crate::linalg::gemm::matmul_f64;
use crate::linalg::kernel::{self, kf64, kmix, View};
use crate::linalg::perm::Perm;
use crate::linalg::{Mat, MatF64};
use crate::pruning::metric::{
    nm_mask, smallest_r_mask_into_with_idx, wanda_metric_window_into,
    wanda_metric_window_rows_into,
};
use crate::pruning::select::{smallest_r_mask_threshold_into, SelectScratch};
use crate::pruning::{CalibStats, PruneOpts, Pruned};
use anyhow::{Context, Result};

/// Residual-block inverse-Hessian provider. Two modes with identical
/// math (pinned by `faithful_and_fast_inverse_agree`):
///
/// * `Faithful` — invert `H[j1:, j1:]` per block, the paper's Alg. 1
///   line 17 (O(b⁴/B) per layer, Table 1).
/// * `Fast` — one global factorization `H⁻¹ = UᵀU`; every residual
///   inverse is `(H[j1:, j1:])⁻¹ = U[j1:, j1:]ᵀ·U[j1:, j1:]`, so a
///   block needs only two small matmuls (O(b³) total per layer).
enum SuffixInverse {
    Faithful { h_full: MatF64 },
    Fast { u: MatF64 },
}

impl SuffixInverse {
    fn new(h_full: MatF64, faithful: bool) -> Result<SuffixInverse> {
        if faithful {
            Ok(SuffixInverse::Faithful { h_full })
        } else {
            // reversal-trick factorization: no full inverse formed
            let _span = crate::trace::span("walk.factor");
            let u = crate::linalg::chol::inverse_factor_upper(&h_full)
                .context("factorizing layer Hessian")?;
            Ok(SuffixInverse::Fast { u })
        }
    }

    /// For the block starting at `j1` with `width` columns out of `b`:
    /// the first `width` rows of the residual inverse Hessian
    /// (width×rest). Its leading width×width block is the `R̂` gather
    /// source (the old separate `hinv_bb` was element-for-element a
    /// copy of those columns, so one matrix now serves both roles).
    fn block_rows(&self, j1: usize, width: usize, b: usize, panel: bool) -> Result<MatF64> {
        let _span = crate::trace::span("walk.factor");
        let rest = b - j1;
        match self {
            SuffixInverse::Faithful { h_full } => {
                let hres = h_full.block(j1, b, j1, b);
                let hinv = chol_inverse(&hres)
                    .with_context(|| format!("inverting residual Hessian at block {j1}"))?;
                Ok(hinv.block(0, width, 0, rest))
            }
            SuffixInverse::Fast { u } => {
                if kernel::naive_mode() || !panel {
                    // pre-§Perf-L4 chain, preserved exactly for the
                    // reference walks: materialized blocks through
                    // `matmul_f64` (the seed zero-skip nest under
                    // naive mode, the density-probed packed GEMM
                    // otherwise — including its zero-skip routing of
                    // the sparse leading `usqᵀ` rows)
                    let usq = u.block(j1, j1 + width, j1, j1 + width);
                    let ublk = u.block(j1, j1 + width, j1, b);
                    let usq_t = usq.transpose();
                    return Ok(matmul_f64(&usq_t, &ublk));
                }
                // §Perf-L4: the layer-global factor U is stored once;
                // both GEMM operands are offset *views* of it — no
                // per-block `usq`/`ublk` copies, no transpose
                // materialization — and B is packed once per block,
                // shared read-only across the engine bands.
                let mut out = MatF64::zeros(width, rest);
                let av = View::transposed(&u.data, b).offset(j1, j1);
                let bv = View::row_major(&u.data, b).offset(j1, j1);
                let bp = kf64::pack_b(bv, width, rest);
                kf64::gemm_banded(&mut out.data, rest, av, 0, width, &bp, false);
                Ok(out)
            }
        }
    }
}

/// Thanos unstructured pruning (Alg. 1) to sparsity `p` with block
/// size `opts.block_size`.
pub fn unstructured(w: &Mat, stats: &CalibStats, p: f64, opts: &PruneOpts) -> Result<Pruned> {
    assert!((0.0..1.0).contains(&p));
    let (c, b) = (w.rows, w.cols);
    let bsize = opts.block_size.clamp(1, b);
    let mut wk = w.clone();
    let mut mask = vec![false; c * b];
    let mut r_left = (p * (c * b) as f64).floor() as usize;
    let h_full = stats.hessian(opts.percdamp);
    let suffix = SuffixInverse::new(h_full, opts.paper_faithful_inverse)?;

    // Per-call scratch carried across the block walk: the full `c×rest`
    // metric / mask buffers used to be reallocated on every block
    // iteration (O(b/B) large allocations per layer for pure churn).
    let mut metric: Vec<f64> = Vec::new();
    let mut res_mask: Vec<bool> = Vec::new();
    let mut local: Vec<bool> = Vec::new();
    let mut sel = SelectScratch::new();
    // §Perf-L5: the panel walk routes the global-residual selection
    // through the engine-parallel threshold select (bitwise-identical
    // masks — pinned by tests/selection.rs); the reference walks keep
    // the select_nth oracle, now fed a per-call index scratch.
    let threshold_select = opts.panel_apply && !kernel::naive_mode();

    let mut j1 = 0;
    while j1 < b && r_left > 0 {
        let j2 = (j1 + bsize).min(b);
        let width = j2 - j1;
        let rest = b - j1;
        // Hessian of the unseen suffix (Alg. 1 line 17: H ← 2(XXᵀ)_{j:,j:})
        let hinv_rows = suffix.block_rows(j1, width, b, opts.panel_apply)?;

        // ψ_X over the residual window (global residual mask, line 6),
        // local part = first `width` columns (line 7)
        {
            let _metric_span = crate::trace::span("walk.metric");
            wanda_metric_window_into(&wk, stats, j1, b, &mut metric);
        }
        let r_block = r_left.min(c * rest);
        let select_span = crate::trace::span("walk.select");
        if threshold_select {
            smallest_r_mask_threshold_into(&metric, r_block, &mut res_mask, &mut sel);
        } else {
            smallest_r_mask_into_with_idx(&metric, r_block, &mut res_mask, &mut sel.idx);
        }
        local.clear();
        local.resize(c * width, false);
        for i in 0..c {
            local[i * width..(i + 1) * width]
                .copy_from_slice(&res_mask[i * rest..i * rest + width]);
        }
        // feasibility top-up: everything left over must still fit in the
        // remaining columns after this block
        let mut count = local.iter().filter(|&&m| m).count();
        let capacity_after = c * (rest - width);
        if r_left > count + capacity_after {
            let need = r_left - capacity_after - count;
            // add the `need` smallest not-yet-selected local cells.
            // Only `need` of them are consumed, so an O(n) partition
            // (select_nth) replaces the old full sort; the (value,
            // index) comparator is a strict total order, so the
            // selected *set* — all that matters for the mask — is
            // identical to the sorted prefix, ties broken by index.
            let mut cand: Vec<(f64, usize)> = Vec::new();
            for i in 0..c {
                for k in 0..width {
                    if !local[i * width + k] {
                        cand.push((metric[i * rest + k], i * width + k));
                    }
                }
            }
            let need = need.min(cand.len());
            if need > 0 && need < cand.len() {
                cand.select_nth_unstable_by(need - 1, |a, b| {
                    a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
                });
            }
            for &(_, idx) in cand.iter().take(need) {
                local[idx] = true;
            }
            count += need;
        }
        drop(select_span);
        r_left -= count;
        for i in 0..c {
            for k in 0..width {
                mask[i * b + j1 + k] = local[i * width + k];
            }
        }

        // joint per-row updates over the residual frame, rows in parallel
        update_rows_blocked(&mut wk, &local, &hinv_rows, j1, width, opts)?;
        j1 = j2;
    }
    Ok(Pruned { w: wk, mask })
}

/// Thanos semi-structured n:m pruning (Alg. 8). `alpha` outlier rows
/// (largest row loss `h_i = W_i·H·W_iᵀ`, eq. 14) are left untouched, so
/// effective sparsity is `(n/m)·(1−α)` as the paper notes in §5.1.
pub fn semi_structured(
    w: &Mat,
    stats: &CalibStats,
    n: usize,
    m: usize,
    alpha: f64,
    opts: &PruneOpts,
) -> Result<Pruned> {
    assert!(w.cols % m == 0, "n:m needs b divisible by m");
    assert!(n <= m);
    assert!((0.0..1.0).contains(&alpha));
    let (c, b) = (w.rows, w.cols);
    // block size aligned down to a multiple of m
    let bsize = {
        let raw = opts.block_size.clamp(m, b);
        raw - raw % m
    };
    let h_full = stats.hessian(opts.percdamp);

    // rows sorted ascending by loss; the ⌈αc⌉ largest (outliers) land at
    // the end and are excluded from pruning (Alg. 8 lines 3–5, 12)
    let hrow = {
        let _metric_span = crate::trace::span("walk.metric");
        row_losses_gated(w, &h_full, opts)
    };
    let q = Perm::sorting(&hrow);
    let mut wq = q.apply_rows(w);
    let c_prune = c - ((alpha * c as f64).ceil() as usize).min(c);
    let mut mask_q = vec![false; c * b];
    let suffix = SuffixInverse::new(h_full, opts.paper_faithful_inverse)?;

    // block-metric scratch reused across the walk (scores only the
    // non-outlier rows directly — no per-block row-slice clone)
    let mut block_metric: Vec<f64> = Vec::new();
    let mut j1 = 0;
    while j1 < b {
        let j2 = (j1 + bsize).min(b);
        let width = j2 - j1;
        debug_assert_eq!(width % m, 0);
        let hinv_rows = suffix.block_rows(j1, width, b, opts.panel_apply)?;
        // n:m mask over the block, pruned rows only
        {
            let _metric_span = crate::trace::span("walk.metric");
            wanda_metric_window_rows_into(&wq, c_prune, stats, j1, j2, &mut block_metric);
        }
        let local = {
            let _select_span = crate::trace::span("walk.select");
            nm_mask(&block_metric, c_prune, width, n, m)
        };
        for i in 0..c_prune {
            for k in 0..width {
                mask_q[i * b + j1 + k] = local[i * width + k];
            }
        }
        update_rows_blocked_subset(&mut wq, &local, &hinv_rows, j1, width, c_prune, opts)?;
        j1 = j2;
    }

    // inverse row permutation
    let w_out = q.inverse().apply_rows(&wq);
    let mut mask = vec![false; c * b];
    for (new, &old) in q.sigma.iter().enumerate() {
        mask[old * b..(old + 1) * b].copy_from_slice(&mask_q[new * b..(new + 1) * b]);
    }
    Ok(Pruned { w: w_out, mask })
}

/// Thanos structured pruning (Alg. 2): remove `s = ⌈p·b/(1−α)⌉` whole
/// columns from the non-outlier rows with the closed-form joint update
/// (eq. 13), preserving the `⌈αc⌉` highest-loss rows.
pub fn structured(
    w: &Mat,
    stats: &CalibStats,
    p: f64,
    alpha: f64,
    opts: &PruneOpts,
) -> Result<Pruned> {
    assert!((0.0..1.0).contains(&p));
    assert!((0.0..1.0).contains(&alpha));
    let (c, b) = (w.rows, w.cols);
    let s = (((p * b as f64) / (1.0 - alpha)).ceil() as usize).min(b);
    let h = stats.hessian(opts.percdamp);

    // 1. row permutation: ascending loss, outliers (largest h_i) last
    let hrow = {
        let _metric_span = crate::trace::span("walk.metric");
        row_losses_gated(w, &h, opts)
    };
    let q = Perm::sorting(&hrow);
    let wq = q.apply_rows(w);
    let c_prune = c - ((alpha * c as f64).ceil() as usize).min(c);

    // 2. column permutation: ascending column loss v_j over pruned rows
    //    (eq. 15: ‖W_{1:c−⌈αc⌉, j}‖²·‖X_{j:}‖²). The old per-column
    //    loop strode `wq` column-major (one cache line per element);
    //    the panel walk replaces it with a row-major accumulation
    //    pass, band-parallel on the engine. Bands are a FIXED row
    //    count (not thread-scaled) and the partials reduce in
    //    ascending band order, so the summation tree — hence every bit
    //    of `v` — is independent of the thread count. The reference
    //    walks (per-row / naive) keep the seed per-column chain so the
    //    bench oracle stays independent of the new pass.
    let eng = crate::engine::global();
    let v_span = crate::trace::span("walk.metric");
    let v: Vec<f64> = if opts.panel_apply && !kernel::naive_mode() {
        const V_ROWS_PER_BAND: usize = 64;
        let n_vbands = c_prune.div_ceil(V_ROWS_PER_BAND).max(1);
        let mut v_partials: Vec<Vec<f64>> = vec![Vec::new(); n_vbands];
        let wq_ref = &wq;
        eng.for_each_band(&mut v_partials, 1, |bi, slot| {
            let r0 = bi * V_ROWS_PER_BAND;
            let r1 = ((bi + 1) * V_ROWS_PER_BAND).min(c_prune);
            let mut acc = vec![0.0f64; b];
            for i in r0..r1 {
                for (a, &wv) in acc.iter_mut().zip(wq_ref.row(i)) {
                    let wd = wv as f64;
                    *a += wd * wd;
                }
            }
            slot[0] = acc;
        });
        let mut v = vec![0.0f64; b];
        for part in &v_partials {
            for (dst, &pv) in v.iter_mut().zip(part) {
                *dst += pv;
            }
        }
        for (dst, &xn) in v.iter_mut().zip(&stats.xnorm_sq) {
            *dst *= xn;
        }
        v
    } else {
        (0..b)
            .map(|j| {
                let wnorm: f64 = (0..c_prune).map(|i| (wq.at(i, j) as f64).powi(2)).sum();
                wnorm * stats.xnorm_sq[j]
            })
            .collect()
    };
    drop(v_span);
    let pperm = Perm::sorting(&v);
    let mut wp = pperm.apply_cols(&wq);
    let hp = pperm.conjugate_sym(&h);

    // 3. eq. (13): Δ = −W_{:,1:s}·(Hinv_{1:s,1:s})⁻¹·Hinv_{1:s,:}
    //    over the non-outlier rows. With H⁻¹ = UᵀU (U upper) the whole
    //    chain collapses: Hinv_{1:s,1:s} = UₛᵀUₛ and Hinv_{1:s,:} =
    //    Uₛᵀ·U[0:s,:], so Z = (UₛᵀUₛ)⁻¹·Uₛᵀ·U[0:s,:] = Uₛ⁻¹·U[0:s,:] —
    //    ONE triangular solve instead of inverse+Cholesky+solves
    //    (§Perf-L3; numerics pinned against the direct form in tests).
    let u = {
        let _factor_span = crate::trace::span("walk.factor");
        crate::linalg::chol::inverse_factor_upper(&hp)?
    };
    let us = u.block(0, s, 0, s);
    let u_top = u.block(0, s, 0, b);
    let z = {
        let _solve_span = crate::trace::span("walk.solve");
        crate::linalg::chol::upper_tri_solve_many(&us, &u_top)
    };
    // W[0..c_prune] += Δ = −W[:,0..s]·Z, row bands on the shared engine
    let z_ref = &z;
    let rows_per = eng.chunk(c_prune);
    let apply_span = crate::trace::span("walk.apply");
    if opts.panel_apply && !kernel::naive_mode() {
        // §Perf-L4: the eq. 13 Δ is a rank-s update — one
        // mixed-precision packed GEMM per band against Z packed once
        // and shared. Each band snapshots its W[:, :s] operand into a
        // f64 panel first (the GEMM writes those same columns), exactly
        // mirroring the read-all-then-write order of the scalar loop.
        let zp = kf64::pack_b(View::row_major(&z.data, b), s, b);
        let zp_ref = &zp;
        eng.for_each_band(&mut wp.data[..c_prune * b], rows_per * b, |_bi, head| {
            let rows_here = head.len() / b;
            let mut a_panel = vec![0.0f64; rows_here * s];
            for ri in 0..rows_here {
                for (dst, &wv) in a_panel[ri * s..(ri + 1) * s]
                    .iter_mut()
                    .zip(&head[ri * b..ri * b + s])
                {
                    *dst = wv as f64;
                }
            }
            let a_view = View::row_major(&a_panel, s);
            kmix::gemm_core(head, b, 0, a_view, 0, rows_here, zp_ref, b, true);
            for ri in 0..rows_here {
                head[ri * b..ri * b + s].iter_mut().for_each(|v| *v = 0.0);
            }
        });
    } else {
        // reference path: per-row scalar Δ accumulation (seed loop)
        eng.for_each_band(&mut wp.data[..c_prune * b], rows_per * b, |_bi, head| {
            let rows_here = head.len() / b;
            // Δ accumulator (f64) reused across the band's rows
            let mut delta = vec![0.0f64; b];
            for ri in 0..rows_here {
                let row = &mut head[ri * b..(ri + 1) * b];
                delta.iter_mut().for_each(|v| *v = 0.0);
                for t in 0..s {
                    let wt = row[t] as f64;
                    if wt == 0.0 {
                        continue;
                    }
                    let zr = z_ref.row(t);
                    for jj in 0..b {
                        delta[jj] += wt * zr[jj];
                    }
                }
                for jj in 0..b {
                    row[jj] -= delta[jj] as f32;
                }
                for item in row.iter_mut().take(s) {
                    *item = 0.0;
                }
            }
        });
    }
    drop(apply_span);

    // 4. mask in permuted coordinates, then undo both permutations
    let mut mask_p = vec![false; c * b];
    for i in 0..c_prune {
        for j in 0..s {
            mask_p[i * b + j] = true;
        }
    }
    let w_unp = pperm.inverse().apply_cols(&wp);
    let w_out = q.inverse().apply_rows(&w_unp);
    let mut mask = vec![false; c * b];
    for (new_r, &old_r) in q.sigma.iter().enumerate() {
        for (new_c, &old_c) in pperm.sigma.iter().enumerate() {
            mask[old_r * b + old_c] = mask_p[new_r * b + new_c];
        }
    }
    Ok(Pruned { w: w_out, mask })
}

/// Row losses `h_i = W_i·H·W_iᵀ` (∝ ‖W_{i:}X‖², eq. 14), computed from
/// the accumulated Hessian so no calibration matrix X needs to be kept.
///
/// Packed path (§Perf-L4): the old O(c·b²) naive double loop is
/// `Y = W·H` through the packed f64 GEMM (W widened once) followed by
/// banded per-row dots `h_i = Σ_t W_it·Y_it` — same O(c·b²) flops, run
/// at GEMM rate. Per-row chains are row-local, so results stay
/// bit-identical for any thread count; `THANOS_LINALG_NAIVE=1` restores
/// the seed nest.
pub fn row_losses(w: &Mat, h: &MatF64) -> Vec<f64> {
    let (c, b) = (w.rows, w.cols);
    assert_eq!(h.rows, b);
    if kernel::naive_mode() {
        return row_losses_naive(w, h);
    }
    let wd = MatF64::from_fn(c, b, |i, j| w.at(i, j) as f64);
    let y = matmul_f64(&wd, h);
    let mut out = vec![0.0f64; c];
    if c == 0 {
        return out;
    }
    let eng = crate::engine::global();
    let rows_per = eng.chunk(c);
    eng.for_each_band(&mut out, rows_per, |bi, head| {
        let row0 = bi * rows_per;
        for (k, loss) in head.iter_mut().enumerate() {
            let i = row0 + k;
            let mut acc = 0.0f64;
            for (&wv, &yv) in wd.row(i).iter().zip(y.row(i)) {
                acc = crate::linalg::kernel::kf64::fmadd(wv, yv, acc);
            }
            *loss = acc;
        }
    });
    out
}

/// [`row_losses`] under the walk's path selection: the per-row
/// reference walk (`panel_apply = false`) keeps the seed nest so the
/// bench baseline is exactly the pre-§Perf-L4 walk.
fn row_losses_gated(w: &Mat, h: &MatF64, opts: &PruneOpts) -> Vec<f64> {
    if opts.panel_apply {
        row_losses(w, h)
    } else {
        row_losses_naive(w, h)
    }
}

/// Seed O(c·b²) nest (zero-skip over `W_ij`): the naive reference for
/// [`row_losses`].
pub fn row_losses_naive(w: &Mat, h: &MatF64) -> Vec<f64> {
    let (c, b) = (w.rows, w.cols);
    assert_eq!(h.rows, b);
    let mut out = vec![0.0f64; c];
    if c == 0 {
        return out;
    }
    let eng = crate::engine::global();
    let rows_per = eng.chunk(c);
    eng.for_each_band(&mut out, rows_per, |bi, head| {
        let row0 = bi * rows_per;
        for (k, loss) in head.iter_mut().enumerate() {
            let wrow = w.row(row0 + k);
            let mut acc = 0.0f64;
            for (jj, &wj) in wrow.iter().enumerate() {
                if wj == 0.0 {
                    continue;
                }
                let hrow = h.row(jj);
                let mut dot = 0.0f64;
                for (t, &wt) in wrow.iter().enumerate() {
                    dot += wt as f64 * hrow[t];
                }
                acc += wj as f64 * dot;
            }
            *loss = acc;
        }
    });
    out
}

/// Per-row joint updates for a block: rows `[0, c)` of `wk`, local mask
/// `c×width`. `hinv_rows` holds the first `width` rows of the residual
/// inverse Hessian over the whole residual frame (width×rest); its
/// leading width×width columns double as the `R̂` gather source.
fn update_rows_blocked(
    wk: &mut Mat,
    local: &[bool],
    hinv_rows: &MatF64,
    j1: usize,
    width: usize,
    opts: &PruneOpts,
) -> Result<()> {
    let c = wk.rows;
    update_rows_blocked_subset(wk, local, hinv_rows, j1, width, c, opts)
}

/// Same, but only the first `c_limit` rows are updated (outlier rows at
/// the end of the permuted matrix are skipped).
///
/// Two implementations (§Perf-L4):
///
/// * **Λ-panel** (default) — per engine band, every row's removal
///   system is gathered and solved through the §H.1 padded batch
///   ([`solve_band_padded_into_panel`], bit-identical to the per-row
///   solves), the multipliers land in a rows×width Λ panel (zero
///   off-support), and the whole band applies as ONE mixed-precision
///   packed GEMM `W[:, j1:] -= Λ·hinv_rows` against `hinv_rows` packed
///   once per block and shared read-only across bands. Removed cells
///   are then clamped to exact zero, as before.
/// * **per-row** (reference) — the seed path: exact-size scratch solve
///   plus one f32 axpy chain per selected weight per row. Forced by
///   `THANOS_LINALG_NAIVE=1` (overriding `opts.panel_apply`) so the
///   bench/CI divergence gates compare old vs new in one process.
fn update_rows_blocked_subset(
    wk: &mut Mat,
    local: &[bool],
    hinv_rows: &MatF64,
    j1: usize,
    width: usize,
    c_limit: usize,
    opts: &PruneOpts,
) -> Result<()> {
    let b = wk.cols;
    let rest = b - j1;
    assert_eq!(hinv_rows.rows, width);
    assert_eq!(hinv_rows.cols, rest);
    if c_limit == 0 {
        return Ok(());
    }
    let panel = opts.panel_apply && !kernel::naive_mode();
    let eng = crate::engine::global();
    let rows_per = eng.chunk(c_limit);
    // One error slot per band, reduced in ascending band order after the
    // job: the reported error is a function of the data, not of which
    // worker lost the race to a shared error bag (determinism contract
    // rule D1 — no sync primitives inside submission closures).
    let n_bands = (c_limit * b).div_ceil(rows_per * b);
    let mut band_err: Vec<Option<anyhow::Error>> = (0..n_bands).map(|_| None).collect();
    // Λ-panel path only: hinv_rows packed once per block, shared by all
    // bands (à la the GEMM core's PackedB contract).
    let hinv_packed =
        panel.then(|| kf64::pack_b(View::row_major(&hinv_rows.data, rest), width, rest));
    eng.for_each_band2(
        &mut wk.data[..c_limit * b],
        &mut band_err,
        rows_per * b,
        1,
        |bi, whead, err_slot| {
            let row0 = bi * rows_per;
            let rows_here = whead.len() / b;
            let local_ref = &local[row0 * width..(row0 + rows_here) * width];
            if let Some(bp) = &hinv_packed {
                // gather supports + rhs, batch-solve into the Λ panel,
                // apply the band as one mixed-precision GEMM, clamp.
                with_panel_scratch(|ps| {
                    {
                        let _solve_span = crate::trace::span("walk.solve");
                        ps.begin(rows_here, width);
                        for ri in 0..rows_here {
                            let lmask = &local_ref[ri * width..(ri + 1) * width];
                            let row = &whead[ri * b + j1..(ri + 1) * b];
                            for (k, &selected) in lmask.iter().enumerate() {
                                if selected {
                                    ps.push(k, row[k] as f64);
                                }
                            }
                            ps.end_row();
                        }
                        if let Err(e) = solve_band_padded_into_panel(hinv_rows, ps) {
                            err_slot[0] = Some(e);
                            return;
                        }
                    }
                    let _apply_span = crate::trace::span("walk.apply");
                    let lam_view = View::row_major(&ps.lam, width);
                    kmix::gemm_core(whead, b, j1, lam_view, 0, rows_here, bp, rest, true);
                    for ri in 0..rows_here {
                        for &k in ps.row_support(ri) {
                            whead[ri * b + j1 + k] = 0.0;
                        }
                    }
                });
                return;
            }
            // q / u / R̂ / λ buffers live in this worker's pooled scratch —
            // no per-row (or even per-block) allocations on the hot path
            let _solve_span = crate::trace::span("walk.solve");
            with_row_solve_scratch(|s| {
                for ri in 0..rows_here {
                    let lmask = &local_ref[ri * width..(ri + 1) * width];
                    s.q.clear();
                    for (k, &selected) in lmask.iter().enumerate() {
                        if selected {
                            s.q.push(k);
                        }
                    }
                    if s.q.is_empty() {
                        continue;
                    }
                    let row = &mut whead[ri * b + j1..(ri + 1) * b];
                    debug_assert_eq!(row.len(), rest);
                    s.u.clear();
                    for &t in &s.q {
                        s.u.push(row[t] as f64);
                    }
                    match solve_row_in_scratch(hinv_rows, s) {
                        Ok(()) => apply_row_update(row, hinv_rows, &s.q, &s.lam),
                        // first error in the band wins; later rows still
                        // run so the band's weight state stays the same
                        // as the shared-bag version it replaced
                        Err(e) => {
                            if err_slot[0].is_none() {
                                err_slot[0] = Some(e);
                            }
                        }
                    }
                }
            });
        },
    );
    if let Some(e) = band_err.into_iter().flatten().next() {
        return Err(e.context("thanos row solve failed"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::recon_loss;
    use crate::pruning::testutil::setup;
    use crate::pruning::PruneOpts;

    fn opts(bsize: usize) -> PruneOpts {
        PruneOpts { block_size: bsize, percdamp: 0.01, ..Default::default() }
    }

    #[test]
    fn unstructured_exact_sparsity() {
        let (w, stats, _) = setup(12, 24, 48, 30);
        for &p in &[0.25, 0.5, 0.7] {
            let pruned = unstructured(&w, &stats, p, &opts(8)).unwrap();
            let want = (p * (12.0 * 24.0)).floor() as usize;
            let zeros = pruned.w.data.iter().filter(|&&v| v == 0.0).count();
            assert_eq!(zeros, want, "p={p}");
        }
    }

    #[test]
    fn unstructured_mask_positions_zeroed_exactly() {
        let (w, stats, _) = setup(8, 16, 40, 31);
        let pruned = unstructured(&w, &stats, 0.5, &opts(4)).unwrap();
        for (k, &m) in pruned.mask.iter().enumerate() {
            if m {
                assert_eq!(pruned.w.data[k], 0.0);
            }
        }
        assert_eq!(
            pruned.mask.iter().filter(|&&m| m).count(),
            8 * 16 / 2
        );
    }

    #[test]
    fn unstructured_beats_wanda() {
        // weight updates must reduce reconstruction loss vs mask-only
        let mut wins = 0;
        for seed in 0..5 {
            let (w, stats, x) = setup(20, 32, 96, 400 + seed);
            let th = unstructured(&w, &stats, 0.5, &opts(8)).unwrap();
            let wa = crate::pruning::wanda::unstructured(&w, &stats, 0.5);
            if recon_loss(&th.w, &w, &x) < recon_loss(&wa.w, &w, &x) {
                wins += 1;
            }
        }
        assert!(wins >= 4, "thanos won {wins}/5 vs wanda");
    }

    #[test]
    fn unstructured_all_blocksizes_beat_no_update_baseline() {
        // every block size must do better than mask-only pruning
        // (the paper's Table-5 stability claim is about end-model PPL at
        // real scale; at toy layer scale the invariant that always holds
        // is update ≥ no-update for each B — larger B monotonically
        // approaches the single-shot joint optimum)
        let (w, stats, x) = setup(16, 32, 64, 32);
        let wanda_loss = {
            let p = crate::pruning::wanda::unstructured(&w, &stats, 0.5);
            recon_loss(&p.w, &w, &x)
        };
        let mut prev = f64::INFINITY;
        for &bsz in &[4usize, 8, 16, 32] {
            let p = unstructured(&w, &stats, 0.5, &opts(bsz)).unwrap();
            let loss = recon_loss(&p.w, &w, &x);
            assert!(loss < wanda_loss, "B={bsz}: {loss} !< wanda {wanda_loss}");
            // not strictly monotone in theory, but should not explode
            assert!(loss < prev * 2.0, "B={bsz} regressed: {loss} vs {prev}");
            prev = loss;
        }
    }

    #[test]
    fn nm_format_valid_with_alpha_zero() {
        let (w, stats, _) = setup(10, 16, 40, 33);
        let pruned = semi_structured(&w, &stats, 2, 4, 0.0, &opts(8)).unwrap();
        for i in 0..10 {
            for g in (0..16).step_by(4) {
                let zeros = pruned.w.row(i)[g..g + 4].iter().filter(|&&v| v == 0.0).count();
                assert_eq!(zeros, 2, "row {i} group {g}");
            }
        }
    }

    #[test]
    fn nm_alpha_preserves_outlier_rows() {
        let (w, stats, _) = setup(10, 16, 40, 34);
        let pruned = semi_structured(&w, &stats, 2, 4, 0.2, &opts(8)).unwrap();
        // ⌈0.2·10⌉ = 2 untouched rows
        let untouched = (0..10)
            .filter(|&i| pruned.w.row(i) == w.row(i))
            .count();
        assert_eq!(untouched, 2);
        // and they are the max-loss rows
        let h = stats.hessian(0.01);
        let losses = row_losses(&w, &h);
        let mut idx: Vec<usize> = (0..10).collect();
        idx.sort_by(|&a, &b| losses[b].partial_cmp(&losses[a]).unwrap());
        for &i in &idx[..2] {
            assert_eq!(pruned.w.row(i), w.row(i), "outlier row {i} modified");
        }
    }

    #[test]
    fn structured_removes_columns_only_in_pruned_rows() {
        let (w, stats, _) = setup(12, 20, 60, 35);
        let p = 0.3;
        let alpha = 0.25;
        let pruned = structured(&w, &stats, p, alpha, &opts(8)).unwrap();
        let keep = (0.25f64 * 12.0).ceil() as usize; // 3 outlier rows
        let c_prune = 12 - keep;
        let s = ((p * 20.0) / (1.0 - alpha)).ceil() as usize;
        // per pruned row: exactly s zeros; outlier rows: unchanged
        let h = stats.hessian(0.01);
        let losses = row_losses(&w, &h);
        let mut idx: Vec<usize> = (0..12).collect();
        idx.sort_by(|&a, &b| losses[a].partial_cmp(&losses[b]).unwrap());
        for &i in &idx[..c_prune] {
            let zeros = pruned.w.row(i).iter().filter(|&&v| v == 0.0).count();
            assert_eq!(zeros, s, "pruned row {i}");
        }
        for &i in &idx[c_prune..] {
            assert_eq!(pruned.w.row(i), w.row(i), "outlier row {i}");
        }
        // pruned rows share the same removed column set
        let removed: Vec<usize> = (0..20)
            .filter(|&j| pruned.w.at(idx[0], j) == 0.0)
            .collect();
        for &i in &idx[..c_prune] {
            for &j in &removed {
                assert_eq!(pruned.w.at(i, j), 0.0);
            }
        }
        assert_eq!(removed.len(), s);
    }

    #[test]
    fn structured_alpha_zero_hits_target_sparsity() {
        let (w, stats, _) = setup(10, 16, 48, 36);
        let pruned = structured(&w, &stats, 0.25, 0.0, &opts(8)).unwrap();
        let s = (0.25f64 * 16.0).ceil() as usize;
        assert!((pruned.sparsity() - s as f64 / 16.0).abs() < 1e-9);
    }

    #[test]
    fn structured_beats_sparsegpt_structured() {
        // the paper's headline: joint column update beats greedy
        // one-column-at-a-time OBS (Table 2 struct block)
        let mut wins = 0;
        for seed in 0..5 {
            let (w, stats, x) = setup(24, 24, 96, 500 + seed);
            let th = structured(&w, &stats, 0.3, 0.0, &opts(8)).unwrap();
            let sg = crate::pruning::sparsegpt::structured(&w, &stats, 0.3, &opts(8)).unwrap();
            // compare at equal column counts: both remove ceil(0.3*24)
            let lt = recon_loss(&th.w, &w, &x);
            let ls = recon_loss(&sg.w, &w, &x);
            if lt <= ls * 1.05 {
                wins += 1;
            }
        }
        assert!(wins >= 4, "thanos-struct competitive in {wins}/5");
    }

    #[test]
    fn unstructured_update_improves_over_mask_only_same_mask() {
        // directly verify the optimality of the joint update: zeroing
        // the same mask WITHOUT the update must be worse
        let (w, stats, x) = setup(16, 24, 72, 37);
        let th = unstructured(&w, &stats, 0.5, &opts(8)).unwrap();
        let mut mask_only = w.clone();
        for (k, &m) in th.mask.iter().enumerate() {
            if m {
                mask_only.data[k] = 0.0;
            }
        }
        let l_th = recon_loss(&th.w, &w, &x);
        let l_mask = recon_loss(&mask_only, &w, &x);
        assert!(l_th < l_mask, "update {l_th} vs mask-only {l_mask}");
    }

    #[test]
    fn faithful_and_fast_inverse_agree() {
        // the fast suffix-factor path must reproduce the paper-faithful
        // per-block inversion to numerical precision, on every variant
        let (w, stats, _) = setup(14, 24, 72, 39);
        let faithful = PruneOpts { paper_faithful_inverse: true, ..opts(8) };
        let fast = opts(8);
        let a = unstructured(&w, &stats, 0.5, &faithful).unwrap();
        let b = unstructured(&w, &stats, 0.5, &fast).unwrap();
        assert_eq!(a.mask, b.mask, "masks must be identical");
        assert!(a.w.max_abs_diff(&b.w) < 1e-4, "diff {}", a.w.max_abs_diff(&b.w));

        let a = semi_structured(&w, &stats, 2, 4, 0.1, &faithful).unwrap();
        let b = semi_structured(&w, &stats, 2, 4, 0.1, &fast).unwrap();
        assert_eq!(a.mask, b.mask);
        assert!(a.w.max_abs_diff(&b.w) < 1e-4);
    }

    #[test]
    fn row_losses_match_direct_computation() {
        let (w, stats, x) = setup(6, 10, 30, 38);
        let h = stats.hessian(0.0);
        let losses = row_losses(&w, &h);
        for i in 0..6 {
            // h_i = 2·‖W_i X‖² / n_cols when H = (2/n)·XXᵀ   (damping off)
            let y = crate::linalg::gemm::row_times_mat(w.row(i), &x);
            let direct: f64 = y.iter().map(|v| v * v).sum();
            let expect = 2.0 * direct / stats.n_cols as f64;
            assert!(
                (losses[i] - expect).abs() / expect.max(1e-9) < 1e-6,
                "row {i}: {} vs {}",
                losses[i],
                expect
            );
        }
    }
}
