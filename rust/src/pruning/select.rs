//! §Perf-L5 threshold-select selection engine (DESIGN.md §Perf-L5).
//!
//! [`metric::smallest_r_mask_into`](crate::pruning::metric) — the
//! oracle the block walks used on their hot path — materializes an
//! index array and runs an index-pair `select_nth_unstable_by` whose
//! every comparison chases two random `metric` loads. At c=3072,
//! b=1024 that selection is ~40% of the unstructured walk's wall time
//! and is a *serial* stage in the otherwise engine-parallel walk (the
//! Amdahl cap called out in ROADMAP).
//!
//! This module replaces it with a **values-only threshold select**:
//!
//! 1. **Band-parallel key histogram** — each engine band histograms the
//!    top 16 bits of a monotone `f64 → u64` key ([`sel_key`]) into its
//!    own bucket table (4-way split counters inside a band break the
//!    store-forward chains of same-bucket runs). Counts are integers,
//!    so the merged histogram is independent of banding.
//! 2. **Candidate window** — the bucket where the cumulative count
//!    crosses `r` contains the threshold; each band gathers its bucket
//!    members (value + flat index) into a compact per-band segment.
//! 3. **Refinement + θ** — the concatenated window is narrowed by
//!    range histograms until small, then a values-only
//!    `select_nth_unstable` pins θ, the r-th smallest value. θ is a
//!    rank statistic: it does not depend on banding or on the
//!    selection algorithm.
//! 4. **Deterministic scatter** — bands count `value < θ` and
//!    `value == θ` (ties) exactly; a serial prefix over the ascending
//!    bands turns the global tie budget `r − #less` into per-band
//!    quotas; the mark pass then writes `metric < θ` as a pure
//!    vectorizable compare and tops up ties **in ascending index
//!    order** from the compact segments.
//!
//! The produced mask is **bitwise identical** to the oracle's
//! (value, index) total order — all cells `< θ`, plus the
//! lowest-indexed cells `== θ` up to `r` — for every `r` and any
//! thread count, including heavy ties and mixed ±0.0 (the key map
//! sends −0.0 to +0.0, exactly the `partial_cmp == Equal` class the
//! oracle ties by index). Pinned by `tests/selection.rs`. NaN metrics
//! are not supported (the oracle's `unwrap_or(Equal)` order is not a
//! total order there either); the Wanda/OBS metrics are NaN-free by
//! construction.

use crate::engine;
use crate::pruning::metric::smallest_r_mask_into_with_idx;

/// Number of top-level histogram buckets: the top 16 bits of the key
/// (sign ⊕ exponent ⊕ 4 mantissa bits — 16 buckets per binade, so the
/// candidate window is a ~0.4% slice of a smooth metric distribution).
const TOP_BUCKETS: usize = 1 << 16;
const TOP_SHIFT: u32 = 48;
/// Range-histogram refinement buckets (narrowing works on a compact
/// window buffer, so a smaller table suffices).
const REF_BUCKETS: usize = 4096;
/// Below this window size the values-only `select_nth_unstable` is
/// cheaper than another refinement pass.
const WINDOW_MAX: usize = 4096;
/// Band-length floor (elements). Each band owns a `4 × TOP_BUCKETS`
/// u32 table (1 MiB) that is zeroed, filled and folded per call, so
/// bands must stay at least as large as the table or the fixed
/// per-band cost would grow with the thread count (`eng.chunk` alone
/// makes `threads × 4` bands): 2¹⁷ elements = 1 MiB of metric per
/// band caps histogram overhead at ~data size on any machine. The
/// floor only binds on many-core hosts — at the ≤2-thread C-mirror
/// provenance shapes `eng.chunk` already exceeds it.
const MIN_BAND: usize = 1 << 17;

/// Monotone `f64 → u64` key: `a < b  ⇔  sel_key(a) < sel_key(b)` for
/// all non-NaN values, with `-0.0` normalized onto `+0.0` so the tie
/// class at zero is a single key (the oracle's `partial_cmp` treats
/// them as equal and falls back to the index).
#[inline]
pub fn sel_key(v: f64) -> u64 {
    let b = (v + 0.0).to_bits();
    if b >> 63 == 1 {
        !b
    } else {
        b | (1u64 << 63)
    }
}

/// One band's gather segment plus its exact selection counts.
#[derive(Default)]
struct Seg {
    /// candidate values (bucket members), in ascending index order
    v: Vec<f64>,
    /// their flat metric indices (`u32`: selection inputs are layer
    /// windows, far below 2³² cells)
    i: Vec<u32>,
    /// cells in buckets strictly below the candidate bucket
    below: usize,
    /// cells with `value == θ` in this band
    tie: usize,
    /// how many of this band's ties the scatter marks (set serially)
    quota: usize,
}

/// Reusable workspace for [`smallest_r_mask_threshold_into`], carried
/// across the block walk like the metric/mask buffers (the engine's
/// no-hot-path-allocations convention). Also hosts the `idx` scratch
/// the *oracle* path threads through
/// [`smallest_r_mask_into_with_idx`](crate::pruning::metric::smallest_r_mask_into_with_idx),
/// so reference walks stop allocating `O(c·rest)` per block too.
pub struct SelectScratch {
    /// per-band histograms, `4 × TOP_BUCKETS` each (4-way split
    /// counters, folded into the leading quarter after the pass)
    hists: Vec<Vec<u32>>,
    segs: Vec<Seg>,
    window: Vec<f64>,
    refhist: Vec<u32>,
    /// index scratch for the oracle (`select_nth`) path
    pub idx: Vec<u32>,
}

impl SelectScratch {
    pub fn new() -> SelectScratch {
        SelectScratch {
            hists: Vec::new(),
            segs: Vec::new(),
            window: Vec::new(),
            refhist: Vec::new(),
            idx: Vec::new(),
        }
    }
}

impl Default for SelectScratch {
    fn default() -> SelectScratch {
        SelectScratch::new()
    }
}

/// Mask of the `r` smallest `(value, index)` cells of `metric` —
/// bitwise identical to
/// [`metric::smallest_r_mask_into`](crate::pruning::metric::smallest_r_mask_into)
/// for NaN-free input, for any `r` and any engine thread count, at
/// values-only streaming cost. The mask buffer is cleared and resized
/// in place; `scratch` persists across calls.
///
/// Windows below the band floor dispatch to the oracle directly: the
/// engine's fixed per-band table (a 1 MiB zero + fold) would outweigh
/// the data there, and the selected mask is identical by contract —
/// only the crossover changes, never a bit. (The engine body keeps its
/// own small-`n` correctness via the in-module unit tests, which call
/// it directly.)
pub fn smallest_r_mask_threshold_into(
    metric: &[f64],
    r: usize,
    mask: &mut Vec<bool>,
    scratch: &mut SelectScratch,
) {
    if metric.len() < MIN_BAND {
        smallest_r_mask_into_with_idx(metric, r, mask, &mut scratch.idx);
        return;
    }
    threshold_select_engine(metric, r, mask, scratch);
}

/// The engine proper (public entry above dispatches here for windows
/// at or over the band floor).
fn threshold_select_engine(
    metric: &[f64],
    r: usize,
    mask: &mut Vec<bool>,
    scratch: &mut SelectScratch,
) {
    let n = metric.len();
    let r = r.min(n);
    mask.clear();
    mask.resize(n, false);
    if r == 0 {
        return;
    }
    if r == n {
        mask.iter_mut().for_each(|m| *m = true);
        return;
    }

    let eng = engine::global();
    let band_len = eng.chunk(n).max(MIN_BAND.min(n));
    let n_bands = n.div_ceil(band_len);
    // grow-only: keep band buffers allocated across calls of any size
    if scratch.hists.len() < n_bands {
        scratch.hists.resize_with(n_bands, Vec::new);
    }
    if scratch.segs.len() < n_bands {
        scratch.segs.resize_with(n_bands, Seg::default);
    }
    let hists = &mut scratch.hists[..n_bands];
    let segs = &mut scratch.segs[..n_bands];

    // 1. band-parallel histogram over the key's top bits
    eng.for_each_band(hists, 1, |bi, slot| {
        let h = &mut slot[0];
        h.clear();
        h.resize(4 * TOP_BUCKETS, 0);
        let k0 = bi * band_len;
        let k1 = (k0 + band_len).min(n);
        let mut chunks = metric[k0..k1].chunks_exact(4);
        for c in &mut chunks {
            // 4-way split counters: same-bucket runs would serialize a
            // single table on store-forward latency
            h[(sel_key(c[0]) >> TOP_SHIFT) as usize] += 1;
            h[TOP_BUCKETS + (sel_key(c[1]) >> TOP_SHIFT) as usize] += 1;
            h[2 * TOP_BUCKETS + (sel_key(c[2]) >> TOP_SHIFT) as usize] += 1;
            h[3 * TOP_BUCKETS + (sel_key(c[3]) >> TOP_SHIFT) as usize] += 1;
        }
        for &v in chunks.remainder() {
            h[(sel_key(v) >> TOP_SHIFT) as usize] += 1;
        }
        for bkt in 0..TOP_BUCKETS {
            let ways = h[TOP_BUCKETS + bkt] + h[2 * TOP_BUCKETS + bkt] + h[3 * TOP_BUCKETS + bkt];
            h[bkt] += ways;
        }
    });

    // 2. the bucket where the cumulative count crosses r
    let mut cum = 0usize;
    let mut bucket = TOP_BUCKETS - 1;
    for bkt in 0..TOP_BUCKETS {
        let mut tot = 0usize;
        for h in hists.iter() {
            tot += h[bkt] as usize;
        }
        if cum + tot >= r {
            bucket = bkt;
            break;
        }
        cum += tot;
    }

    // band-parallel gather of the bucket members (value + index), plus
    // each band's exact below-bucket count
    {
        let hists_ref = &hists[..];
        eng.for_each_band(segs, 1, |bi, slot| {
            let seg = &mut slot[0];
            let k0 = bi * band_len;
            let k1 = (k0 + band_len).min(n);
            seg.v.clear();
            seg.i.clear();
            let cnt = hists_ref[bi][bucket] as usize;
            seg.v.reserve(cnt);
            seg.i.reserve(cnt);
            for (k, &v) in metric[k0..k1].iter().enumerate() {
                if (sel_key(v) >> TOP_SHIFT) as usize == bucket {
                    seg.v.push(v);
                    seg.i.push((k0 + k) as u32);
                }
            }
            seg.below = hists_ref[bi][..bucket].iter().map(|&c| c as usize).sum();
        });
    }

    // 3. refine the compact window, then select θ by value
    let window = &mut scratch.window;
    window.clear();
    for seg in segs.iter() {
        window.extend_from_slice(&seg.v);
    }
    let mut rloc = r - cum; // 1-based rank of θ inside the window
    while window.len() > WINDOW_MAX {
        let mut kmin = u64::MAX;
        let mut kmax = 0u64;
        for &v in window.iter() {
            let key = sel_key(v);
            kmin = kmin.min(key);
            kmax = kmax.max(key);
        }
        if kmin == kmax {
            break;
        }
        let span = (kmax - kmin) as u128 + 1;
        let rh = &mut scratch.refhist;
        rh.clear();
        rh.resize(REF_BUCKETS, 0);
        let rbucket =
            |v: f64| ((sel_key(v) - kmin) as u128 * REF_BUCKETS as u128 / span) as usize;
        for &v in window.iter() {
            rh[rbucket(v)] += 1;
        }
        let mut rcum = 0usize;
        let mut rb = REF_BUCKETS - 1;
        for (bkt, &cnt) in rh.iter().enumerate() {
            if rcum + cnt as usize >= rloc {
                rb = bkt;
                break;
            }
            rcum += cnt as usize;
        }
        window.retain(|&v| rbucket(v) == rb);
        rloc -= rcum;
    }
    let pos = rloc - 1;
    window.select_nth_unstable_by(pos, |a, b| sel_key(*a).cmp(&sel_key(*b)));
    let theta = window[pos];

    // 4. exact per-band (less, tie) counts from the segments, then the
    // serial quota prefix over ascending bands
    eng.for_each_band(segs, 1, |_bi, slot| {
        let seg = &mut slot[0];
        let mut less = seg.below;
        let mut tie = 0usize;
        for &v in &seg.v {
            if v < theta {
                less += 1;
            } else if v == theta {
                tie += 1;
            }
        }
        seg.below = less; // reuse the field: now "cells < θ" in-band
        seg.tie = tie;
    });
    let less_total: usize = segs.iter().map(|s| s.below).sum();
    let mut need = r - less_total;
    for seg in segs.iter_mut() {
        let q = need.min(seg.tie);
        seg.quota = q;
        need -= q;
    }
    debug_assert_eq!(need, 0, "tie budget must be coverable by θ cells");

    // band-parallel mark: a pure `< θ` compare per cell, then the tie
    // top-up walks this band's segment (indices ascending, so the
    // (value, index) order is free)
    {
        let segs_ref = &segs[..];
        eng.for_each_band(&mut mask[..], band_len, |bi, band| {
            let k0 = bi * band_len;
            for (m, &v) in band.iter_mut().zip(&metric[k0..k0 + band.len()]) {
                *m = v < theta;
            }
            let seg = &segs_ref[bi];
            let mut q = seg.quota;
            for (&v, &si) in seg.v.iter().zip(&seg.i) {
                if q == 0 {
                    break;
                }
                if v == theta {
                    band[si as usize - k0] = true;
                    q -= 1;
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::metric::smallest_r_mask_into;
    use crate::rng::Rng;

    // drive the ENGINE body directly (not the public small-n oracle
    // dispatch), so these sizes pin the engine's own arithmetic
    fn check(metric: &[f64], r: usize, scratch: &mut SelectScratch) {
        let mut oracle = Vec::new();
        smallest_r_mask_into(metric, r, &mut oracle);
        let mut got = Vec::new();
        threshold_select_engine(metric, r, &mut got, scratch);
        assert_eq!(oracle, got, "r={r} n={}", metric.len());
        let mut via_public = Vec::new();
        smallest_r_mask_threshold_into(metric, r, &mut via_public, scratch);
        assert_eq!(oracle, via_public, "public dispatch r={r}");
    }

    #[test]
    fn matches_oracle_on_random_metrics() {
        let mut rng = Rng::new(0x5E1);
        let mut scratch = SelectScratch::new();
        for _ in 0..20 {
            let n = 1 + rng.below(5000);
            let metric: Vec<f64> = (0..n).map(|_| rng.normal().abs()).collect();
            for r in [0, 1, n / 3, n.saturating_sub(1), n, n + 7] {
                check(&metric, r, &mut scratch);
            }
        }
    }

    #[test]
    fn matches_oracle_with_heavy_ties_and_signed_zero() {
        let mut rng = Rng::new(0x5E2);
        let mut scratch = SelectScratch::new();
        for _ in 0..20 {
            let n = 1 + rng.below(4000);
            let metric: Vec<f64> = (0..n)
                .map(|_| match rng.below(5) {
                    0 => 0.0,
                    1 => -0.0,
                    2 => 1.5,
                    3 => (rng.below(4) as f64) * 0.25,
                    _ => -((rng.below(3) + 1) as f64),
                })
                .collect();
            for r in [0, 1, n / 2, n.saturating_sub(1), n] {
                check(&metric, r, &mut scratch);
            }
        }
    }

    #[test]
    fn all_equal_selects_lowest_indices() {
        let metric = vec![3.25f64; 100];
        let mut scratch = SelectScratch::new();
        let mut mask = Vec::new();
        threshold_select_engine(&metric, 37, &mut mask, &mut scratch);
        for (i, &m) in mask.iter().enumerate() {
            assert_eq!(m, i < 37, "index {i}");
        }
    }

    #[test]
    fn scratch_reuse_across_disparate_sizes() {
        let mut rng = Rng::new(0x5E3);
        let mut scratch = SelectScratch::new();
        for &n in &[10usize, 5000, 3, 900, 1] {
            let metric: Vec<f64> = (0..n).map(|_| rng.normal().abs()).collect();
            check(&metric, n / 2, &mut scratch);
        }
    }
}
