//! Classic Optimal Brain Surgeon (paper §3.2 / App. F.2) — the
//! greedy one-weight-at-a-time ancestor of SparseGPT and Thanos,
//! included as a reference implementation and quality upper-bound probe
//! for small layers.
//!
//! Each step removes the single weight with the smallest saliency
//! `S_kq = ½·w_kq²/[H⁻¹]_qq` (eq. 4) and applies the exact update
//! `Δ_k: = −(w_kq/[H⁻¹]_qq)·H⁻¹_q:`. After a weight is removed its
//! column stays removable for other rows, so per-row "eliminated" sets
//! differ — the exact problem (§F.3) that makes naive OBS O(c·b³)-ish
//! and motivated SparseGPT's left-to-right order. Here we keep a
//! per-row eliminated set with per-row Hessian downdates; cost is
//! O(removals · b²), fine for the layer sizes the tests probe.

use crate::linalg::chol::chol_inverse;
use crate::linalg::{Mat, MatF64};
use crate::pruning::{CalibStats, PruneOpts, Pruned};
use anyhow::Result;

/// Greedy OBS to sparsity `p`. Exact but slow — reference only.
pub fn unstructured(w: &Mat, stats: &CalibStats, p: f64, opts: &PruneOpts) -> Result<Pruned> {
    assert!((0.0..1.0).contains(&p));
    let (c, b) = (w.rows, w.cols);
    let r = (p * (c * b) as f64).floor() as usize;
    let h = stats.hessian(opts.percdamp);
    let hinv0 = chol_inverse(&h)?;

    let mut wk = w.clone();
    let mut mask = vec![false; c * b];
    // per-row inverse Hessian over that row's remaining coordinates
    let mut hinvs: Vec<MatF64> = vec![hinv0; c];
    let mut removed = 0usize;
    while removed < r {
        // global best (row, col) by saliency
        let mut best = (f64::INFINITY, 0usize, 0usize);
        for i in 0..c {
            let hi = &hinvs[i];
            for j in 0..b {
                if mask[i * b + j] {
                    continue;
                }
                let d = hi.at(j, j);
                let s = 0.5 * (wk.at(i, j) as f64).powi(2) / d;
                if s < best.0 {
                    best = (s, i, j);
                }
            }
        }
        let (_, i, j) = best;
        let hi = hinvs[i].clone();
        let d = hi.at(j, j);
        let coef = wk.at(i, j) as f64 / d;
        // exact OBS row update over remaining coordinates
        for t in 0..b {
            if !mask[i * b + t] {
                let v = wk.at(i, t) as f64 - coef * hi.at(j, t);
                *wk.at_mut(i, t) = v as f32;
            }
        }
        *wk.at_mut(i, j) = 0.0;
        mask[i * b + j] = true;
        // downdate this row's inverse Hessian: eliminate coordinate j
        let hj: Vec<f64> = hi.row(j).to_vec();
        let target = &mut hinvs[i];
        for rr in 0..b {
            if mask[i * b + rr] {
                continue;
            }
            let f = target.at(rr, j) / d;
            if f == 0.0 {
                continue;
            }
            let row = target.row_mut(rr);
            for (t, &hjt) in hj.iter().enumerate() {
                if !mask[i * b + t] {
                    row[t] -= f * hjt;
                }
            }
        }
        removed += 1;
    }
    Ok(Pruned { w: wk, mask })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::recon_loss;
    use crate::pruning::testutil::setup;

    #[test]
    fn obs_hits_exact_count() {
        let (w, stats, _) = setup(6, 8, 24, 50);
        let pruned = unstructured(&w, &stats, 0.5, &PruneOpts::default()).unwrap();
        let zeros = pruned.w.data.iter().filter(|&&v| v == 0.0).count();
        assert_eq!(zeros, 24);
    }

    #[test]
    fn obs_beats_magnitude_and_wanda() {
        let mut wins = 0;
        for seed in 0..4 {
            let (w, stats, x) = setup(8, 10, 40, 60 + seed);
            let obs = unstructured(&w, &stats, 0.4, &PruneOpts::default()).unwrap();
            let wa = crate::pruning::wanda::unstructured(&w, &stats, 0.4);
            if recon_loss(&obs.w, &w, &x) < recon_loss(&wa.w, &w, &x) {
                wins += 1;
            }
        }
        assert!(wins >= 3, "obs won {wins}/4");
    }

    #[test]
    fn obs_single_removal_matches_closed_form() {
        // one removal == eq. (4): pick argmin saliency, apply δ*
        let (w, stats, _) = setup(3, 6, 20, 70);
        let p = 1.0 / 18.0 + 1e-9; // exactly one weight
        let pruned = unstructured(&w, &stats, p, &PruneOpts::default()).unwrap();
        let zeros = pruned.w.data.iter().filter(|&&v| v == 0.0).count();
        assert_eq!(zeros, 1);
        // the removed weight is the global saliency argmin
        let h = stats.hessian(crate::pruning::PERCDAMP);
        let hinv = chol_inverse(&h).unwrap();
        let mut best = (f64::INFINITY, 0, 0);
        for i in 0..3 {
            for j in 0..6 {
                let s = 0.5 * (w.at(i, j) as f64).powi(2) / hinv.at(j, j);
                if s < best.0 {
                    best = (s, i, j);
                }
            }
        }
        let k = pruned.mask.iter().position(|&m| m).unwrap();
        assert_eq!((k / 6, k % 6), (best.1, best.2));
    }
}
