//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute
//! from the request path.
//!
//! The bridge follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Executables are compiled lazily and cached for the lifetime of the
//! [`Runtime`]; all artifact metadata (argument shapes/dtypes, layer
//! shapes, the flat-parameter layout) comes from `manifest.json`
//! written by `python/compile/aot.py`.

use crate::jsonutil::Json;
use crate::linalg::Mat;
use crate::trace::{self, clock};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Duration;

/// Element type of an executable argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// Declared argument of an AOT executable.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl ArgSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact entry from the manifest.
#[derive(Clone, Debug)]
pub struct ExeEntry {
    pub file: String,
    pub args: Vec<ArgSpec>,
}

/// Flat-parameter layout row.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl ParamEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Per-model manifest section.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub config: crate::config::ModelConfig,
    pub flat_size: usize,
    pub block_flat_size: usize,
    pub layout: Vec<ParamEntry>,
}

impl ModelManifest {
    pub fn entry(&self, name: &str) -> Result<&ParamEntry> {
        self.layout
            .iter()
            .find(|e| e.name == name)
            .with_context(|| format!("no param '{name}' in layout"))
    }

    /// Offset + size of the contiguous flat slice holding block `l`.
    pub fn block_span(&self, l: usize) -> Result<(usize, usize)> {
        let first = self.entry(&format!("blocks.{l}.ln1"))?;
        Ok((first.offset, self.block_flat_size))
    }
}

/// The manifest: constants + models + executables.
#[derive(Debug)]
pub struct Manifest {
    pub nb_calib: usize,
    pub nb_eval: usize,
    pub train_bs: usize,
    pub models: HashMap<String, ModelManifest>,
    pub executables: HashMap<String, ExeEntry>,
}

impl Manifest {
    pub fn parse(j: &Json) -> Result<Manifest> {
        let consts = j.get("constants")?;
        let mut models = HashMap::new();
        for (name, mj) in j.get("models")?.as_obj()? {
            let cfgj = mj.get("config")?;
            let cfg = crate::config::ModelConfig {
                name: name.clone(),
                vocab: cfgj.get("vocab")?.as_usize()?,
                d_model: cfgj.get("d_model")?.as_usize()?,
                n_layers: cfgj.get("n_layers")?.as_usize()?,
                n_heads: cfgj.get("n_heads")?.as_usize()?,
                d_ff: cfgj.get("d_ff")?.as_usize()?,
                seq_len: cfgj.get("seq_len")?.as_usize()?,
            };
            let layout = mj
                .get("param_layout")?
                .as_arr()?
                .iter()
                .map(|e| {
                    Ok(ParamEntry {
                        name: e.get("name")?.as_str()?.to_string(),
                        offset: e.get("offset")?.as_usize()?,
                        shape: e
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<_>>()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelManifest {
                    config: cfg,
                    flat_size: mj.get("flat_size")?.as_usize()?,
                    block_flat_size: mj.get("block_flat_size")?.as_usize()?,
                    layout,
                },
            );
        }
        let mut executables = HashMap::new();
        for (name, ej) in j.get("executables")?.as_obj()? {
            let args = ej
                .get("args")?
                .as_arr()?
                .iter()
                .map(|a| {
                    let dtype = match a.get("dtype")?.as_str()? {
                        "i32" => Dtype::I32,
                        _ => Dtype::F32,
                    };
                    Ok(ArgSpec {
                        shape: a
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<_>>()?,
                        dtype,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            executables.insert(
                name.clone(),
                ExeEntry { file: ej.get("file")?.as_str()?.to_string(), args },
            );
        }
        Ok(Manifest {
            nb_calib: consts.get("nb_calib")?.as_usize()?,
            nb_eval: consts.get("nb_eval")?.as_usize()?,
            train_bs: consts.get("train_bs")?.as_usize()?,
            models,
            executables,
        })
    }
}

/// Cache row: the compiled executable plus its pre-interned metric
/// keys, so the steady-state `exec` path records timing without
/// formatting or allocating a key per call.
#[derive(Clone)]
struct CachedExe {
    exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
    k_time: &'static str,
    k_count: &'static str,
}

/// The runtime: PJRT CPU client + lazily-compiled executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, CachedExe>>,
    pub metrics: crate::metrics::Metrics,
}

impl Runtime {
    /// Load the manifest and create the PJRT CPU client.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let j = Json::parse_file(&mpath)
            .with_context(|| "artifacts missing — run `make artifacts` first")?;
        let manifest = Manifest::parse(&j)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            dir,
            cache: Mutex::new(HashMap::new()),
            metrics: crate::metrics::Metrics::new(),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.manifest.models.get(name).with_context(|| {
            format!("model '{name}' not in manifest (run `make artifacts MODELS=...,{name}`)")
        })
    }

    pub fn has_exe(&self, name: &str) -> bool {
        self.manifest.executables.contains_key(name)
    }

    fn compile(&self, name: &str) -> Result<CachedExe> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let entry = self
            .manifest
            .executables
            .get(name)
            .with_context(|| format!("unknown executable '{name}'"))?;
        let path = self.dir.join(&entry.file);
        let _span = trace::span("runtime.compile");
        let t0 = clock::now_nanos();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let dt = clock::now_nanos().saturating_sub(t0);
        self.metrics
            .add_time_static(crate::metrics::intern("runtime.compile"), Duration::from_nanos(dt));
        self.metrics
            .incr_static(crate::metrics::intern("runtime.compiled_executables"), 1);
        let cached = CachedExe {
            exe: std::sync::Arc::new(exe),
            k_time: crate::metrics::intern(&format!("exec.{name}")),
            k_count: crate::metrics::intern(&format!("exec_count.{name}")),
        };
        self.cache.lock().unwrap().insert(name.to_string(), cached.clone());
        Ok(cached)
    }

    /// Execute `name` with the given inputs; returns the decomposed
    /// output tuple (every AOT graph returns a tuple).
    pub fn exec(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let entry = self
            .manifest
            .executables
            .get(name)
            .with_context(|| format!("unknown executable '{name}'"))?;
        if inputs.len() != entry.args.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                entry.args.len(),
                inputs.len()
            );
        }
        for (i, (lit, spec)) in inputs.iter().zip(&entry.args).enumerate() {
            let n = lit.element_count();
            if n != spec.numel() {
                bail!(
                    "{name}: input {i} has {n} elements, expected {:?}",
                    spec.shape
                );
            }
        }
        let cached = self.compile(name)?;
        let _span = trace::span("runtime.exec");
        let t0 = clock::now_nanos();
        let result = cached.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let dt = clock::now_nanos().saturating_sub(t0);
        self.metrics.add_time_static(cached.k_time, Duration::from_nanos(dt));
        self.metrics.incr_static(cached.k_count, 1);
        result.to_tuple().map_err(Into::into)
    }
}

// ---------------------------------------------------------------------------
// literal marshalling helpers
// ---------------------------------------------------------------------------

/// f32 literal with the given dims.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    assert_eq!(data.len(), n, "lit_f32 shape mismatch");
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims_i64).map_err(Into::into)
}

/// i32 literal with the given dims.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    assert_eq!(data.len(), n, "lit_i32 shape mismatch");
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims_i64).map_err(Into::into)
}

pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract an f32 literal to a Vec.
pub fn to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(Into::into)
}

/// Extract to a [`Mat`] with the given dims.
pub fn to_mat(l: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
    let v = to_vec_f32(l)?;
    if v.len() != rows * cols {
        bail!("literal has {} elements, expected {rows}x{cols}", v.len());
    }
    Ok(Mat::from_vec(rows, cols, v))
}

pub fn mat_lit(m: &Mat) -> Result<xla::Literal> {
    lit_f32(&m.data, &[m.rows, m.cols])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_minimal() {
        let src = r#"{
          "constants": {"nb_calib": 8, "nb_eval": 8, "train_bs": 8},
          "models": {"tiny": {
            "config": {"vocab":512,"d_model":128,"n_layers":2,"n_heads":4,"d_ff":512,"seq_len":128},
            "flat_size": 100, "block_flat_size": 40,
            "param_layout": [{"name":"emb","offset":0,"shape":[512,128]},
                             {"name":"blocks.0.ln1","offset":60,"shape":[128]}]
          }},
          "executables": {"embed_tiny": {"file": "embed_tiny.hlo.txt",
            "args": [{"shape":[100],"dtype":"f32"},{"shape":[8,128],"dtype":"i32"}]}}
        }"#;
        let m = Manifest::parse(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(m.nb_calib, 8);
        let tiny = &m.models["tiny"];
        assert_eq!(tiny.config.d_model, 128);
        assert_eq!(tiny.entry("emb").unwrap().numel(), 512 * 128);
        assert_eq!(tiny.block_span(0).unwrap(), (60, 40));
        let e = &m.executables["embed_tiny"];
        assert_eq!(e.args[1].dtype, Dtype::I32);
        assert_eq!(e.args[1].numel(), 8 * 128);
    }
}
