//! Deterministic random-number generation.
//!
//! The offline vendor set has no `rand`, so experiments use a
//! from-scratch xoshiro256** generator (Blackman & Vigna). Everything
//! downstream (weight init, corpus generation, calibration sampling,
//! property tests) derives from an explicit seed, making every
//! experiment in EXPERIMENTS.md bit-reproducible.

/// xoshiro256** PRNG. Not cryptographic; excellent statistical quality
/// for simulation workloads and fast enough for corpus generation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed. The seed is expanded with
    /// SplitMix64 so that small/sequential seeds still produce
    /// well-distributed initial states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        // All-zero state is the one invalid state; seed expansion of any
        // u64 cannot produce it, but guard anyway.
        let s = if s == [0; 4] { [1, 2, 3, 4] } else { s };
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Uniform in `[0, 1)`, 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in `[0, n)`. Uses Lemire-style rejection to avoid
    /// modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box–Muller (no cached second value: simpler,
    /// still fast enough for init paths).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Normal with mean/std, as f32 (weight init).
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        (self.normal() as f32) * std + mean
    }

    /// Fill a slice with iid N(0, std²) values.
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for v in buf.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Sample from Zipf(s) over `{0, .., n-1}` using a precomputed CDF.
    /// Used by the synthetic-corpus generator for realistic vocabulary
    /// frequency (heavy head, long tail — as in natural text).
    pub fn zipf(&mut self, cdf: &[f64]) -> usize {
        let u = self.uniform();
        // binary search for the first cdf entry >= u
        match cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cdf.len() - 1),
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Build a Zipf(s) CDF over `n` items: P(i) ∝ (i+1)^-s.
pub fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut w: Vec<f64> = (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(s)).collect();
    let total: f64 = w.iter().sum();
    let mut acc = 0.0;
    for v in w.iter_mut() {
        acc += *v / total;
        *v = acc;
    }
    // guard against fp drift: the last entry must be exactly 1.0
    if let Some(last) = w.last_mut() {
        *last = 1.0;
    }
    w
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_head_heavier_than_tail() {
        let cdf = zipf_cdf(100, 1.1);
        let mut r = Rng::new(3);
        let mut head = 0;
        for _ in 0..10_000 {
            if r.zipf(&cdf) < 10 {
                head += 1;
            }
        }
        assert!(head > 5_000, "head {head}");
    }

    #[test]
    fn zipf_cdf_monotone_ending_at_one() {
        let cdf = zipf_cdf(57, 1.3);
        for w in cdf.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert_eq!(*cdf.last().unwrap(), 1.0);
    }

    #[test]
    fn choose_k_distinct_and_in_range() {
        let mut r = Rng::new(5);
        for _ in 0..50 {
            let ks = r.choose_k(20, 8);
            assert_eq!(ks.len(), 8);
            let mut sorted = ks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8);
            assert!(ks.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_decorrelated() {
        let mut base = Rng::new(1234);
        let mut a = base.fork();
        let mut b = base.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
