//! Synthetic corpus + task generation — the stand-in for C4 (training /
//! calibration) and WikiText-2 (perplexity), and for the seven
//! EleutherAI zero-shot tasks (option-scored multiple choice).
//!
//! ## Why this design
//!
//! The pruning methods only ever see the data through (a) the model's
//! training distribution and (b) per-layer calibration activations.
//! What the paper's experiments need from the corpus is *structure*:
//! text whose next-token distribution a small transformer can learn
//! well enough that damaging its weights measurably damages perplexity,
//! with correlated features (so Hessians are anisotropic and
//! update-based methods beat metric-only ones — the effect Tables 2–3
//! measure). A hidden-state Markov grammar over a Zipfian vocabulary
//! provides exactly that: learnable long-range regime structure +
//! heavy-tailed token frequencies, all seeded and offline.

use crate::rng::{zipf_cdf, Rng};

/// Token id type (vocab is small; u16 keeps corpora compact).
pub type Token = u16;

/// Parameters of the hierarchical Markov grammar.
#[derive(Clone, Debug)]
pub struct GrammarConfig {
    pub vocab: usize,
    /// number of hidden regimes
    pub states: usize,
    /// probability of staying in the current regime each step
    pub stickiness: f64,
    /// Zipf exponent of per-regime emission distributions
    pub zipf_s: f64,
    /// per-regime vocabulary slice size
    pub regime_vocab: usize,
    pub seed: u64,
}

impl Default for GrammarConfig {
    fn default() -> Self {
        GrammarConfig {
            vocab: 512,
            states: 8,
            stickiness: 0.92,
            zipf_s: 1.05,
            regime_vocab: 96,
            seed: 1234,
        }
    }
}

/// The generator: hidden regime chain; each regime emits from a
/// Zipf-weighted window of the vocabulary plus a bigram bias (each
/// token deterministically boosts a successor token, giving the model
/// an easily-learnable local signal on top of the regime signal).
pub struct Grammar {
    cfg: GrammarConfig,
    /// per-regime emission CDF over its vocab window
    cdfs: Vec<Vec<f64>>,
    /// per-regime vocab window start
    window: Vec<usize>,
    /// bigram successor map: token t is followed by succ[t] w.p. bigram_p
    succ: Vec<Token>,
    bigram_p: f64,
}

impl Grammar {
    pub fn new(cfg: GrammarConfig) -> Self {
        let mut r = Rng::new(cfg.seed);
        let mut cdfs = Vec::with_capacity(cfg.states);
        let mut window = Vec::with_capacity(cfg.states);
        for _ in 0..cfg.states {
            window.push(r.below(cfg.vocab.saturating_sub(cfg.regime_vocab).max(1)));
            cdfs.push(zipf_cdf(cfg.regime_vocab.min(cfg.vocab), cfg.zipf_s));
        }
        let succ: Vec<Token> = (0..cfg.vocab).map(|_| r.below(cfg.vocab) as Token).collect();
        Grammar { cfg, cdfs, window, succ, bigram_p: 0.35 }
    }

    pub fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    /// Generate `n` tokens into a fresh Vec, starting from a random
    /// regime, using the supplied RNG (callers fork per split).
    pub fn generate(&self, n: usize, r: &mut Rng) -> Vec<Token> {
        let mut out = Vec::with_capacity(n);
        let mut state = r.below(self.cfg.states);
        let mut prev: Option<Token> = None;
        for _ in 0..n {
            // regime transition
            if r.uniform() >= self.cfg.stickiness {
                state = r.below(self.cfg.states);
            }
            // emission: bigram bias or regime Zipf draw
            let tok = match prev {
                Some(p) if r.uniform() < self.bigram_p => self.succ[p as usize],
                _ => {
                    let k = r.zipf(&self.cdfs[state]);
                    ((self.window[state] + k) % self.cfg.vocab) as Token
                }
            };
            out.push(tok);
            prev = Some(tok);
        }
        out
    }

    /// Probability-weighted "plausible continuation" of a context's last
    /// token under the bigram channel (used to build zero-shot answers).
    pub fn likely_next(&self, t: Token) -> Token {
        self.succ[t as usize]
    }
}

/// A dataset split packaged as fixed-length sequences.
#[derive(Clone, Debug)]
pub struct Sequences {
    pub seq_len: usize,
    /// row-major `[n_seqs × seq_len]`
    pub tokens: Vec<Token>,
}

impl Sequences {
    pub fn n_seqs(&self) -> usize {
        self.tokens.len() / self.seq_len
    }

    pub fn seq(&self, i: usize) -> &[Token] {
        &self.tokens[i * self.seq_len..(i + 1) * self.seq_len]
    }
}

/// The three splits every experiment consumes.
pub struct Corpus {
    pub grammar: Grammar,
    pub train: Sequences,
    pub calib: Sequences,
    pub eval: Sequences,
}

/// Corpus sizing.
#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub grammar: GrammarConfig,
    pub seq_len: usize,
    pub train_seqs: usize,
    /// the paper uses 128 calibration sequences from C4
    pub calib_seqs: usize,
    pub eval_seqs: usize,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            grammar: GrammarConfig::default(),
            seq_len: 128,
            train_seqs: 2048,
            calib_seqs: 128,
            eval_seqs: 64,
        }
    }
}

impl Corpus {
    pub fn build(cfg: &CorpusConfig) -> Corpus {
        let grammar = Grammar::new(cfg.grammar.clone());
        // independent RNG streams per split so resizing one split never
        // perturbs the others (important for paper-style ablations)
        let mut train_rng = Rng::new(cfg.grammar.seed ^ 0xA11CE);
        let mut calib_rng = Rng::new(cfg.grammar.seed ^ 0xB0B);
        let mut eval_rng = Rng::new(cfg.grammar.seed ^ 0xCAFE);
        let gen = |g: &Grammar, n_seqs: usize, sl: usize, r: &mut Rng| Sequences {
            seq_len: sl,
            tokens: g.generate(n_seqs * sl, r),
        };
        Corpus {
            train: gen(&grammar, cfg.train_seqs, cfg.seq_len, &mut train_rng),
            calib: gen(&grammar, cfg.calib_seqs, cfg.seq_len, &mut calib_rng),
            eval: gen(&grammar, cfg.eval_seqs, cfg.seq_len, &mut eval_rng),
            grammar,
        }
    }
}

// ---------------------------------------------------------------------------
// Zero-shot tasks
// ---------------------------------------------------------------------------

/// A multiple-choice instance: a context and `options`, one of which
/// (`answer`) is the grammar-consistent continuation. Evaluation scores
/// each option by pruned-model log-likelihood — the same readout as
/// ARC / HellaSwag / PiQA in the EleutherAI harness.
#[derive(Clone, Debug)]
pub struct TaskInstance {
    pub context: Vec<Token>,
    pub options: Vec<Vec<Token>>,
    pub answer: usize,
}

/// One of the seven synthetic zero-shot tasks. Tasks differ in context
/// length, number of options, continuation length and distractor
/// construction — mirroring how the real benchmarks differ in difficulty.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// short context, 2 options, 1-token continuation (BoolQ-like binary)
    BinaryNext,
    /// medium context, 4 options, 1-token continuation (ARC-easy-like)
    Choice4Next,
    /// medium context, 4 options, hard distractors from same regime (ARC-challenge-like)
    Choice4Hard,
    /// long context, 4 options, 8-token continuations (HellaSwag-like)
    Continuation8,
    /// 2 options, continuation must match context regime (PiQA-like)
    RegimeMatch,
    /// 4 options, bigram-successor identification (OBQA-like)
    BigramProbe,
    /// 2 options, longer continuation pair (WinoGrande-like)
    PairCoherence,
}

pub const ALL_TASKS: [Task; 7] = [
    Task::BinaryNext,
    Task::Choice4Next,
    Task::Choice4Hard,
    Task::Continuation8,
    Task::RegimeMatch,
    Task::BigramProbe,
    Task::PairCoherence,
];

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::BinaryNext => "BinaryNext",
            Task::Choice4Next => "Choice4Next",
            Task::Choice4Hard => "Choice4Hard",
            Task::Continuation8 => "Continuation8",
            Task::RegimeMatch => "RegimeMatch",
            Task::BigramProbe => "BigramProbe",
            Task::PairCoherence => "PairCoherence",
        }
    }

    fn params(&self) -> (usize, usize, usize) {
        // (context_len, n_options, cont_len)
        match self {
            Task::BinaryNext => (24, 2, 1),
            Task::Choice4Next => (32, 4, 1),
            Task::Choice4Hard => (32, 4, 1),
            Task::Continuation8 => (48, 4, 8),
            Task::RegimeMatch => (32, 2, 4),
            Task::BigramProbe => (16, 4, 1),
            Task::PairCoherence => (40, 2, 6),
        }
    }

    /// Build `n` instances of this task from the grammar.
    pub fn build(&self, grammar: &Grammar, n: usize, seed: u64) -> Vec<TaskInstance> {
        let (ctx_len, n_opts, cont_len) = self.params();
        let mut r = Rng::new(seed ^ (*self as u64) << 32 ^ 0x7A5C);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            // context + true continuation generated as one stream so the
            // continuation is genuinely grammar-consistent
            let stream = grammar.generate(ctx_len + cont_len, &mut r);
            let context = stream[..ctx_len].to_vec();
            let truth = stream[ctx_len..].to_vec();
            let mut options = Vec::with_capacity(n_opts);
            let answer = r.below(n_opts);
            for k in 0..n_opts {
                if k == answer {
                    options.push(truth.clone());
                } else {
                    options.push(self.distractor(grammar, &context, cont_len, &mut r));
                }
            }
            out.push(TaskInstance { context, options, answer });
        }
        out
    }

    fn distractor(
        &self,
        grammar: &Grammar,
        context: &[Token],
        cont_len: usize,
        r: &mut Rng,
    ) -> Vec<Token> {
        match self {
            // hard distractors: plausible-looking tokens from the grammar
            // but generated from an unrelated stream (regime mismatch)
            Task::Choice4Hard | Task::RegimeMatch | Task::PairCoherence => {
                grammar.generate(cont_len, r)
            }
            // bigram probe: distractors are near-miss successor tokens
            Task::BigramProbe => {
                let last = *context.last().unwrap();
                let shift = 1 + r.below(grammar.vocab() - 1);
                vec![((grammar.likely_next(last) as usize + shift) % grammar.vocab()) as Token]
            }
            // easy distractors: uniform random tokens
            _ => (0..cont_len)
                .map(|_| r.below(grammar.vocab()) as Token)
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic() {
        let cfg = CorpusConfig { train_seqs: 4, calib_seqs: 2, eval_seqs: 2, ..Default::default() };
        let a = Corpus::build(&cfg);
        let b = Corpus::build(&cfg);
        assert_eq!(a.train.tokens, b.train.tokens);
        assert_eq!(a.calib.tokens, b.calib.tokens);
        assert_eq!(a.eval.tokens, b.eval.tokens);
    }

    #[test]
    fn splits_are_distinct() {
        let cfg = CorpusConfig { train_seqs: 2, calib_seqs: 2, eval_seqs: 2, ..Default::default() };
        let c = Corpus::build(&cfg);
        assert_ne!(c.train.tokens[..64], c.calib.tokens[..64]);
        assert_ne!(c.calib.tokens[..64], c.eval.tokens[..64]);
    }

    #[test]
    fn tokens_in_vocab() {
        let cfg = CorpusConfig { train_seqs: 8, ..Default::default() };
        let c = Corpus::build(&cfg);
        let v = c.grammar.vocab() as Token;
        assert!(c.train.tokens.iter().all(|&t| t < v));
    }

    #[test]
    fn corpus_has_low_entropy_structure() {
        // the bigram channel must make P(succ[t] | t) clearly above the
        // uniform baseline — that's what the LM learns
        let cfg = CorpusConfig { train_seqs: 64, ..Default::default() };
        let c = Corpus::build(&cfg);
        let toks = &c.train.tokens;
        let mut hits = 0usize;
        let mut total = 0usize;
        for w in toks.windows(2) {
            if c.grammar.likely_next(w[0]) == w[1] {
                hits += 1;
            }
            total += 1;
        }
        let rate = hits as f64 / total as f64;
        assert!(rate > 0.2, "bigram rate {rate}");
    }

    #[test]
    fn sequences_indexing() {
        let s = Sequences { seq_len: 4, tokens: (0..12).map(|t| t as Token).collect() };
        assert_eq!(s.n_seqs(), 3);
        assert_eq!(s.seq(1), &[4, 5, 6, 7]);
    }

    #[test]
    fn tasks_have_correct_shapes_and_valid_answers() {
        let g = Grammar::new(GrammarConfig::default());
        for task in ALL_TASKS {
            let instances = task.build(&g, 10, 42);
            assert_eq!(instances.len(), 10);
            let (ctx_len, n_opts, cont_len) = task.params();
            for inst in &instances {
                assert_eq!(inst.context.len(), ctx_len);
                assert_eq!(inst.options.len(), n_opts);
                assert!(inst.answer < n_opts);
                for o in &inst.options {
                    assert_eq!(o.len(), cont_len);
                }
            }
        }
    }

    #[test]
    fn task_answers_not_always_same_position() {
        let g = Grammar::new(GrammarConfig::default());
        let instances = Task::Choice4Next.build(&g, 40, 7);
        let firsts = instances.iter().filter(|i| i.answer == 0).count();
        assert!(firsts < 30, "answer position not randomized: {firsts}/40");
    }

    #[test]
    fn tasks_are_deterministic_per_seed() {
        let g = Grammar::new(GrammarConfig::default());
        let a = Task::Continuation8.build(&g, 5, 9);
        let b = Task::Continuation8.build(&g, 5, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.options, y.options);
            assert_eq!(x.answer, y.answer);
        }
    }
}
