//! Mini property-testing framework (no `proptest` crate offline).
//!
//! Provides seeded generators and a `check` runner that reports the
//! failing case's seed + a human description so failures reproduce
//! deterministically. Used by the pruning test-suite for invariants
//! like "every method hits the requested sparsity exactly" and "Thanos
//! never increases reconstruction loss vs. no-update masking".

use crate::linalg::Mat;
use crate::rng::Rng;

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 32, seed: 0xC0FFEE }
    }
}

/// Run `prop` over `cfg.cases` generated inputs. `gen` receives a fresh
/// forked RNG per case; `prop` returns `Err(description)` on violation.
/// Panics with the case index + seed on the first failure.
pub fn check<T: std::fmt::Debug>(
    cfg: &Config,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut root = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut r = root.fork();
        let input = generate(&mut r);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed (case {case}, root seed {:#x}): {msg}\ninput: {input:?}",
                cfg.seed
            );
        }
    }
}

/// Generator helpers ------------------------------------------------------

/// Random dims in `[lo, hi]`.
pub fn dim(r: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + r.below(hi - lo + 1)
}

/// Random dense matrix with N(0,1) entries.
pub fn mat(r: &mut Rng, rows: usize, cols: usize) -> Mat {
    let mut m = Mat::zeros(rows, cols);
    r.fill_normal(&mut m.data, 1.0);
    m
}

/// Random matrix with heavy-tailed entries (mixture of N(0,1) and
/// N(0,10) outliers) — models real LLM weight/activation statistics
/// where outlier channels drive the pruning-method gap.
pub fn mat_heavy(r: &mut Rng, rows: usize, cols: usize, outlier_frac: f64) -> Mat {
    let mut m = Mat::zeros(rows, cols);
    for v in m.data.iter_mut() {
        let std = if r.uniform() < outlier_frac { 10.0 } else { 1.0 };
        *v = r.normal_f32(0.0, std);
    }
    m
}

/// Random sparsity ratio in `[0.1, 0.9]` quantized to 1/16ths so exact
/// counts are reproducible in failure messages.
pub fn sparsity(r: &mut Rng) -> f64 {
    let q = 2 + r.below(13); // 2..=14 of 16
    q as f64 / 16.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check(
            &Config { cases: 10, seed: 1 },
            |r| dim(r, 1, 5),
            |&n| {
                if n >= 1 && n <= 5 {
                    Ok(())
                } else {
                    Err(format!("dim out of range: {n}"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_reports_failures() {
        check(
            &Config { cases: 10, seed: 2 },
            |r| dim(r, 1, 5),
            |&n| if n < 3 { Ok(()) } else { Err("too big".into()) },
        );
    }

    #[test]
    fn generators_are_reproducible() {
        let mk = || {
            let mut root = Rng::new(77);
            let mut r = root.fork();
            mat(&mut r, 4, 4).data
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn heavy_tail_has_outliers() {
        let mut r = Rng::new(5);
        let m = mat_heavy(&mut r, 40, 40, 0.05);
        let big = m.data.iter().filter(|v| v.abs() > 5.0).count();
        assert!(big > 10, "expected heavy tail, got {big} large entries");
    }
}
