//! Append-only, fsynced prune journal.
//!
//! Record framing on disk: `u32 LE payload length | u64 LE CRC-64/XZ of
//! payload | payload` (UTF-8 JSON). Appends are fsynced and wrapped in
//! the deterministic retry policy; a failed append rolls the file back
//! to its pre-record length first, so a retried write never leaves a
//! torn record in the middle of the stream. Replay tolerates a torn
//! tail — the suffix after the last complete, checksum-valid record —
//! by truncating it away, which is exactly the state a crash mid-append
//! leaves behind.

use std::fs::{File, OpenOptions};
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::crc::crc64;
use super::faults::{self, RetryPolicy};

const MAX_RECORD_LEN: u32 = 64 * 1024 * 1024;

/// An open journal file positioned at its end, ready to append.
pub struct Journal {
    path: PathBuf,
    file: File,
    len: u64,
}

impl Journal {
    /// Create a fresh journal, truncating any existing file.
    pub fn create(path: impl AsRef<Path>) -> crate::Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let file = OpenOptions::new().create(true).write(true).truncate(true).open(&path)?;
        file.sync_all()?;
        Ok(Self { path, file, len: 0 })
    }

    /// Open an existing journal for resumption: replay every complete
    /// record, truncate any torn tail, and return the journal positioned
    /// to append plus the replayed payloads in order.
    pub fn open_resume(path: impl AsRef<Path>) -> crate::Result<(Self, Vec<String>)> {
        let path = path.as_ref().to_path_buf();
        let mut file = OpenOptions::new().read(true).write(true).open(&path)?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        let (records, valid_len) = replay(&bytes)?;
        if valid_len as u64 != bytes.len() as u64 {
            file.set_len(valid_len as u64)?;
            file.sync_all()?;
        }
        file.seek(SeekFrom::Start(valid_len as u64))?;
        Ok((Self { path, file, len: valid_len as u64 }, records))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Roll the journal back to `len` bytes (a record boundary computed
    /// by the caller) — used on resume to drop the records of a block
    /// that never completed.
    pub fn truncate_to(&mut self, len: u64) -> crate::Result<()> {
        anyhow::ensure!(
            len <= self.len,
            "cannot truncate journal forward ({} -> {len} bytes)",
            self.len
        );
        self.file.set_len(len)?;
        self.file.sync_all()?;
        self.file.seek(SeekFrom::Start(len))?;
        self.len = len;
        Ok(())
    }

    /// Append one record and fsync it. Transient faults are retried with
    /// the default deterministic backoff; before each retry the file is
    /// rolled back to its pre-record length, so the stream never carries
    /// a torn interior record.
    pub fn append(&mut self, payload: &str) -> crate::Result<()> {
        let body = payload.as_bytes();
        anyhow::ensure!(
            body.len() <= MAX_RECORD_LEN as usize,
            "journal record of {} bytes exceeds the {MAX_RECORD_LEN}-byte cap",
            body.len()
        );
        let mut frame = Vec::with_capacity(12 + body.len());
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc64(body).to_le_bytes());
        frame.extend_from_slice(body);

        let pre_len = self.len;
        let policy = RetryPolicy::default();
        let res = faults::with_retry(&policy, || {
            // Roll back any torn partial record from a previous attempt.
            self.file.set_len(pre_len)?;
            self.file.seek(SeekFrom::Start(pre_len))?;
            let wrote = match faults::write_action("journal.append")? {
                Some(n) => {
                    let n = n.min(frame.len());
                    self.file.write_all(&frame[..n])?;
                    // A truncated append is a torn record: surface it as a
                    // transient error so the retry path rolls it back.
                    return Err(io::Error::new(
                        io::ErrorKind::Interrupted,
                        "injected fault: truncated journal append",
                    ));
                }
                None => {
                    self.file.write_all(&frame)?;
                    frame.len()
                }
            };
            faults::point("journal.sync")?;
            self.file.sync_all()?;
            Ok(wrote)
        });
        match res {
            Ok(wrote) => {
                self.len = pre_len + wrote as u64;
                Ok(())
            }
            Err(e) => {
                // Best-effort rollback so a later append starts clean.
                let _ = self.file.set_len(pre_len);
                Err(anyhow::anyhow!("journal append to {} failed: {e}", self.path.display()))
            }
        }
    }
}

/// Decode `(records, valid_prefix_len)` from raw journal bytes. A torn
/// tail (incomplete frame, or a final frame whose CRC fails) is not an
/// error — it marks the end of the valid prefix. A CRC failure *followed
/// by more complete records* is corruption and errors out.
pub fn replay(bytes: &[u8]) -> crate::Result<(Vec<String>, usize)> {
    let mut records = Vec::new();
    let mut off = 0usize;
    while bytes.len() - off >= 12 {
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().expect("4-byte slice")) as usize;
        if len > MAX_RECORD_LEN as usize || len > bytes.len() - off - 12 {
            // Header or body incomplete / implausible: torn tail.
            break;
        }
        let crc = u64::from_le_bytes(bytes[off + 4..off + 12].try_into().expect("8-byte slice"));
        let body = &bytes[off + 12..off + 12 + len];
        if crc64(body) != crc {
            anyhow::ensure!(
                off + 12 + len == bytes.len(),
                "journal record at offset {off} fails its checksum but is not the final record: \
                 the journal is corrupt, not merely torn"
            );
            break;
        }
        let text = std::str::from_utf8(body)
            .map_err(|_| anyhow::anyhow!("journal record at offset {off} is not UTF-8"))?
            .to_string();
        records.push(text);
        off += 12 + len;
    }
    Ok((records, off))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmppath(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("thanos-journal-{tag}-{}.jnl", std::process::id()))
    }

    #[test]
    fn roundtrip_and_torn_tail() {
        let p = tmppath("roundtrip");
        let mut j = Journal::create(&p).unwrap();
        j.append("{\"layer\":0}").unwrap();
        j.append("{\"layer\":1}").unwrap();
        drop(j);

        // Simulate a crash mid-append: garbage tail after valid records.
        let mut bytes = std::fs::read(&p).unwrap();
        let clean_len = bytes.len();
        bytes.extend_from_slice(&[7u8; 5]);
        std::fs::write(&p, &bytes).unwrap();

        let (j, records) = Journal::open_resume(&p).unwrap();
        assert_eq!(records, vec!["{\"layer\":0}", "{\"layer\":1}"]);
        assert_eq!(std::fs::metadata(&p).unwrap().len(), clean_len as u64);
        drop(j);

        // Appending after resume continues the stream.
        let (mut j, _) = Journal::open_resume(&p).unwrap();
        j.append("{\"layer\":2}").unwrap();
        drop(j);
        let (_, records) = Journal::open_resume(&p).unwrap();
        assert_eq!(records.len(), 3);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn interior_corruption_is_an_error() {
        let p = tmppath("interior");
        let mut j = Journal::create(&p).unwrap();
        j.append("{\"layer\":0}").unwrap();
        j.append("{\"layer\":1}").unwrap();
        drop(j);
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[13] ^= 0x40; // flip a bit inside the first record's payload
        assert!(replay(&bytes).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn torn_final_record_is_tolerated() {
        let p = tmppath("tornfinal");
        let mut j = Journal::create(&p).unwrap();
        j.append("{\"layer\":0}").unwrap();
        let clean_len = std::fs::metadata(&p).unwrap().len() as usize;
        j.append("{\"layer\":1}").unwrap();
        drop(j);
        let mut bytes = std::fs::read(&p).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01; // corrupt the final record's payload
        let (records, valid) = replay(&bytes).unwrap();
        assert_eq!(records, vec!["{\"layer\":0}"]);
        assert_eq!(valid, clean_len);
        std::fs::remove_file(&p).unwrap();
    }
}
