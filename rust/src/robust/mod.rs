//! Crash-safety layer: atomic checksummed file IO, the prune journal,
//! and deterministic fault injection.
//!
//! - [`atomic`] — temp-file + fsync + rename writes; a kill at any point
//!   leaves the destination either old or new, never torn.
//! - [`crc`] — hand-rolled CRC-64/XZ used by checkpoint v3 section
//!   framing and journal records.
//! - [`journal`] — append-only fsynced record stream with torn-tail
//!   tolerant replay; the coordinator logs one record per completed
//!   layer and per saved block so `--resume` can skip finished work.
//! - [`faults`] — site-keyed, schedule-driven fault injection
//!   (`THANOS_FAULTS`) plus the deterministic retry/backoff wrapper.
//! - [`stream`] — chunked CRC-64-framed container IO and the
//!   [`stream::MemoryGovernor`] byte-budget gate behind the coordinator's
//!   bounded-memory streaming pipeline (DESIGN.md §Streaming).
//!
//!   No wall clock and no RNG anywhere in this tree: the module lives
//!   under the determinism contract's compute prefixes (D1–D6) and is
//!   the one tree exempt from D7 (raw file-write ban) because it *is*
//!   the sanctioned write path.

pub mod atomic;
pub mod crc;
pub mod faults;
pub mod journal;
pub mod stream;

pub use atomic::{write_atomic, AtomicFile};
pub use crc::{crc64, crc64_f32s, Crc64};
pub use faults::{FaultStats, RetryPolicy, SERVE_SITES, SITES};
pub use journal::Journal;
pub use stream::{ChunkReader, ChunkWriter, MemoryGovernor, SectionedReader, STREAM_SITES};
