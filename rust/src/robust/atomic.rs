//! Atomic file writes: stage into a temp file in the target directory,
//! fsync, then rename over the destination. A crash at any point leaves
//! either the old file intact or a stray `.tmp` — never a torn target.
//!
//! Every step probes a fault site (`atomic.create` / `atomic.write` /
//! `atomic.sync` / `atomic.rename`) so the chaos harness can kill the
//! writer mid-commit, and the sync/rename steps retry transient errors
//! through [`super::faults::with_retry`].

use std::fs::{self, File};
use std::io::{self, BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::faults::{self, RetryPolicy};

/// Process-wide temp-name counter: no wall clock, no RNG (D6-clean), and
/// concurrent writers in one process never collide.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// A file being written atomically. Write through the [`Write`] impl,
/// then call [`AtomicFile::commit`]; dropping without committing removes
/// the temp file and leaves the destination untouched.
pub struct AtomicFile {
    dest: PathBuf,
    tmp: PathBuf,
    writer: Option<BufWriter<File>>,
}

impl AtomicFile {
    /// Start an atomic write targeting `dest`. Parent directories are
    /// created; the temp file lives beside `dest` so the final rename
    /// stays within one filesystem.
    pub fn create(dest: impl AsRef<Path>) -> io::Result<Self> {
        let dest = dest.as_ref().to_path_buf();
        if let Some(parent) = dest.parent() {
            if !parent.as_os_str().is_empty() {
                fs::create_dir_all(parent)?;
            }
        }
        let name = dest
            .file_name()
            .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "atomic write needs a file name"))?
            .to_string_lossy()
            .into_owned();
        let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
        let tmp = dest.with_file_name(format!(".{name}.tmp.{}.{seq}", std::process::id()));
        faults::point("atomic.create")?;
        let file = File::create(&tmp)?;
        Ok(Self { dest, tmp, writer: Some(BufWriter::new(file)) })
    }

    fn writer(&mut self) -> &mut BufWriter<File> {
        self.writer.as_mut().expect("AtomicFile used after commit")
    }

    /// Flush, fsync the temp file, rename it over the destination, and
    /// fsync the parent directory so the rename itself is durable.
    pub fn commit(mut self) -> io::Result<()> {
        let mut writer = self.writer.take().expect("AtomicFile committed twice");
        writer.flush()?;
        let file = writer
            .into_inner()
            .map_err(|e| io::Error::other(e.to_string()))?;
        let policy = RetryPolicy::default();
        faults::with_retry(&policy, || {
            faults::point("atomic.sync")?;
            file.sync_all()
        })?;
        faults::with_retry(&policy, || {
            faults::point("atomic.rename")?;
            fs::rename(&self.tmp, &self.dest)
        })?;
        if let Some(parent) = self.dest.parent() {
            if !parent.as_os_str().is_empty() {
                // Directory fsync makes the rename durable; best-effort on
                // filesystems that refuse to open directories.
                if let Ok(dir) = File::open(parent) {
                    let _ = dir.sync_all();
                }
            }
        }
        Ok(())
    }
}

impl Write for AtomicFile {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match faults::write_action("atomic.write")? {
            Some(n) => {
                let n = n.min(buf.len());
                self.writer().write_all(&buf[..n])?;
                // Report full consumption so the caller's write_all moves
                // on: the truncation models bytes lost below the API.
                Ok(buf.len())
            }
            None => {
                self.writer().write_all(buf)?;
                Ok(buf.len())
            }
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        self.writer().flush()
    }
}

impl Drop for AtomicFile {
    fn drop(&mut self) {
        if self.writer.take().is_some() {
            let _ = fs::remove_file(&self.tmp);
        }
    }
}

/// Write `bytes` to `path` atomically in one call.
pub fn write_atomic(path: impl AsRef<Path>, bytes: &[u8]) -> io::Result<()> {
    let mut f = AtomicFile::create(path)?;
    f.write_all(bytes)?;
    f.commit()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("thanos-atomic-{tag}-{}", std::process::id()));
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn commit_replaces_and_abort_preserves() {
        let dir = tmpdir("basic");
        let path = dir.join("out.bin");
        write_atomic(&path, b"first").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"first");

        // Dropping without commit leaves the old contents and no temp file.
        {
            let mut f = AtomicFile::create(&path).unwrap();
            f.write_all(b"torn").unwrap();
        }
        assert_eq!(fs::read(&path).unwrap(), b"first");
        let stray: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(stray.is_empty(), "uncommitted temp file left behind");

        write_atomic(&path, b"second").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"second");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn creates_missing_parents() {
        let dir = tmpdir("parents");
        let path = dir.join("a/b/c.bin");
        write_atomic(&path, b"x").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"x");
        fs::remove_dir_all(&dir).unwrap();
    }
}
