//! Hand-rolled CRC-64/XZ (a.k.a. CRC-64/GO-ECMA): reflected polynomial
//! `0xC96C5795D7870F42`, init and xor-out both all-ones. This is the
//! checksum woven into checkpoint v3 section framing and journal records.
//!
//! Why CRC-64/XZ: it is the standard 64-bit CRC with published check
//! vectors (`crc64("123456789") == 0x995DC9BBDF1939FA`), detects all
//! single-bit and burst errors up to 64 bits, and needs no dependencies —
//! a 256-entry table built at compile time by a `const fn`.

const POLY: u64 = 0xC96C_5795_D787_0F42;

const fn build_table() -> [u64; 256] {
    let mut table = [0u64; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u64;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u64; 256] = build_table();

/// Incremental CRC-64/XZ state. `Crc64::new()` → `update(..)*` → `finish()`
/// is bit-identical to the one-shot [`crc64`].
#[derive(Clone, Copy, Debug)]
pub struct Crc64 {
    state: u64,
}

impl Default for Crc64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc64 {
    pub fn new() -> Self {
        Self { state: !0 }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            let idx = ((crc ^ b as u64) & 0xFF) as usize;
            crc = TABLE[idx] ^ (crc >> 8);
        }
        self.state = crc;
    }

    pub fn finish(&self) -> u64 {
        !self.state
    }
}

/// One-shot CRC-64/XZ of a byte slice.
pub fn crc64(bytes: &[u8]) -> u64 {
    let mut c = Crc64::new();
    c.update(bytes);
    c.finish()
}

/// CRC-64/XZ over the little-endian byte image of an `f32` slice, matching
/// the byte order checkpoints use on disk.
pub fn crc64_f32s(vals: &[f32]) -> u64 {
    let mut c = Crc64::new();
    for v in vals {
        c.update(&v.to_le_bytes());
    }
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_vector() {
        assert_eq!(crc64(b"123456789"), 0x995D_C9BB_DF19_39FA);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(crc64(b""), 0);
    }

    #[test]
    fn table_spot_values() {
        assert_eq!(TABLE[0], 0);
        assert_eq!(TABLE[1], 0xB32E_4CBE_03A7_5F6F);
        assert_eq!(TABLE[255], 0xE0AD_A173_6467_3F59);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let data: Vec<u8> = (0u16..4096).map(|i| (i % 251) as u8).collect();
        let mut inc = Crc64::new();
        for chunk in data.chunks(7) {
            inc.update(chunk);
        }
        assert_eq!(inc.finish(), crc64(&data));
    }

    #[test]
    fn single_bit_flip_changes_crc() {
        let data: Vec<u8> = (0u16..512).map(|i| (i * 31 % 256) as u8).collect();
        let base = crc64(&data);
        for byte in 0..data.len() {
            for bit in 0..8 {
                let mut mutated = data.clone();
                mutated[byte] ^= 1 << bit;
                assert_ne!(crc64(&mutated), base, "flip at byte {byte} bit {bit} undetected");
            }
        }
    }

    #[test]
    fn f32_helper_matches_byte_image() {
        let vals = [1.5f32, -0.25, 3.75e-3, f32::MIN_POSITIVE];
        let mut bytes = Vec::new();
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        assert_eq!(crc64_f32s(&vals), crc64(&bytes));
    }
}
