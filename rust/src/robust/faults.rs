//! Deterministic fault injection for the crash-safety harness.
//!
//! Production IO paths call [`point`] (and the write path calls
//! [`write_action`]) at named sites. With no schedule installed both are
//! a single relaxed atomic load — effectively free. A schedule, installed
//! from the `THANOS_FAULTS` env var or `--faults`, maps `(site, nth hit)`
//! to an action: return a transient IO error, truncate a write, panic, or
//! exit the process. Everything is keyed by site name and hit count — no
//! wall clock, no RNG — so a given schedule reproduces the same failure
//! on every run (D6-clean).
//!
//! Schedule grammar (semicolon-separated, `nth` is 1-based):
//!
//! ```text
//! THANOS_FAULTS="atomic.sync:1=err;journal.append:2=panic;atomic.write:1=trunc(8);ckpt:1=exit(17)"
//! ```
//!
//! Sites that run inside the parallel engine (`prune.layer.<i>`) embed the
//! slot index in the site name, so which layer faults never depends on
//! thread scheduling; file-IO sites run serially on the submitter thread
//! and use plain per-site hit counters. The serving daemon probes its own
//! sites ([`SERVE_SITES`]: `serve.accept` / `serve.batch` / `serve.reload`)
//! from single dedicated threads, so their hit counts are equally
//! schedule-independent.

use std::collections::BTreeMap;
use std::io;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Fault sites registered by the robust IO layer itself. Per-layer prune
/// sites (`prune.layer.<i>`) are registered dynamically and are not listed
/// here. The chaos harness iterates this list to kill at every site.
pub const SITES: [&str; 6] = [
    "atomic.create",
    "atomic.write",
    "atomic.sync",
    "atomic.rename",
    "journal.append",
    "journal.sync",
];

/// Fault sites probed by the serving daemon (`thanos serve`,
/// DESIGN.md §Serving). Kept separate from [`SITES`] because the
/// crash/resume chaos harness kills the *offline* pipeline at every
/// entry of that list, while these sites live on the online path and
/// are driven by the serving chaos tests instead:
///
/// * `serve.accept` — probed per accepted connection, before the
///   connection handler starts; an injected fault drops the connection.
/// * `serve.batch` — probed per formed batch, before the forward pass;
///   an injected `panic` exercises per-request panic containment, an
///   `err` the transient-batch-failure path.
/// * `serve.reload` — probed per hot-reload candidate read, inside the
///   shared [`with_retry`] ladder; transient `err` actions are absorbed
///   by the retry policy exactly like the atomic-writer IO sites.
pub const SERVE_SITES: [&str; 3] = ["serve.accept", "serve.batch", "serve.reload"];

/// What an armed fault site does when its scheduled hit arrives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Return `io::ErrorKind::Interrupted` — the transient class the retry
    /// wrapper is allowed to absorb.
    Err,
    /// Panic with a site-naming message (in-process kill; unwind-safe
    /// callers catch it, tests kill-and-resume through it).
    Panic,
    /// `std::process::exit(code)` — a true kill that skips every `Drop`.
    Exit(i32),
    /// Truncate the write to the first `n` bytes (write sites only; at
    /// non-write sites it degrades to `Err`).
    Trunc(usize),
}

struct State {
    /// `(site, nth-hit)` → action. Each armed entry fires at most once.
    schedule: BTreeMap<(String, u64), Action>,
    /// Hits observed so far per site.
    hits: BTreeMap<String, u64>,
    injected: u64,
    retries: u64,
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<Option<State>> = Mutex::new(None);

/// Process-wide registry of fault-site names, deduplicated by name. It
/// survives [`install`]/[`clear`] cycles: registration says "this site
/// exists in the binary", not "this site is armed", so re-running
/// `prune_model` twice in one process (the resume-after-degradation
/// path) re-registers the same `prune.layer.<i>` names idempotently
/// instead of accumulating duplicates. The chaos harnesses enumerate
/// this to kill at every site that actually ran.
static REGISTRY: Mutex<std::collections::BTreeSet<String>> =
    Mutex::new(std::collections::BTreeSet::new());

/// Idempotently register a fault-site name. Returns `true` the first
/// time a name is seen in this process, `false` on re-registration.
pub fn register_site(site: &str) -> bool {
    let mut reg = REGISTRY.lock().expect("faults registry poisoned");
    if reg.contains(site) {
        false
    } else {
        reg.insert(site.to_string())
    }
}

/// Register a batch of static site names (e.g. a module's site list).
pub fn register_site_list(sites: &[&str]) {
    let mut reg = REGISTRY.lock().expect("faults registry poisoned");
    for s in sites {
        if !reg.contains(*s) {
            reg.insert((*s).to_string());
        }
    }
}

/// Sorted snapshot of every site name registered so far this process.
pub fn registered_sites() -> Vec<String> {
    REGISTRY.lock().expect("faults registry poisoned").iter().cloned().collect()
}

/// Counters accumulated since the schedule was installed (or since
/// process start when no schedule is active — then always zero injected).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    pub injected: u64,
    pub retries: u64,
}

/// Parse a `THANOS_FAULTS` schedule string. Empty input yields an empty
/// schedule (which [`install`] treats as "clear").
pub fn parse_schedule(spec: &str) -> crate::Result<BTreeMap<(String, u64), Action>> {
    let mut out = BTreeMap::new();
    for part in spec.split(';') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (site_nth, action) = part
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("fault entry `{part}`: expected site:n=action"))?;
        let (site, nth) = site_nth
            .rsplit_once(':')
            .ok_or_else(|| anyhow::anyhow!("fault entry `{part}`: expected site:n=action"))?;
        let nth: u64 = nth
            .parse()
            .map_err(|_| anyhow::anyhow!("fault entry `{part}`: hit index `{nth}` is not a number"))?;
        anyhow::ensure!(nth >= 1, "fault entry `{part}`: hit index is 1-based");
        anyhow::ensure!(!site.is_empty(), "fault entry `{part}`: empty site name");
        let action = parse_action(action)
            .ok_or_else(|| anyhow::anyhow!("fault entry `{part}`: unknown action `{action}`"))?;
        out.insert((site.to_string(), nth), action);
    }
    Ok(out)
}

fn parse_action(s: &str) -> Option<Action> {
    match s {
        "err" => Some(Action::Err),
        "panic" => Some(Action::Panic),
        "exit" => Some(Action::Exit(101)),
        _ => {
            if let Some(inner) = s.strip_prefix("exit(").and_then(|r| r.strip_suffix(')')) {
                inner.parse().ok().map(Action::Exit)
            } else if let Some(inner) = s.strip_prefix("trunc(").and_then(|r| r.strip_suffix(')')) {
                inner.parse().ok().map(Action::Trunc)
            } else {
                None
            }
        }
    }
}

/// Install a schedule, replacing any previous one and zeroing counters.
/// An empty schedule deactivates injection entirely.
pub fn install(schedule: BTreeMap<(String, u64), Action>) {
    let mut guard = STATE.lock().expect("faults state poisoned");
    if schedule.is_empty() {
        *guard = None;
        ACTIVE.store(false, Ordering::Release);
    } else {
        *guard = Some(State { schedule, hits: BTreeMap::new(), injected: 0, retries: 0 });
        ACTIVE.store(true, Ordering::Release);
    }
}

/// Remove any installed schedule and reset counters.
pub fn clear() {
    install(BTreeMap::new());
}

/// Install the schedule from `THANOS_FAULTS` if the variable is set.
pub fn init_from_env() -> crate::Result<()> {
    if let Ok(spec) = std::env::var("THANOS_FAULTS") {
        install(parse_schedule(&spec)?);
    }
    Ok(())
}

/// Snapshot of injected/retry counters.
pub fn stats() -> FaultStats {
    let guard = STATE.lock().expect("faults state poisoned");
    match guard.as_ref() {
        Some(s) => FaultStats { injected: s.injected, retries: s.retries },
        None => FaultStats::default(),
    }
}

/// Record one retry attempt taken by [`with_retry`].
pub(crate) fn note_retry() {
    if !ACTIVE.load(Ordering::Acquire) {
        return;
    }
    if let Some(s) = STATE.lock().expect("faults state poisoned").as_mut() {
        s.retries += 1;
    }
}

fn trip(site: &str) -> Option<Action> {
    if !ACTIVE.load(Ordering::Acquire) {
        return None;
    }
    let mut guard = STATE.lock().expect("faults state poisoned");
    let state = guard.as_mut()?;
    let hit = state.hits.entry(site.to_string()).or_insert(0);
    *hit += 1;
    let action = state.schedule.remove(&(site.to_string(), *hit))?;
    state.injected += 1;
    Some(action)
}

fn fire_terminal(site: &str, action: Action) -> io::Error {
    match action {
        Action::Panic => panic!("injected fault: panic at `{site}`"),
        Action::Exit(code) => std::process::exit(code),
        Action::Err | Action::Trunc(_) => io::Error::new(
            io::ErrorKind::Interrupted,
            format!("injected fault: transient error at `{site}`"),
        ),
    }
}

/// Probe a fault site. Returns `Err` for the transient class, panics or
/// exits for the kill class, `Ok(())` when unarmed.
pub fn point(site: &str) -> io::Result<()> {
    match trip(site) {
        None => Ok(()),
        Some(action) => Err(fire_terminal(site, action)),
    }
}

/// Probe a write-path fault site. `Ok(None)` when unarmed, `Ok(Some(n))`
/// to truncate this write to `n` bytes, `Err` for a transient error;
/// panics/exits for the kill class.
pub fn write_action(site: &str) -> io::Result<Option<usize>> {
    match trip(site) {
        None => Ok(None),
        Some(Action::Trunc(n)) => Ok(Some(n)),
        Some(action) => Err(fire_terminal(site, action)),
    }
}

/// Deterministic bounded exponential backoff for the transient-error
/// class. The default schedule is 1, 4, 16, 50, 50, … milliseconds.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    pub max_attempts: u32,
    pub base_millis: u64,
    pub factor: u64,
    pub cap_millis: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { max_attempts: 3, base_millis: 1, factor: 4, cap_millis: 50 }
    }
}

impl RetryPolicy {
    /// Sleep before retry number `retry` (0-based): `base * factor^retry`,
    /// saturating, capped at `cap_millis`.
    pub fn backoff_millis(&self, retry: u32) -> u64 {
        let mut ms = self.base_millis;
        for _ in 0..retry {
            ms = ms.saturating_mul(self.factor);
            if ms >= self.cap_millis {
                return self.cap_millis;
            }
        }
        ms.min(self.cap_millis)
    }
}

/// Run `op`, retrying transient IO errors (`Interrupted`/`WouldBlock`)
/// up to `policy.max_attempts` extra times with deterministic backoff.
/// Non-transient errors and exhaustion return the last error.
pub fn with_retry<T>(
    policy: &RetryPolicy,
    mut op: impl FnMut() -> io::Result<T>,
) -> io::Result<T> {
    let mut attempt = 0u32;
    loop {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) if is_transient(&e) && attempt < policy.max_attempts => {
                note_retry();
                std::thread::sleep(std::time::Duration::from_millis(
                    policy.backoff_millis(attempt),
                ));
                attempt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

fn is_transient(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::Interrupted | io::ErrorKind::WouldBlock)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The schedule is process-global; tests that install one take this
    /// lock so the parallel test runner cannot interleave them.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn schedule_grammar() {
        let s = parse_schedule("atomic.sync:1=err; journal.append:2=panic;a.b:3=trunc(8);x:1=exit(7);y:2=exit")
            .unwrap();
        assert_eq!(s[&("atomic.sync".to_string(), 1)], Action::Err);
        assert_eq!(s[&("journal.append".to_string(), 2)], Action::Panic);
        assert_eq!(s[&("a.b".to_string(), 3)], Action::Trunc(8));
        assert_eq!(s[&("x".to_string(), 1)], Action::Exit(7));
        assert_eq!(s[&("y".to_string(), 2)], Action::Exit(101));
        assert!(parse_schedule("").unwrap().is_empty());
        assert!(parse_schedule("nonsense").is_err());
        assert!(parse_schedule("site:0=err").is_err());
        assert!(parse_schedule("site:1=boom").is_err());
    }

    #[test]
    fn nth_hit_fires_once() {
        let _g = TEST_LOCK.lock().unwrap();
        install(parse_schedule("t.site:2=err").unwrap());
        assert!(point("t.site").is_ok());
        assert!(point("t.site").is_err());
        assert!(point("t.site").is_ok());
        assert_eq!(stats().injected, 1);
        clear();
        assert!(point("t.site").is_ok());
    }

    #[test]
    fn write_action_truncates() {
        let _g = TEST_LOCK.lock().unwrap();
        install(parse_schedule("t.write:1=trunc(3)").unwrap());
        assert_eq!(write_action("t.write").unwrap(), Some(3));
        assert_eq!(write_action("t.write").unwrap(), None);
        clear();
    }

    #[test]
    fn registry_dedupes_and_survives_install_cycles() {
        let _g = TEST_LOCK.lock().unwrap();
        assert!(register_site("t.registry.once"));
        assert!(!register_site("t.registry.once"), "re-registration must dedupe");
        register_site_list(&["t.registry.a", "t.registry.once", "t.registry.a"]);
        let count = |names: &[String]| {
            names.iter().filter(|n| n.as_str() == "t.registry.once").count()
        };
        assert_eq!(count(&registered_sites()), 1);
        // install/clear zero the injection counters but never the registry
        install(parse_schedule("t.registry.once:1=err").unwrap());
        clear();
        assert_eq!(count(&registered_sites()), 1);
        assert!(registered_sites().iter().any(|n| n == "t.registry.a"));
        assert!(!register_site("t.registry.once"));
    }

    #[test]
    fn per_run_injection_deltas_do_not_double_count() {
        // Two journaled runs in one process under one installed schedule:
        // each run's `faults_injected` is `stats().injected - before`, and
        // a fired entry is removed from the schedule, so the second run
        // observes a delta of zero rather than re-counting run one's hit.
        let _g = TEST_LOCK.lock().unwrap();
        install(parse_schedule("t.rerun:1=err").unwrap());
        let before = stats().injected;
        assert!(point("t.rerun").is_err());
        assert_eq!(stats().injected - before, 1);
        // second "run" over the same sites, same process, same schedule
        let before = stats().injected;
        assert!(point("t.rerun").is_ok());
        assert_eq!(stats().injected - before, 0, "run 1's injection must not recount");
        clear();
    }

    #[test]
    fn backoff_schedule_is_pinned() {
        let p = RetryPolicy::default();
        let seq: Vec<u64> = (0..5).map(|i| p.backoff_millis(i)).collect();
        assert_eq!(seq, vec![1, 4, 16, 50, 50]);
    }

    #[test]
    fn retry_absorbs_transients() {
        let policy = RetryPolicy { max_attempts: 3, base_millis: 0, factor: 1, cap_millis: 0 };
        let mut left = 2;
        let out = with_retry(&policy, || {
            if left > 0 {
                left -= 1;
                Err(io::Error::new(io::ErrorKind::Interrupted, "transient"))
            } else {
                Ok(42)
            }
        })
        .unwrap();
        assert_eq!(out, 42);

        let err = with_retry(&policy, || -> io::Result<()> {
            Err(io::Error::other("permanent"))
        })
        .unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::Other);
    }
}
