//! Chunked, CRC-64-framed streaming IO for bounded-memory pruning
//! (DESIGN.md §Streaming).
//!
//! Three pieces:
//!
//! * [`ChunkWriter`] / [`ChunkReader`] — an append-only chunk container
//!   (`.thsc`) the coordinator spills calibration activations into. The
//!   writer streams chunks without knowing the final count (the table
//!   rides at the *end* of the file) and commits through
//!   [`super::AtomicFile`], so a kill at any point leaves either the
//!   previous container or the new one — never a torn file. The reader
//!   verifies the table against its own CRC-64 and every chunk against
//!   its table entry: a torn or bit-flipped container is rejected with a
//!   descriptive error, never a panic, never a wrong load.
//! * [`SectionedReader`] — incremental access to the v3 checkpoint
//!   container (`model::ModelState` format): the section table is read
//!   up front and each section streams through a rolling CRC-64, so a
//!   checkpoint can be loaded with one section chunk resident instead of
//!   the whole file ([`crate::model::ModelState::load_streamed`]).
//! * [`MemoryGovernor`] — the byte-budget admission gate of the
//!   streaming pipeline: capacity is pure integer math over the budget
//!   (no timing anywhere in the decision), `admit`/`release` track
//!   in-flight bytes and the observed peak, and every admission probes
//!   the `governor.admit` fault site.
//!
//! Fault sites ([`STREAM_SITES`]): `stream.read` / `stream.verify` are
//! probed by the readers, `stream.prefetch` / `governor.admit` /
//! `pipeline.stage` by the coordinator's streaming pipeline. All five
//! absorb transient (`err`) actions through [`super::faults::with_retry`];
//! `panic`/`exit` actions kill the run for the chaos harness.

use std::fs::File;
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{ensure, Context, Result};

use super::crc::{crc64, Crc64};
use super::faults::{self, RetryPolicy};

/// Chunk-container magic, leading and trailing (`.thsc` files).
const CHUNK_MAGIC: &[u8; 4] = b"THSC";
/// Chunk-container format version.
const CHUNK_VERSION: u32 = 1;
/// Sanity cap on the declared chunk count.
const MAX_CHUNKS: u64 = 1 << 24;
/// Leading header: magic + u32 version.
const HEADER_LEN: u64 = 8;
/// Trailing footer: u64 n_chunks + u64 table CRC + trailing magic.
const FOOTER_LEN: u64 = 20;

/// Fault sites probed by the streaming layer (this module plus the
/// coordinator's streaming pipeline). The streaming chaos harness
/// (`tests/stream_chaos.rs`) kills at every entry of this list:
///
/// * `stream.read` — before every container/section read syscall.
/// * `stream.verify` — before every CRC-64 verification.
/// * `stream.prefetch` — per chunk, at the top of the pipeline's
///   prefetch stage (the producer thread).
/// * `governor.admit` — per admission into the memory budget.
/// * `pipeline.stage` — per chunk, at the top of the compute stage
///   (the consumer side of the layer pipeline).
pub const STREAM_SITES: [&str; 5] = [
    "stream.read",
    "stream.verify",
    "stream.prefetch",
    "governor.admit",
    "pipeline.stage",
];

// ---------------------------------------------------------------------------
// ChunkWriter
// ---------------------------------------------------------------------------

/// Streaming chunk-container writer. Layout:
///
/// ```text
/// magic "THSC" | u32 version
/// chunk payloads, concatenated
/// table: n × (u64 LE len | u64 LE crc64(payload))
/// footer: u64 LE n | u64 LE crc64(table bytes) | magic "THSC"
/// ```
///
/// The table trails the payloads so chunks stream out without knowing
/// the final count. Everything goes through [`super::AtomicFile`]:
/// nothing is visible at the destination until [`ChunkWriter::finish`]
/// commits, and an uncommitted writer cleans its temp file up on drop.
pub struct ChunkWriter {
    file: super::AtomicFile,
    table: Vec<(u64, u64)>,
}

impl ChunkWriter {
    /// Start a container targeting `path` (committed only by `finish`).
    pub fn create(path: impl AsRef<Path>) -> Result<ChunkWriter> {
        faults::register_site_list(&STREAM_SITES);
        let mut file = super::AtomicFile::create(path.as_ref())
            .with_context(|| format!("creating chunk container {}", path.as_ref().display()))?;
        file.write_all(CHUNK_MAGIC)?;
        file.write_all(&CHUNK_VERSION.to_le_bytes())?;
        Ok(ChunkWriter { file, table: Vec::new() })
    }

    /// Append one chunk payload.
    pub fn write_chunk(&mut self, payload: &[u8]) -> Result<()> {
        self.file.write_all(payload)?;
        self.table.push((payload.len() as u64, crc64(payload)));
        Ok(())
    }

    /// Append one chunk of f32s as little-endian bytes (bit-exact round
    /// trip through [`ChunkReader::read_chunk_f32s`], NaNs included).
    pub fn write_chunk_f32s(&mut self, values: &[f32]) -> Result<()> {
        let mut bytes = Vec::with_capacity(values.len() * 4);
        for v in values {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        self.write_chunk(&bytes)
    }

    pub fn n_chunks(&self) -> usize {
        self.table.len()
    }

    /// Write the table + footer and atomically commit the container.
    pub fn finish(mut self) -> Result<()> {
        let mut table_bytes = Vec::with_capacity(self.table.len() * 16);
        for (len, crc) in &self.table {
            table_bytes.extend_from_slice(&len.to_le_bytes());
            table_bytes.extend_from_slice(&crc.to_le_bytes());
        }
        self.file.write_all(&table_bytes)?;
        self.file.write_all(&(self.table.len() as u64).to_le_bytes())?;
        self.file.write_all(&crc64(&table_bytes).to_le_bytes())?;
        self.file.write_all(CHUNK_MAGIC)?;
        self.file.commit()?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// ChunkReader
// ---------------------------------------------------------------------------

/// Verified random access over a committed chunk container. `open`
/// validates the framing (magics, version, table CRC, and that the
/// chunk lengths account for every payload byte, all with checked
/// arithmetic); `read_chunk` verifies each payload against its table
/// entry. The file descriptor stays open, so a concurrent atomic
/// rewrite of the same path (the re-forward spill swap) never disturbs
/// in-flight reads of the old generation.
pub struct ChunkReader {
    path: PathBuf,
    file: File,
    /// per-chunk `(offset, len, crc64)`
    index: Vec<(u64, u64, u64)>,
}

impl ChunkReader {
    pub fn open(path: impl AsRef<Path>) -> Result<ChunkReader> {
        faults::register_site_list(&STREAM_SITES);
        let path = path.as_ref().to_path_buf();
        let policy = RetryPolicy::default();
        let (mut file, file_len) = faults::with_retry(&policy, || {
            faults::point("stream.read")?;
            let f = File::open(&path)?;
            let len = f.metadata()?.len();
            Ok((f, len))
        })
        .with_context(|| format!("opening chunk container {}", path.display()))?;

        ensure!(
            file_len >= HEADER_LEN + FOOTER_LEN,
            "chunk container {}: {file_len} bytes is shorter than the fixed framing",
            path.display()
        );
        let mut head = [0u8; 8];
        read_exact_at(&mut file, 0, &mut head, &policy)
            .with_context(|| format!("reading chunk-container header of {}", path.display()))?;
        ensure!(
            &head[..4] == CHUNK_MAGIC,
            "chunk container {}: bad leading magic",
            path.display()
        );
        let version = u32::from_le_bytes(head[4..8].try_into().expect("4-byte slice"));
        ensure!(
            version == CHUNK_VERSION,
            "chunk container {}: unsupported version {version}",
            path.display()
        );

        let mut foot = [0u8; FOOTER_LEN as usize];
        read_exact_at(&mut file, file_len - FOOTER_LEN, &mut foot, &policy)
            .with_context(|| format!("reading chunk-container footer of {}", path.display()))?;
        ensure!(
            &foot[16..20] == CHUNK_MAGIC,
            "chunk container {}: bad trailing magic (torn or truncated file)",
            path.display()
        );
        let n = u64::from_le_bytes(foot[..8].try_into().expect("8-byte slice"));
        let table_crc = u64::from_le_bytes(foot[8..16].try_into().expect("8-byte slice"));
        ensure!(
            n <= MAX_CHUNKS,
            "chunk container {}: implausible chunk count {n}",
            path.display()
        );
        let table_len = n
            .checked_mul(16)
            .context("chunk-table length overflows")?;
        let table_off = file_len
            .checked_sub(FOOTER_LEN)
            .and_then(|v| v.checked_sub(table_len))
            .filter(|&off| off >= HEADER_LEN)
            .with_context(|| {
                format!(
                    "chunk container {}: table of {n} chunks does not fit the file",
                    path.display()
                )
            })?;
        let mut table_bytes = vec![0u8; table_len as usize];
        read_exact_at(&mut file, table_off, &mut table_bytes, &policy)
            .with_context(|| format!("reading chunk table of {}", path.display()))?;
        faults::with_retry(&policy, || faults::point("stream.verify"))?;
        let got = crc64(&table_bytes);
        ensure!(
            got == table_crc,
            "chunk container {}: chunk table fails its CRC-64 \
             (stored {table_crc:016x}, computed {got:016x}): the file is corrupt",
            path.display()
        );

        let mut index = Vec::with_capacity(n as usize);
        let mut off = HEADER_LEN;
        for entry in table_bytes.chunks_exact(16) {
            let len = u64::from_le_bytes(entry[..8].try_into().expect("8-byte slice"));
            let crc = u64::from_le_bytes(entry[8..16].try_into().expect("8-byte slice"));
            index.push((off, len, crc));
            off = off
                .checked_add(len)
                .context("chunk offsets overflow")?;
        }
        ensure!(
            off == table_off,
            "chunk container {}: chunk lengths cover {} payload bytes but the table \
             starts at {} (truncated or corrupt)",
            path.display(),
            off - HEADER_LEN,
            table_off - HEADER_LEN
        );
        Ok(ChunkReader { path, file, index })
    }

    pub fn n_chunks(&self) -> usize {
        self.index.len()
    }

    /// Byte length of chunk `i`.
    pub fn chunk_len(&self, i: usize) -> usize {
        self.index[i].1 as usize
    }

    /// Read chunk `i` and verify it against its table entry.
    pub fn read_chunk(&mut self, i: usize) -> Result<Vec<u8>> {
        let (off, len, want) = *self
            .index
            .get(i)
            .with_context(|| format!("chunk {i} out of range ({} chunks)", self.index.len()))?;
        let policy = RetryPolicy::default();
        let mut buf = vec![0u8; len as usize];
        read_exact_at(&mut self.file, off, &mut buf, &policy)
            .with_context(|| format!("reading chunk {i} of {}", self.path.display()))?;
        faults::with_retry(&policy, || faults::point("stream.verify"))?;
        let got = crc64(&buf);
        ensure!(
            got == want,
            "chunk {i} of {} fails its CRC-64 (stored {want:016x}, computed {got:016x}): \
             the container is corrupt",
            self.path.display()
        );
        Ok(buf)
    }

    /// [`Self::read_chunk`] decoded as little-endian f32s.
    pub fn read_chunk_f32s(&mut self, i: usize) -> Result<Vec<f32>> {
        let bytes = self.read_chunk(i)?;
        ensure!(
            bytes.len() % 4 == 0,
            "chunk {i} of {} holds {} bytes — not a whole number of f32s",
            self.path.display(),
            bytes.len()
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// `pread`-style helper: seek + read_exact under the shared retry
/// policy, probing `stream.read` so the chaos harness can kill or
/// transiently fail any container read.
fn read_exact_at(
    file: &mut File,
    off: u64,
    buf: &mut [u8],
    policy: &RetryPolicy,
) -> io::Result<()> {
    faults::with_retry(policy, || {
        faults::point("stream.read")?;
        file.seek(SeekFrom::Start(off))?;
        file.read_exact(buf)
    })
}

// ---------------------------------------------------------------------------
// SectionedReader — incremental v3 checkpoint access
// ---------------------------------------------------------------------------

/// v3 checkpoint magic/version (mirrors `model::ModelState`; the byte
/// layout is owned there — this reader only *consumes* it).
const CKPT_MAGIC: &[u8; 4] = b"THNS";
const CKPT_VERSION_SECTIONED: u32 = 3;
const CKPT_MAX_SECTIONS: usize = 4096;

/// Incremental reader over the v3 checkpoint container: front matter
/// and the `(len, crc64)` section table are read eagerly, sections
/// stream on demand — whole ([`Self::read_section`]) or chunk-at-a-time
/// with a rolling CRC ([`Self::for_each_chunk`]), so the caller's peak
/// memory is one section (or one chunk) instead of the whole file.
pub struct SectionedReader {
    path: PathBuf,
    file: File,
    /// per-section `(offset, len, crc64)`
    index: Vec<(u64, u64, u64)>,
}

impl SectionedReader {
    pub fn open(path: impl AsRef<Path>) -> Result<SectionedReader> {
        faults::register_site_list(&STREAM_SITES);
        let path = path.as_ref().to_path_buf();
        let policy = RetryPolicy::default();
        let (mut file, file_len) = faults::with_retry(&policy, || {
            faults::point("stream.read")?;
            let f = File::open(&path)?;
            let len = f.metadata()?.len();
            Ok((f, len))
        })
        .with_context(|| format!("opening checkpoint {}", path.display()))?;

        let mut head = [0u8; 12];
        ensure!(
            file_len >= head.len() as u64,
            "checkpoint {} too short: {file_len} bytes",
            path.display()
        );
        read_exact_at(&mut file, 0, &mut head, &policy)?;
        ensure!(
            &head[..4] == CKPT_MAGIC,
            "{} is not a thanos checkpoint (bad magic)",
            path.display()
        );
        let version = u32::from_le_bytes(head[4..8].try_into().expect("4-byte slice"));
        ensure!(
            version == CKPT_VERSION_SECTIONED,
            "streamed loading requires a v3 (sectioned) checkpoint; {} is version {version}",
            path.display()
        );
        let n = u32::from_le_bytes(head[8..12].try_into().expect("4-byte slice")) as usize;
        ensure!(
            (2..=CKPT_MAX_SECTIONS).contains(&n),
            "v3 checkpoint declares {n} sections (expected 2..={CKPT_MAX_SECTIONS})"
        );
        let table_len = (n as u64) * 16;
        ensure!(
            table_len <= file_len - 12,
            "truncated v3 section table in {}",
            path.display()
        );
        let mut table_bytes = vec![0u8; table_len as usize];
        read_exact_at(&mut file, 12, &mut table_bytes, &policy)?;
        let mut index = Vec::with_capacity(n);
        let mut off = 12 + table_len;
        for entry in table_bytes.chunks_exact(16) {
            let len = u64::from_le_bytes(entry[..8].try_into().expect("8-byte slice"));
            let crc = u64::from_le_bytes(entry[8..16].try_into().expect("8-byte slice"));
            index.push((off, len, crc));
            off = off
                .checked_add(len)
                .context("v3 section lengths overflow")?;
        }
        ensure!(
            off == file_len,
            "v3 sections of {} total {} bytes but the file holds {} payload bytes \
             (truncated or corrupt section table)",
            path.display(),
            off - 12 - table_len,
            file_len - 12 - table_len
        );
        Ok(SectionedReader { path, file, index })
    }

    pub fn n_sections(&self) -> usize {
        self.index.len()
    }

    pub fn section_len(&self, i: usize) -> u64 {
        self.index[i].1
    }

    /// Stream section `i` in pieces of at most `chunk_bytes`, feeding
    /// each to `f`. The rolling CRC-64 over everything fed is verified
    /// against the section's table entry before this returns `Ok` —
    /// a caller never observes a complete-but-corrupt section.
    pub fn for_each_chunk(
        &mut self,
        i: usize,
        chunk_bytes: usize,
        mut f: impl FnMut(&[u8]) -> Result<()>,
    ) -> Result<()> {
        let (off, len, want) = *self
            .index
            .get(i)
            .with_context(|| format!("section {i} out of range ({} sections)", self.index.len()))?;
        let policy = RetryPolicy::default();
        let chunk_bytes = chunk_bytes.max(1) as u64;
        let mut crc = Crc64::new();
        let mut done = 0u64;
        let mut buf = vec![0u8; chunk_bytes.min(len) as usize];
        while done < len {
            let take = chunk_bytes.min(len - done) as usize;
            read_exact_at(&mut self.file, off + done, &mut buf[..take], &policy)
                .with_context(|| format!("reading section {i} of {}", self.path.display()))?;
            crc.update(&buf[..take]);
            f(&buf[..take])?;
            done += take as u64;
        }
        faults::with_retry(&policy, || faults::point("stream.verify"))?;
        let got = crc.finish();
        ensure!(
            got == want,
            "checkpoint section {i} of {} fails its CRC-64 \
             (stored {want:016x}, computed {got:016x}): the file is corrupt",
            self.path.display()
        );
        Ok(())
    }

    /// Read and verify a whole section (for small sections: the JSON
    /// header and sparse blobs).
    pub fn read_section(&mut self, i: usize) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(self.section_len(i) as usize);
        self.for_each_chunk(i, 1 << 20, |piece| {
            out.extend_from_slice(piece);
            Ok(())
        })?;
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// MemoryGovernor
// ---------------------------------------------------------------------------

/// Byte-budget admission gate for the streaming pipeline.
///
/// The admission rule is pure integer math — no wall clock, no load
/// feedback: with a budget of `B` bytes and chunks of `c` bytes, at
/// most `max(1, B/c − 2)` chunks may sit prefetched in the pipeline
/// queue. The `− 2` reserves room for the chunk the compute stage is
/// consuming *and* the chunk the prefetch stage holds while waiting
/// for queue space (the producer reads before it enqueues), so total
/// in-flight bytes stay within `B`. A budget below three chunks
/// degrades to that structural floor — one queued, one in hand, one
/// in consumption — the minimum the overlapped pipeline cannot go
/// under. `None` means unbounded: the all-in-RAM default behavior.
///
/// `admit`/`release` track in-flight bytes and the high-water mark the
/// bench/CI RSS gate reads, and every admission probes the
/// `governor.admit` fault site (transients absorbed by the shared
/// retry ladder).
pub struct MemoryGovernor {
    budget: Option<u64>,
    state: Mutex<GovernorState>,
}

#[derive(Default)]
struct GovernorState {
    in_use: u64,
    peak: u64,
    admitted: u64,
}

impl MemoryGovernor {
    pub fn new(budget: Option<u64>) -> MemoryGovernor {
        faults::register_site_list(&STREAM_SITES);
        MemoryGovernor { budget, state: Mutex::new(GovernorState::default()) }
    }

    pub fn budget(&self) -> Option<u64> {
        self.budget
    }

    /// Queue capacity (prefetched chunks in flight) for `chunk_bytes`-
    /// sized chunks under this budget. Deterministic: depends only on
    /// the two byte counts.
    pub fn capacity(&self, chunk_bytes: u64) -> usize {
        match self.budget {
            None => usize::MAX,
            Some(b) => {
                let per = chunk_bytes.max(1);
                (b / per).saturating_sub(2).max(1) as usize
            }
        }
    }

    /// Account `bytes` entering the pipeline (probing `governor.admit`).
    pub fn admit(&self, bytes: u64) -> io::Result<()> {
        faults::with_retry(&RetryPolicy::default(), || faults::point("governor.admit"))?;
        let mut s = self.state.lock().expect("governor state poisoned");
        s.in_use = s.in_use.saturating_add(bytes);
        s.peak = s.peak.max(s.in_use);
        s.admitted += 1;
        Ok(())
    }

    /// Account `bytes` leaving the pipeline.
    pub fn release(&self, bytes: u64) {
        let mut s = self.state.lock().expect("governor state poisoned");
        s.in_use = s.in_use.saturating_sub(bytes);
    }

    /// High-water mark of in-flight admitted bytes.
    pub fn peak_bytes(&self) -> u64 {
        self.state.lock().expect("governor state poisoned").peak
    }

    /// Total admissions (one per chunk entering the pipeline).
    pub fn admitted(&self) -> u64 {
        self.state.lock().expect("governor state poisoned").admitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmppath(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("thanos-stream-{tag}-{}.thsc", std::process::id()))
    }

    #[test]
    fn chunk_container_roundtrip() {
        let p = tmppath("roundtrip");
        let mut w = ChunkWriter::create(&p).unwrap();
        let chunks: Vec<Vec<u8>> = vec![b"alpha".to_vec(), Vec::new(), vec![7u8; 300]];
        for c in &chunks {
            w.write_chunk(c).unwrap();
        }
        assert_eq!(w.n_chunks(), 3);
        w.finish().unwrap();

        let mut r = ChunkReader::open(&p).unwrap();
        assert_eq!(r.n_chunks(), 3);
        for (i, c) in chunks.iter().enumerate() {
            assert_eq!(r.chunk_len(i), c.len());
            assert_eq!(&r.read_chunk(i).unwrap(), c);
        }
        // random access in any order
        assert_eq!(r.read_chunk(0).unwrap(), chunks[0]);
        assert!(r.read_chunk(3).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn f32_chunks_roundtrip_bitwise() {
        let p = tmppath("f32");
        let vals = vec![0.0f32, -0.0, 1.5, f32::NAN, f32::INFINITY, -3.25e-40];
        let mut w = ChunkWriter::create(&p).unwrap();
        w.write_chunk_f32s(&vals).unwrap();
        w.finish().unwrap();
        let back = ChunkReader::open(&p).unwrap().read_chunk_f32s(0).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back), bits(&vals));
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn uncommitted_writer_leaves_no_container() {
        let p = tmppath("abort");
        {
            let mut w = ChunkWriter::create(&p).unwrap();
            w.write_chunk(b"doomed").unwrap();
            // dropped without finish()
        }
        assert!(!p.exists(), "uncommitted container must not appear");
    }

    #[test]
    fn rewrite_does_not_disturb_open_reader() {
        let p = tmppath("rewrite");
        let mut w = ChunkWriter::create(&p).unwrap();
        w.write_chunk(b"generation-0").unwrap();
        w.finish().unwrap();
        let mut old = ChunkReader::open(&p).unwrap();
        // atomically replace the container while the old fd is open
        let mut w = ChunkWriter::create(&p).unwrap();
        w.write_chunk(b"generation-1").unwrap();
        w.finish().unwrap();
        assert_eq!(old.read_chunk(0).unwrap(), b"generation-0");
        assert_eq!(ChunkReader::open(&p).unwrap().read_chunk(0).unwrap(), b"generation-1");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn every_bit_flip_is_rejected() {
        let p = tmppath("flip");
        let mut w = ChunkWriter::create(&p).unwrap();
        w.write_chunk(b"abcdefgh").unwrap();
        w.write_chunk(&[0x55u8; 17]).unwrap();
        w.finish().unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let try_load = |img: &[u8]| -> bool {
            std::fs::write(&p, img).unwrap();
            let mut r = match ChunkReader::open(&p) {
                Ok(r) => r,
                Err(_) => return false,
            };
            (0..r.n_chunks()).all(|i| r.read_chunk(i).is_ok())
        };
        assert!(try_load(&bytes), "pristine container must load");
        let mut work = bytes.clone();
        for i in 0..work.len() {
            for bit in 0..8 {
                work[i] ^= 1 << bit;
                assert!(
                    !try_load(&work),
                    "bit {bit} of byte {i} flipped but the container still loaded"
                );
                work[i] ^= 1 << bit;
            }
        }
        assert_eq!(work, bytes);
        for len in 0..bytes.len() {
            assert!(!try_load(&bytes[..len]), "truncation to {len} bytes still loaded");
        }
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn governor_capacity_rule() {
        let g = MemoryGovernor::new(None);
        assert_eq!(g.capacity(1 << 20), usize::MAX);
        let g = MemoryGovernor::new(Some(10 << 20));
        // 10 MiB budget, 2 MiB chunks: 5 in flight minus one being
        // consumed and one held by the producer awaiting queue space
        assert_eq!(g.capacity(2 << 20), 3);
        // exactly three chunks: the structural floor still streams
        assert_eq!(g.capacity(3 << 20), 1);
        // budget below the floor degrades to single-chunk prefetch
        assert_eq!(g.capacity(64 << 20), 1);
        assert_eq!(MemoryGovernor::new(Some(0)).capacity(1), 1);
    }

    #[test]
    fn governor_tracks_peak() {
        let g = MemoryGovernor::new(Some(100));
        g.admit(40).unwrap();
        g.admit(40).unwrap();
        g.release(40);
        g.admit(10).unwrap();
        assert_eq!(g.peak_bytes(), 80);
        assert_eq!(g.admitted(), 3);
    }

    #[test]
    fn sectioned_reader_streams_v3_checkpoints() {
        // hand-build a minimal v3-framed file: 2 sections
        let s0 = b"header-bytes".to_vec();
        let s1: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let mut img = Vec::new();
        img.extend_from_slice(CKPT_MAGIC);
        img.extend_from_slice(&CKPT_VERSION_SECTIONED.to_le_bytes());
        img.extend_from_slice(&2u32.to_le_bytes());
        for s in [&s0, &s1] {
            img.extend_from_slice(&(s.len() as u64).to_le_bytes());
            img.extend_from_slice(&crc64(s).to_le_bytes());
        }
        img.extend_from_slice(&s0);
        img.extend_from_slice(&s1);
        let p = tmppath("sectioned");
        std::fs::write(&p, &img).unwrap();

        let mut r = SectionedReader::open(&p).unwrap();
        assert_eq!(r.n_sections(), 2);
        assert_eq!(r.read_section(0).unwrap(), s0);
        // chunked streaming with an awkward chunk size reassembles exactly
        let mut got = Vec::new();
        r.for_each_chunk(1, 7, |piece| {
            assert!(piece.len() <= 7);
            got.extend_from_slice(piece);
            Ok(())
        })
        .unwrap();
        assert_eq!(got, s1);

        // corrupt payload byte: streamed read fails its rolling CRC
        let mut bad = img.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        std::fs::write(&p, &bad).unwrap();
        let err = SectionedReader::open(&p)
            .unwrap()
            .read_section(1)
            .unwrap_err();
        assert!(format!("{err:#}").contains("CRC-64"), "unexpected error: {err:#}");
        // truncation is caught at open
        std::fs::write(&p, &img[..img.len() - 3]).unwrap();
        assert!(SectionedReader::open(&p).is_err());
        // non-v3 versions are refused descriptively
        let mut v1 = img.clone();
        v1[4..8].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&p, &v1).unwrap();
        let err = SectionedReader::open(&p).unwrap_err();
        assert!(format!("{err:#}").contains("v3"), "unexpected error: {err:#}");
        std::fs::remove_file(&p).unwrap();
    }
}
