//! Property-based tests over the pruning library (mini-proptest):
//! invariants that must hold for EVERY method on randomly generated
//! layers, plus cross-method quality orderings the paper's tables rely
//! on. No artifacts needed — pure Rust.

use thanos::linalg::gemm::recon_loss;
use thanos::linalg::Mat;
use thanos::proptest::{check, dim, mat_heavy, sparsity, Config};
use thanos::pruning::{self, CalibStats, Method, Pattern, PruneOpts};
use thanos::rng::Rng;

fn gen_layer(r: &mut Rng) -> (Mat, CalibStats, Mat, f64) {
    let c = dim(r, 6, 24);
    let b = dim(r, 2, 6) * 4; // multiple of 4 for n:m
    let a = b * 3 + dim(r, 0, 16);
    let w = mat_heavy(r, c, b, 0.02);
    let x = mat_heavy(r, b, a, 0.05);
    let stats = CalibStats::from_x(&x);
    let p = sparsity(r);
    (w, stats, x, p)
}

fn opts() -> PruneOpts {
    PruneOpts { block_size: 8, ..Default::default() }
}

#[test]
fn prop_every_method_masks_are_exact_zeros() {
    check(
        &Config { cases: 24, seed: 0xA1 },
        |r| gen_layer(r),
        |(w, stats, _x, p)| {
            for method in Method::ALL {
                let pruned =
                    pruning::prune(method, w, stats, Pattern::Unstructured { p: *p }, &opts())
                        .map_err(|e| e.to_string())?;
                for (k, &m) in pruned.mask.iter().enumerate() {
                    if m && pruned.w.data[k] != 0.0 {
                        return Err(format!("{}: masked weight not zero", method.name()));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_unstructured_sparsity_hits_target() {
    check(
        &Config { cases: 24, seed: 0xA2 },
        |r| gen_layer(r),
        |(w, stats, _x, p)| {
            let cells = w.rows * w.cols;
            // magnitude + thanos: exact global count
            for method in [Method::Magnitude, Method::Thanos] {
                let pruned =
                    pruning::prune(method, w, stats, Pattern::Unstructured { p: *p }, &opts())
                        .map_err(|e| e.to_string())?;
                let zeros = pruned.w.data.iter().filter(|&&v| v == 0.0).count();
                let want = (p * cells as f64).floor() as usize;
                if zeros != want {
                    return Err(format!(
                        "{}: {zeros} zeros, want {want} (p={p})",
                        method.name()
                    ));
                }
            }
            // wanda: per-row count
            let pruned =
                pruning::prune(Method::Wanda, w, stats, Pattern::Unstructured { p: *p }, &opts())
                    .map_err(|e| e.to_string())?;
            let k = (p * w.cols as f64).floor() as usize;
            for i in 0..w.rows {
                let zeros = pruned.w.row(i).iter().filter(|&&v| v == 0.0).count();
                if zeros != k {
                    return Err(format!("wanda row {i}: {zeros} != {k}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_nm_format_all_methods() {
    check(
        &Config { cases: 16, seed: 0xA3 },
        |r| gen_layer(r),
        |(w, stats, _x, _p)| {
            for method in Method::ALL {
                let pruned = pruning::prune(
                    method,
                    w,
                    stats,
                    Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 },
                    &opts(),
                )
                .map_err(|e| e.to_string())?;
                pruning::nm::validate(&pruned.w, 2, 4, &pruning::nm::RowSet::new())
                    .map_err(|e| format!("{}: {e}", method.name()))?;
                // the packed format must reconstruct the pruned weights
                // bitwise (the sparse/ subsystem consumes these outputs)
                let packed = thanos::sparse::NmPacked::from_dense(&pruned.w, 2, 4)
                    .map_err(|e| e.to_string())?;
                if packed
                    .to_dense()
                    .data
                    .iter()
                    .zip(&pruned.w.data)
                    .any(|(a, b)| a.to_bits() != b.to_bits())
                {
                    return Err(format!("{}: NmPacked round-trip differs", method.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_structured_removes_whole_columns() {
    check(
        &Config { cases: 16, seed: 0xA4 },
        |r| gen_layer(r),
        |(w, stats, _x, _p)| {
            for method in [Method::Magnitude, Method::Wanda, Method::SparseGpt] {
                let pruned = pruning::prune(
                    method,
                    w,
                    stats,
                    Pattern::Structured { p: 0.25, alpha: 0.0 },
                    &opts(),
                )
                .map_err(|e| e.to_string())?;
                for j in 0..w.cols {
                    let zeros = (0..w.rows).filter(|&i| pruned.w.at(i, j) == 0.0).count();
                    if zeros != 0 && zeros != w.rows {
                        return Err(format!("{}: column {j} partial", method.name()));
                    }
                }
            }
            // thanos with alpha=0 too
            let pruned = pruning::prune(
                Method::Thanos,
                w,
                stats,
                Pattern::Structured { p: 0.25, alpha: 0.0 },
                &opts(),
            )
            .map_err(|e| e.to_string())?;
            for j in 0..w.cols {
                let zeros = (0..w.rows).filter(|&i| pruned.w.at(i, j) == 0.0).count();
                if zeros != 0 && zeros != w.rows {
                    return Err(format!("thanos: column {j} partial"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_update_methods_beat_mask_only_on_same_mask() {
    // For SparseGPT and Thanos: re-zeroing their own mask WITHOUT the
    // weight update must never do better (the OBS update is optimal for
    // the chosen mask).
    check(
        &Config { cases: 16, seed: 0xA5 },
        |r| gen_layer(r),
        |(w, stats, x, p)| {
            for method in [Method::SparseGpt, Method::Thanos] {
                let pruned =
                    pruning::prune(method, w, stats, Pattern::Unstructured { p: *p }, &opts())
                        .map_err(|e| e.to_string())?;
                let mut mask_only = w.clone();
                for (k, &m) in pruned.mask.iter().enumerate() {
                    if m {
                        mask_only.data[k] = 0.0;
                    }
                }
                let lu = recon_loss(&pruned.w, w, x);
                let lm = recon_loss(&mask_only, w, x);
                if lu > lm * 1.0001 + 1e-9 {
                    return Err(format!(
                        "{} p={p}: update {lu} worse than mask-only {lm}",
                        method.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_idempotent_on_already_pruned() {
    // pruning an already-pruned matrix at the same pattern keeps the
    // zeros (n:m formats remain valid)
    check(
        &Config { cases: 12, seed: 0xA6 },
        |r| gen_layer(r),
        |(w, stats, _x, _p)| {
            let once = pruning::prune(
                Method::Thanos,
                w,
                stats,
                Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 },
                &opts(),
            )
            .map_err(|e| e.to_string())?;
            let twice = pruning::prune(
                Method::Thanos,
                &once.w,
                stats,
                Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 },
                &opts(),
            )
            .map_err(|e| e.to_string())?;
            pruning::nm::validate(&twice.w, 2, 4, &pruning::nm::RowSet::new())
                .map_err(|e| e.to_string())?;
            Ok(())
        },
    );
}

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn engine_serial_and_parallel_bit_identical_all_methods() {
    // `THANOS_THREADS=1` forces every engine job inline;
    // `engine::with_serial` reproduces exactly that execution path
    // in-process. Pruned weights AND masks must be bit-identical to the
    // default-parallel run for every method × pattern: band splits and
    // work stealing must never change arithmetic.
    let patterns = [
        Pattern::Unstructured { p: 0.5 },
        Pattern::SemiStructured { n: 2, m: 4, alpha: 0.1 },
        Pattern::Structured { p: 0.3, alpha: 0.1 },
    ];
    let mut root = Rng::new(0xE7);
    for case in 0..4 {
        let mut r = root.fork();
        let (w, stats, _x, _p) = gen_layer(&mut r);
        for method in Method::ALL {
            for pattern in patterns {
                let par = pruning::prune(method, &w, &stats, pattern, &opts()).unwrap();
                let ser = thanos::engine::with_serial(|| {
                    pruning::prune(method, &w, &stats, pattern, &opts()).unwrap()
                });
                assert_eq!(
                    bits(&par.w),
                    bits(&ser.w),
                    "case {case}: {} {pattern:?} weights differ serial vs parallel",
                    method.name()
                );
                assert_eq!(
                    par.mask,
                    ser.mask,
                    "case {case}: {} {pattern:?} masks differ serial vs parallel",
                    method.name()
                );
            }
        }
    }
    // the greedy OBS reference implementation as well
    let mut r = root.fork();
    let (w, stats, _x, _p) = gen_layer(&mut r);
    let par = pruning::obs::unstructured(&w, &stats, 0.4, &opts()).unwrap();
    let ser = thanos::engine::with_serial(|| {
        pruning::obs::unstructured(&w, &stats, 0.4, &opts()).unwrap()
    });
    assert_eq!(bits(&par.w), bits(&ser.w), "obs weights differ serial vs parallel");
    assert_eq!(par.mask, ser.mask, "obs masks differ serial vs parallel");
}

#[test]
fn prune_many_matches_sequential_prune_bitwise() {
    // the layer-parallel fan-out must be a pure scheduling change:
    // same outputs, same order, as one-at-a-time pruning
    let mut root = Rng::new(0xE8);
    let mut make_layer = |root: &mut Rng| {
        let mut r = root.fork();
        gen_layer(&mut r)
    };
    let (w1, s1, _x1, _) = make_layer(&mut root);
    let (w2, s2, _x2, _) = make_layer(&mut root);
    let (w3, s3, _x3, _) = make_layer(&mut root);
    let layers = vec![(&w1, &s1), (&w2, &s2), (&w3, &s3)];
    let pattern = Pattern::Unstructured { p: 0.5 };
    let many = pruning::prune_many(&layers, Method::Thanos, pattern, &opts());
    assert_eq!(many.len(), 3);
    for ((w, s), res) in layers.iter().zip(many) {
        let (p, secs) = res.unwrap();
        assert!(secs >= 0.0);
        let seq = pruning::prune(Method::Thanos, w, s, pattern, &opts()).unwrap();
        assert_eq!(bits(&p.w), bits(&seq.w), "prune_many vs prune weights");
        assert_eq!(p.mask, seq.mask, "prune_many vs prune masks");
    }
}

#[test]
fn quality_ordering_structured_thanos_best() {
    // The Table-2 structured ranking at layer level: mean reconstruction
    // loss over seeds — Thanos(joint) <= SparseGPT(one-shot+rightward)
    // <= Wanda(no update). Averaged, not per-case (noise).
    let mut l_th = 0.0;
    let mut l_sg = 0.0;
    let mut l_wa = 0.0;
    let n = 8;
    for seed in 0..n {
        let mut r = Rng::new(0xB000 + seed);
        let (w, stats, x, _) = gen_layer(&mut r);
        let th = pruning::prune(
            Method::Thanos,
            &w,
            &stats,
            Pattern::Structured { p: 0.3, alpha: 0.0 },
            &opts(),
        )
        .unwrap();
        let sg = pruning::prune(
            Method::SparseGpt,
            &w,
            &stats,
            Pattern::Structured { p: 0.3, alpha: 0.0 },
            &opts(),
        )
        .unwrap();
        let wa = pruning::prune(
            Method::Wanda,
            &w,
            &stats,
            Pattern::Structured { p: 0.3, alpha: 0.0 },
            &opts(),
        )
        .unwrap();
        l_th += recon_loss(&th.w, &w, &x);
        l_sg += recon_loss(&sg.w, &w, &x);
        l_wa += recon_loss(&wa.w, &w, &x);
    }
    assert!(l_th < l_sg, "thanos {l_th} !< sparsegpt {l_sg}");
    assert!(l_sg < l_wa, "sparsegpt {l_sg} !< wanda {l_wa}");
}

#[test]
fn quality_ordering_unstructured_update_methods_beat_metric_methods() {
    let mut l_th = 0.0;
    let mut l_sg = 0.0;
    let mut l_wa = 0.0;
    let mut l_mg = 0.0;
    for seed in 0..8 {
        let mut r = Rng::new(0xC000 + seed);
        let (w, stats, x, _) = gen_layer(&mut r);
        let run = |m: Method| {
            let p = pruning::prune(m, &w, &stats, Pattern::Unstructured { p: 0.5 }, &opts())
                .unwrap();
            recon_loss(&p.w, &w, &x)
        };
        l_th += run(Method::Thanos);
        l_sg += run(Method::SparseGpt);
        l_wa += run(Method::Wanda);
        l_mg += run(Method::Magnitude);
    }
    assert!(l_th < l_wa && l_sg < l_wa, "updates must beat wanda");
    assert!(l_wa < l_mg, "wanda must beat magnitude");
}

#[test]
fn alpha_outlier_rows_monotone_benefit_structured() {
    // with heavy-tailed rows, protecting outliers (α=0.1) should reduce
    // loss vs α=0 at matched total sparsity, on average (the Table 2
    // α-ablation)
    let mut l_a0 = 0.0;
    let mut l_a1 = 0.0;
    for seed in 0..8 {
        let mut r = Rng::new(0xD000 + seed);
        let c = 20;
        let b = 24;
        let w = {
            let mut w = mat_heavy(&mut r, c, b, 0.01);
            // make two rows strong outliers
            for j in 0..b {
                *w.at_mut(3, j) *= 8.0;
                *w.at_mut(11, j) *= 8.0;
            }
            w
        };
        let x = mat_heavy(&mut r, b, 96, 0.03);
        let stats = CalibStats::from_x(&x);
        let a0 = pruning::prune(
            Method::Thanos,
            &w,
            &stats,
            Pattern::Structured { p: 0.3, alpha: 0.0 },
            &opts(),
        )
        .unwrap();
        let a1 = pruning::prune(
            Method::Thanos,
            &w,
            &stats,
            Pattern::Structured { p: 0.3, alpha: 0.1 },
            &opts(),
        )
        .unwrap();
        l_a0 += recon_loss(&a0.w, &w, &x);
        l_a1 += recon_loss(&a1.w, &w, &x);
    }
    assert!(l_a1 < l_a0, "alpha=0.1 {l_a1} !< alpha=0 {l_a0}");
}
