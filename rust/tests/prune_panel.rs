//! §Perf-L4 Λ-panel property tests: the panel block-update path
//! (padded batched solves + mixed-precision packed GEMM apply) pinned
//! against the per-row reference path across variants × block sizes ×
//! thread counts, plus the bitwise guarantees the design rests on
//! (padding-independent solves, naive-mode reference restoration).
//!
//! Some tests toggle the PROCESS-GLOBAL `set_naive_mode` switch, and
//! every comparison here assumes the mode is stable for the whole test
//! body — so all tests in this binary serialize on one mutex. (Other
//! test binaries are separate processes and never toggle the switch.)

use std::sync::Mutex;
use thanos::linalg::batched::{
    solve_band_padded_into_panel, solve_row_in_scratch, PanelSolveScratch, RowSolveScratch,
};
use thanos::linalg::chol::{chol_inverse, damp_hessian};
use thanos::linalg::gemm::{matmul, xxt_f64};
use thanos::linalg::kernel;
use thanos::linalg::Mat;
use thanos::pruning::{self, CalibStats, Method, Pattern, PruneOpts};
use thanos::rng::Rng;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Restores the packed mode even if the test body panics. (Tests run
/// with the env switch unset, so "packed" is the correct restore.)
struct NaiveGuard;
impl Drop for NaiveGuard {
    fn drop(&mut self) {
        kernel::set_naive_mode(false);
    }
}

fn setup(c: usize, b: usize, a: usize, seed: u64) -> (Mat, CalibStats, Mat) {
    let mut r = Rng::new(seed);
    let w = Mat::from_fn(c, b, |_, _| {
        let v = r.normal_f32(0.0, 1.0);
        if v == 0.0 {
            1e-3
        } else {
            v
        }
    });
    let k = (b / 4).max(2);
    let factors = Mat::from_fn(k, a, |_, _| r.normal_f32(0.0, 1.0));
    let loading = Mat::from_fn(b, k, |_, _| r.normal_f32(0.0, 1.0));
    let mut x = matmul(&loading, &factors);
    for v in x.data.iter_mut() {
        *v += r.normal_f32(0.0, 0.3);
    }
    let stats = CalibStats::from_x(&x);
    (w, stats, x)
}

fn popts(bsize: usize, panel: bool) -> PruneOpts {
    PruneOpts { block_size: bsize, panel_apply: panel, ..Default::default() }
}

fn patterns() -> [Pattern; 3] {
    [
        Pattern::Unstructured { p: 0.5 },
        Pattern::SemiStructured { n: 2, m: 4, alpha: 0.1 },
        Pattern::Structured { p: 0.3, alpha: 0.1 },
    ]
}

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

fn scale(m: &Mat) -> f32 {
    m.data.iter().fold(1.0f32, |s, &v| s.max(v.abs()))
}

#[test]
fn panel_matches_per_row_all_variants_and_block_sizes() {
    let _g = lock();
    let (w, stats, _x) = setup(20, 32, 96, 0x51);
    for &bsize in &[4usize, 8, 16, 32] {
        for pattern in patterns() {
            for method in [Method::Thanos, Method::SparseGpt] {
                let panel =
                    pruning::prune(method, &w, &stats, pattern, &popts(bsize, true)).unwrap();
                let perrow =
                    pruning::prune(method, &w, &stats, pattern, &popts(bsize, false)).unwrap();
                assert_eq!(
                    panel.mask,
                    perrow.mask,
                    "{} {pattern:?} B={bsize}: masks must be bitwise identical",
                    method.name()
                );
                let rel = panel.w.max_abs_diff(&perrow.w) / scale(&perrow.w);
                assert!(
                    rel <= 1e-5,
                    "{} {pattern:?} B={bsize}: panel vs per-row rel diff {rel}",
                    method.name()
                );
            }
        }
    }
}

#[test]
fn panel_path_serial_parallel_bit_identical() {
    // the Λ-panel path must keep the crate's serial==parallel contract:
    // band decomposition (and the band-local r_max padding it implies)
    // never changes a single bit
    let _g = lock();
    let (w, stats, _x) = setup(18, 24, 72, 0x52);
    for pattern in patterns() {
        for method in [Method::Thanos, Method::SparseGpt] {
            let par = pruning::prune(method, &w, &stats, pattern, &popts(8, true)).unwrap();
            let ser = thanos::engine::with_serial(|| {
                pruning::prune(method, &w, &stats, pattern, &popts(8, true)).unwrap()
            });
            assert_eq!(bits(&par.w), bits(&ser.w), "{} {pattern:?} weights", method.name());
            assert_eq!(par.mask, ser.mask, "{} {pattern:?} masks", method.name());
        }
    }
}

#[test]
fn naive_mode_overrides_panel_flag_bitwise() {
    // THANOS_LINALG_NAIVE=1 (here: set_naive_mode) must restore the
    // reference path exactly: with it on, the panel flag is inert and
    // both settings produce bit-identical outputs — i.e. the seed
    // arithmetic is fully preserved behind the switch.
    let _g = lock();
    let _restore = NaiveGuard;
    let (w, stats, _x) = setup(14, 24, 64, 0x53);
    kernel::set_naive_mode(true);
    for pattern in patterns() {
        for method in [Method::Thanos, Method::SparseGpt] {
            let a = pruning::prune(method, &w, &stats, pattern, &popts(8, true)).unwrap();
            let b = pruning::prune(method, &w, &stats, pattern, &popts(8, false)).unwrap();
            assert_eq!(
                bits(&a.w),
                bits(&b.w),
                "{} {pattern:?}: naive mode must make panel_apply inert",
                method.name()
            );
            assert_eq!(a.mask, b.mask, "{} {pattern:?} masks", method.name());
        }
    }
}

#[test]
fn padded_band_solver_bit_identical_to_per_row() {
    // the §H.1 bitwise claim at integration scale: band-level padding
    // (r_max up to 120, crossing the blocked-Cholesky panel width
    // NB = 96) must not change a single bit of any row's multipliers
    let _g = lock();
    let width = 128usize;
    let mut r = Rng::new(0x54);
    let x = Mat::from_fn(width, width + 9, |_, _| r.normal_f32(0.0, 1.0));
    let mut h = xxt_f64(&x);
    for v in h.data.iter_mut() {
        *v *= 2.0;
    }
    damp_hessian(&mut h, 0.01);
    let hinv = chol_inverse(&h).unwrap();

    // supports of very different sizes, incl. one pushing r_max > NB
    let mut qs: Vec<Vec<usize>> = vec![
        (0..120).collect(), // r_max = 120 > NB
        vec![3],
        vec![],
        (0..width).step_by(3).collect(),
        vec![7, 19, 64, 100, 127],
    ];
    qs.push((0..40).map(|k| k * 3).collect());
    let mut us: Vec<Vec<f64>> = Vec::new();
    for q in &qs {
        us.push(q.iter().map(|_| r.normal()).collect());
    }

    let mut ps = PanelSolveScratch::new();
    ps.begin(qs.len(), width);
    for (q, u) in qs.iter().zip(&us) {
        for (&k, &v) in q.iter().zip(u) {
            ps.push(k, v);
        }
        ps.end_row();
    }
    solve_band_padded_into_panel(&hinv, &mut ps).unwrap();

    for (ri, (q, u)) in qs.iter().zip(&us).enumerate() {
        let mut s = RowSolveScratch::new();
        s.q.extend_from_slice(q);
        s.u.extend_from_slice(u);
        solve_row_in_scratch(&hinv, &mut s).unwrap();
        let lrow = &ps.lam[ri * width..(ri + 1) * width];
        let mut expect = vec![0.0f64; width];
        for (t, &qt) in q.iter().enumerate() {
            expect[qt] = s.lam[t];
        }
        for (k, (&got, &want)) in lrow.iter().zip(&expect).enumerate() {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "row {ri} slot {k}: padded {got} vs exact {want}"
            );
        }
    }
}

#[test]
fn panel_block_sizes_cross_agree_on_quality() {
    // sanity: the panel path's outputs remain real prunes — exact
    // sparsity for unstructured, and the update must beat mask-only
    // zeroing (the OBS optimality invariant) at every block size
    let _g = lock();
    let (w, stats, x) = setup(16, 32, 80, 0x55);
    for &bsize in &[8usize, 16] {
        let p = pruning::prune(
            Method::Thanos,
            &w,
            &stats,
            Pattern::Unstructured { p: 0.5 },
            &popts(bsize, true),
        )
        .unwrap();
        let zeros = p.w.data.iter().filter(|&&v| v == 0.0).count();
        assert_eq!(zeros, 16 * 32 / 2, "B={bsize}");
        let mut mask_only = w.clone();
        for (k, &m) in p.mask.iter().enumerate() {
            if m {
                mask_only.data[k] = 0.0;
            }
        }
        let lu = thanos::linalg::gemm::recon_loss(&p.w, &w, &x);
        let lm = thanos::linalg::gemm::recon_loss(&mask_only, &w, &x);
        assert!(lu <= lm * 1.0001 + 1e-9, "B={bsize}: update {lu} vs mask-only {lm}");
    }
}
